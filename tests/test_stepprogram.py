"""StepProgram (DESIGN.md §9): the full training step as CommSchedule IR.

Three layers of checks:
  - pure-IR transform properties (microseconds, no devices): every
    registered strategy's plan rewrites into valid per-bucket
    RS→UPDATE→AG triples with the NORM clip op gating updates;
  - simulator semantics: UPDATE/NORM ops are costed, bucket k's update
    overlaps bucket k+1's reduce-scatter, zero1-scheduled plans beat the
    flat allreduce + monolithic-update baseline, and ``auto`` ranks the
    rewritten step programs;
  - executable parity on the smoke mesh (dp=1): scheduled per-bucket
    zero1 ≡ monolithic zero1 ≡ flat allreduce+update bit-for-bit, and
    the scheduled NORM clip ≡ ``clip_by_global_norm``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sim  # noqa: F401  (registers the "auto" strategy)
from repro.core.buckets import Bucket, BucketPlan, LeafInfo
from repro.core.registry import fixed_strategy_names, get_strategy
from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    NORM,
    REDUCE_SCATTER,
    UPDATE,
)
from repro.core.stepprogram import (
    build_step_program,
    zero1_bucket_plan,
    zero1_schedule,
)
from repro.sim import (
    ComputeModel,
    UpdateModel,
    flat_step_schedule,
    last_auto_report,
    rank_step_plans,
    simulate,
)

MESH = {"data": 8, "model": 1}
COMPUTE = ComputeModel(t_fwd=1e-4, t_bwd=2e-4, n_stages=8)


def _plan(n_buckets=8, num_channels=4, elems=1 << 20):
    buckets = []
    for bid in range(n_buckets):
        leaves = (LeafInfo(name=f"g{bid}", index=bid, shape=(elems,),
                           dtype=jnp.float32, size=elems),)
        buckets.append(Bucket(leaves=leaves, reduce_axes=("data",),
                              channel=bid % num_channels, bucket_id=bid,
                              comm_dtype=jnp.float32))
    return BucketPlan(buckets=tuple(buckets), treedef=None,
                      num_leaves=n_buckets, comm_dtype=jnp.float32)


# ------------------------------------------------------------- IR shape

def test_transform_every_strategy_makes_rs_update_ag_triples():
    plan = _plan()
    for name in fixed_strategy_names():
        base = get_strategy(name).plan(plan)
        zs = zero1_schedule(base, dp_axes=("data",))
        assert zs.validate() is zs
        kinds = zs.stats()["kinds"]
        n = len(plan.buckets)
        assert kinds == {REDUCE_SCATTER: n, UPDATE: n, ALL_GATHER: n}, name
        by_id = {op.op_id: op for op in zs.ops}
        for op in zs.ops:
            if op.kind == UPDATE:
                srcs = [d for d in op.depends_on
                        if by_id[d].kind == REDUCE_SCATTER
                        and by_id[d].bucket.bucket_id == op.bucket.bucket_id]
                assert len(srcs) == 1, name
            if op.kind == ALL_GATHER:
                (d,) = op.depends_on
                assert by_id[d].kind == UPDATE, name
                assert by_id[d].bucket.bucket_id == op.bucket.bucket_id
        # wire bytes unchanged: the RS/AG pair moves what the allreduce
        # moved (UPDATE/NORM move nothing)
        assert zs.comm_bytes(4) == base.comm_bytes(4), name


def test_transform_clip_adds_one_norm_gating_every_update():
    plan = _plan()
    zs = zero1_schedule(get_strategy("concom").plan(plan),
                        dp_axes=("data",), clip=True)
    norms = [op for op in zs.ops if op.kind == NORM]
    assert len(norms) == 1
    norm = norms[0]
    rs_ids = {op.op_id for op in zs.ops if op.kind == REDUCE_SCATTER}
    assert set(norm.depends_on) == rs_ids       # norm waits on ALL shards
    assert norm.bucket.leaves == ()             # synthetic scalar bucket
    for op in zs.ops:
        if op.kind == UPDATE:
            assert norm.op_id in op.depends_on  # clip-on-shards gate


def test_transform_preserves_strategy_chain_structure():
    plan = _plan(n_buckets=8, num_channels=4)
    for name, want_chains in (("funnel", 1), ("concom", 4)):
        base = get_strategy(name).plan(plan)
        zs = zero1_schedule(base, dp_axes=("data",))
        rs = [op for op in zs.ops if op.kind == REDUCE_SCATTER]
        assert len({op.chain for op in rs}) == want_chains, name
        # chain-ordering edges live on the RS ops only: AGs and UPDATEs
        # free-fly behind their data deps (the overlap the paper's
        # dependency chains buy, extended through the update)
        by_id = {op.op_id: op for op in zs.ops}
        for op in rs:
            for d in op.depends_on:
                assert by_id[d].kind == REDUCE_SCATTER, name


def test_build_step_program_splices_sync_deps():
    # sync schedule: one allreduce over bucket "a"; dp plan shares leaf
    sync_leaf = LeafInfo(name="a", index=0, shape=(16,),
                         dtype=jnp.float32, size=16)
    sync_bucket = Bucket(leaves=(sync_leaf,), reduce_axes=("model",),
                         channel=0, bucket_id=0)
    from repro.core.schedule import CollectiveOp, CommSchedule

    sync = CommSchedule((CollectiveOp(op_id=0, bucket=sync_bucket,
                                      chain=0, kind=ALLREDUCE),))
    sync_plan = BucketPlan(buckets=(sync_bucket,), treedef=None,
                           num_leaves=2, comm_dtype=jnp.float32)
    dp_buckets = tuple(
        Bucket(leaves=(LeafInfo(name=n, index=i, shape=(16,),
                                dtype=jnp.float32, size=16),),
               reduce_axes=("data",), channel=0, bucket_id=1 + i,
               comm_dtype=jnp.float32)
        for i, n in enumerate(("a", "b")))
    dp_plan = BucketPlan(buckets=dp_buckets, treedef=None,
                         num_leaves=2, comm_dtype=jnp.float32)
    base = get_strategy("concom").plan(dp_plan)
    prog = build_step_program(sync, sync_plan, base, dp_plan,
                              dp_axes=("data",), dp_size=8)
    assert prog.num_sync_ops == 1
    assert prog.schedule.validate() is prog.schedule
    rs = {op.bucket.leaves[0].name: op for op in prog.schedule.ops
          if op.kind == REDUCE_SCATTER}
    assert 0 in rs["a"].depends_on      # dp RS of "a" waits on its sync
    assert 0 not in rs["b"].depends_on  # "b" had no sync op


# ---------------------------------------------------------------- sim

def test_sim_costs_update_and_norm_ops():
    plan = _plan()
    zs = zero1_schedule(get_strategy("concom").plan(plan),
                        dp_axes=("data",), clip=True)
    tl = simulate(zs, MESH, compute=COMPUTE)
    assert len(tl.events) == len(zs.ops)
    upd = [e for e in tl.events if e.kind == UPDATE]
    assert len(upd) == len(plan.buckets)
    assert all(e.duration > 0 for e in upd)
    # shard update time matches the compute model (f32 shard = size/8)
    want = COMPUTE.update.update_time(plan.buckets[0].size * 4 / 8)
    assert upd[0].duration == pytest.approx(want)
    (nrm,) = [e for e in tl.events if e.kind == NORM]
    assert nrm.duration > 0
    # NORM starts only after every RS finished
    rs_end = max(e.end for e in tl.events if e.kind == REDUCE_SCATTER)
    assert nrm.start >= rs_end - 1e-15


def test_sim_update_overlaps_next_reduce_scatter():
    plan = _plan(n_buckets=8, num_channels=1)
    zs = zero1_schedule(get_strategy("concom").plan(plan),
                        dp_axes=("data",))
    tl = simulate(zs, MESH, compute=COMPUTE)
    upd = [e for e in tl.events if e.kind == UPDATE]
    rs = [e for e in tl.events if e.kind == REDUCE_SCATTER]
    # bucket k's shard update runs while a LATER bucket reduce-scatters
    assert any(u.start < r.end and r.start < u.end
               for u in upd for r in rs if r.bucket_id > u.bucket_id)


def test_zero1_scheduled_beats_flat_monolithic_baseline():
    plan = _plan(n_buckets=12, num_channels=4)
    ranked = rank_step_plans(plan, MESH, dp_axes=("data",),
                             compute=COMPUTE)
    names = [n for n, _ in ranked]
    assert {n.split(":")[0] for n in names} == {"deferred", "zero1", "flat"}
    assert {n.split(":")[1] for n in names} == set(fixed_strategy_names())
    by = dict(ranked)
    for s in fixed_strategy_names():
        assert by[f"zero1:{s}"].step_time <= by[f"flat:{s}"].step_time, s


def test_flat_step_schedule_has_one_terminal_update():
    plan = _plan()
    fs = flat_step_schedule(plan, "concom")
    upd = fs.update_ops()
    assert len(upd) == 1
    assert upd[0].bucket.reduce_axes == ()      # full-buffer update
    assert len(upd[0].bucket.leaves) == len(plan.buckets)
    tl = simulate(fs, MESH, compute=COMPUTE)
    # the monolithic update is the LAST thing that happens
    assert max(tl.events, key=lambda e: e.end).kind == UPDATE


def test_update_model_prices_sharding():
    um = UpdateModel()
    full = um.update_time(64 << 20)
    shard = um.update_time((64 << 20) / 8)
    assert 0.0 < shard < full
    assert full == pytest.approx(um.passes * (64 << 20) / um.hbm_bw
                                 + um.overhead)


def test_auto_ranks_zero1_step_programs():
    plan = _plan()
    info = get_strategy("auto")
    schedule = info.plan(plan, context={
        "mesh_shape": MESH, "compute": COMPUTE,
        "zero1": {"dp_axes": ("data",), "dp_size": 8, "clip": False}})
    report = last_auto_report()
    assert report["zero1"] is True
    assert report["winner"] in fixed_strategy_names()
    assert report["plan"] in ("deferred", "zero1", "flat")
    # the ranking covers all three step-plan families × every strategy
    labels = {n for n, _ in report["ranking"]}
    assert labels == {f"{fam}:{s}" for fam in ("deferred", "zero1", "flat")
                      for s in fixed_strategy_names()}
    # auto returns the winner's BASE plan (GradSync applies the rewrite)
    assert schedule == get_strategy(report["winner"]).plan(plan)


# ------------------------------------------------- executable parity

@pytest.fixture(scope="module")
def step_setup(smoke_mesh):
    from repro.data import TokenPipeline
    from repro.models import transformer as tf

    cfg = tf.TransformerConfig(
        name="stepprog", n_layers=2, d_model=32, n_heads=4, kv_heads=2,
        d_ff=64, vocab=64, tp=1, attn_chunk=16, dtype=jnp.float32)
    pipe = TokenPipeline(64, 16, 4, seed=7, mesh=smoke_mesh)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, pipe.batch_at(0), params


def _one_step(cfg, batch, params, mesh, *, mode, clip_norm=0.0,
              strategy="concom", reducer="flat", loss_scale=1.0):
    from repro.core import GradSyncConfig
    from repro.optim import adamw, zero1
    from repro.runtime import make_train_step

    if mode == "flat":
        opt = adamw(1e-3)
        sync = GradSyncConfig(strategy=strategy, reducer=reducer,
                              bucket_bytes=1 << 14,
                              loss_scale=loss_scale)
        ts = make_train_step(cfg, mesh, sync, opt, batch_like=batch,
                             params_like=params, clip_norm=clip_norm)
    else:
        opt = zero1(adamw(1e-3), ("data",), 1)
        sync = GradSyncConfig(strategy=strategy, reducer=reducer,
                              bucket_bytes=1 << 14,
                              exclude_axes=("data",),
                              loss_scale=loss_scale)
        ts = make_train_step(cfg, mesh, sync, opt, batch_like=batch,
                             params_like=params, zero1_mode=True,
                             zero1_plan=mode, clip_norm=clip_norm)
    p, _, m = ts.fn(params, ts.init_opt(), batch, jnp.int32(0))
    return ts, p, m


def _max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_gradsync_schedule_carries_zero1_collectives(step_setup,
                                                     smoke_mesh):
    cfg, batch, params = step_setup
    ts, _, _ = _one_step(cfg, batch, params, smoke_mesh,
                         mode="scheduled", clip_norm=0.5)
    kinds = ts.gradsync.schedule.stats()["kinds"]
    assert kinds.get(UPDATE, 0) > 1          # per-bucket, not monolithic
    assert kinds.get(REDUCE_SCATTER) == kinds.get(UPDATE) \
        == kinds.get(ALL_GATHER)
    assert kinds.get(NORM) == 1
    assert ts.gradsync.program is not None
    assert ts.gradsync.program.schedule is ts.gradsync.schedule
    assert len(ts.gradsync.schedule.update_ops()) == kinds[UPDATE]


def test_scheduled_matches_monolithic_and_flat_bit_exact(step_setup,
                                                         smoke_mesh):
    cfg, batch, params = step_setup
    _, p_s, m_s = _one_step(cfg, batch, params, smoke_mesh,
                            mode="scheduled")
    _, p_m, m_m = _one_step(cfg, batch, params, smoke_mesh,
                            mode="monolithic")
    _, p_f, m_f = _one_step(cfg, batch, params, smoke_mesh, mode="flat")
    assert float(m_s["loss"]) == float(m_m["loss"]) == float(m_f["loss"])
    assert _max_diff(p_s, p_m) == 0.0
    assert _max_diff(p_s, p_f) == 0.0        # dp=1: RS/AG are identities


def test_scheduled_clip_matches_clip_by_global_norm(step_setup,
                                                    smoke_mesh):
    cfg, batch, params = step_setup
    clip = 0.05                              # small enough to bind
    _, p_s, m_s = _one_step(cfg, batch, params, smoke_mesh,
                            mode="scheduled", clip_norm=clip)
    _, p_f, m_f = _one_step(cfg, batch, params, smoke_mesh,
                            mode="flat", clip_norm=clip)
    assert float(m_s["grad_norm"]) > clip    # the clip actually engaged
    assert float(m_s["grad_norm"]) == pytest.approx(
        float(m_f["grad_norm"]), rel=1e-6)
    assert _max_diff(p_s, p_f) < 1e-6


def test_scheduled_clip_unaffected_by_loss_scale(step_setup, smoke_mesh):
    """The NORM op sees loss-scaled, pre-mean RS shards — it must undo
    both so the norm (and the clip threshold) match the true grads."""
    cfg, batch, params = step_setup
    clip = 0.05
    _, p_s, m_s = _one_step(cfg, batch, params, smoke_mesh,
                            mode="scheduled", clip_norm=clip,
                            loss_scale=1024.0)
    _, p_f, m_f = _one_step(cfg, batch, params, smoke_mesh,
                            mode="flat", clip_norm=clip,
                            loss_scale=1024.0)
    assert float(m_s["grad_norm"]) == pytest.approx(
        float(m_f["grad_norm"]), rel=1e-5)
    assert _max_diff(p_s, p_f) < 1e-6


def test_scheduled_every_strategy_same_params(step_setup, smoke_mesh):
    """The StepProgram is schedule-only: every strategy (auto included)
    trains to identical params."""
    from repro.core import strategy_names

    cfg, batch, params = step_setup
    outs = {}
    for strat in strategy_names():
        _, p, _ = _one_step(cfg, batch, params, smoke_mesh,
                            mode="scheduled", strategy=strat)
        outs[strat] = p
    ref = outs.pop("concom")
    for strat, p in outs.items():
        assert _max_diff(ref, p) == 0.0, strat


def test_zero1_bucket_plan_covers_all_leaves(smoke_mesh):
    from jax.sharding import PartitionSpec as P

    grads = {"w": jnp.ones((64, 8)), "b": jnp.ones((8,))}
    specs = jax.tree.map(lambda _: P(), grads)
    sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    plan = zero1_bucket_plan(sds, specs, smoke_mesh, dp_axes=("data",),
                             bucket_bytes=256, id_offset=5)
    covered = {l.index for b in plan.buckets for l in b.leaves}
    assert covered == {0, 1}
    assert min(b.bucket_id for b in plan.buckets) >= 5
    assert all(b.comm_dtype == jnp.float32 for b in plan.buckets)
    assert all(b.reduce_axes == ("data",) for b in plan.buckets)
    # params already sharded over dp (FSDP-style) must be rejected
    with pytest.raises(ValueError, match="replicated over the dp axes"):
        zero1_bucket_plan(sds, jax.tree.map(lambda _: P("data"), grads),
                          smoke_mesh, dp_axes=("data",))
