"""Property-based tests (hypothesis) for checkpoint resharding: random
leaf shapes × random grow/shrink meshes — divisible layouts always
validate, indivisible ones always fail loudly with the offending leaf
named.  Skips cleanly when hypothesis is absent (requirements-dev)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (requirements-dev.txt)")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.checkpoint.reshard import validate_divisibility  # noqa: E402

hypothesis.settings.register_profile(
    "repro", max_examples=60,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("repro")


class FakeMesh:
    """Duck-typed stand-in: ``validate_divisibility`` reads only
    ``mesh.shape`` (an axis-name → size mapping), so grow/shrink meshes
    far beyond the host's device count stay testable."""

    def __init__(self, shape):
        self.shape = dict(shape)


def _leaf(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


# axis sizes cover shrink (1) through grow (8): a transition in either
# direction validates against the TARGET mesh only
mesh_sizes = st.fixed_dictionaries(
    {"data": st.sampled_from([1, 2, 4, 8]),
     "model": st.sampled_from([1, 2, 4, 8])})


@given(mesh=mesh_sizes,
       rows=st.integers(1, 8), cols=st.integers(1, 8))
def test_divisible_layouts_always_validate(mesh, rows, cols):
    m = FakeMesh(mesh)
    tree = {"w": _leaf((rows * mesh["data"], cols * mesh["model"])),
            "b": _leaf((cols * mesh["model"],))}
    specs = {"w": P("data", "model"), "b": P("model")}
    validate_divisibility(tree, specs, m)    # must not raise


@given(mesh=mesh_sizes, rows=st.integers(1, 8))
def test_indivisible_leaf_fails_loudly(mesh, rows):
    hypothesis.assume(mesh["model"] > 1)
    # dim 1 is off by one element: never divisible when model > 1
    off = rows * mesh["model"] + 1
    tree = {"ok": _leaf((4 * mesh["data"],)), "bad": _leaf((2, off))}
    specs = {"ok": P("data"), "bad": P(None, "model")}
    with pytest.raises(ValueError) as e:
        validate_divisibility(tree, specs, FakeMesh(mesh))
    assert "bad" in str(e.value) and "not divisible" in str(e.value)


@given(mesh=mesh_sizes, k=st.integers(1, 6))
def test_tuple_axis_specs_use_product(mesh, k):
    # a dim sharded over BOTH axes must divide by the product...
    prod = mesh["data"] * mesh["model"]
    tree = {"w": _leaf((k * prod, 3))}
    specs = {"w": P(("data", "model"), None)}
    validate_divisibility(tree, specs, FakeMesh(mesh))
    # ...and an off-by-one size must fail whenever the product > 1
    if prod > 1:
        bad = {"w": _leaf((k * prod + 1, 3))}
        with pytest.raises(ValueError, match="not divisible"):
            validate_divisibility(bad, specs, FakeMesh(mesh))


@given(old=mesh_sizes, new=mesh_sizes, k=st.integers(1, 4))
def test_grow_shrink_roundtrip_validates_against_target(old, new, k):
    # the global view is mesh-independent: a tree built divisible for
    # BOTH meshes validates on both (the supervisor's ladder contract)
    lcm_d = old["data"] * new["data"]
    lcm_m = old["model"] * new["model"]
    tree = {"w": _leaf((k * lcm_d, lcm_m))}
    specs = {"w": P("data", "model")}
    validate_divisibility(tree, specs, FakeMesh(old))
    validate_divisibility(tree, specs, FakeMesh(new))


@given(mesh=mesh_sizes)
def test_plan_reshard_divisibility_shares_the_rule(mesh):
    """The elastic transition IR's static divisibility facts
    (leaf_divisibility) agree with validate_divisibility: same
    dim-size-vs-axis-product rule, checked by the reshard pass."""
    from repro.analysis import ScheduleError, verify_schedule
    from repro.analysis.mutations import (
        NEW_MESH_RS,
        OLD_MESH_RS,
        synthetic_reshard_schedule,
    )

    s = synthetic_reshard_schedule()
    n = mesh["data"] * mesh["model"]
    facts = {"w@dim0": (8 * n, n)}
    verify_schedule(s, old_mesh_shape=OLD_MESH_RS,
                    new_mesh_shape=NEW_MESH_RS, leaf_divisibility=facts)
    if n > 1:
        with pytest.raises(ScheduleError, match="leaf-indivisible"):
            verify_schedule(s, old_mesh_shape=OLD_MESH_RS,
                            new_mesh_shape=NEW_MESH_RS,
                            leaf_divisibility={"w@dim0": (8 * n + 1, n)})
