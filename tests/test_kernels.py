"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.ops import dequantize_blocks, quantize_blocks
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref
from repro.kernels.rwkv6.ops import wkv_chunk
from repro.kernels.rwkv6.ref import wkv_ref


@pytest.mark.parametrize("B,S,Hq,Hkv,D,dtype,causal", [
    (2, 128, 4, 2, 64, jnp.float32, True),
    (1, 256, 2, 2, 128, jnp.float32, False),
    (2, 128, 4, 1, 64, jnp.bfloat16, True),
    (1, 512, 8, 4, 64, jnp.float32, True),
    (2, 128, 2, 2, 256, jnp.bfloat16, False),
])
def test_flash_attention_vs_ref(B, S, Hq, Hkv, D, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    rep = Hq // Hkv
    kr = jnp.repeat(k, rep, 2).transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    vr = jnp.repeat(v, rep, 2).transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    ref = attention_ref(qr, kr, vr, causal=causal)
    ref = ref.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("n_blocks", [64, 128, 1024])
@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e3])
def test_quantize_vs_ref(n_blocks, scale):
    x = jax.random.normal(
        jax.random.PRNGKey(1), (n_blocks * 256,), jnp.float32) * scale
    q, s = quantize_blocks(x, interpret=True)
    qr, sr = quantize_ref(x.reshape(-1, 256))
    np.testing.assert_array_equal(np.asarray(q).reshape(-1, 256),
                                  np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = dequantize_blocks(q, s, interpret=True)
    ref = dequantize_ref(qr, sr).reshape(-1)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(ref), rtol=1e-6)
    # quantization error bound: |x - deq| <= scale/2 per block
    err = np.abs(np.asarray(x) - np.asarray(xd)).reshape(-1, 256)
    bound = np.asarray(s)[:, None] * 0.5 + 1e-7
    assert np.all(err <= bound)


@pytest.mark.parametrize("B,C,H,N,dtype", [
    (2, 32, 4, 64, jnp.float32),
    (1, 64, 2, 64, jnp.float32),
    (2, 16, 8, 64, jnp.bfloat16),
])
def test_wkv_chunk_vs_sequential_ref(B, C, H, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (B, C, H, N), dtype)
    k = jax.random.normal(ks[1], (B, C, H, N), dtype)
    v = jax.random.normal(ks[2], (B, C, H, N), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, C, H, N)) * 0.5 - 2.0
                    ).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, N)) * 0.1).astype(jnp.float32)
    st = (jax.random.normal(ks[5], (B, H, N, N)) * 0.1).astype(jnp.float32)
    y, s1 = wkv_chunk(r, k, v, logw, u, st, interpret=True)

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, C, N)
    u_b = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)
    yr, sr = wkv_ref(flat(r), flat(k), flat(v), flat(logw), u_b,
                     st.reshape(B * H, N, N))
    yr = yr.reshape(B, H, C, N).transpose(0, 2, 1, 3)
    sr = sr.reshape(B, H, N, N)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(sr),
                               atol=tol, rtol=tol)


def test_model_wkv_matches_kernel():
    """The model's chunked-parallel WKV == the Pallas kernel's math (one
    chunk), tying model and kernel implementations together."""
    from repro.models.rwkv import wkv_chunked

    B, C, H, N = 2, 32, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r = jax.random.normal(ks[0], (B, C, H, N), jnp.float32)
    k = jax.random.normal(ks[1], (B, C, H, N), jnp.float32)
    v = jax.random.normal(ks[2], (B, C, H, N), jnp.float32)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, C, H, N)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    st = jax.random.normal(ks[5], (B, H, N, N)) * 0.1

    y_model, s_model = wkv_chunked(r, k, v, logw, u, st, chunk=C)
    y_kern, s_kern = wkv_chunk(r, k, v, logw, u, st, interpret=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kern),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(s_model), np.asarray(s_kern),
                               atol=5e-4, rtol=5e-4)
