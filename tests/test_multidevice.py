"""Multi-device (8 fake CPU devices) correctness, run in a subprocess —
jax fixes the device count at first init, so the main pytest process
(1 device) can't host these."""
import os
import subprocess
import sys

import pytest


@pytest.fixture(scope="module")
def worker_output():
    script = os.path.join(os.path.dirname(__file__), "_mdworker.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    proc = subprocess.run(
        [sys.executable, script], env=env,
        capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_worker_completed(worker_output):
    assert "DONE" in worker_output


def test_all_multidevice_checks_pass(worker_output):
    fails = [l for l in worker_output.splitlines() if l.startswith("FAIL")]
    passes = [l for l in worker_output.splitlines() if l.startswith("PASS")]
    assert not fails, fails
    assert len(passes) >= 15, worker_output
