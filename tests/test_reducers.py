"""Reducer coverage: the compressed reducer's error-feedback round-trip
(residual carries quantization error into the next step; ~4× fewer wire
bytes) — pure-math checks on the wire format in ``repro.core.compression``.
The hierarchical ≡ flat equivalence over REAL process groups (the 3-stage
RS→AR→AG path on a pod mesh) runs on 8 fake devices in tests/_mdworker.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    BLOCK,
    dequantize_blockwise,
    error_feedback_step,
    quantize_blockwise,
)


def _rt(x):
    """The int8 wire round-trip (what the network sees)."""
    q, s = quantize_blockwise(x)
    return dequantize_blockwise(q, s)


def test_quantize_roundtrip_error_bounded_by_block_scale():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(8 * BLOCK), jnp.float32)
    err = np.abs(np.asarray(x - _rt(x))).reshape(-1, BLOCK)
    scales = np.max(np.abs(np.asarray(x).reshape(-1, BLOCK)), axis=1) / 127.0
    assert (err.max(axis=1) <= scales * 0.5 + 1e-7).all()


def test_compressed_wire_bytes_are_quarter_of_fp32():
    x = jnp.zeros((64 * BLOCK,), jnp.float32)
    q, s = quantize_blockwise(x)
    wire = q.size * q.dtype.itemsize + s.size * s.dtype.itemsize
    assert wire / x.nbytes == pytest.approx(0.25, rel=0.05)
    # the simulator's cost model assumes the same wire format
    from repro.sim.netmodel import _COMP_RATIO

    assert _COMP_RATIO == pytest.approx(wire / x.nbytes, rel=1e-6)


def test_error_feedback_residual_carries_to_next_step():
    rng = np.random.default_rng(1)
    g1 = jnp.asarray(rng.standard_normal(4 * BLOCK), jnp.float32)
    g2 = jnp.asarray(rng.standard_normal(4 * BLOCK), jnp.float32)

    s1, r1 = error_feedback_step(g1, jnp.zeros_like(g1), _rt)
    # step 1 sent the quantized gradient; the residual is EXACTLY the
    # quantization error it left behind
    np.testing.assert_allclose(np.asarray(s1), np.asarray(_rt(g1)),
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(g1 - _rt(g1)),
                               atol=1e-7)
    assert float(jnp.max(jnp.abs(r1))) > 0.0   # lossy ⇒ nonzero residual

    # step 2 syncs g2 + r1 (the carried residual), not g2 alone
    s2, r2 = error_feedback_step(g2, r1, _rt)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(_rt(g2 + r1)),
                               atol=1e-7)
    # telescoping: everything sent so far + the final residual recovers
    # the true gradient sum — no error is ever dropped, only deferred
    np.testing.assert_allclose(
        np.asarray(s1 + s2 + r2), np.asarray(g1 + g2), atol=1e-5)


def test_error_feedback_converges_on_constant_gradient():
    """Repeating the same gradient, the time-averaged synced value
    approaches the true gradient (the EF correctness intuition)."""
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal(2 * BLOCK) * 1e-3, jnp.float32)
    r = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    steps = 16
    for _ in range(steps):
        s, r = error_feedback_step(g, r, _rt)
        total = total + s
    avg_err = np.abs(np.asarray(total / steps - g))
    one_shot_err = np.abs(np.asarray(_rt(g) - g))
    assert avg_err.max() <= one_shot_err.max() + 1e-7
    assert avg_err.mean() < one_shot_err.mean()
