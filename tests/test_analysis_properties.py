"""Property-based tests (hypothesis) for the static analyzer: random
VALID schedules are accepted, random structure-breaking mutations are
rejected.  Skips cleanly when hypothesis is absent (requirements-dev)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (requirements-dev.txt)")

import dataclasses

import hypothesis.strategies as st
import jax.numpy as jnp
from hypothesis import given

from repro.analysis import run_passes
from repro.analysis.mutations import MESH, MUTATIONS, synthetic_plan
from repro.core.registry import get_strategy
from repro.core.schedule import CommSchedule
from repro.core.stepprogram import zero1_schedule

hypothesis.settings.register_profile(
    "fast", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("fast")

STRATEGIES = ("funnel", "concom", "depcha", "priority", "rsag")


@st.composite
def plans(draw):
    return synthetic_plan(
        n_buckets=draw(st.integers(1, 8)),
        num_channels=draw(st.integers(1, 4)),
        leaves_per_bucket=draw(st.integers(1, 3)),
        pin=jnp.float32)


@given(plans(), st.sampled_from(STRATEGIES))
def test_random_valid_plans_accepted(plan, strategy):
    s = get_strategy(strategy).plan(plan)
    report = run_passes(s, mesh_shape=MESH, plan_comm_dtype=jnp.float32,
                        expect_defer=False)
    assert report.ok, report.render()


@given(plans(), st.sampled_from(("concom", "rsag", "funnel")),
       st.booleans(), st.booleans())
def test_random_valid_zero1_programs_accepted(plan, strategy, defer, clip):
    base = get_strategy(strategy).plan(plan)
    s = zero1_schedule(base, dp_axes=("data",), clip=clip, defer_ag=defer)
    report = run_passes(s, mesh_shape=MESH, plan_comm_dtype=jnp.float32,
                        expect_defer=defer)
    assert report.ok, report.render()


@given(st.sampled_from(MUTATIONS))
def test_every_corpus_mutation_rejected(mutation):
    schedule, ctx = mutation.build()
    report = run_passes(schedule, **ctx)
    assert any(f.pass_name == mutation.owner and f.code == mutation.code
               for f in report.findings), report.error_classes


@given(plans(), st.sampled_from(STRATEGIES), st.data())
def test_random_dropped_dep_never_accepted_silently(plan, strategy, data):
    """Removing a dependency edge from a multi-op single-channel chain
    must trip the analyzer (serialization or data-order loss)."""
    s = get_strategy(strategy).plan(
        dataclasses.replace(
            plan,
            buckets=tuple(dataclasses.replace(b, channel=0)
                          for b in plan.buckets)))
    victims = [op for op in s.ops if op.depends_on]
    if not victims:
        return
    victim = data.draw(st.sampled_from(victims))
    mutated = CommSchedule(tuple(
        dataclasses.replace(op, depends_on=())
        if op.op_id == victim.op_id else op for op in s.ops))
    report = run_passes(mutated, mesh_shape=MESH,
                        plan_comm_dtype=jnp.float32, expect_defer=False)
    assert not report.ok
