"""Optimizer math + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    constant_lr,
    cosine_warmup,
    linear_scaling_rule,
    sgd,
)


def test_sgd_momentum_matches_manual():
    opt = sgd(0.1, momentum=0.9)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    st = opt.init(p)
    u1, st = opt.update(g, st, p, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(u1["w"]), [-0.05, 0.1])
    u2, st = opt.update(g, st, p, jnp.int32(1))
    # mom = 0.9*g + g = 1.9g → update = -0.19g
    np.testing.assert_allclose(np.asarray(u2["w"]), [-0.095, 0.19],
                               rtol=1e-6)


def test_adamw_first_step_is_lr_signed():
    opt = adamw(1e-2, weight_decay=0.0)
    p = {"w": jnp.array([1.0, -1.0])}
    g = {"w": jnp.array([0.3, -0.7])}
    st = opt.init(p)
    u, _ = opt.update(g, st, p, jnp.int32(0))
    # bias-corrected first step ≈ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(u["w"]), [-1e-2, 1e-2], rtol=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5


def test_apply_updates_preserves_dtype():
    p = {"w": jnp.ones((3,), jnp.bfloat16)}
    u = {"w": jnp.full((3,), 0.5, jnp.float32)}
    out = apply_updates(p, u)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32), 1.5)


def test_schedules():
    lr = cosine_warmup(1.0, warmup=10, total=110)
    assert float(lr(jnp.int32(0))) < 0.2
    assert abs(float(lr(jnp.int32(9))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(109))) < 0.01
    assert float(constant_lr(0.3)(jnp.int32(7))) == np.float32(0.3)
    # paper §5.2: 0.1 at 1 worker → 1.0 at 256 GPUs (vs base 16... linear)
    assert abs(linear_scaling_rule(0.1, 16, 160) - 1.0) < 1e-9
