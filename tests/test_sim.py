"""repro.sim properties: determinism, the paper's qualitative strategy
ordering, timeline ↔ IR correspondence, and the ``auto`` meta strategy —
pure-Python assertions (no device mesh, no HLO compile), microseconds per
test like tests/test_schedule_ir.py.
"""
import json

import jax.numpy as jnp
import pytest

import repro.sim  # noqa: F401  (registers the "auto" strategy)
from repro.core.buckets import Bucket, BucketPlan, LeafInfo
from repro.core.registry import (
    fixed_strategy_names,
    get_strategy,
    strategy_names,
)
from repro.core.schedule import ALL_GATHER, REDUCE_SCATTER
from repro.sim import (
    ComputeModel,
    SimConfig,
    chrome_trace,
    default_network,
    grid_search,
    last_auto_report,
        sim_config_for,
    simulate,
    simulate_strategy,
)

MESH = {"data": 16, "model": 1}
# tiny compute + megabyte buckets over 16-way data-parallel: comm-bound
COMPUTE = ComputeModel(t_fwd=1e-4, t_bwd=2e-4, n_stages=12)


def _plan(n_buckets=12, num_channels=4, elems=1 << 20,
          axes=("data",)):
    buckets = []
    for bid in range(n_buckets):
        leaves = (LeafInfo(name=f"g{bid}", index=bid, shape=(elems,),
                           dtype=jnp.float32, size=elems),)
        buckets.append(Bucket(leaves=leaves, reduce_axes=axes,
                              channel=bid % num_channels, bucket_id=bid))
    return BucketPlan(buckets=tuple(buckets), treedef=None,
                      num_leaves=n_buckets, comm_dtype=jnp.float32)


def test_simulator_is_deterministic():
    plan = _plan()
    for name in strategy_names():
        _, a = simulate_strategy(name, plan, MESH, compute=COMPUTE)
        _, b = simulate_strategy(name, plan, MESH, compute=COMPUTE)
        assert a == b, name


def test_timeline_op_count_matches_ir_for_every_strategy():
    plan = _plan()
    for name in strategy_names():    # fixed strategies AND auto
        schedule = get_strategy(name).plan(plan)
        tl = simulate(schedule, MESH, compute=COMPUTE,
                      sim=sim_config_for(name))
        assert len(tl.events) == len(schedule.ops), name
        assert sorted(e.op_id for e in tl.events) == \
            sorted(op.op_id for op in schedule.ops), name


def test_paper_qualitative_ordering_comm_bound():
    """Paper Figs 13-15: Funneled ≥ ConCom ≥ DepCha when communication
    dominates (here strictly: serial chain vs 4 chains vs free-flying)."""
    plan = _plan(n_buckets=12, num_channels=4)
    times = {}
    for name in ("funnel", "concom", "depcha"):
        _, tl = simulate_strategy(name, plan, MESH, compute=COMPUTE)
        times[name] = tl.step_time
    assert times["funnel"] > times["concom"] > times["depcha"]
    # and the exposed-comm metric tells the same story
    _, f = simulate_strategy("funnel", plan, MESH, compute=COMPUTE)
    _, d = simulate_strategy("depcha", plan, MESH, compute=COMPUTE)
    assert f.exposed_comm > d.exposed_comm
    assert f.overlap_fraction < d.overlap_fraction


def test_chain_serialization_and_release_gating():
    plan = _plan()
    for name in ("funnel", "concom", "priority"):
        schedule, tl = simulate_strategy(name, plan, MESH, compute=COMPUTE)
        assert all(e.start >= e.release - 1e-15 for e in tl.events)
        by_chain = {}
        for e in tl.events:
            by_chain.setdefault(e.chain, []).append(e)
        for evs in by_chain.values():    # chained ops never overlap
            for a, b in zip(evs, evs[1:]):
                assert b.start >= a.end - 1e-15, name


def test_rsag_pipelines_ag_over_next_rs():
    """Each AG waits only on its own RS, so AG_i overlaps RS_{i+1}."""
    plan = _plan(n_buckets=8, num_channels=1)
    _, tl = simulate_strategy("rsag", plan, MESH, compute=COMPUTE)
    ag = [e for e in tl.events if e.kind == ALL_GATHER]
    rs = [e for e in tl.events if e.kind == REDUCE_SCATTER]
    assert len(ag) == len(rs) == 8
    overlaps = any(
        a.start < r.end and r.start < a.end
        for a in ag for r in rs if r.op_id > a.op_id)
    assert overlaps


def test_window_bounds_concurrency():
    plan = _plan(n_buckets=8, num_channels=8)
    for window in (1, 2, 4):
        _, tl = simulate_strategy(
            "concom", plan, MESH, compute=COMPUTE,
            sim=SimConfig(window=window))
        # max concurrent in-flight ops never exceeds the window
        points = sorted({e.start for e in tl.events})
        for t in points:
            live = sum(1 for e in tl.events if e.start <= t < e.end)
            assert live <= window
    _, w1 = simulate_strategy("concom", plan, MESH, compute=COMPUTE,
                              sim=SimConfig(window=1))
    _, w8 = simulate_strategy("concom", plan, MESH, compute=COMPUTE,
                              sim=SimConfig(window=8))
    assert w1.step_time >= w8.step_time


def test_auto_plans_via_registry_and_never_loses():
    plan = _plan()
    info = get_strategy("auto")
    assert info.meta
    assert "auto" in strategy_names()
    assert "auto" not in fixed_strategy_names()

    schedule = info.plan(plan, context={"mesh_shape": MESH,
                                        "compute": COMPUTE})
    report = last_auto_report()
    assert report["winner"] in fixed_strategy_names()
    assert schedule == get_strategy(report["winner"]).plan(plan)

    tl = simulate(schedule, MESH, compute=COMPUTE,
                  sim=sim_config_for(report["winner"]))
    worst = max(t for _, t in [
        (n, simulate_strategy(n, plan, MESH, compute=COMPUTE)[1].step_time)
        for n in fixed_strategy_names()])
    assert tl.step_time <= worst + 1e-12
    # the ranking is sorted best-first and covers every fixed strategy
    steps = [t for _, t in report["ranking"]]
    assert steps == sorted(steps)
    assert {n for n, _ in report["ranking"]} == set(fixed_strategy_names())


def test_auto_through_gradsync(smoke_mesh):
    """GradSync(strategy="auto") plans via the registry with the real
    mesh topology in context and produces a valid executable schedule."""
    import jax

    from repro.core import GradSync, GradSyncConfig

    grads = {"a": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((7,))}
    specs = jax.tree.map(lambda _: jax.sharding.PartitionSpec(), grads)
    gs = GradSync(
        GradSyncConfig(strategy="auto", bucket_bytes=64, num_channels=2),
        smoke_mesh, specs,
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     grads))
    assert gs.schedule.validate() is gs.schedule
    assert last_auto_report()["winner"] in fixed_strategy_names()
    assert gs.schedule.leaf_names() == {"a", "b"}


def test_netmodel_alpha_beta_properties():
    net = default_network()
    ms = {"pod": 2, "data": 16, "model": 1}
    # monotone in bytes; zero for group size 1
    t1 = net.allreduce_time(1 << 20, ("data",), ms)
    t2 = net.allreduce_time(2 << 20, ("data",), ms)
    assert 0.0 < t1 < t2
    assert net.allreduce_time(1 << 20, ("model",), ms) == 0.0
    # ring identity: RS + AG over one axis == allreduce over it
    rs = net.reduce_scatter_time(1 << 20, ("data",), ms)
    ag = net.all_gather_time(1 << 20, ("data",), ms)
    assert rs + ag == pytest.approx(t1)
    # hierarchical sends 1/g_fast of the payload over DCN → cheaper
    n = 64 << 20
    flat = net.allreduce_time(n, ("pod", "data"), ms)
    hier = net.allreduce_time(n, ("pod", "data"), ms,
                              reducer="hierarchical")
    assert hier < flat
    # compressed: ~4x fewer wire bytes for big buffers, flat fallback
    comp = net.allreduce_time(n, ("data",), ms, reducer="compressed")
    assert comp < net.allreduce_time(n, ("data",), ms)
    small = 8 << 10
    assert net.allreduce_time(small, ("data",), ms, reducer="compressed") \
        == net.allreduce_time(small, ("data",), ms)


def test_staging_cost_fused_vs_leafwise():
    """The simulator must price CopyFromTo distinctly: leafwise staging
    (per-leaf copies, two passes) is strictly slower than the fused
    kernels, and the gap grows with leaves per bucket (DESIGN.md §8)."""
    net = default_network()
    n = 4 << 20
    fused = net.staging_time("allreduce", n, 16, fused=True)
    leafwise = net.staging_time("allreduce", n, 16, fused=False)
    assert 0.0 < fused < leafwise
    # more leaves → more per-copy dispatches, leafwise only
    assert net.staging_time("allreduce", n, 64, fused=False) > leafwise
    assert net.staging_time("allreduce", n, 64, fused=True) == fused
    # the RS/AG pair splits one allreduce's staging round trip
    rs = net.staging_time("reduce_scatter", n, 16, fused=True)
    ag = net.staging_time("all_gather", n, 16, fused=True)
    assert rs + ag == pytest.approx(fused)

    # end-to-end: the same schedule simulates strictly slower leafwise
    many = BucketPlan(
        buckets=tuple(
            Bucket(leaves=tuple(
                LeafInfo(name=f"g{b}_{i}", index=b * 8 + i, shape=(1 << 16,),
                         dtype=jnp.float32, size=1 << 16)
                for i in range(8)),
                reduce_axes=("data",), channel=b % 4, bucket_id=b)
            for b in range(8)),
        treedef=None, num_leaves=64, comm_dtype=jnp.float32)
    _, tl_f = simulate_strategy("concom", many, MESH, compute=COMPUTE,
                                sim=SimConfig(fused_staging=True))
    _, tl_l = simulate_strategy("concom", many, MESH, compute=COMPUTE,
                                sim=SimConfig(fused_staging=False))
    assert tl_l.step_time > tl_f.step_time
    assert tl_l.total_comm > tl_f.total_comm


def test_grid_search_orders_candidates(smoke_mesh):
    import jax

    grads = {"w": jnp.ones((256, 64)), "b": jnp.ones((4096,))}
    specs = jax.tree.map(lambda _: jax.sharding.PartitionSpec(), grads)
    preds = grid_search(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     grads),
        specs, smoke_mesh, mesh_shape={"data": 8, "model": 1},
        compute=COMPUTE, channels=(1, 2), bucket_bytes=(1 << 10, 1 << 20))
    steps = [p.step_time for p in preds]
    assert steps == sorted(steps)
    assert all(p.step_time >= preds[0].step_time for p in preds)
    # single-chain strategies collapse the channel dimension
    funnel_cells = [p for p in preds if p.strategy == "funnel"]
    assert {p.num_channels for p in funnel_cells} == {1}
    assert {p.strategy for p in preds} == set(fixed_strategy_names())


def test_chrome_trace_has_one_event_per_op():
    plan = _plan(n_buckets=6, num_channels=3)
    schedule, tl = simulate_strategy("concom", plan, MESH, compute=COMPUTE)
    doc = chrome_trace({"concom": tl})
    payload = json.dumps(doc)        # must serialize
    assert "traceEvents" in doc and payload
    xs = [e for e in doc["traceEvents"]
          if e["ph"] == "X" and e["name"].startswith("allreduce")]
    assert len(xs) == len(schedule.ops)


def test_zero1_step_program_sim_is_deterministic_and_costed():
    """StepProgram schedules simulate like any other IR: one event per
    op (UPDATE/NORM included), deterministically, with the zero1
    buckets' pinned f32 wire dtype overriding SimConfig.itemsize."""
    import dataclasses

    from repro.core.stepprogram import zero1_schedule

    plan = _plan(n_buckets=6, num_channels=3)
    f32_plan = BucketPlan(
        buckets=tuple(dataclasses.replace(b, comm_dtype=jnp.float32)
                      for b in plan.buckets),
        treedef=None, num_leaves=6, comm_dtype=jnp.float32)
    zs = zero1_schedule(get_strategy("concom").plan(f32_plan),
                        dp_axes=("data",), clip=True)
    a = simulate(zs, MESH, compute=COMPUTE)
    b = simulate(zs, MESH, compute=COMPUTE)
    assert a == b
    assert len(a.events) == len(zs.ops)
    # a bf16 SimConfig must NOT shrink the zero1 wire ops (f32 pinned)
    bf16 = simulate(zs, MESH, compute=COMPUTE, sim=SimConfig(itemsize=2))
    rs_a = min(e.duration for e in a.events if e.kind == REDUCE_SCATTER)
    rs_b = min(e.duration for e in bf16.events
               if e.kind == REDUCE_SCATTER)
    assert rs_a == rs_b


def test_schedule_byte_metadata():
    plan = _plan(n_buckets=6, num_channels=3, elems=1024)
    for name in ("concom", "rsag"):
        s = get_strategy(name).plan(plan)
        # RS/AG pairs counted once: both strategies move the same bytes
        assert s.comm_bytes(4) == 6 * 1024 * 4
        assert sum(s.chain_bytes(4).values()) == s.comm_bytes(4)
        assert s.axes_used() == {("data",)}
