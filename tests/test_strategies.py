"""The paper's core correctness claim: every embedding strategy computes
the same reduction; they differ only in schedule (§4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (
    GradSync,
    GradSyncConfig,
    KVStore,
    get_strategy,
    make_bucket_plan,
    reducer_names,
    strategy_names,
)
from repro.core.buckets import pack, unpack
from repro.parallel.sharding import ShardingRules


def _grads_and_specs():
    params = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "b": jnp.ones((7,)) * 0.5,
        "emb": jnp.arange(32.0).reshape(8, 4),
        "w": jnp.full((4, 6), 2.0),
    }
    rules = ShardingRules(rules=(
        ("emb", P("model", None)),
        ("w", P(None, "model")),
    ))
    return params, rules.tree_specs(params)


@pytest.mark.parametrize("strategy", strategy_names())
@pytest.mark.parametrize("reducer", reducer_names())
def test_strategy_identity_on_unit_mesh(smoke_mesh, strategy, reducer):
    """On a size-1 mesh every psum is the identity → sync must return the
    input grads bit-exactly (modulo comm dtype round-trip)."""
    grads, specs = _grads_and_specs()
    cfg = GradSyncConfig(strategy=strategy, reducer=reducer,
                         bucket_bytes=64, num_channels=3)
    gspecs = jax.tree.map(lambda _: P(), grads)
    if get_strategy(strategy).two_phase and reducer not in ("flat", "ring"):
        # two-phase schedules emit raw RS/AG and would ignore any reducer
        # except "ring", which carries the RS/AG ops itself (DESIGN.md §8)
        with pytest.raises(ValueError, match="reduce-scatter"):
            GradSync(cfg, smoke_mesh, specs, jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads))
        return

    def run(g):
        gs = GradSync(cfg, smoke_mesh, specs, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), g))
        return gs(g)

    out = jax.jit(lambda g: jax.shard_map(
        run, mesh=smoke_mesh, in_specs=(gspecs,), out_specs=gspecs,
        check_vma=False)(g))(grads)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_bucket_plan_partition(smoke_mesh):
    """Every leaf appears in exactly one bucket; bucket reduce axes match
    the leaf's missing axes; channels are round-robin."""
    grads, specs = _grads_and_specs()
    plan = make_bucket_plan(grads, specs, smoke_mesh,
                            bucket_bytes=64, num_channels=2)
    seen = {}
    for b in plan.buckets:
        for leaf in b.leaves:
            assert leaf.name not in seen
            seen[leaf.name] = b
    assert set(seen) == {"a", "b", "emb", "w"}
    # emb sharded over model -> reduced over data only
    assert seen["emb"].reduce_axes == ("data",)
    assert seen["a"].reduce_axes == ("data", "model")
    # channel hash: bucket_id % num_channels
    for b in plan.buckets:
        assert b.channel == b.bucket_id % 2


def test_bucket_bytes_cap(smoke_mesh):
    grads, specs = _grads_and_specs()
    plan = make_bucket_plan(grads, specs, smoke_mesh,
                            bucket_bytes=0, num_channels=4)
    # bucket_bytes=0 → paper's per-key granularity: one leaf per bucket
    assert all(len(b.leaves) == 1 for b in plan.buckets)


def test_pack_unpack_roundtrip(smoke_mesh):
    grads, specs = _grads_and_specs()
    plan = make_bucket_plan(grads, specs, smoke_mesh, bucket_bytes=1 << 20)
    flat = jax.tree.leaves(grads)
    out = [None] * len(flat)
    for b in plan.buckets:
        buf = pack(b, flat, jnp.float32)
        assert buf.ndim == 1 and buf.size == b.size
        unpack(b, buf, out)
    for got, want in zip(out, flat):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_kvstore_api(smoke_mesh):
    """Paper Figs 5/8/10 port: push/pull/barrier with all three kinds."""
    g1 = jnp.arange(6.0).reshape(2, 3)
    g2 = jnp.ones((5,))
    mesh_shape = {"data": 1, "model": 1}

    for kind in strategy_names():
        def step(a, b):
            kv = KVStore.create(kind, reduce_axes=("data",), num_channels=2,
                                mesh_shape=mesh_shape)
            kv.push(0, a)
            kv.push(1, b)
            out0 = kv.pull(0)
            out1 = kv.pull(1)
            kv.barrier()
            return out0, out1

        o0, o1 = jax.jit(lambda a, b: jax.shard_map(
            step, mesh=smoke_mesh, in_specs=(P(), P()),
            out_specs=(P(), P()), check_vma=False)(a, b))(g1, g2)
        np.testing.assert_allclose(np.asarray(o0), np.asarray(g1))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(g2))


def test_kvstore_init_is_bitexact_broadcast(smoke_mesh):
    """init = psum of rank-0's value with zeros elsewhere: bit-exact."""
    v = jnp.asarray(np.random.default_rng(0).standard_normal(33),
                    jnp.float32) * 1e-3

    def step(x):
        kv = KVStore.create("concom", reduce_axes=("data",))
        out = kv.init(0, x)
        assert kv.schedule().stats()["num_ops"] == 1  # recorded in the IR
        return out

    out = jax.jit(lambda x: jax.shard_map(
        step, mesh=smoke_mesh, in_specs=(P(),), out_specs=P(),
        check_vma=False)(x))(v)
    assert np.array_equal(np.asarray(out), np.asarray(v))  # no rounding


def test_kvstore_barrier_recorded_in_ir(smoke_mesh):
    """Ops emitted after barrier() depend on all pre-barrier chain tails."""
    recorded = {}

    def step(a, b, c):
        kv = KVStore.create("concom", reduce_axes=("data",), num_channels=2)
        kv.push(0, a)
        kv.push(1, b)
        kv.barrier()
        kv.push(2, c)
        out = kv.pull(0) + kv.pull(2)
        s = kv.schedule()
        recorded["post_deps"] = s.ops[2].depends_on
        return out

    g = jnp.ones((3,))
    jax.jit(lambda a, b, c: jax.shard_map(
        step, mesh=smoke_mesh, in_specs=(P(), P(), P()), out_specs=P(),
        check_vma=False)(a, b, c))(g, g, g)
    assert set(recorded["post_deps"]) == {0, 1}


def test_dependency_tokens_preserve_values():
    from repro.core import chain, gate, new_token, update

    x = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}

    def f(x):
        t = new_token()
        gated = gate(x, t)
        t2 = update(t, gated)
        y, t3 = chain(t2, gated)
        return y

    y = jax.jit(f)(x)
    np.testing.assert_allclose(np.asarray(y["a"]), np.asarray(x["a"]))
    np.testing.assert_allclose(np.asarray(y["b"]), np.asarray(x["b"]))
