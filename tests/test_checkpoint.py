"""Checkpointing: atomic roundtrip, retention, async, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, reshard, restore, save
from repro.checkpoint.manager import latest_step
from repro.checkpoint.reshard import validate_divisibility
from repro.parallel.sharding import ShardingRules


def _tree():
    return {
        "params": {"w": jnp.arange(24.0).reshape(4, 6),
                   "b": jnp.ones((3,), jnp.bfloat16)},
        "opt": {"m": {"w": jnp.zeros((4, 6)), "b": jnp.zeros((3,))}},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    out = restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomicity_ignores_tmp(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    # a crashed write leaves only a .tmp dir — must be invisible
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 3


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2, blocking=True)
    t = _tree()
    for s in range(1, 6):
        mgr.maybe_save(s, t)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    assert len(steps) == 2
    assert mgr.latest() == 5


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=3, blocking=False)
    t = _tree()
    mgr.maybe_save(1, t)
    mgr.wait()
    s, out = mgr.restore(t)
    assert s == 1
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"]), np.asarray(t["params"]["w"]))


def test_shape_mismatch_detected(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    bad = jax.tree.map(lambda x: x, t)
    bad["params"]["w"] = jnp.zeros((5, 6))
    with pytest.raises(ValueError, match="checkpoint"):
        restore(str(tmp_path), 1, bad)


def test_reshard_elastic(tmp_path, smoke_mesh):
    """Checkpoint written under one mesh restores onto another (here the
    smoke mesh — the mechanism is placement-by-spec, mesh-agnostic)."""
    rules = ShardingRules(rules=(("w", P(None, "model")),))
    t = {"w": jnp.arange(32.0).reshape(4, 8), "b": jnp.ones((4,))}
    save(str(tmp_path), 1, t)
    loaded = restore(str(tmp_path), 1, t)
    placed = reshard(loaded, rules, smoke_mesh)
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(t["w"]))


def test_reshard_divisibility_error(smoke_mesh):

    rules = ShardingRules(rules=(("w", P(None, "model")),))
    t = {"w": jnp.zeros((4, 7))}   # 7 not divisible by any model axis > 1
    specs = rules.tree_specs(t)
    # on the 1-device smoke mesh it IS divisible; fabricate a failure by
    # checking the validator logic directly with a fake mesh dict
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 2}
    with pytest.raises(ValueError, match="not divisible"):
        validate_divisibility(t, specs, FakeMesh())
