"""Checkpointing: atomic roundtrip, retention, async, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, reshard, restore, save
from repro.checkpoint.manager import latest_step
from repro.checkpoint.reshard import validate_divisibility
from repro.parallel.sharding import ShardingRules


def _tree():
    return {
        "params": {"w": jnp.arange(24.0).reshape(4, 6),
                   "b": jnp.ones((3,), jnp.bfloat16)},
        "opt": {"m": {"w": jnp.zeros((4, 6)), "b": jnp.zeros((3,))}},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    out = restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomicity_ignores_tmp(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    # a crashed write leaves only a .tmp dir — must be invisible
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 3


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2, blocking=True)
    t = _tree()
    for s in range(1, 6):
        mgr.maybe_save(s, t)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    assert len(steps) == 2
    assert mgr.latest() == 5


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=3, blocking=False)
    t = _tree()
    mgr.maybe_save(1, t)
    mgr.wait()
    s, out = mgr.restore(t)
    assert s == 1
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"]), np.asarray(t["params"]["w"]))


def test_shape_mismatch_detected(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    bad = jax.tree.map(lambda x: x, t)
    bad["params"]["w"] = jnp.zeros((5, 6))
    with pytest.raises(ValueError, match="checkpoint"):
        restore(str(tmp_path), 1, bad)


def test_reshard_elastic(tmp_path, smoke_mesh):
    """Checkpoint written under one mesh restores onto another (here the
    smoke mesh — the mechanism is placement-by-spec, mesh-agnostic)."""
    rules = ShardingRules(rules=(("w", P(None, "model")),))
    t = {"w": jnp.arange(32.0).reshape(4, 8), "b": jnp.ones((4,))}
    save(str(tmp_path), 1, t)
    loaded = restore(str(tmp_path), 1, t)
    placed = reshard(loaded, rules, smoke_mesh)
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(t["w"]))


def test_reshard_divisibility_error(smoke_mesh):

    rules = ShardingRules(rules=(("w", P(None, "model")),))
    t = {"w": jnp.zeros((4, 7))}   # 7 not divisible by any model axis > 1
    specs = rules.tree_specs(t)
    # on the 1-device smoke mesh it IS divisible; fabricate a failure by
    # checking the validator logic directly with a fake mesh dict
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 2}
    with pytest.raises(ValueError, match="not divisible"):
        validate_divisibility(t, specs, FakeMesh())


# ---------------------------------------------- retry / transient I/O

class _FlakyIO:
    """Raises OSError for the first ``n`` attempts of the given op."""

    def __init__(self, n, ops=("save", "restore")):
        self.left = n
        self.ops = ops
        self.calls = []

    def __call__(self, op):
        self.calls.append(op)
        if op in self.ops and self.left > 0:
            self.left -= 1
            raise OSError(f"injected {op} fault")


def test_save_retries_absorb_transient_faults(tmp_path):
    flaky = _FlakyIO(2, ops=("save",))
    mgr = CheckpointManager(str(tmp_path), every=1, blocking=True,
                            retries=3, backoff_s=0.001,
                            fault_injector=flaky)
    t = _tree()
    assert mgr.maybe_save(1, t)
    assert flaky.calls.count("save") == 3          # 2 faults + 1 success
    s, out = mgr.restore(t)
    assert s == 1
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_restore_retries_absorb_transient_faults(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, blocking=True)
    t = _tree()
    mgr.maybe_save(1, t)
    flaky = _FlakyIO(2, ops=("restore",))
    mgr2 = CheckpointManager(str(tmp_path), every=1, retries=2,
                             backoff_s=0.001, fault_injector=flaky)
    s, out = mgr2.restore(t)
    assert s == 1 and flaky.calls.count("restore") == 3


def test_retries_exhausted_reraises(tmp_path):
    flaky = _FlakyIO(10)
    mgr = CheckpointManager(str(tmp_path), every=1, blocking=True,
                            retries=2, backoff_s=0.001,
                            fault_injector=flaky)
    with pytest.raises(OSError, match="injected save fault"):
        mgr.maybe_save(1, _tree())
    assert flaky.calls.count("save") == 3          # retries + 1, then raise


def test_atomicity_preserved_under_fault(tmp_path):
    """A fault mid-retry never corrupts the last complete checkpoint:
    every attempt goes through the tmp-dir + rename protocol."""
    mgr = CheckpointManager(str(tmp_path), every=1, blocking=True)
    t = _tree()
    mgr.maybe_save(1, t)
    flaky = _FlakyIO(10)
    mgr2 = CheckpointManager(str(tmp_path), every=1, blocking=True,
                             retries=1, backoff_s=0.001,
                             fault_injector=flaky)
    bad = jax.tree.map(lambda x: x * 0 - 1, t)
    with pytest.raises(OSError):
        mgr2.maybe_save(2, bad)
    # latest is still the good step-1 checkpoint, bit-for-bit
    assert latest_step(str(tmp_path)) == 1
    s, out = mgr.restore(t)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_save_now_blocking_anchor(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=100, blocking=False)
    t = _tree()
    assert not mgr.maybe_save(7, t)     # off the periodic grid
    mgr.save_now(7, t)                  # the supervisor's anchor path
    assert mgr.latest() == 7


def test_manifest_lists_leaf_names(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, blocking=True)
    t = _tree()
    t["opt"]["pending"] = {"0": jnp.zeros((4,))}
    mgr.maybe_save(1, t)
    names = mgr.manifest(1)
    assert "params/w" in names
    assert any("pending" in n for n in names)
    with pytest.raises(OSError):
        mgr.manifest(99)                # absent step fails loudly
