"""Decode-vs-prefill consistency for the recurrent families: running
prefill on [t0..tn] must equal prefill on [t0..tk] + decode steps for
t(k+1)..tn (state-handoff correctness for rwkv and mamba/zamba)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models.registry import family_of


def _logits_from_prefill(api, cfg, params, toks, mesh, window=0):
    pspecs = jax.tree.map(lambda _: P(), params)
    state_like = jax.eval_shape(
        lambda: api.make_decode_state(cfg, toks.shape[0],
                                      window or toks.shape[1]))
    sspecs = jax.tree.map(lambda _: P(), state_like)

    def pf(p, t):
        if api.family == "ssm":
            # ring cache must be sized for the FINAL length up front
            return api.prefill(p, t, cfg, attn_window=window or
                               toks.shape[1])
        return api.prefill(p, t, cfg)

    return jax.jit(lambda p, t: jax.shard_map(
        pf, mesh=mesh, in_specs=(pspecs, P()), out_specs=(P(), sspecs),
        check_vma=False)(p, t))(params, toks)


def _decode(api, cfg, params, state, tok, pos, mesh):
    pspecs = jax.tree.map(lambda _: P(), params)
    sspecs = jax.tree.map(lambda _: P(), state)

    def dc(p, st, t):
        return api.decode_step(p, st, t, pos, cfg)

    return jax.jit(lambda p, st, t: jax.shard_map(
        dc, mesh=mesh, in_specs=(pspecs, sspecs, P()),
        out_specs=(P(), sspecs), check_vma=False)(p, st, t))(
        params, state, tok)


@pytest.mark.parametrize("arch_id", ["rwkv6-7b", "zamba2-2.7b"])
def test_recurrent_decode_matches_prefill(smoke_mesh, arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.make_smoke()
    api = family_of(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 32
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)

    # reference: prefill the full sequence, read final logits
    ref_logits, _ = _logits_from_prefill(api, cfg, params, toks,
                                         smoke_mesh, window=S)

    # incremental: prefill S-2 tokens, then decode the last two
    logits, state = _logits_from_prefill(
        api, cfg, params, toks[:, :S - 2], smoke_mesh, window=S)
    _, state = _decode(api, cfg, params, state, toks[:, S - 2], S - 2,
                       smoke_mesh)
    inc_logits, _ = _decode(api, cfg, params, state, toks[:, S - 1], S - 1,
                            smoke_mesh)
    # NOTE: decode returns logits for the token JUST consumed; the
    # reference's last-position logits correspond to the same prediction
    np.testing.assert_allclose(
        np.asarray(inc_logits, np.float32),
        np.asarray(ref_logits, np.float32), atol=2e-3, rtol=2e-3)
