"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED config
of the same family runs one forward/train step on CPU; output shapes +
no NaNs.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models.registry import family_of

LM_ARCHS = [a for a in ARCHS if ARCHS[a].family in
            ("transformer", "rwkv", "ssm")]
IMG_ARCHS = [a for a in ARCHS if ARCHS[a].family in ("resnet", "inception")]


def _lm_batch(cfg, B=2, S=32, extra=()):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "global_tokens": jnp.float32(B * S),
    }
    for name, shape_fn, _ in extra:
        batch[name] = jnp.asarray(
            rng.standard_normal((B, *shape_fn(cfg, S))), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_train_step_smoke(smoke_mesh, arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.make_smoke()
    api = family_of(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _lm_batch(cfg, extra=arch.extra_inputs)
    pspecs = jax.tree.map(lambda _: P(), params)
    bspecs = jax.tree.map(lambda _: P(), batch)

    def step(p, b):
        loss, grads = jax.value_and_grad(
            lambda pp: api.train_forward(pp, b, cfg))(p)
        return loss, grads

    loss, grads = jax.jit(lambda p, b: jax.shard_map(
        step, mesh=smoke_mesh, in_specs=(pspecs, bspecs),
        out_specs=(P(), pspecs), check_vma=False)(p, b))(params, batch)
    assert np.isfinite(float(loss)), arch_id
    assert float(loss) > 0
    for name, g in zip(jax.tree_util.tree_structure(grads).flatten_up_to(grads),
                       jax.tree.leaves(grads)):
        assert np.all(np.isfinite(np.asarray(g, np.float32))), arch_id


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_serve_smoke(smoke_mesh, arch_id):
    """prefill + one decode step: shapes + finite logits."""
    arch = ARCHS[arch_id]
    cfg = arch.make_smoke()
    api = family_of(cfg)
    if api.prefill is None:
        pytest.skip("no serve path")
    params = api.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jnp.ones((B, S), jnp.int32)
    pspecs = jax.tree.map(lambda _: P(), params)

    kw = {}
    if any(n == "img_embeds" for n, _, _ in arch.extra_inputs):
        kw["img_embeds"] = jnp.ones((B, 8, cfg.d_model), jnp.float32)

    def pf(p, t):
        if kw:
            return api.prefill(p, t, cfg, **kw)
        return api.prefill(p, t, cfg)

    state_like = jax.eval_shape(
        lambda: api.make_decode_state(cfg, B, S))
    sspecs_out = jax.tree.map(lambda _: P(), state_like)
    logits, state = jax.jit(lambda p, t: jax.shard_map(
        pf, mesh=smoke_mesh, in_specs=(pspecs, P()),
        out_specs=(P(), sspecs_out), check_vma=False)(p, t))(params, toks)
    assert logits.shape[0] == B
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch_id

    # decode one token continuing from the prefill state
    if arch.family == "transformer":
        state = jax.tree.map(
            lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
            if a.ndim == 5 else a, state)
    tok = jnp.ones((B,), jnp.int32)
    sspecs = jax.tree.map(lambda _: P(), state)

    def dc(p, st, t):
        if kw and arch.family == "transformer":
            return api.decode_step(p, st, t, S, cfg, **kw)
        return api.decode_step(p, st, t, S, cfg)

    logits2, state2 = jax.jit(lambda p, st, t: jax.shard_map(
        dc, mesh=smoke_mesh, in_specs=(pspecs, sspecs, P()),
        out_specs=(P(), sspecs), check_vma=False)(p, st, t))(
        params, state, tok)
    assert logits2.shape[0] == B
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch_id


@pytest.mark.parametrize("arch_id", IMG_ARCHS)
def test_image_arch_train_step_smoke(smoke_mesh, arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.make_smoke()
    api = family_of(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B = 4
    batch = {
        "images": jnp.asarray(rng.standard_normal(
            (B, cfg.img_size, cfg.img_size, 3)), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, cfg.num_classes, (B,)),
                              jnp.int32),
        "global_tokens": jnp.float32(B),
    }
    pspecs = jax.tree.map(lambda _: P(), params)
    bspecs = jax.tree.map(lambda _: P(), batch)

    def step(p, b):
        return jax.value_and_grad(
            lambda pp: api.train_forward(pp, b, cfg))(p)

    loss, grads = jax.jit(lambda p, b: jax.shard_map(
        step, mesh=smoke_mesh, in_specs=(pspecs, bspecs),
        out_specs=(P(), pspecs), check_vma=False)(p, b))(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
