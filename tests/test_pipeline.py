"""Pipeline-parallel mechanism test (subprocess: needs >1 device)."""
import os
import subprocess
import sys


WORKER = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import warnings; warnings.filterwarnings("ignore")
import repro  # applies the jaxcompat shim before jax imports
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
from repro.parallel.pipeline import pipeline_forward, bubble_fraction

mesh = jax.make_mesh((4,), ("stage",), axis_types=(AxisType.Auto,))
S, M, D = 4, 6, 8
# stage i: x -> x * w_i  (stacked weights, one per stage)
w = jnp.arange(1.0, S + 1.0)          # sharded over stage
mbs = jnp.arange(M * D, dtype=jnp.float32).reshape(M, D) + 1.0

def stage_fn(wi, x):
    return x * wi[0]

def run(w, mbs):
    return pipeline_forward(stage_fn, w, mbs, axis="stage", n_stages=S)

out = jax.jit(lambda w, m: jax.shard_map(
    run, mesh=mesh, in_specs=(P("stage"), P()), out_specs=P(),
    check_vma=False)(w, m))(w, mbs)
expect = mbs * float(np.prod(np.arange(1, S + 1)))
ok = np.allclose(np.asarray(out), np.asarray(expect))
print("PIPE_OK" if ok else f"PIPE_FAIL {np.asarray(out)[0]} vs {np.asarray(expect)[0]}")
assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
print("BUBBLE_OK")
'''


def test_pipeline_parallel_subprocess(tmp_path):
    script = tmp_path / "pipe_worker.py"
    script.write_text(WORKER)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "PIPE_OK" in proc.stdout, proc.stdout
    assert "BUBBLE_OK" in proc.stdout
