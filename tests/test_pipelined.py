"""Pipelined StepProgram (DESIGN.md §10): deferred all-gathers crossing
into the next step + sync overlapping the accumulation tail.

Three layers of checks, mirroring tests/test_stepprogram.py:
  - pure-IR phase-split properties (microseconds, no devices);
  - simulator semantics: the steady-state pipelined timeline hides the
    PRE gathers under the next forward (deferred exposed comm strictly
    below the same-step zero1 plan), and the accumulation compute model
    places releases only in the final microbatch's backward;
  - executable parity on the smoke mesh (dp=1): deferred ≡ scheduled
    across consecutive steps (tight tolerance — with dp=1 the elided
    all-gather lets XLA contract the update math into the apply-add,
    a 1-ulp artifact; tests/_mdworker.py asserts BIT-exactness on real
    dp=2 × tp=4 groups where the gather materializes the shards), and
    microbatch=1 ≡ microbatch=M training (the grad-accumulation
    normalization fix) with the peeled final microbatch bit-exact
    against the plain scan.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sim  # noqa: F401  (registers the "auto" strategy)
from repro.core.buckets import Bucket, BucketPlan, LeafInfo
from repro.core.registry import fixed_strategy_names, get_strategy
from repro.core.schedule import ALL_GATHER, POST, PRE
from repro.core.stepprogram import zero1_schedule
from repro.sim import (
    ComputeModel,
    PipelinedTimeline,
    rank_step_plans,
    simulate_pipelined,
)

MESH = {"data": 8, "model": 1}
COMPUTE = ComputeModel(t_fwd=1e-4, t_bwd=2e-4, n_stages=8)


def _plan(n_buckets=8, num_channels=4, elems=1 << 20):
    buckets = []
    for bid in range(n_buckets):
        leaves = (LeafInfo(name=f"g{bid}", index=bid, shape=(elems,),
                           dtype=jnp.float32, size=elems),)
        buckets.append(Bucket(leaves=leaves, reduce_axes=("data",),
                              channel=bid % num_channels, bucket_id=bid,
                              comm_dtype=jnp.float32))
    return BucketPlan(buckets=tuple(buckets), treedef=None,
                      num_leaves=n_buckets, comm_dtype=jnp.float32)


# ---------------------------------------------------------- IR phases

def test_defer_ag_tags_only_all_gathers_pre():
    plan = _plan()
    for name in fixed_strategy_names():
        base = get_strategy(name).plan(plan)
        zd = zero1_schedule(base, dp_axes=("data",), clip=True,
                            defer_ag=True)
        assert zd.validate() is zd
        for op in zd.ops:
            want = PRE if op.kind == ALL_GATHER else POST
            assert op.phase == want, (name, op.kind)
        n = len(plan.buckets)
        assert zd.phase_counts() == {POST: 2 * n + 1, PRE: n}, name
        # every dp bucket's payload crosses the boundary, at f32 wire
        assert zd.deferred_bytes() == sum(
            b.size * 4 for b in plan.buckets), name
        # without the flag nothing is deferred
        zs = zero1_schedule(base, dp_axes=("data",), clip=True)
        assert zs.phase_counts() == {POST: 3 * n + 1}, name
        assert zs.deferred_bytes() == 0, name


def test_split_phases_reroots_pre_ops():
    plan = _plan()
    zd = zero1_schedule(get_strategy("concom").plan(plan),
                        dp_axes=("data",), defer_ag=True)
    post, pre = zd.split_phases()
    assert post.validate() is post and pre.validate() is pre
    n = len(plan.buckets)
    assert len(post.ops) == 2 * n and len(pre.ops) == n
    assert all(op.kind != ALL_GATHER for op in post.ops)
    # the PRE gathers lost their UPDATE deps (those ran LAST step —
    # the shards arrive as carried state) and free-fly
    assert all(op.kind == ALL_GATHER and op.depends_on == ()
               for op in pre.ops)
    # op ids survive the split: the two halves partition the program
    assert ({op.op_id for op in post.ops} | {op.op_id for op in pre.ops}
            == {op.op_id for op in zd.ops})


def test_build_step_program_deferred_keeps_sync_post(smoke_mesh):
    from jax.sharding import PartitionSpec as P

    from repro.core import GradSync, GradSyncConfig

    grads = {"w": jnp.ones((64, 8)), "b": jnp.ones((8,))}
    specs = jax.tree.map(lambda _: P(), grads)
    sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    gs = GradSync(
        GradSyncConfig(strategy="concom", bucket_bytes=256,
                       exclude_axes=("data",), zero1_dp_axes=("data",),
                       zero1_defer_ag=True),
        smoke_mesh, specs, sds)
    assert gs.program is not None and gs.program.defer_ag
    pre = gs.program.pre_schedule()
    post = gs.program.post_schedule()
    assert len(pre.ops) == len(gs.dp_plan.buckets)
    assert all(op.kind == ALL_GATHER for op in pre.ops)
    # the model-axis sync ops all stay in the POST half
    assert {op.op_id for op in post.ops} >= set(
        range(gs.program.num_sync_ops))


# ------------------------------------------------------------ simulator

def test_simulate_pipelined_deterministic_and_complete():
    plan = _plan()
    zd = zero1_schedule(get_strategy("concom").plan(plan),
                        dp_axes=("data",), defer_ag=True)
    post, pre = zd.split_phases()
    a = simulate_pipelined(post, pre, MESH, compute=COMPUTE)
    b = simulate_pipelined(post, pre, MESH, compute=COMPUTE)
    assert a == b
    assert isinstance(a, PipelinedTimeline)
    assert len(a.events) == len(zd.ops)
    # PRE gathers are released at t=0 — the step's head, not its tail
    ag_starts = [e.start for e in a.events if e.kind == ALL_GATHER]
    assert min(ag_starts) == 0.0


def test_deferred_exposes_strictly_less_than_zero1():
    """The acceptance bar: per strategy, the pipelined plan's exposed
    comm is strictly below the same-step zero1 plan's (the AG tail
    moved under the next forward), and the best plan overall is a
    deferred one on this comm-heavy cell."""
    plan = _plan(n_buckets=8, num_channels=4)
    ranked = rank_step_plans(plan, MESH, dp_axes=("data",),
                             compute=COMPUTE)
    by = dict(ranked)
    names = {n.split(":")[0] for n in by}
    assert names == {"deferred", "zero1", "flat"}
    best_z = min(v.exposed_comm for k, v in by.items()
                 if k.startswith("zero1:"))
    best_d = min(v.exposed_comm for k, v in by.items()
                 if k.startswith("deferred:"))
    assert best_d < best_z
    for s in ("concom", "rsag", "depcha"):
        assert by[f"deferred:{s}"].exposed_comm \
            < by[f"zero1:{s}"].exposed_comm, s
        assert by[f"deferred:{s}"].step_time \
            <= by[f"zero1:{s}"].step_time, s


def test_pre_gathers_outrunning_the_forward_push_the_step():
    plan = _plan(n_buckets=8, num_channels=4)
    zd = zero1_schedule(get_strategy("concom").plan(plan),
                        dp_axes=("data",), defer_ag=True)
    post, pre = zd.split_phases()
    wide = simulate_pipelined(post, pre, MESH, compute=COMPUTE,
                              pre_window=1.0)      # fully hidden
    tight = simulate_pipelined(post, pre, MESH, compute=COMPUTE,
                               pre_window=0.0)     # fully exposed
    assert tight.step_time > wide.step_time
    # the push is exactly the un-hidden PRE makespan
    pre_end = max(e.end for e in tight.events if e.kind == ALL_GATHER
                  and e.release == 0.0)
    assert tight.t_fwd == pytest.approx(COMPUTE.t_fwd + pre_end)


def test_with_accum_places_releases_in_final_microbatch():
    micro = ComputeModel(t_fwd=1e-4, t_bwd=2e-4, n_stages=4)
    m4 = micro.with_accum(4)
    # total compute = 4 microbatches; head = 3 full microbatches + fwd
    assert m4.end == pytest.approx(4 * micro.end)
    assert m4.t_fwd == pytest.approx(3 * micro.end + micro.t_fwd)
    assert m4.t_bwd == pytest.approx(micro.t_bwd)
    sizes = [(0, 100), (1, 100)]
    rel = m4.bucket_release_times(sizes)
    # releases live inside the FINAL microbatch's backward window
    assert all(m4.t_fwd < t <= m4.end + 1e-15 for t in rel.values())
    # plain scan: everything releases at the very end
    flat = micro.with_accum(4, overlap_tail=False)
    rel_f = flat.bucket_release_times(sizes)
    assert all(t == pytest.approx(4 * micro.end) for t in rel_f.values())
    assert micro.with_accum(1) is micro


def test_rank_step_plans_accum_scales_step_time():
    plan = _plan(n_buckets=4)
    r1 = dict(rank_step_plans(plan, MESH, dp_axes=("data",),
                              compute=COMPUTE))
    r4 = dict(rank_step_plans(plan, MESH, dp_axes=("data",),
                              compute=COMPUTE, accum=4))
    for k in r1:
        assert r4[k].step_time > r1[k].step_time, k
        # the extra time is compute (the 3 head microbatches), not comm
        assert r4[k].total_comm == pytest.approx(r1[k].total_comm), k


# ------------------------------------------------- executable parity

@pytest.fixture(scope="module")
def pipe_setup(smoke_mesh):
    from repro.data import TokenPipeline
    from repro.models import transformer as tf

    cfg = tf.TransformerConfig(
        name="pipelined", n_layers=2, d_model=32, n_heads=4, kv_heads=2,
        d_ff=64, vocab=64, tp=1, attn_chunk=16, dtype=jnp.float32)
    pipe = TokenPipeline(64, 16, 4, seed=7, mesh=smoke_mesh)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, pipe, params


def _make_step(cfg, pipe, params, mesh, *, mode=None, microbatch=1,
               accum_overlap=True, clip_norm=0.0):
    from repro.core import GradSyncConfig
    from repro.optim import adamw, zero1
    from repro.runtime import make_train_step

    batch = pipe.batch_at(0)
    if mode is None:        # plain adamw (no zero1)
        return make_train_step(
            cfg, mesh,
            GradSyncConfig(strategy="concom", bucket_bytes=1 << 14),
            adamw(1e-3), batch_like=batch, params_like=params,
            microbatch=microbatch, accum_overlap=accum_overlap,
            clip_norm=clip_norm)
    opt = zero1(adamw(1e-3), ("data",), 1)
    return make_train_step(
        cfg, mesh,
        GradSyncConfig(strategy="concom", bucket_bytes=1 << 14,
                       exclude_axes=("data",)),
        opt, batch_like=batch, params_like=params, zero1_mode=True,
        zero1_plan=mode, microbatch=microbatch,
        accum_overlap=accum_overlap, clip_norm=clip_norm)


def _run(ts, pipe, params, n_steps):
    p, s = params, ts.init_opt()
    m = None
    for k in range(n_steps):
        p, s, m = ts.fn(p, s, pipe.batch_at(k), jnp.int32(k))
    return p, s, m


def _max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_deferred_carries_pending_state(pipe_setup, smoke_mesh):
    cfg, pipe, params = pipe_setup
    ts = _make_step(cfg, pipe, params, smoke_mesh, mode="deferred")
    state = ts.init_opt()
    assert "pending" in state
    assert set(state["pending"]) == set(state["inner"])
    # zero-initialized carry: gathering it is the identity update
    assert all(float(jnp.max(jnp.abs(v))) == 0.0
               for v in jax.tree.leaves(state["pending"]))
    p, s, _ = _run(ts, pipe, params, 1)
    # after one step the carry holds real (nonzero) update shards and
    # the params are still untouched-by-step-0's update until finalize
    assert any(float(jnp.max(jnp.abs(v))) > 0.0
               for v in jax.tree.leaves(s["pending"]))
    assert ts.finalize is not None
    assert _max_diff(ts.finalize(p, s), p) > 0.0


def test_deferred_matches_scheduled_across_steps(pipe_setup, smoke_mesh):
    cfg, pipe, params = pipe_setup
    ts_s = _make_step(cfg, pipe, params, smoke_mesh, mode="scheduled")
    ts_d = _make_step(cfg, pipe, params, smoke_mesh, mode="deferred")
    p_s, s_s = params, ts_s.init_opt()
    p_d, s_d = params, ts_d.init_opt()
    for k in range(3):
        p_s, s_s, m_s = ts_s.fn(p_s, s_s, pipe.batch_at(k), jnp.int32(k))
        p_d, s_d, m_d = ts_d.fn(p_d, s_d, pipe.batch_at(k), jnp.int32(k))
        # the optimizer moments track the same trajectory: the carried
        # shards feed the SAME update math one boundary later (the tiny
        # dp=1 drift is the same contraction artifact as below)
        assert _max_diff(s_s["inner"], s_d["inner"]) < 1e-6, k
        # params agree once the pending gathers are flushed (dp=1 ulp
        # tolerance: the elided AG lets XLA contract update into apply;
        # _mdworker asserts == 0.0 on real dp=2 groups)
        assert _max_diff(p_s, ts_d.finalize(p_d, s_d)) < 1e-6, k
        assert abs(float(m_s["grad_norm"])
                   - float(m_d["grad_norm"])) < 1e-6, k


def test_deferred_clip_matches_scheduled_clip(pipe_setup, smoke_mesh):
    cfg, pipe, params = pipe_setup
    clip = 0.05                              # small enough to bind
    ts_s = _make_step(cfg, pipe, params, smoke_mesh, mode="scheduled",
                      clip_norm=clip)
    ts_d = _make_step(cfg, pipe, params, smoke_mesh, mode="deferred",
                      clip_norm=clip)
    p_s, _, m_s = _run(ts_s, pipe, params, 2)
    p_d, s_d, m_d = _run(ts_d, pipe, params, 2)
    assert float(m_s["grad_norm"]) > clip    # the clip actually engaged
    assert abs(float(m_s["grad_norm"]) - float(m_d["grad_norm"])) < 1e-6
    assert _max_diff(p_s, ts_d.finalize(p_d, s_d)) < 1e-6


def test_microbatch_count_does_not_scale_training(pipe_setup, smoke_mesh):
    """The grad-accumulation normalization: same global batch split
    M ways trains the same trajectory (loss and params), to float
    round-off — the scan accumulates means, not sums."""
    cfg, pipe, params = pipe_setup
    ts1 = _make_step(cfg, pipe, params, smoke_mesh, microbatch=1)
    ts4 = _make_step(cfg, pipe, params, smoke_mesh, microbatch=4)
    p1, s1 = params, ts1.init_opt()
    p4, s4 = params, ts4.init_opt()
    for k in range(2):
        p1, s1, m1 = ts1.fn(p1, s1, pipe.batch_at(k), jnp.int32(k))
        p4, s4, m4 = ts4.fn(p4, s4, pipe.batch_at(k), jnp.int32(k))
        assert float(m1["loss"]) == pytest.approx(
            float(m4["loss"]), rel=1e-6), k
        assert float(m1["grad_norm"]) == pytest.approx(
            float(m4["grad_norm"]), rel=1e-5), k
        assert _max_diff(p1, p4) < 1e-6, k


def test_peeled_final_microbatch_is_bit_exact(pipe_setup, smoke_mesh):
    """Peeling the last microbatch out of the scan keeps the exact
    accumulation order — overlapped and plain paths are bit-identical."""
    cfg, pipe, params = pipe_setup
    ts_o = _make_step(cfg, pipe, params, smoke_mesh, microbatch=4,
                      accum_overlap=True)
    ts_p = _make_step(cfg, pipe, params, smoke_mesh, microbatch=4,
                      accum_overlap=False)
    p_o, _, m_o = _run(ts_o, pipe, params, 2)
    p_p, _, m_p = _run(ts_p, pipe, params, 2)
    assert float(m_o["loss"]) == float(m_p["loss"])
    assert _max_diff(p_o, p_p) == 0.0


def test_deferred_with_accumulation(pipe_setup, smoke_mesh):
    """Both boundaries crossed at once: deferred AGs + peeled
    accumulation tail still track the scheduled plain-scan step."""
    cfg, pipe, params = pipe_setup
    ts_s = _make_step(cfg, pipe, params, smoke_mesh, mode="scheduled",
                      microbatch=2, accum_overlap=False)
    ts_d = _make_step(cfg, pipe, params, smoke_mesh, mode="deferred",
                      microbatch=2, accum_overlap=True)
    p_s, _, _ = _run(ts_s, pipe, params, 2)
    p_d, s_d, _ = _run(ts_d, pipe, params, 2)
    assert _max_diff(p_s, ts_d.finalize(p_d, s_d)) < 1e-6
