"""Elastic-training worker: runs under XLA_FLAGS=8 fake devices in a
subprocess (jax device count is fixed at first init).  Prints PASS/FAIL
lines parsed by tests/test_elastic.py.

Covers DESIGN.md §13 end-to-end on real device groups:
  - StateCodec encode∘decode bit-exactness (scheduled + deferred)
  - zero-step 8→4→8 reshard round-trip identity
  - plan_reshard static facts + sim costing + seeded-mutation rejection
  - Supervisor fault cycles (rank loss, transient steps, checkpoint-I/O
    faults) with bit-exact faulty ≡ clean-scripted-replay parity, for
    scheduled AND deferred ZeRO-1 plans
  - deferred-plan exact resume through the PLAIN checkpoint path (tp=1)
    and the pending-manifest restore guard
  - straggler-driven shrink (opt-in remesh hook) with parity
  - measured per-op replay of the codec's RESHARD programs
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings

warnings.filterwarnings("ignore")
import dataclasses
import shutil
import tempfile

import repro  # noqa: F401  (applies the jaxcompat shim before jax imports)
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import GradSyncConfig
from repro.data import TokenPipeline
from repro.elastic import (
    FaultPlan,
    StateCodec,
    Supervisor,
    plan_reshard,
    reshard_state,
)
from repro.models import transformer as tf
from repro.models.registry import family_of
from repro.optim import adamw, zero1
from repro.runtime import make_train_step
from repro.utils.trees import named_leaves


def check(name, cond):
    print(("PASS " if cond else "FAIL ") + name, flush=True)


def tree_maxdiff(a, b):
    worst = 0.0
    for (n, x), (_, y) in zip(named_leaves(a), named_leaves(b)):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        if x.shape != y.shape:
            return float("inf")
        if x.size:
            worst = max(worst, float(np.max(np.abs(x - y))))
    return worst


mk_dense = lambda tp: tf.TransformerConfig(
    name="dense", n_layers=2, d_model=64, n_heads=8, kv_heads=2, d_ff=128,
    vocab=96, tp=tp, attn_chunk=16, dtype=jnp.float32)

MESHES = {
    "tp4": ((2, 4), 8, 4),      # (mesh dims, device count, tp)
    "tp2": ((2, 2), 4, 2),
    "tp1": ((2, 1), 2, 1),
}
_BUILT: dict = {}


def build_for(mode, key):
    """Memoized (train_step, pipeline, placed_params) per (plan, mesh).

    Builder contract (Supervisor docstring): the batch schedule is
    mesh-independent — same seed, same global batch, dp extent 2 on
    every rung — so a replayed trajectory sees identical data.
    """
    if (mode, key) not in _BUILT:
        dims, ndev, tp = MESHES[key]
        mesh = jax.make_mesh(dims, ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2,
                             devices=jax.devices()[:ndev])
        cfg = mk_dense(tp)
        pipe = TokenPipeline(96, 32, 8, seed=5, mesh=mesh)
        params = family_of(cfg).init(jax.random.PRNGKey(2), mk_dense(1))
        # 1<<12 buckets: the config the pipelined-plan tests prove
        # bit-exact (deferred ≡ scheduled); larger buckets shift the
        # deferred AG's float fusion by ~1e-6 (pre-existing, see
        # tests/_mdworker.py check 10) and would turn the cross-plan
        # parity checks below into tolerance checks
        sync = GradSyncConfig(strategy="concom", bucket_bytes=1 << 12,
                              exclude_axes=("data",))
        ts = make_train_step(
            cfg, mesh, sync, zero1(adamw(1e-3), ("data",), 2),
            batch_like=pipe.batch_at(0), params_like=params,
            zero1_mode=True, zero1_plan=mode, clip_norm=0.0)
        ps = jax.device_put(params, ts.shardings(ts.param_specs))
        _BUILT[(mode, key)] = (ts, pipe, ps)
    return _BUILT[(mode, key)]


def run_plain(mode, key, n):
    ts, pipe, ps = build_for(mode, key)
    st = ts.init_opt()
    for k in range(n):
        ps, st, _ = ts.fn(ps, st, pipe.batch_at(k), jnp.int32(k))
    return ts, ps, st


# 1. StateCodec round-trip on the SAME mesh is bit-exact — scheduled
#    state (m, v) and deferred state (m, v, pending carry)
ts_s, p_s, o_s = run_plain("scheduled", "tp4", 2)
codec_s = StateCodec(ts_s)
enc = jax.device_get(codec_s.encode(p_s, o_s))
p_rt, o_rt = codec_s.decode(enc)
check("codec-roundtrip-scheduled-params", tree_maxdiff(p_s, p_rt) == 0.0)
check("codec-roundtrip-scheduled-opt", tree_maxdiff(o_s, o_rt) == 0.0)

ts_d, p_d, o_d = run_plain("deferred", "tp4", 2)
codec_d = StateCodec(ts_d)
enc_d = jax.device_get(codec_d.encode(p_d, o_d, include_pending=True))
check("codec-encodes-pending-stream", "pending" in enc_d["stats"])
p_drt, o_drt = codec_d.decode(enc_d)
check("codec-roundtrip-deferred-opt+pending",
      tree_maxdiff(o_d, o_drt) == 0.0
      and tree_maxdiff(p_d, p_drt) == 0.0)

# 2. zero-step 8→4→8 reshard round-trip is the identity (the tp-honest
#    global view survives a tp=4 → tp=2 → tp=4 relayout bit-for-bit)
ts_s2, _, _ = build_for("scheduled", "tp2")
p_4, o_4 = reshard_state(ts_s, ts_s2, p_s, o_s,
                         old_codec=codec_s, new_codec=StateCodec(ts_s2))
p_8, o_8 = reshard_state(ts_s2, ts_s, p_4, o_4,
                         old_codec=StateCodec(ts_s2), new_codec=codec_s)
check("reshard-8-4-8-roundtrip-params", tree_maxdiff(p_s, p_8) == 0.0)
check("reshard-8-4-8-roundtrip-opt", tree_maxdiff(o_s, o_8) == 0.0)

# 3. plan_reshard: verified transition IR with byte accounting, costable
#    by the simulator, and the analysis pass rejects a PRE op crossing
#    the REGROUP (the seeded mutation of the acceptance criteria)
rp = plan_reshard(ts_s, ts_s2, codec_s._params_like())
n_param = sum(int(np.prod(l.shape))
              for l in jax.tree.leaves(codec_s._params_like()))
check("plan-reshard-bytes-cover-streams",
      rp.reshard_bytes >= 3 * n_param * 4 and rp.streams[0] == "param")

from repro.sim.engine import SimConfig, simulate

merged = {"data": 2, "model": 4}
tl = simulate(rp.transition, merged, sim=SimConfig())
check("plan-reshard-sim-costable",
      tl.step_time > 0 and len(tl.events) == len(rp.transition.ops))

from repro.analysis import ScheduleError, verify_schedule
from repro.core.schedule import CommSchedule

mut_ops = list(rp.transition.ops)
mut_ops[0] = dataclasses.replace(mut_ops[0], phase="pre")
caught = False
try:
    verify_schedule(CommSchedule(tuple(mut_ops)), mesh_shape=None,
                    old_mesh_shape=rp.old_mesh_shape,
                    new_mesh_shape=rp.new_mesh_shape,
                    leaf_divisibility=rp.leaf_divisibility)
except ScheduleError as e:
    caught = "pre-crosses-regroup" in str(e)
check("plan-reshard-rejects-pre-crossing-regroup", caught)

# 4. measured per-op replay (repro.obs) of the codec's RESHARD programs:
#    gather side is bit-exact with the jitted gather, scatter side emits
#    one event per op
from repro.obs.measure import measured_timeline

gs = ts_s.gradsync
m_shards = {bid: o_s["inner"][k]["m"] for bid, k in codec_s.keys}
zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), p_s)
out_m, tl_m, _ = measured_timeline(
    codec_s._sched, zeros, codec_s.dp_plan, mesh=ts_s.mesh,
    param_specs=ts_s.param_specs, reducer=lambda b, _bk: b,
    mesh_shape=gs.mesh_shape, two_phase_impl=gs._two_phase_impl(),
    pending=m_shards)
ref_m = codec_s._gather(p_s, m_shards)
check("obs-replays-reshard-gather-bitexact",
      tree_maxdiff(out_m, ref_m) == 0.0
      and len(tl_m.events) == len(codec_s._sched.ops))

# 5. supervisor fault cycle, scheduled plan: transient step (absorbed by
#    rung-1 retry), checkpoint-I/O faults (absorbed by the manager's
#    backoff), rank loss at step 5 → shrink tp4→tp2, grow back at 10 —
#    and the clean scripted replay of the SAME mesh trajectory is
#    bit-exact with the faulty run
PLAN = FaultPlan(rank_loss=frozenset({5}), transient=frozenset({2}),
                 step_retries=1, ckpt_io_faults=2, ckpt_retries=3)
TOTAL, EVERY, GROW = 16, 4, 5


def run_super(mode, plan=None, script=None, **kw):
    root = tempfile.mkdtemp(prefix="elastic_")
    sup = Supervisor(lambda key: build_for(mode, key), ("tp4", "tp2"),
                     root, plan=plan, script=script, every=EVERY,
                     grow_back_after=GROW, printer=lambda s: None, **kw)
    p, o, rep = sup.run(TOTAL)
    shutil.rmtree(root, ignore_errors=True)
    return p, o, rep


pF, oF, repF = run_super("scheduled", plan=PLAN)
kindsF = [e["kind"] for e in repF["events"]]
check("supervisor-sched-cycle-script",
      repF["script"] == ((5, "tp2"), (10, "tp4"))
      and repF["final_mesh"] == "tp4")
check("supervisor-sched-events",
      "retry" in kindsF and "rank_lost" in kindsF
      and kindsF.count("transition") == 2)
check("supervisor-sched-metrics",
      repF["metrics"]["recovery_latency_s"]["count"] == 2
      and repF["metrics"]["reshard_bytes_total"] > 0)

pC, oC, repC = run_super("scheduled", script=repF["script"])
check("supervisor-sched-faulty-equals-clean-params",
      tree_maxdiff(pF, pC) == 0.0)
check("supervisor-sched-faulty-equals-clean-opt",
      tree_maxdiff(oF, oC) == 0.0)

# an uninterrupted tp4-only run is NOT bit-comparable (different tp →
# different reduction order on the middle segment) but must stay close
_, p_un, _ = run_plain("scheduled", "tp4", TOTAL)
check("supervisor-sched-close-to-uninterrupted",
      tree_maxdiff(pF, p_un) < 5e-2)

# 6. the same cycle under the DEFERRED plan: the pending carry is
#    flushed at each transition (finalize), decodes to the identity
#    carry on the new mesh, and the whole faulty run stays bit-exact
#    with its clean replay AND with the scheduled plan's trajectory
pFd, oFd, repFd = run_super("deferred", plan=PLAN)
check("supervisor-deferred-cycle-script",
      repFd["script"] == repF["script"])
pCd, oCd, repCd = run_super("deferred", script=repFd["script"])
check("supervisor-deferred-faulty-equals-clean",
      tree_maxdiff(pFd, pCd) == 0.0 and tree_maxdiff(oFd, oCd) == 0.0)
ts_d8, _, _ = build_for("deferred", "tp4")
check("supervisor-deferred-equals-scheduled-bitexact",
      tree_maxdiff(ts_d8.finalize(pFd, oFd), pF) == 0.0)

# 7. straggler-driven shrink (opt-in): two consecutive injected slow
#    steps trip the patience window, the remesh hook answers "shrink",
#    the supervisor transitions with the HEALTHY post-step state — and
#    the decision lands in the event stream
SPLAN = FaultPlan(straggler=frozenset({7, 8}), straggler_s=3.0,
                  straggler_shrink=True)
pS, oS, repS = run_super("scheduled", plan=SPLAN, straggler_factor=6.0,
                         straggler_patience=2)
remesh = [e for e in repS["events"] if e["kind"] == "remesh_requested"]
trans = repS["transitions"]
check("straggler-shrink-decision-event",
      bool(remesh) and remesh[0]["decision"] == "shrink")
check("straggler-shrink-transition",
      len(trans) == 2 and trans[0]["reason"] == "straggler_shrink"
      and trans[0]["resume_step"] == 9)
pSc, _, _ = run_super("scheduled", script=repS["script"])
check("straggler-shrink-faulty-equals-clean",
      tree_maxdiff(pS, pSc) == 0.0)

# 8. deferred-plan exact resume through the PLAIN checkpoint path: at
#    tp=1 the global view is honest, so CheckpointManager round-trips
#    the pending carry — a killed-and-recovered run matches the
#    uninterrupted one bit-for-bit; and the restore guard refuses a
#    checkpoint WITHOUT the carry
from repro.checkpoint.manager import CheckpointManager
from repro.runtime.train_loop import Trainer

ts1, pipe1, ps1 = build_for("deferred", "tp1")


def run_trainer(root, fail_at=frozenset(), every=2):
    ck = CheckpointManager(root, every=every, keep=0, blocking=True)
    tr = Trainer(ts1, pipe1, ck, fail_at=frozenset(fail_at),
                 printer=lambda s: None, log_every=10_000)
    return tr.run(ps1, ts1.init_opt(), 8)


root_a = tempfile.mkdtemp(prefix="elastic_")
root_b = tempfile.mkdtemp(prefix="elastic_")
p_kill, o_kill, rep_kill = run_trainer(root_a, fail_at={5})
p_ok, o_ok, _ = run_trainer(root_b)
kinds_k = [e["kind"] for e in rep_kill["events"]]
check("deferred-plain-ckpt-exact-resume",
      "recover" in kinds_k
      and tree_maxdiff(ts1.finalize(p_kill, o_kill),
                       ts1.finalize(p_ok, o_ok)) == 0.0)

# guard: a checkpoint saved WITHOUT the pending carry must be refused
no_pending = {"params": ps1,
              "opt": {k: v for k, v in ts1.init_opt().items()
                      if k != "pending"}}
root_c = tempfile.mkdtemp(prefix="elastic_")
CheckpointManager(root_c, every=1, blocking=True).maybe_save(
    1, no_pending)
guard_hit = False
try:
    run_trainer(root_c)
except RuntimeError as e:
    guard_hit = "pending" in str(e)
check("deferred-restore-guard-refuses-carry-less-ckpt", guard_hit)
for r in (root_a, root_b, root_c):
    shutil.rmtree(r, ignore_errors=True)

print("DONE", flush=True)
