"""Schedule-IR properties: chain count, chain length, op ordering —
pure-Python assertions on CommSchedule, no device mesh or HLO compile.

Plans are built from hand-constructed BucketPlans so each test runs in
microseconds; the numeric equivalence of the strategies is covered by
tests/test_strategies.py and tests/_mdworker.py.
"""
import jax.numpy as jnp
import pytest

from repro.core.buckets import Bucket, BucketPlan, LeafInfo
from repro.core.registry import (
    get_strategy,
    register_strategy,
    strategy_names,
)
from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    REDUCE_SCATTER,
    CollectiveOp,
    CommSchedule,
)


def _plan(n_buckets=6, num_channels=3, leaves_per_bucket=2):
    """A synthetic BucketPlan: round-robin channels like make_bucket_plan."""
    buckets = []
    idx = 0
    for bid in range(n_buckets):
        leaves = tuple(
            LeafInfo(name=f"g{idx + j}", index=idx + j, shape=(4,),
                     dtype=jnp.float32, size=4)
            for j in range(leaves_per_bucket))
        idx += leaves_per_bucket
        buckets.append(Bucket(leaves=leaves, reduce_axes=("data",),
                              channel=bid % num_channels, bucket_id=bid))
    return BucketPlan(buckets=tuple(buckets), treedef=None,
                      num_leaves=idx, comm_dtype=jnp.float32)


def _bucket_ids(ops):
    return [op.bucket.bucket_id for op in ops]


def test_funnel_single_chain_through_all_buckets():
    plan = _plan(n_buckets=6, num_channels=3)
    s = get_strategy("funnel").plan(plan)
    assert s.num_chains == 1
    assert s.chain_lengths() == {0: 6}
    # creation order, each op waits on the previous (fully serialized)
    assert s.bucket_order() == (0, 1, 2, 3, 4, 5)
    for prev, op in zip(s.ops, s.ops[1:]):
        assert op.depends_on == (prev.op_id,)


def test_concom_chain_per_channel():
    for n_buckets, channels in [(6, 3), (2, 4), (8, 4), (5, 2)]:
        plan = _plan(n_buckets=n_buckets, num_channels=channels)
        s = get_strategy("concom").plan(plan)
        assert s.num_chains == min(channels, n_buckets)
        # chains are mutually independent: deps never cross chains
        by_id = {op.op_id: op for op in s.ops}
        for op in s.ops:
            assert all(by_id[d].chain == op.chain for d in op.depends_on)
        # union covers every bucket exactly once
        assert sorted(_bucket_ids(s.ops)) == list(range(n_buckets))


def test_depcha_drops_in_scan_leaves():
    plan = _plan(n_buckets=4, num_channels=2, leaves_per_bucket=2)
    # skip one leaf of bucket 0 and BOTH leaves of bucket 2
    skip = frozenset({"g0", "g4", "g5"})
    s = get_strategy("depcha").plan(plan, skip_names=skip)
    assert s.leaf_names() == {"g1", "g2", "g3", "g6", "g7"}
    # bucket 2 vanished entirely; bucket 0 survives with one leaf
    assert sorted(set(_bucket_ids(s.ops))) == [0, 1, 3]
    b0 = next(op.bucket for op in s.ops if op.bucket.bucket_id == 0)
    assert [l.name for l in b0.leaves] == ["g1"]


def test_depcha_without_skips_matches_concom():
    plan = _plan(n_buckets=6, num_channels=3)
    d = get_strategy("depcha").plan(plan)
    c = get_strategy("concom").plan(plan)
    assert d == c


def test_priority_reverses_bucket_order():
    plan = _plan(n_buckets=8, num_channels=3)
    s = get_strategy("priority").plan(plan)
    c = get_strategy("concom").plan(plan)
    assert s.num_chains == c.num_chains == 3
    for ch in range(3):
        assert s.bucket_order(ch) == tuple(reversed(c.bucket_order(ch)))
        ids = s.bucket_order(ch)
        assert list(ids) == sorted(ids, reverse=True)
    # single channel → globally exact reverse of funnel
    plan1 = _plan(n_buckets=5, num_channels=1)
    s1 = get_strategy("priority").plan(plan1)
    assert s1.bucket_order() == (4, 3, 2, 1, 0)


def test_rsag_two_phase_structure():
    plan = _plan(n_buckets=6, num_channels=3)
    s = get_strategy("rsag").plan(plan)
    assert s.stats()["kinds"] == {REDUCE_SCATTER: 6, ALL_GATHER: 6}
    by_id = {op.op_id: op for op in s.ops}
    rs = [op for op in s.ops if op.kind == REDUCE_SCATTER]
    ag = [op for op in s.ops if op.kind == ALL_GATHER]
    # each AG waits ONLY on its own RS (so AG_i overlaps RS_{i+1})
    for op in ag:
        assert len(op.depends_on) == 1
        dep = by_id[op.depends_on[0]]
        assert dep.kind == REDUCE_SCATTER
        assert dep.bucket.bucket_id == op.bucket.bucket_id
    # RS stream is serialized per channel
    for ch in range(3):
        chain_rs = [op for op in rs if op.chain == ch]
        for prev, op in zip(chain_rs, chain_rs[1:]):
            assert op.depends_on == (prev.op_id,)
    # bucket_order counts each RS/AG pair once
    assert sorted(s.bucket_order()) == list(range(6))


def test_validate_rejects_forward_and_duplicate_deps():
    b = _plan(n_buckets=2, num_channels=1).buckets
    with pytest.raises(ValueError, match="does not[\\s\\S]*precede"):
        CommSchedule((
            CollectiveOp(op_id=0, bucket=b[0], chain=0, depends_on=(1,)),
            CollectiveOp(op_id=1, bucket=b[1], chain=0),
        )).validate()
    with pytest.raises(ValueError, match="duplicate"):
        CommSchedule((
            CollectiveOp(op_id=0, bucket=b[0], chain=0),
            CollectiveOp(op_id=0, bucket=b[1], chain=0),
        )).validate()
    with pytest.raises(ValueError, match="unknown kind"):
        CommSchedule((
            CollectiveOp(op_id=0, bucket=b[0], chain=0, kind="bogus"),
        )).validate()


def test_registry_is_the_single_source_of_truth():
    from repro.core import strategies

    import repro.core

    names = strategy_names()
    assert {"funnel", "concom", "depcha", "priority", "rsag"} <= set(names)
    # STRATEGIES/REDUCERS are registry-derived LIVE views, not snapshots
    assert strategies.STRATEGIES == names
    assert repro.core.STRATEGIES == names
    assert set(strategies.REDUCERS) >= {"flat", "hierarchical", "compressed"}
    assert repro.core.REDUCERS == strategies.REDUCERS
    # metadata replaces name-string special cases
    assert get_strategy("depcha").uses_in_scan
    assert get_strategy("funnel").single_chain
    assert get_strategy("rsag").two_phase
    assert not get_strategy("concom").uses_in_scan
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("nope")
    # duplicate registration is an error
    with pytest.raises(ValueError, match="already registered"):
        register_strategy("funnel")(lambda plan, **kw: None)


def test_kvstore_records_same_ir(smoke_mesh):
    """KVStore traces the ops it emits as CommSchedule IR."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import KVStore

    recorded = {}

    def step(a, b):
        kv = KVStore.create("concom", reduce_axes=("data",), num_channels=2)
        kv.push(0, a)
        kv.push(1, b)
        o0, o1 = kv.pull(0), kv.pull(1)
        s = kv.schedule()
        recorded["stats"] = s.stats()
        recorded["chains"] = s.chain_lengths()
        return o0, o1

    g1 = jnp.arange(6.0).reshape(2, 3)
    g2 = jnp.ones((5,))
    o0, o1 = jax.jit(lambda a, b: jax.shard_map(
        step, mesh=smoke_mesh, in_specs=(P(), P()),
        out_specs=(P(), P()), check_vma=False)(a, b))(g1, g2)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(g1))
    assert recorded["stats"] == {
        "num_ops": 2, "num_chains": 2, "max_chain_len": 1,
        "kinds": {ALLREDUCE: 2}, "phases": {"post": 2}}
    assert recorded["chains"] == {0: 1, 1: 1}
