"""Continuous-batching serving runtime (DESIGN.md §14): paged KV-cache
allocator, in-flight batching engine, sharded sampling, error
propagation, and the decode-plan cost model."""
import queue

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf
from repro.runtime import (
    BlockAllocator,
    ContinuousScheduler,
    PagedLayout,
    RequestQueue,
    SamplingParams,
    Server,
    sharded_sample,
)
from repro.runtime.kvcache import SCRATCH_BLOCK, blocks_for


@pytest.fixture(scope="module")
def setup(smoke_mesh):
    cfg = tf.TransformerConfig(
        name="serve", n_layers=2, d_model=32, n_heads=4, kv_heads=2,
        d_ff=64, vocab=64, tp=1, attn_chunk=16, dtype=jnp.float32)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, smoke_mesh, params, max_len=32)
    eng = ContinuousScheduler(srv, slots=4, block_size=8, chunk=4)
    return cfg, srv, eng


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(L)).astype(np.int32)
            for L in rng.integers(3, 20, size=n)]


# ------------------------------------------------------------- kvcache
def test_blocks_for():
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2
    assert blocks_for(0, 8) == 0


def test_paged_layout_capacity():
    lay = PagedLayout.for_requests(32, 8, 4)
    assert lay.max_blocks == 4                  # 32/8 per request
    assert lay.seq_capacity == 32
    assert lay.usable_blocks == 4 * 4           # slots × per-request
    assert lay.num_blocks == 1 + 16             # + the scratch block


def test_allocator_all_or_nothing_and_reuse():
    lay = PagedLayout.for_requests(32, 8, 2)    # 8 usable blocks
    alloc = BlockAllocator(lay)
    a = alloc.alloc(32)                         # 4 blocks
    b = alloc.alloc(32)                         # the other 4
    assert len(a) == len(b) == 4
    assert SCRATCH_BLOCK not in a + b           # block 0 is never handed out
    assert alloc.alloc(1) is None               # pool exhausted: no partial
    assert not alloc.can_fit(1)
    assert alloc.in_use == 8
    assert alloc.utilization == 1.0
    alloc.free(a)
    assert alloc.can_fit(32)
    c = alloc.alloc(9)                          # 2 blocks
    row = alloc.table_row(c)
    assert len(row) == lay.max_blocks
    assert row[:2] == c
    assert all(r == SCRATCH_BLOCK for r in row[2:])


# ----------------------------------------------- static path regressions
def test_request_queue_delivers_errors(setup, smoke_mesh):
    """A raise inside Server.generate must reach EVERY waiter — before
    this regression test, waiters blocked forever on a failed batch."""
    cfg, srv, _ = setup

    class Boom(Server):
        def __init__(self):             # reuse srv's state, poison generate
            self.__dict__.update(srv.__dict__)

        def generate(self, prompts, max_new, **kw):
            raise RuntimeError("device lost")

    q = RequestQueue(Boom(), batch=4)
    handles = [q.submit(np.arange(1, 6, dtype=np.int32), 3)
               for _ in range(3)]
    assert q.serve_once() == 3
    for h in handles:
        out = h.get(timeout=5)
        assert isinstance(out, RuntimeError)


def test_sync_per_token_parity(setup):
    """Device-side token accumulation (one sync per generate) must be a
    pure perf change: identical output to the per-token-sync path."""
    _, srv, _ = setup
    prompts = np.arange(1, 9, dtype=np.int32)[None].repeat(2, 0)
    fast = srv.generate(prompts, 6)
    slow = srv.generate(prompts, 6, sync_per_token=True)
    np.testing.assert_array_equal(fast, slow)


# ------------------------------------------- continuous-batching engine
def test_continuous_greedy_bit_exact_with_static(setup):
    """Mixed-length prompts through the paged engine yield EXACTLY the
    static path's greedy tokens (acceptance criterion: paged KV-cache is
    bit-exact under greedy)."""
    cfg, srv, eng = setup
    prompts = _prompts(6, cfg.vocab)
    outs = eng.generate_batch(prompts, 8)
    for p, o in zip(prompts, outs):
        ref = srv.generate(p[None], 8)[0]
        np.testing.assert_array_equal(o, ref)


def test_continuous_oversubscribed_slots_drain(setup):
    """More requests than slots: admission recycles retired slots and
    every request completes with its own budget."""
    cfg, _, eng = setup
    prompts = _prompts(11, cfg.vocab, seed=1)
    outs = eng.generate_batch(prompts, 5)
    assert len(outs) == 11
    assert all(o.shape == (5,) for o in outs)
    assert eng.idle
    assert all(a.in_use == 0 for a in eng.allocators)


def test_continuous_rejects_oversized_request(setup):
    _, _, eng = setup
    done = eng.submit(np.ones(30, np.int32), 10)    # 40 > capacity 32
    out = done.get(timeout=5)
    assert isinstance(out, ValueError)
    assert eng.idle                                  # nothing was admitted


def test_continuous_seed_reproducible(setup):
    cfg, _, eng = setup
    prompts = _prompts(3, cfg.vocab, seed=2)
    sp = SamplingParams(temperature=0.8, top_k=8, seed=42)
    a = eng.generate_batch(prompts, 8, sp)
    b = eng.generate_batch(prompts, 8, sp)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = eng.generate_batch(prompts, 8,
                           SamplingParams(temperature=0.8, top_k=8, seed=7))
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_continuous_topk1_equals_greedy(setup):
    cfg, _, eng = setup
    prompts = _prompts(3, cfg.vocab, seed=3)
    greedy = eng.generate_batch(prompts, 6)
    k1 = eng.generate_batch(prompts, 6,
                            SamplingParams(temperature=0.9, top_k=1, seed=5))
    for x, y in zip(k1, greedy):
        np.testing.assert_array_equal(x, y)


def test_continuous_temp_zero_equals_greedy(setup):
    cfg, _, eng = setup
    prompts = _prompts(2, cfg.vocab, seed=4)
    greedy = eng.generate_batch(prompts, 6)
    t0 = eng.generate_batch(prompts, 6,
                            SamplingParams(temperature=0.0, top_k=4, seed=9))
    for x, y in zip(t0, greedy):
        np.testing.assert_array_equal(x, y)


def test_continuous_eos_stops_early(setup):
    """EOS mid-chunk retires the slot and truncates the output AT the
    EOS token; blocks free immediately."""
    cfg, srv, _ = setup
    prompt = _prompts(1, cfg.vocab, seed=5)[0]
    ref = srv.generate(prompt[None], 8)[0]
    eos = int(ref[3])                       # greedy token at step 3
    eng = ContinuousScheduler(srv, slots=4, block_size=8, chunk=4,
                              eos_id=eos)
    out = eng.generate_batch([prompt], 8)[0]
    stop = int(np.argmax(ref == eos))       # first occurrence in reference
    np.testing.assert_array_equal(out, ref[:stop + 1])
    assert all(a.in_use == 0 for a in eng.allocators)


# ------------------------------------------------------ sharded sampling
def test_sharded_sample_tp1_matches_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4, dtype=jnp.uint32))
    out = sharded_sample(logits, 1, keys,
                         jnp.zeros(4), jnp.zeros(4, jnp.int32), jnp.ones(4))
    np.testing.assert_array_equal(
        np.asarray(out), np.argmax(np.asarray(logits), axis=-1))


def test_sharded_sample_respects_topk():
    """With top_k=2 every draw lands in the two best candidates."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(1, 64)), jnp.float32)
    top2 = set(np.argsort(-np.asarray(logits[0]))[:2].tolist())
    for s in range(16):
        keys = jax.random.PRNGKey(s)[None]
        out = sharded_sample(logits, 1, keys, jnp.ones(1) * 5.0,
                             jnp.full(1, 2, jnp.int32), jnp.ones(1))
        assert int(out[0]) in top2


# ------------------------------------------------- decode-plan cost model
def test_decode_plan_verifies_and_ranks():
    from repro.sim import DecodeModel, rank_decode_plans

    m = DecodeModel(n_layers=4, layer_params_local=1 << 18,
                    head_params_local=1 << 18, d_model=256, vocab=8192,
                    tp=4, dp=2, batch=8)
    ranked = rank_decode_plans(m, {"data": 2, "model": 4})
    assert [r["findings"] for r in ranked] == [[], [], []]
    times = {r["sampler"]: r["token_time"] for r in ranked}
    # the candidate gathers beat the naive full-vocab gather
    assert times["argmax"] < times["full"]
    assert times["topk"] < times["full"]
    assert all(r["tokens_per_s"] > 0 for r in ranked)


def test_decode_plan_schedule_shape():
    from repro.core.schedule import ALL_GATHER, ALLREDUCE, DECODE
    from repro.sim import DecodeModel, plan_decode

    m = DecodeModel(n_layers=3, layer_params_local=100,
                    head_params_local=100, d_model=16, vocab=128,
                    tp=2, batch=1)
    sched = plan_decode(m, sampler="topk", k_cand=4).validate()
    kinds = [op.kind for op in sched.ops]
    assert kinds.count(DECODE) == 3 + 2         # layers + head + sampler
    assert kinds.count(ALLREDUCE) == 2 * 3      # attn + ffn psums per layer
    assert kinds.count(ALL_GATHER) == 1
    # single chain, fully serialized: each op depends on its predecessor
    for prev, op in zip(sched.ops, sched.ops[1:]):
        assert op.depends_on == (prev.op_id,)


def test_decode_plan_tp1_has_no_wire_ops():
    from repro.core.schedule import DECODE
    from repro.sim import DecodeModel, plan_decode, simulate_decode

    m = DecodeModel(n_layers=2, layer_params_local=100,
                    head_params_local=100, d_model=16, vocab=128)
    sched = plan_decode(m, sampler="argmax")
    assert all(op.kind == DECODE for op in sched.ops)
    tl = simulate_decode(sched, {"data": 1, "model": 1})
    assert len(tl.events) == len(sched.ops)
    assert tl.step_time > 0
    assert tl.comm_end == tl.step_time          # pure dependency chain


def test_decode_op_flows_through_emitter():
    """The IR emitter treats DECODE as a pure scheduling point: token
    gating only, leaves untouched, node recorded in aux."""
    from repro.core.buckets import BucketPlan
    from repro.core.schedule import execute
    from repro.sim import DecodeModel, plan_decode

    m = DecodeModel(n_layers=2, layer_params_local=8,
                    head_params_local=8, d_model=4, vocab=16)
    sched = plan_decode(m, sampler="argmax")    # tp=1: DECODE ops only
    grads = {"x": jnp.arange(4.0)}
    flat, treedef = jax.tree_util.tree_flatten(grads)
    plan = BucketPlan(buckets=(), treedef=treedef, num_leaves=len(flat),
                      comm_dtype=jnp.float32)
    aux = {}
    out = jax.jit(lambda g: execute(
        sched, g, plan, reducer=lambda b, _bk: b, aux=aux))(grads)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(grads["x"]))
    assert len(aux["decode_nodes"]) == len(sched.ops)
