"""Config integrity: the FULL assigned configs (via eval_shape only — no
allocation) must match the assignment table and plausible param counts."""
import numpy as np
import pytest

from repro.configs import ARCHS, param_structs
from repro.utils.trees import named_leaves

# arch -> (expected total params, rel tolerance). MoE = total (not active).
EXPECTED_PARAMS = {
    "llama-3.2-vision-11b": (11e9, 0.25),
    "musicgen-large": (2.2e9, 0.4),        # 48L d2048 + small vocab
    "h2o-danube-1.8b": (1.8e9, 0.25),
    "qwen3-1.7b": (2.0e9, 0.3),
    "starcoder2-3b": (3.2e9, 0.3),         # incl. padded heads
    "minitron-8b": (8.3e9, 0.25),
    "rwkv6-7b": (7.6e9, 0.3),
    "granite-moe-1b-a400m": (1.3e9, 0.35),
    "kimi-k2-1t-a32b": (1.0e12, 0.15),
    "zamba2-2.7b": (2.7e9, 0.35),
}

ASSIGNED_TABLE = {
    # arch: (n_layers, d_model, vocab)
    "llama-3.2-vision-11b": (40, 4096, 128256),
    "musicgen-large": (48, 2048, 2048),
    "h2o-danube-1.8b": (24, 2560, 32000),
    "qwen3-1.7b": (28, 2048, 151936),
    "starcoder2-3b": (30, 3072, 49152),
    "minitron-8b": (32, 4096, 256000),
    "rwkv6-7b": (32, 4096, 65536),
    "granite-moe-1b-a400m": (24, 1024, 49155),
    "kimi-k2-1t-a32b": (61, 7168, 163840),
    "zamba2-2.7b": (54, 2560, 32000),
}


@pytest.mark.parametrize("arch_id", sorted(EXPECTED_PARAMS))
def test_full_config_param_count(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.make_config(tp=16, dp_axes=("data",))
    structs = param_structs(cfg)          # eval_shape: no allocation
    total = sum(int(np.prod(l.shape)) for _, l in named_leaves(structs))
    want, tol = EXPECTED_PARAMS[arch_id]
    assert abs(total - want) / want < tol, (
        f"{arch_id}: {total/1e9:.2f}B params vs expected "
        f"{want/1e9:.2f}B ±{tol*100:.0f}%")


@pytest.mark.parametrize("arch_id", sorted(ASSIGNED_TABLE))
def test_assigned_dims(arch_id):
    cfg = ARCHS[arch_id].make_config(tp=16, dp_axes=("data",))
    L, d, v = ASSIGNED_TABLE[arch_id]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.vocab == v


def test_all_archs_have_smoke_and_shapes():
    for aid, arch in ARCHS.items():
        assert arch.make_smoke() is not None
        assert len(arch.shapes) >= 1
        if arch.family in ("transformer", "rwkv", "ssm"):
            names = {s.name for s in arch.shapes}
            assert names == {"train_4k", "prefill_32k", "decode_32k",
                             "long_500k"}, aid


def test_full_configs_divisible_for_production_mesh():
    """Every sharded dim of every full config divides tp=16 (the
    production model axis) — the dry-run proves this end-to-end; this is
    the fast structural check."""
    from repro.models.registry import family_of

    for aid in EXPECTED_PARAMS:
        cfg = ARCHS[aid].make_config(tp=16, dp_axes=("data",))
        api = family_of(cfg)
        rules = api.param_rules(cfg)
        structs = param_structs(cfg)
        for name, leaf in named_leaves(structs):
            spec = rules.spec(name)
            for dim, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                n = 1
                for a in axes:
                    n *= {"model": 16, "data": 16, "pod": 2}[a]
                assert leaf.shape[dim] % n == 0, (aid, name, dim)
