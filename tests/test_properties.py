"""Property-based tests (hypothesis) on system invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (requirements-dev.txt)")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from jax.sharding import PartitionSpec as P

from repro.core.buckets import make_bucket_plan, pack, unpack
from repro.core.compression import (
    dequantize_blockwise,
    quantize_blockwise,
)
from repro.models.common import HeadLayout, rms_norm
from repro.parallel.sharding import ShardingRules

hypothesis.settings.register_profile(
    "fast", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("fast")


@st.composite
def leaf_shapes(draw):
    n = draw(st.integers(1, 6))
    return [tuple(draw(st.lists(st.integers(1, 8), min_size=1, max_size=3)))
            for _ in range(n)]


@given(leaf_shapes(), st.integers(0, 256), st.integers(1, 4))
def test_bucket_plan_covers_each_leaf_once(shapes, bucket_bytes, channels):
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh(1, 1)
    grads = {f"g{i}": jnp.zeros(s, jnp.float32)
             for i, s in enumerate(shapes)}
    specs = jax.tree.map(lambda _: P(), grads)
    plan = make_bucket_plan(grads, specs, mesh,
                            bucket_bytes=bucket_bytes,
                            num_channels=channels)
    names = [l.name for b in plan.buckets for l in b.leaves]
    assert sorted(names) == sorted(grads)
    # size-capped: any multi-leaf bucket is within cap (single leaves may
    # exceed — a leaf larger than the cap still needs one collective)
    for b in plan.buckets:
        if bucket_bytes and len(b.leaves) > 1:
            assert b.size * 4 <= bucket_bytes or len(b.leaves) == 1
    # channels are within range
    assert all(0 <= b.channel < channels for b in plan.buckets)


@given(leaf_shapes())
def test_pack_unpack_identity(shapes):
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh(1, 1)
    rng = np.random.default_rng(0)
    grads = {f"g{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
             for i, s in enumerate(shapes)}
    specs = jax.tree.map(lambda _: P(), grads)
    plan = make_bucket_plan(grads, specs, mesh, bucket_bytes=97)
    flat = jax.tree.leaves(grads)
    out = [None] * len(flat)
    for b in plan.buckets:
        unpack(b, pack(b, flat, jnp.float32), out)
    for got, want in zip(out, flat):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)


@given(st.integers(1, 64), st.floats(1e-5, 1e4))
def test_quantize_error_bound(n_blocks, scale):
    rng = np.random.default_rng(n_blocks)
    x = jnp.asarray(rng.standard_normal(n_blocks * 256) * scale,
                    jnp.float32)
    q, s = quantize_blockwise(x)
    xd = dequantize_blockwise(q, s)
    err = np.abs(np.asarray(x) - np.asarray(xd)).reshape(-1, 256)
    bound = np.asarray(s)[:, None] * 0.5 * (1 + 1e-5) + 1e-8
    assert np.all(err <= bound)


@given(st.integers(1, 8), st.integers(1, 32))
def test_rms_norm_scale_invariance(b, d):
    """rms_norm(c*x) ≈ rms_norm(x) for c>0 — exact up to the eps term
    (eps=1e-6 regularizes the rsqrt, so tiny-variance rows deviate)."""
    rng = np.random.default_rng(b * 100 + d)
    x = jnp.asarray(rng.standard_normal((b, d)) + 0.1, jnp.float32)
    g = jnp.ones((d,), jnp.float32)
    y1 = rms_norm(x, g)
    y2 = rms_norm(x * 7.5, g)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)


@given(st.sampled_from([1, 2, 4, 8, 16]),
       st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
       st.sampled_from([1, 2, 4, 8, 16, 32]))
def test_head_layout_invariants(tp, n_heads, kv_heads):
    hypothesis.assume(n_heads % tp == 0)
    hypothesis.assume(kv_heads <= n_heads)
    hypothesis.assume(n_heads % kv_heads == 0)
    lay = HeadLayout(n_heads, kv_heads, 64, tp)
    assert lay.q_local * tp == n_heads
    if lay.kv_sharded:
        assert lay.kv_local * tp == kv_heads
    else:
        # every device's q heads map to exactly the kv heads it slices
        group = lay.group
        for dev in range(tp):
            start = (dev * lay.q_local) // group
            for qi in range(lay.q_local):
                g_q = dev * lay.q_local + qi
                kv = g_q // group
                assert start <= kv < start + lay.kv_local


@given(st.integers(1, 5), st.integers(1, 3))
def test_sharding_rules_first_match_wins(n_rules, seed):
    rules = ShardingRules(rules=tuple(
        (f"w{i}", P("model" if i % 2 == 0 else None))
        for i in range(n_rules)))
    # w0 matches rule 0 regardless of later rules
    assert rules.spec("blocks/w0") == P("model")
    assert rules.spec("nomatch") == P()
