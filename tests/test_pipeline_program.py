"""Pipeline schedules as CommSchedule programs (DESIGN.md §15).

Plan-shape and costing tests run in-process (pure IR, no devices); the
SEND/RECV emitter's executed semantics need >1 device and run in a
subprocess.  Property tests ride Hypothesis when it is installed.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.pipeline_program import (
    PipelinePlan,
    SCHEDULES,
    Slot,
    bucket_stage_map,
    compose_step,
    max_in_flight,
    plan_pipeline,
)
from repro.core.schedule import RECV, SEND
from repro.sim.autotune import choose_pp_schedule
from repro.sim.compute import ComputeModel, pipeline_timeline

CM = ComputeModel(t_fwd=1.0, t_bwd=2.0)


def n_boundary_ops(S_tot, M):
    # per phase: (S_tot - 1) crossings per microbatch, SEND + RECV each
    return 2 * 2 * (S_tot - 1) * M


@pytest.mark.parametrize("kind", ["gpipe", "1f1b"])
@pytest.mark.parametrize("S,M", [(2, 2), (2, 4), (4, 8)])
def test_plan_shape(kind, S, M):
    plan = plan_pipeline(S, M, kind=kind, activation_bytes=1 << 10)
    ops = plan.schedule.ops
    assert len(ops) == n_boundary_ops(S, M)
    sends = [o for o in ops if o.kind == SEND]
    recvs = [o for o in ops if o.kind == RECV]
    assert len(sends) == len(recvs) == len(ops) // 2
    # every RECV pairs with exactly one SEND: same bucket, SEND in deps
    by_bucket = {o.bucket.bucket_id: o for o in sends}
    for r in recvs:
        s = by_bucket[r.bucket.bucket_id]
        assert s.op_id in r.depends_on
        assert r.shift == s.shift
    # activations ride +1, cotangents -1
    shifts = {plan.op_slot[o.op_id][1].phase: o.shift for o in ops}
    assert shifts["F"] == 1 and shifts["B"] == -1


def test_interleaved_plan():
    plan = plan_pipeline(2, 8, kind="interleaved", virtual=2,
                         activation_bytes=1 << 10)
    assert plan.total_stages == 4
    assert len(plan.schedule.ops) == n_boundary_ops(4, 8)
    # device of global stage g is g % S: stage 2 lives on device 0
    devs = {s.stage: d for d, s in plan.commits}
    assert devs[0] == devs[2] == 0 and devs[1] == devs[3] == 1


def test_plan_rejects_bad_args():
    with pytest.raises(ValueError):
        plan_pipeline(0, 4, activation_bytes=0)
    with pytest.raises(ValueError):
        plan_pipeline(2, 0, activation_bytes=0)
    with pytest.raises(ValueError):
        plan_pipeline(2, 4, kind="gpipe", virtual=2, activation_bytes=0)
    with pytest.raises(ValueError):
        plan_pipeline(2, 4, kind="wavefront", activation_bytes=0)
    with pytest.raises(ValueError):
        plan_pipeline(2, 4, kind="1f1b", virtual=2, activation_bytes=0)


def test_1f1b_in_flight_bound():
    for S, M in [(2, 4), (4, 8), (3, 9)]:
        plan = plan_pipeline(S, M, kind="1f1b", activation_bytes=1 << 10)
        assert max_in_flight(plan) <= S
        gp = plan_pipeline(S, M, kind="gpipe", activation_bytes=1 << 10)
        assert max_in_flight(gp) == M   # gpipe flushes everything


def test_gpipe_bubble_closed_form():
    for S, M in [(2, 2), (2, 8), (4, 4), (4, 16)]:
        plan = plan_pipeline(S, M, kind="gpipe", activation_bytes=1 << 10)
        tl = pipeline_timeline(plan, CM, wire_time=0.0)
        assert tl.bubble_fraction == pytest.approx((S - 1) / (M + S - 1))


def test_1f1b_beats_gpipe_wall():
    for S, M in [(2, 2), (2, 8), (4, 8)]:
        walls = {}
        for kind in ("gpipe", "1f1b"):
            plan = plan_pipeline(S, M, kind=kind,
                                 activation_bytes=1 << 20)
            walls[kind] = pipeline_timeline(plan, CM, wire_time=0.3).wall
        assert walls["1f1b"] < walls["gpipe"]


def test_choose_pp_schedule_never_worse_than_fixed():
    for S, M in [(2, 2), (2, 8), (4, 8)]:
        pick = choose_pp_schedule(S, M, activation_bytes=1 << 20)
        assert pick in SCHEDULES

        def wall(kind):
            plan = plan_pipeline(S, M, kind=kind,
                                 activation_bytes=1 << 20)
            return pipeline_timeline(plan, CM, wire_time=0.0).wall

        # at wire 0 the analytic walls rank the same way the chooser
        # saw them (same cost model): the pick's wall is the min
        walls = {k: wall(k) for k in ("gpipe", "1f1b")}
        assert walls[pick] == min(walls.values())


def test_compose_step_releases_buckets_by_stage():
    from repro.core.buckets import Bucket, LeafInfo
    from repro.core.schedule import CollectiveOp, CommSchedule, ALLREDUCE

    pp = plan_pipeline(2, 4, kind="1f1b", activation_bytes=1 << 10)
    mk = lambda bid, oid, deps: CollectiveOp(
        op_id=oid, bucket=Bucket(
            leaves=(LeafInfo(name=f"b{bid}", index=0, shape=(8,),
                             dtype=np.float32, size=8),),
            reduce_axes=("data",), channel=0, bucket_id=bid),
        chain=0, depends_on=deps, kind=ALLREDUCE)
    sync = CommSchedule((mk(0, 0, ()), mk(1, 1, (0,))))
    joint, id_map = compose_step(pp, sync)
    off = len(pp.schedule.ops)
    assert id_map == {0: off, 1: off + 1}
    smap = bucket_stage_map(pp, sync)
    # bucket 0 = output-side = LAST stage (first to drain under 1f1b)
    assert smap[0] == 1 and smap[1] == 0
    for op in joint.ops[off:]:
        rel = pp.final_backward_op(smap[op.bucket.bucket_id])
        assert rel in op.depends_on


def test_timeline_release_times_cover_all_ops():
    plan = plan_pipeline(2, 4, kind="1f1b", activation_bytes=1 << 10)
    tl = pipeline_timeline(plan, CM, wire_time=0.1)
    assert set(tl.op_release) == {o.op_id for o in plan.schedule.ops}
    assert tl.wall >= tl.fwd_wall > 0
    assert len(tl.stage_grad_release) == plan.total_stages
    # gradients drain in reverse stage order under 1f1b: stage 1's last
    # backward retires before stage 0's
    assert tl.stage_grad_release[1] < tl.stage_grad_release[0]


# --- executed SEND/RECV semantics (subprocess: needs 2 devices) -------

WORKER = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import warnings; warnings.filterwarnings("ignore")
import repro  # applies the jaxcompat shim before jax imports
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.core.buckets import Bucket, BucketPlan, LeafInfo
from repro.core.schedule import (CollectiveOp, CommSchedule, RECV, SEND,
                                 execute)
from repro.core.strategies import make_reducer

mesh = jax.make_mesh((2,), ("stage",), axis_types=(AxisType.Auto,))
N = 8
x = jnp.arange(2 * N, dtype=jnp.float32)     # rank r holds [rN, rN+N)
bucket = Bucket(
    leaves=(LeafInfo(name="act", index=0, shape=(N,), dtype=jnp.float32,
                     size=N),),
    reduce_axes=("stage",), channel=0, bucket_id=0)
sched = CommSchedule((
    CollectiveOp(op_id=0, bucket=bucket, chain=0, depends_on=(),
                 kind=SEND, shift=1),
    CollectiveOp(op_id=1, bucket=bucket, chain=0, depends_on=(0,),
                 kind=RECV, shift=1),
)).validate()
treedef = jax.tree_util.tree_structure([0])
plan = BucketPlan(buckets=(bucket,), treedef=treedef, num_leaves=1,
                  comm_dtype=jnp.float32)

def f(xs):
    out = execute(sched, [xs], plan,
                  reducer=make_reducer("flat", {"stage": 2},
                                       mean_axes=()),
                  mesh_shape={"stage": 2}, mean_axes=())
    return out[0]

out = jax.jit(lambda v: jax.shard_map(
    f, mesh=mesh, in_specs=(P("stage"),), out_specs=P("stage"))(v))(x)
got = np.asarray(out)
want = np.concatenate([np.arange(N, 2 * N), np.arange(0, N)])
print("SENDRECV_OK" if np.array_equal(got, want)
      else f"SENDRECV_FAIL {got}")
'''


def test_send_recv_moves_payload_subprocess(tmp_path):
    script = tmp_path / "sr_worker.py"
    script.write_text(WORKER)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SENDRECV_OK" in proc.stdout, proc.stdout


# --- Hypothesis properties (skipped when hypothesis is absent) --------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:   # pragma: no cover — optional dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(S=st.integers(2, 4), M=st.integers(1, 12))
    def test_prop_1f1b_in_flight_le_stages(S, M):
        plan = plan_pipeline(S, M, kind="1f1b",
                             activation_bytes=1 << 10)
        assert max_in_flight(plan) <= S

    @settings(max_examples=25, deadline=None)
    @given(S=st.integers(1, 4), M=st.integers(1, 12))
    def test_prop_gpipe_bubble_formula(S, M):
        plan = plan_pipeline(S, M, kind="gpipe",
                             activation_bytes=1 << 10)
        tl = pipeline_timeline(plan, CM, wire_time=0.0)
        assert tl.bubble_fraction == pytest.approx(
            (S - 1) / (M + S - 1))

    @settings(max_examples=25, deadline=None)
    @given(S=st.integers(2, 4), M=st.integers(2, 12),
           wire=st.floats(0.01, 1.0))
    def test_prop_1f1b_wall_beats_gpipe(S, M, wire):
        if M < S:
            return   # the claim is for M >= S
        walls = {}
        for kind in ("gpipe", "1f1b"):
            plan = plan_pipeline(S, M, kind=kind,
                                 activation_bytes=1 << 20)
            walls[kind] = pipeline_timeline(
                plan, CM, wire_time=wire).wall
        assert walls["1f1b"] < walls["gpipe"]
else:   # keep a visible skip marker in the test report
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_pipeline_properties():
        pass
