"""Fused comm-staging + ring collective kernels (DESIGN.md §8).

Interpret-mode parity against the leafwise/jnp oracles, the staging
knobs through the real emitter, bucket-plan memoization, and the
donation regression.  Real-process-group ring equivalence
(ring RS/AG ≡ psum_scatter/all_gather, ring reducer end-to-end) runs on
the 8-fake-device mesh in tests/_mdworker.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import GradSync, GradSyncConfig, make_bucket_plan
from repro.core.buckets import clear_bucket_plan_cache, pack, unpack
from repro.kernels.collectives.kernel import RING_CHUNK, ring_accum_kernel
from repro.kernels.collectives.ops import (
    fused_pack,
    fused_unpack,
    ring_allreduce,
    staging_supported,
)
from repro.parallel.sharding import ShardingRules


def _grads_and_specs():
    params = {
        "a": jnp.linspace(-3.0, 5.0, 12).reshape(3, 4),
        "b": jnp.ones((7,)) * 0.5,
        "emb": jnp.linspace(0.0, 31.0, 32).reshape(8, 4),
        "w": jnp.full((4, 6), 2.0),
        "tiny": jnp.asarray([1.5]),
    }
    rules = ShardingRules(rules=(
        ("emb", P("model", None)),
        ("w", P(None, "model")),
    ))
    return params, rules.tree_specs(params)


# ------------------------------------------------------- staging parity

@pytest.mark.parametrize("impl", ["kernel", "xla"])
@pytest.mark.parametrize("comm_dtype", [jnp.float32, jnp.bfloat16])
def test_fused_pack_unpack_bitexact_vs_leafwise(smoke_mesh, impl,
                                                comm_dtype):
    """pack→unpack through the fused path must match buckets.pack/unpack
    BIT-exactly (same casts, same order, no extra rounding)."""
    grads, specs = _grads_and_specs()
    plan = make_bucket_plan(grads, specs, smoke_mesh, bucket_bytes=1 << 20,
                            comm_dtype=comm_dtype)
    flat = jax.tree.leaves(grads)
    ref_out = [None] * len(flat)
    fused_out = [None] * len(flat)
    for b in plan.buckets:
        ref_buf = pack(b, flat, comm_dtype)
        buf = fused_pack(b, flat, comm_dtype, impl=impl, interpret=True)
        assert buf.dtype == ref_buf.dtype and buf.shape == ref_buf.shape
        np.testing.assert_array_equal(
            np.asarray(buf, np.float32), np.asarray(ref_buf, np.float32))
        unpack(b, ref_buf, ref_out)
        fused_unpack(b, buf, fused_out, impl=impl, interpret=True)
    for got, want in zip(fused_out, ref_out):
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(want, np.float32))


@pytest.mark.parametrize("impl", ["kernel", "xla"])
def test_fused_staging_loss_scale_roundtrip(smoke_mesh, impl):
    """Power-of-two loss-scale folded into pack and divided out in unpack
    is exact in f32."""
    grads, specs = _grads_and_specs()
    plan = make_bucket_plan(grads, specs, smoke_mesh, bucket_bytes=1 << 20)
    flat = jax.tree.leaves(grads)
    out = [None] * len(flat)
    for b in plan.buckets:
        buf = fused_pack(b, flat, jnp.float32, scale=64.0, impl=impl,
                         interpret=True)
        fused_unpack(b, buf, out, scale=1.0 / 64.0, impl=impl,
                     interpret=True)
    for got, want in zip(out, flat):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_staging_supported_gates_odd_dtypes():
    assert staging_supported((jnp.float32, jnp.bfloat16), jnp.float32)
    assert not staging_supported((jnp.int32,), jnp.float32)
    assert not staging_supported((jnp.float32,), jnp.int8)


@pytest.mark.parametrize("use_fused", [True, False])
@pytest.mark.parametrize("strategy", ["concom", "rsag"])
def test_execute_fused_vs_leafwise_identical(smoke_mesh, strategy,
                                             use_fused):
    """The use_fused_staging knob must not change results: on the unit
    mesh every strategy returns the input grads bit-exactly."""
    grads, specs = _grads_and_specs()
    cfg = GradSyncConfig(strategy=strategy, bucket_bytes=64,
                         num_channels=2, use_fused_staging=use_fused)
    gspecs = jax.tree.map(lambda _: P(), grads)

    def run(g):
        gs = GradSync(cfg, smoke_mesh, specs, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), g))
        return gs(g)

    out = jax.jit(lambda g: jax.shard_map(
        run, mesh=smoke_mesh, in_specs=(gspecs,), out_specs=gspecs,
        check_vma=False)(g))(grads)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(grads)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("use_fused", [True, False])
def test_gradsync_loss_scale_is_transparent(smoke_mesh, use_fused):
    """loss_scale rides the comm buffer only: pack scales (in f32,
    BEFORE the comm cast — on both the fused and the fallback path),
    unpack unscales — grads come back exactly (power-of-two scale)."""
    grads, specs = _grads_and_specs()
    cfg = GradSyncConfig(strategy="concom", bucket_bytes=64,
                         loss_scale=1024.0, use_fused_staging=use_fused)
    gspecs = jax.tree.map(lambda _: P(), grads)

    def run(g):
        gs = GradSync(cfg, smoke_mesh, specs, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), g))
        return gs(g)

    out = jax.jit(lambda g: jax.shard_map(
        run, mesh=smoke_mesh, in_specs=(gspecs,), out_specs=gspecs,
        check_vma=False)(g))(grads)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(grads)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- ring primitives

@pytest.mark.parametrize("n", [100, 4 * RING_CHUNK])
def test_ring_accum_kernel_matches_add(n):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = jax.random.normal(ks[0], (n,), jnp.float32)
    b = jax.random.normal(ks[1], (n,), jnp.float32)
    out = ring_accum_kernel(a, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a + b))


def test_ring_allreduce_unit_group_is_identity():
    buf = jnp.linspace(0.0, 1.0, 37)
    out = ring_allreduce(buf, ("data", "model"), {"data": 1, "model": 1})
    np.testing.assert_array_equal(np.asarray(out), np.asarray(buf))


# ------------------------------------------------- bucket-plan memoization

def test_make_bucket_plan_is_memoized(smoke_mesh):
    grads, specs = _grads_and_specs()
    clear_bucket_plan_cache()
    kw = dict(bucket_bytes=128, num_channels=2)
    p1 = make_bucket_plan(grads, specs, smoke_mesh, **kw)
    p2 = make_bucket_plan(grads, specs, smoke_mesh, **kw)
    assert p1 is p2
    # ShapeDtypeStructs with the same shapes hit the same entry
    sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    assert make_bucket_plan(sds, specs, smoke_mesh, **kw) is p1
    # any knob or shape change misses
    assert make_bucket_plan(grads, specs, smoke_mesh,
                            bucket_bytes=256, num_channels=2) is not p1
    assert make_bucket_plan(grads, specs, smoke_mesh, bucket_bytes=128,
                            num_channels=3) is not p1
    bigger = dict(grads, b=jnp.ones((9,)))
    assert make_bucket_plan(bigger, specs, smoke_mesh, **kw) is not p1


def test_bucket_plan_cache_is_bounded(smoke_mesh):
    from repro.core import buckets as B

    grads, specs = _grads_and_specs()
    clear_bucket_plan_cache()
    for bb in range(64, 64 + 2 * B._PLAN_CACHE_MAX):
        make_bucket_plan(grads, specs, smoke_mesh, bucket_bytes=bb)
    assert len(B._PLAN_CACHE) <= B._PLAN_CACHE_MAX


# ------------------------------------------------------ donation regression

def test_donation_does_not_change_one_train_step(smoke_mesh):
    """donate_argnums on params/opt_state (the production launcher path)
    must be a pure memory optimization: one train step's loss and params
    are identical with and without donation."""
    from repro.data import TokenPipeline
    from repro.models import transformer as tf
    from repro.optim import adamw
    from repro.runtime import make_train_step

    cfg = tf.TransformerConfig(
        name="donate", n_layers=2, d_model=32, n_heads=4, kv_heads=2,
        d_ff=64, vocab=64, tp=1, attn_chunk=16, dtype=jnp.float32)
    pipe = TokenPipeline(64, 16, 4, mesh=smoke_mesh)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = pipe.batch_at(0)
    opt = adamw(1e-3)
    sync = GradSyncConfig(strategy="concom", bucket_bytes=1 << 14)

    results = {}
    for donate in (False, True):
        ts = make_train_step(cfg, smoke_mesh, sync, opt, batch_like=batch,
                             params_like=params, donate=donate)
        ps = jax.device_put(params, ts.shardings(ts.param_specs))
        p2, _, m = ts.fn(ps, ts.init_opt(), batch, jnp.int32(0))
        results[donate] = (float(m["loss"]), jax.device_get(p2))

    l0, p0 = results[False]
    l1, p1 = results[True]
    assert l0 == l1
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
