"""repro.obs: metrics semantics, event stream, provenance headers,
NetworkModel/StagingModel calibration round-trips, fitted-profile
consumption by `auto`, the Trainer's compile-time separation, and the
merged sim+measured trace (subprocess — needs 8 fake devices)."""
import io
import json
import os
import subprocess
import sys

import pytest

from repro.obs import (
    EventLog,
    MetricsRegistry,
    SCHEMA_VERSION,
    bench_metadata,
    comm_byte_counters,
    heartbeat_line,
)


# ------------------------------------------------------------- metrics

def test_counter_accumulates_and_rejects_negative():
    reg = MetricsRegistry()
    c = reg.counter("bytes")
    c.inc(3)
    c.inc(4.5)
    assert c.value == 7.5
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 7.5


def test_gauge_overwrites():
    g = MetricsRegistry().gauge("loss")
    assert g.value is None
    g.set(2.0)
    g.set(1.5)
    assert g.value == 1.5


def test_histogram_summary_and_percentiles():
    h = MetricsRegistry().histogram("t")
    for v in range(1, 101):            # 1..100
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert s["p50"] == pytest.approx(50.0, abs=1.0)
    assert s["p99"] == pytest.approx(99.0, abs=1.0)


def test_histogram_window_bounds_memory_but_keeps_exact_extremes():
    h = MetricsRegistry().histogram("t", window=8)
    h.observe(1e9)                     # falls out of the window...
    for v in range(100):
        h.observe(float(v))
    assert len(h._window) == 8
    assert h.count == 101              # ...but count/max stay exact
    assert h.max == 1e9


def test_registry_reuses_instances_and_rejects_type_conflicts():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    reg.gauge("g").set(1.0)
    reg.histogram("h").observe(2.0)
    snap = reg.snapshot()
    assert snap["x"] == 0.0 and snap["g"] == 1.0
    assert snap["h"]["count"] == 1
    json.dumps(snap)                   # snapshot must be JSON-ready


# -------------------------------------------------------------- events

def test_eventlog_writes_parseable_jsonl():
    buf = io.StringIO()
    log = EventLog(buf)
    log.emit("step", step=3, loss=1.25)
    log.emit("failure", step=4)
    lines = buf.getvalue().strip().splitlines()
    rows = [json.loads(l) for l in lines]
    assert [r["kind"] for r in rows] == ["step", "failure"]
    assert rows[0]["step"] == 3 and rows[0]["loss"] == 1.25
    assert "t_utc" in rows[0] and "t_mono" in rows[0]


def test_eventlog_none_path_is_a_noop():
    log = EventLog(None)
    log.emit("step", step=0)           # must not raise
    log.close()


def test_heartbeat_line_fields():
    line = heartbeat_line(7, loss=1.5, step_ms=12.0, tokens_per_s=1234.0,
                          compile_s=3.0)
    assert line.startswith("[obs] step 7")
    assert "loss 1.5000" in line and "12.0ms" in line
    assert "1,234 tok/s" in line and "compile 3.00s excluded" in line


# ---------------------------------------------------------- provenance

def test_bench_metadata_header():
    meta = bench_metadata({"data": 2, "model": 4}, section="pack")
    assert meta["schema_version"] == SCHEMA_VERSION
    for key in ("utc", "platform", "python"):
        assert meta[key]
    assert meta["mesh_shape"] == {"data": 2, "model": 4}
    assert meta["section"] == "pack"
    json.dumps(meta)


# ------------------------------------------------- comm byte counters

def _static_gradsync(strategy, **cfg_kw):
    from repro.analysis.cli import StaticMesh, _model
    from repro.core.kvstore import GradSync, GradSyncConfig

    mesh = StaticMesh({"data": 2, "model": 4})
    grads, specs = _model("model")
    cfg = GradSyncConfig(strategy=strategy, bucket_bytes=256 * 1024,
                         verify=False, **cfg_kw)
    return GradSync(cfg, mesh, specs, grads)


def test_comm_byte_counters_account_wire_kinds_only():
    gs = _static_gradsync("concom")
    reg = MetricsRegistry()
    comm_byte_counters(gs.schedule, reg, itemsize=4)
    snap = reg.snapshot()
    expected = 4 * sum(op.bucket.size for op in gs.schedule.ops
                       if op.kind == "allreduce")
    assert snap["comm_bytes.allreduce.default.post"] == expected
    assert expected > 0

    gs2 = _static_gradsync("rsag")
    reg2 = MetricsRegistry()
    comm_byte_counters(gs2.schedule, reg2, itemsize=4)
    snap2 = reg2.snapshot()
    assert any(k.startswith("comm_bytes.reduce_scatter.") for k in snap2)
    assert any(k.startswith("comm_bytes.all_gather.") for k in snap2)
    # UPDATE/NORM ops move no payload → never counted
    assert not any("update" in k or "norm" in k for k in snap2)


# ---------------------------------------------------------- calibration

def _true_network():
    from repro.sim.netmodel import LinkModel, NetworkModel

    # "model" deliberately FASTER than "data": the fastest-link-first
    # RS/AG ordering under the fitted model then differs from the
    # default ref's, exercising the iterative re-ordering in fit_network
    return NetworkModel(links=(
        ("data", LinkModel("data", bandwidth=8e9, latency=4e-6)),
        ("model", LinkModel("model", bandwidth=3.2e10, latency=1.5e-6)),
    ))


def _wire_rows(true, mesh_shape, *, with_staging=False):
    rows = []
    for kind in ("allreduce", "reduce_scatter", "all_gather"):
        for nbytes in (1 << 14, 1 << 16, 1 << 18, 1 << 20):
            for axes in (("data",), ("model",), ("data", "model")):
                t = true.collective_time(kind, nbytes, axes, mesh_shape)
                row = {"kind": kind, "nbytes": float(nbytes),
                       "axes": axes, "mesh_shape": mesh_shape, "t": t}
                if with_staging:
                    row["num_leaves"] = 7
                    row["t"] += true.staging_time(kind, nbytes, 7)
                rows.append(row)
    return rows


def test_fit_network_recovers_known_alpha_beta():
    from repro.obs.calibrate import fit_network

    true = _true_network()
    mesh_shape = {"data": 4, "model": 8}
    model, info = fit_network(_wire_rows(true, mesh_shape))
    assert info["rms_residual_s"] < 1e-12
    for axis in ("data", "model"):
        want, got = true.link(axis), model.link(axis)
        assert got.bandwidth == pytest.approx(want.bandwidth, rel=1e-6)
        assert got.latency == pytest.approx(want.latency, rel=1e-6)


def test_fit_network_subtracts_staging_share():
    from repro.obs.calibrate import fit_network

    true = _true_network()
    mesh_shape = {"data": 4, "model": 8}
    rows = _wire_rows(true, mesh_shape, with_staging=True)
    model, _ = fit_network(rows, staging=true.staging)
    for axis in ("data", "model"):
        assert model.link(axis).bandwidth == pytest.approx(
            true.link(axis).bandwidth, rel=1e-6)


def test_fit_network_quality_gate():
    """A clean synthetic fit is quality "ok"; the same rows with large
    multiplicative noise blow the relative-residual gate to "poor"."""
    from repro.obs.calibrate import REL_RESIDUAL_MAX, fit_network

    true = _true_network()
    mesh_shape = {"data": 4, "model": 8}
    rows = _wire_rows(true, mesh_shape)
    _, info = fit_network(rows)
    assert info["quality"] == "ok"
    assert info["rel_residual"] <= REL_RESIDUAL_MAX

    # deterministic "noise": alternate rows 4x slower / 4x faster, the
    # kind of dispatch jitter a CPU-host smoke run produces
    noisy = [dict(r, t=r["t"] * (4.0 if i % 2 else 0.25))
             for i, r in enumerate(rows)]
    _, bad = fit_network(noisy)
    assert bad["quality"] == "poor"
    assert bad["rel_residual"] > REL_RESIDUAL_MAX


def test_fit_network_needs_fittable_rows():
    from repro.obs.calibrate import fit_network

    with pytest.raises(ValueError):
        fit_network([{"kind": "allreduce", "nbytes": 1e6,
                      "axes": ("data",), "mesh_shape": {"data": 1},
                      "t": 0.0}])


def test_fit_staging_recovers_known_params():
    from repro.obs.calibrate import fit_staging
    from repro.sim.compute import StagingModel

    true = StagingModel(hbm_bw=5e11, leaf_overhead=1e-6)
    rows = []
    for nbytes in (1 << 16, 1 << 20, 1 << 22):
        for leaves in (1, 16, 128):
            for fused in (True, False):
                rows.append({
                    "nbytes": float(nbytes), "num_leaves": leaves,
                    "fused": fused,
                    "t": true.stage_time(nbytes, leaves, fused=fused)})
    model, info = fit_staging(rows)
    assert model.hbm_bw == pytest.approx(true.hbm_bw, rel=1e-6)
    assert model.leaf_overhead == pytest.approx(true.leaf_overhead,
                                                rel=1e-6)
    assert info["rms_residual_s"] < 1e-12


# -------------------------------------------------------------- profiles

def test_profile_save_load_round_trip(tmp_path):
    from repro.obs.calibrate import (
        fitted_network,
        load_profile,
        profile_path,
        save_profile,
    )

    true = _true_network()
    mesh_shape = {"data": 2, "model": 4}
    path = save_profile(true, mesh_shape, dir=str(tmp_path),
                        info={"n_rows": 3})
    assert path == profile_path(mesh_shape, str(tmp_path))
    loaded = load_profile(path)
    for axis in ("data", "model"):
        assert loaded.link(axis).bandwidth == true.link(axis).bandwidth
        assert loaded.link(axis).latency == true.link(axis).latency
    doc = json.load(open(path))
    assert doc["schema_version"] == SCHEMA_VERSION
    assert doc["meta"]["mesh_shape"] == mesh_shape
    assert doc["fit"]["n_rows"] == 3

    got, got_path = fitted_network(mesh_shape, str(tmp_path))
    assert got_path == path
    assert got.link("data").bandwidth == true.link("data").bandwidth
    # a different mesh has no profile
    assert fitted_network({"data": 16}, str(tmp_path)) == (None, None)


def test_poor_quality_profile_treated_as_absent(tmp_path):
    """A persisted profile whose recorded fit quality is "poor" must
    never reach `auto` — `fitted_network` skips it (load_profile still
    reads it for forensics)."""
    from repro.obs.calibrate import fitted_network, load_profile, save_profile

    mesh_shape = {"data": 2, "model": 4}
    path = save_profile(_true_network(), mesh_shape, dir=str(tmp_path),
                        info={"quality": "poor", "rel_residual": 1.1})
    assert fitted_network(mesh_shape, str(tmp_path)) == (None, None)
    assert load_profile(path) is not None


def test_corrupt_profile_treated_as_absent(tmp_path):
    from repro.obs.calibrate import fitted_network, profile_path

    mesh_shape = {"data": 2, "model": 4}
    path = profile_path(mesh_shape, str(tmp_path))
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("{not json")
    assert fitted_network(mesh_shape, str(tmp_path)) == (None, None)


# --------------------------------------------- auto × fitted profile

def test_auto_prefers_fitted_profile(tmp_path, monkeypatch):
    """Planning with `auto` must rank under the fitted alpha/beta when a
    per-mesh profile exists — and under the defaults when none does."""
    from repro.obs.calibrate import save_profile
    from repro.sim.autotune import last_auto_report, rank_strategies
    from repro.sim.engine import SimConfig

    mesh_shape = {"data": 2, "model": 4}

    monkeypatch.setenv("REPRO_NETPROFILE_DIR", str(tmp_path / "empty"))
    gs = _static_gradsync("auto")
    default_report = last_auto_report()
    assert default_report["net"] == "default"

    fitted = _true_network()
    profile_dir = str(tmp_path / "profiles")
    path = save_profile(fitted, mesh_shape, dir=profile_dir)
    monkeypatch.setenv("REPRO_NETPROFILE_DIR", profile_dir)
    gs2 = _static_gradsync("auto")
    report = last_auto_report()
    assert report["net"] == f"fitted:{path}"

    # the reported ranking must be EXACTLY the simulation under the
    # fitted model (same plan, same sim config GradSync hands auto)
    expected = rank_strategies(
        gs2.plan, mesh_shape, net=fitted,
        sim=SimConfig(itemsize=4, reducer="flat", fused_staging=True),
        in_scan_active=False)
    assert report["ranking"] == [(n, tl.step_time) for n, tl in expected]
    # ...and differ from the default-network ranking's numbers
    assert dict(report["ranking"]) != dict(default_report["ranking"])
    assert report["winner"] == expected[0][0]
    gs.schedule.validate()
    gs2.schedule.validate()


# ------------------------------------------------ trainer integration

@pytest.fixture(scope="module")
def tiny_train(smoke_mesh):
    import jax
    import jax.numpy as jnp

    from repro.core import GradSyncConfig
    from repro.data import TokenPipeline
    from repro.models import transformer as tf
    from repro.optim import adamw
    from repro.runtime import make_train_step

    cfg = tf.TransformerConfig(
        name="obs", n_layers=2, d_model=32, n_heads=4, kv_heads=2,
        d_ff=64, vocab=64, tp=1, attn_chunk=16, dtype=jnp.float32)
    pipe = TokenPipeline(64, 16, 4, seed=13, mesh=smoke_mesh)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    ts = make_train_step(
        cfg, smoke_mesh,
        GradSyncConfig(strategy="concom", bucket_bytes=1 << 14),
        opt, batch_like=pipe.batch_at(0), params_like=params)
    return ts, pipe, params, opt


def test_trainer_separates_compile_time(tiny_train, tmp_path):
    from repro.runtime import Trainer

    ts, pipe, params, opt = tiny_train
    events = str(tmp_path / "events.jsonl")
    tr = Trainer(ts, pipe, None, log_every=1000, events_path=events)
    _, _, hist = tr.run(params, opt.init(params), 6)

    assert hist["compile_time"] is not None and hist["compile_time"] > 0
    snap = hist["metrics"]
    assert snap["steps_total"] == 6
    # step 0 spans jit warmup → excluded from throughput stats
    assert snap["step_time_s"]["count"] == 5
    assert len(tr.step_times) == 5
    assert snap["compile_time_s"] == hist["compile_time"]
    assert snap["tokens_total"] == 5 * 4 * 16      # 5 timed steps, B*S
    assert snap["tokens_per_s"] > 0
    assert snap["loss"] == hist["losses"][-1]
    assert snap["mem.state_bytes"] > 0
    assert any(k.startswith("comm_bytes.allreduce.") for k in snap)
    assert "sim.step_time_s" in snap
    assert [e["kind"] for e in hist["events"]].count("compile") == 1

    rows = [json.loads(l) for l in open(events)]
    steps = [r for r in rows if r["kind"] == "step"]
    assert len(steps) == 6
    assert sum(r["compile_step"] for r in steps) == 1
    assert steps[0]["compile_step"] is True
    assert {r["kind"] for r in rows} >= {"compile", "step"}


def test_trainer_bounds_loss_history(tiny_train):
    from repro.runtime import Trainer

    ts, pipe, params, opt = tiny_train
    tr = Trainer(ts, pipe, None, log_every=1000, loss_window=3)
    _, _, hist = tr.run(params, opt.init(params), 6)
    assert len(hist["losses"]) == 3


# --------------------------------- measured replay (8 fake devices)

@pytest.fixture(scope="module")
def obs_cli_run(tmp_path_factory):
    """`python -m repro.obs --trace` in a subprocess (the main pytest
    process is pinned to 1 device; the CLI forces 8 fake devices)."""
    trace = str(tmp_path_factory.mktemp("obs") / "trace.json")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", "--trace", trace,
         "--reps", "1", "--diff"],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout, trace


def test_merged_trace_has_matching_sim_and_measured_tracks(obs_cli_run):
    stdout, trace = obs_cli_run
    assert "— match" in stdout, stdout
    doc = json.load(open(trace))
    events = doc["traceEvents"]
    names = {m["args"]["name"] for m in events
             if m.get("ph") == "M" and m.get("name") == "process_name"}
    assert any(n.startswith("measured:") for n in names), names
    assert any(n.startswith("simulated:") for n in names), names
    by_pid = {}
    for m in events:
        if m.get("ph") == "M" and m.get("name") == "process_name":
            by_pid[m["pid"]] = m["args"]["name"]
    counts = {}
    for m in events:
        if m.get("ph") == "X" and m["name"] not in ("forward", "backward"):
            counts[by_pid[m["pid"]]] = counts.get(by_pid[m["pid"]], 0) + 1
    meas = next(v for k, v in counts.items() if k.startswith("measured:"))
    sim = next(v for k, v in counts.items() if k.startswith("simulated:"))
    assert meas == sim > 0
