"""Static analyzer (repro.analysis, DESIGN.md §11): every shipping
schedule passes clean, every corpus mutation is caught by the pass that
owns its error class, and the verify hooks raise with a witness.

Deterministic counterpart of tests/test_analysis_properties.py — all
pure Python on static IR, no mesh, no devices.
"""
import dataclasses

import jax.numpy as jnp
import pytest

from repro.analysis import (
    PASS_NAMES,
    ScheduleError,
    run_passes,
    structural_findings,
    verify_schedule,
)
from repro.analysis.mutations import (
    MESH,
    MUTATIONS,
    Mutation,
    synthetic_plan,
    valid_cases,
)
from repro.core.registry import get_strategy
from repro.core.schedule import CommSchedule


# ----------------------------------------- zero false positives (green)

@pytest.mark.parametrize(
    "name,schedule,ctx", valid_cases(),
    ids=[name for name, _, _ in valid_cases()])
def test_shipping_schedules_pass_clean(name, schedule, ctx):
    report = run_passes(schedule, **ctx)
    assert report.ok, f"{name}: {report.render()}"
    assert report.num_ops == len(schedule.ops)
    # and the raising entry point agrees
    verify_schedule(schedule, **ctx)


def test_kvstore_style_trace_passes_clean():
    # the IR KVStore records: one op per key, chained per channel, with
    # a barrier join — mesh_shape present (rank simulation runs)
    from repro.core.kvstore import KVStore

    kv = KVStore("concom", reduce_axes=("data",), num_channels=2,
                 mesh_shape=MESH)
    for key in range(5):
        kv._shapes[key] = (8,)
    for key in range(4):
        kv._record(key, _buf(), "allreduce")
    kv.barrier()
    kv._record(4, _buf(), "allreduce")
    s = kv.schedule()             # verify=True: raises if unsound
    # post-barrier op depends on every pre-barrier chain tail
    assert set(s.ops[-1].depends_on) >= {2, 3}
    assert run_passes(s, mesh_shape=MESH).ok


def _buf():
    return jnp.zeros((8,), jnp.float32)


# --------------------------------------- every mutation caught (red)

@pytest.mark.parametrize("mutation", MUTATIONS,
                         ids=[m.name for m in MUTATIONS])
def test_mutation_caught_by_owning_pass(mutation: Mutation):
    schedule, ctx = mutation.build()
    report = run_passes(schedule, **ctx)
    assert not report.ok, f"{mutation.name} was not caught at all"
    owned = [f for f in report.by_pass(mutation.owner)
             if f.code == mutation.code]
    assert owned, (
        f"{mutation.name}: expected {mutation.owner}:{mutation.code}, "
        f"got {report.error_classes}")
    # and ONLY via run_passes with that pass enabled — the owning pass
    # alone must be sufficient to catch its class
    solo = run_passes(schedule, **ctx, passes=(mutation.owner,))
    assert any(f.code == mutation.code for f in solo.findings)


def test_corpus_covers_every_pass():
    assert {m.owner for m in MUTATIONS} == set(PASS_NAMES)


def test_verify_raises_schedule_error_with_witness():
    schedule, ctx = next(
        m for m in MUTATIONS if m.name == "orphaned-pre-gather").build()
    with pytest.raises(ScheduleError, match="orphaned-pre-gather"):
        verify_schedule(schedule, **ctx)
    try:
        verify_schedule(schedule, **ctx)
    except ScheduleError as e:
        assert e.pass_name == "carry"
        assert e.code == "orphaned-pre-gather"
        rendered = e.findings[0].render()
        assert "[carry:orphaned-pre-gather]" in rendered
        assert "deferred gather without a producer" in rendered


def test_gradsync_verify_hook_rejects_bad_reducer_dtype(smoke_mesh):
    # end-to-end: a GradSyncConfig whose analyzer verdict is bad raises
    # at PLANNING time (compressed family on an int8 wire)
    import jax

    from repro.core.kvstore import GradSync, GradSyncConfig

    grads = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    specs = {"w": jax.sharding.PartitionSpec()}
    cfg = GradSyncConfig(strategy="concom", reducer="compressed",
                         comm_dtype=jnp.int8)
    with pytest.raises(ScheduleError, match="comm-dtype-illegal"):
        GradSync(cfg, smoke_mesh, specs, grads)
    # verify=False restores the old (unchecked) behavior
    gs = GradSync(dataclasses.replace(cfg, verify=False),
                  smoke_mesh, specs, grads)
    assert gs.schedule.ops


# ------------------------------- validate() routes through the analyzer

def test_validate_matches_structural_findings():
    s = get_strategy("concom").plan(synthetic_plan())
    assert structural_findings(s) == []
    s.validate()                                   # no raise
    bad = CommSchedule(s.ops + (s.ops[0],))        # duplicate op_id
    findings = structural_findings(bad)
    assert findings and findings[0].code == "duplicate-op-id"
    with pytest.raises(ValueError, match="duplicate op_id"):
        bad.validate()


def test_validate_rejects_dangling_and_unknown_bucket():
    s = get_strategy("concom").plan(synthetic_plan())
    dangling = CommSchedule(
        (dataclasses.replace(s.ops[0], depends_on=(999,)),) + s.ops[1:])
    with pytest.raises(ValueError, match="dangling chain-dep"):
        dangling.validate()
    neg = CommSchedule(
        (dataclasses.replace(
            s.ops[0],
            bucket=dataclasses.replace(s.ops[0].bucket, bucket_id=-3)),)
        + s.ops[1:])
    with pytest.raises(ValueError, match="negative bucket_id"):
        neg.validate()


# ----------------------------------------------------- CLI cross-product

def test_cli_cross_product_is_clean(capsys):
    from repro.analysis.cli import main

    assert main([]) == 0
    out = capsys.readouterr().out
    assert "0 analyzer errors" in out


def test_cli_json_report(tmp_path, capsys):
    import json

    from repro.analysis.cli import main

    path = tmp_path / "report.json"
    assert main(["--json", str(path)]) == 0
    capsys.readouterr()
    data = json.loads(path.read_text())
    assert data["summary"]["errors"] == 0
    assert data["summary"]["total"] == len(data["cells"])
    # both meshes and every registered strategy appear
    seen_meshes = {c["mesh"] for c in data["cells"]}
    assert seen_meshes == {"dp8", "smoke-dp2tp4"}
    seen = {c["strategy"] for c in data["cells"]}
    assert {"funnel", "concom", "depcha", "priority", "rsag",
            "auto"} <= seen
