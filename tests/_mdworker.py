"""Multi-device worker: runs under XLA_FLAGS=8 fake devices in a
subprocess (jax device count is fixed at first init, so these checks
can't live in the main pytest process).  Prints PASS/FAIL lines parsed by
tests/test_multidevice.py."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings

warnings.filterwarnings("ignore")
import dataclasses

import repro  # noqa: F401  (applies the jaxcompat shim before jax imports)
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P

from repro.core import GradSync, GradSyncConfig
from repro.models import transformer as tf
from repro.models.registry import family_of
from repro.utils.trees import named_leaves

mesh8 = jax.make_mesh((2, 4), ("data", "model"),
                      axis_types=(AxisType.Auto,) * 2)
mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(AxisType.Auto,) * 2,
                      devices=jax.devices()[:1])

B, S = 4, 32
_rng = jax.random.PRNGKey(0)
BATCH = {
    "tokens": jax.random.randint(_rng, (B, S), 0, 96),
    "labels": jax.random.randint(_rng, (B, S), 0, 96),
    "global_tokens": jnp.float32(B * S),
}


def loss_and_grads(cfg, mesh, params, strategy="concom", reducer="flat"):
    api = family_of(cfg)
    params = params  # global tree; sharded below
    rules = api.param_rules(cfg)
    pspecs = rules.tree_specs(params)
    bspecs = {k: (P() if np.ndim(v) == 0 else P("data"))
              for k, v in BATCH.items()}
    tp = cfg.tp
    sync = GradSyncConfig(strategy=strategy, reducer=reducer,
                          bucket_bytes=1 << 12, num_channels=3)

    in_scan = (api.in_scan_names(params)
               if getattr(cfg, "depcha_in_scan", False) else frozenset())

    def step(p, b):
        loss, grads = jax.value_and_grad(
            lambda pp: api.train_forward(pp, b, cfg))(p)
        if tp > 1:
            grads = jax.tree.map(lambda g: g / tp, grads)
        gs = GradSync(sync, mesh, pspecs, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads),
            in_scan_names=in_scan)
        grads = gs(grads)
        return jax.lax.psum(loss, ("data",)), grads

    f = jax.jit(lambda p, b: jax.shard_map(
        step, mesh=mesh, in_specs=(pspecs, bspecs),
        out_specs=(P(), pspecs), check_vma=False)(p, b))
    ps = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs))
    bs = jax.device_put(BATCH, {k: NamedSharding(mesh, s)
                                for k, s in bspecs.items()})
    return f(ps, bs)


def check(name, cond):
    print(("PASS " if cond else "FAIL ") + name, flush=True)


def compare_tp(name, mk_cfg, strategy="concom", reducer="flat", tol=3e-4,
               grad_tol=2e-3):
    cfg1, cfg4 = mk_cfg(1), mk_cfg(4)
    api = family_of(cfg1)
    params = api.init(jax.random.PRNGKey(1), cfg1)
    l1, g1 = loss_and_grads(cfg1, mesh1, params, strategy, reducer)
    l4, g4 = loss_and_grads(cfg4, mesh8, params, strategy, reducer)
    dl = abs(float(l1) - float(l4))
    worst = 0.0
    for (n, a), (_, b) in zip(named_leaves(g1), named_leaves(g4)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        if a.shape != b.shape:
            continue
        rel = float(np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-8))
        worst = max(worst, rel)
    check(f"{name} dloss<{tol}", dl < tol)
    check(f"{name} grads<{grad_tol}", worst < grad_tol)


mk_dense = lambda tp: tf.TransformerConfig(
    name="dense", n_layers=2, d_model=64, n_heads=8, kv_heads=2, d_ff=128,
    vocab=96, tp=tp, attn_chunk=16, dtype=jnp.float32)

# 1. TP=4 x DP=2 == TP=1 for each registered strategy (the paper's
#    correctness claim across real process groups; priority/rsag ride
#    the same check for free via the registry)
from repro.core import get_strategy, strategy_names

for strat in strategy_names():
    compare_tp(f"tp-equiv[{strat}]",
               lambda tp: dataclasses.replace(
                   mk_dense(tp),
                   depcha_in_scan=(get_strategy(strat).uses_in_scan
                                   and tp > 1)),
               strategy=strat)

# 2. hierarchical + compressed + ring reducers on real groups
compare_tp("tp-equiv[hierarchical]", mk_dense, reducer="hierarchical")
compare_tp("tp-equiv[compressed]", mk_dense, reducer="compressed",
           tol=5e-2, grad_tol=0.35)   # int8 wire: lossy by design
compare_tp("tp-equiv[ring]", mk_dense, reducer="ring",
           tol=3e-4, grad_tol=5e-3)   # ring hop order ≠ psum tree order

# 3. cross-strategy equality on the multi-device mesh
outs = {}
params8 = family_of(mk_dense(4)).init(jax.random.PRNGKey(1), mk_dense(1))
for strat in strategy_names():
    cfg = dataclasses.replace(
        mk_dense(4), depcha_in_scan=get_strategy(strat).uses_in_scan)
    _, g = loss_and_grads(cfg, mesh8, params8, strat)
    outs[strat] = g
ok = True
for strat in [s for s in strategy_names() if s != "funnel"]:
    for a, b in zip(jax.tree.leaves(outs["funnel"]),
                    jax.tree.leaves(outs[strat])):
        if np.max(np.abs(np.asarray(a, np.float32)
                         - np.asarray(b, np.float32))) > 1e-4:
            ok = False
check("strategies-identical-grads-8dev", ok)

# 4. ZeRO-1 on the StepProgram (DESIGN.md §9) at dp=2 × tp=4: the
#    scheduled per-bucket RS→UPDATE→AG program is bit-exact with the
#    monolithic zero1 optimizer, matches flat allreduce+update on the
#    SAME mesh, matches plain adamw at dp=1, rides the ring transport,
#    and clips via the scheduled NORM op exactly like
#    clip_by_global_norm does on the flat path.
from repro.optim import adamw, zero1
from repro.runtime import make_train_step
from repro.data import TokenPipeline


def one_step(mesh, cfg, *, mode, dp_size=1, clip_norm=0.0,
             strategy="concom", reducer="flat", verify=True):
    pipe = TokenPipeline(96, 32, 4, seed=3, mesh=mesh)
    params = family_of(cfg).init(jax.random.PRNGKey(2), mk_dense(1))
    b = pipe.batch_at(0)
    if mode == "flat":
        opt = adamw(1e-3)
        sync = GradSyncConfig(strategy=strategy, reducer=reducer,
                              bucket_bytes=1 << 12, verify=verify)
        ts = make_train_step(cfg, mesh, sync, opt, batch_like=b,
                             params_like=params, clip_norm=clip_norm)
    else:
        opt = zero1(adamw(1e-3), ("data",), dp_size)
        sync = GradSyncConfig(strategy=strategy, reducer=reducer,
                              bucket_bytes=1 << 12,
                              exclude_axes=("data",), verify=verify)
        ts = make_train_step(cfg, mesh, sync, opt, batch_like=b,
                             params_like=params, zero1_mode=True,
                             zero1_plan=mode, clip_norm=clip_norm)
    ps = jax.device_put(params, ts.shardings(ts.param_specs))
    p2, _, m = ts.fn(ps, ts.init_opt(), b, jnp.int32(0))
    return float(m["loss"]), p2, ts


def worst_diff(pa, pb):
    worst = 0.0
    for (n, a), (_, b) in zip(named_leaves(pa), named_leaves(pb)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        if a.shape != b.shape:
            continue
        worst = max(worst, float(np.max(np.abs(a - b))))
    return worst


l_ref, p_ref, _ = one_step(mesh1, mk_dense(1), mode="flat")
l_s, p_s, ts_s = one_step(mesh8, mk_dense(4), mode="scheduled", dp_size=2)
l_m, p_m, _ = one_step(mesh8, mk_dense(4), mode="monolithic", dp_size=2)
l_f8, p_f8, _ = one_step(mesh8, mk_dense(4), mode="flat")

kinds = ts_s.gradsync.schedule.stats()["kinds"]
check("zero1-sched-ir-update-ops",
      kinds.get("update", 0) > 1
      and kinds.get("update") == kinds.get("all_gather"))
check("zero1-sched-multidev-loss", abs(l_ref - l_s) < 3e-4)
check("zero1-sched-multidev-params", worst_diff(p_ref, p_s) < 5e-4)
check("zero1-sched-equals-monolithic-bitexact",
      worst_diff(p_s, p_m) == 0.0)
check("zero1-sched-equals-flat-allreduce-update",
      worst_diff(p_s, p_f8) < 1e-5)

# rsag's two-phase base plan rewrites to the same triples: bit-exact
_, p_rsag, _ = one_step(mesh8, mk_dense(4), mode="scheduled", dp_size=2,
                        strategy="rsag")
check("zero1-sched-rsag-equals-concom", worst_diff(p_s, p_rsag) == 0.0)

# ring-family reducer: the zero1 RS/AG ops ride the chunked ring kernels
_, p_ring, _ = one_step(mesh8, mk_dense(4), mode="scheduled", dp_size=2,
                        reducer="ring")
check("zero1-sched-ring-transport", worst_diff(p_s, p_ring) < 5e-5)

# scheduled NORM clip ≡ clip_by_global_norm on the flat path (same mesh)
_, p_sc, _ = one_step(mesh8, mk_dense(4), mode="scheduled", dp_size=2,
                      clip_norm=0.05)
_, p_fc, _ = one_step(mesh8, mk_dense(4), mode="flat", clip_norm=0.05)
check("zero1-sched-clip-matches-flat-clip",
      worst_diff(p_sc, p_fc) < 1e-5)

# 5. FSDP (ZeRO-3 storage) one train step == plain, params compared
#    globally (device_get gathers the data-sharded weights)
def one_step_cfg(mesh, cfg):
    pipe = TokenPipeline(96, 32, 4, seed=4, mesh=mesh)
    params = family_of(cfg).init(jax.random.PRNGKey(2), mk_dense(1))
    b = pipe.batch_at(0)
    opt = adamw(1e-3)
    ts = make_train_step(cfg, mesh, GradSyncConfig(strategy="concom"),
                         opt, batch_like=b, params_like=params,
                         clip_norm=0)
    ps = jax.device_put(params, ts.shardings(ts.param_specs))
    p2, _, m = ts.fn(ps, ts.init_opt(), b, jnp.int32(0))
    return float(m["loss"]), jax.device_get(p2)


l_ref, p_ref = one_step_cfg(mesh1, mk_dense(1))
l_f, p_f = one_step_cfg(mesh8, dataclasses.replace(mk_dense(4), fsdp=True))
worst = 0.0
for (n, a), (_, b) in zip(named_leaves(p_ref), named_leaves(p_f)):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    worst = max(worst, float(np.max(np.abs(a - b))))
check("fsdp-onestep-loss", abs(l_ref - l_f) < 3e-4)
check("fsdp-onestep-params", worst < 5e-4)

# 6. hierarchical ≡ flat over REAL process groups: the 3-stage
#    RS(data)→AR(pod)→AG(data) path needs a pod axis, so re-mesh the 8
#    fake devices as 2×2×2 (pod, data, model) and compare both reducers
#    on rank-varying data (every rank contributes a different value).
from repro.core.buckets import Bucket, LeafInfo
from repro.core.strategies import make_reducer

mesh_pod = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
pod_shape = {"pod": 2, "data": 2, "model": 2}
N = 1024
base = jax.random.normal(jax.random.PRNGKey(7), (N,), jnp.float32)
bucket_pd = Bucket(
    leaves=(LeafInfo(name="x", index=0, shape=(N,), dtype=jnp.float32,
                     size=N),),
    reduce_axes=("pod", "data"), channel=0, bucket_id=0)


def _reduce_with(reducer_name):
    red = make_reducer(reducer_name, pod_shape, mean_axes=("pod", "data"))

    def body(x):
        rank = (jax.lax.axis_index("pod") * 2
                + jax.lax.axis_index("data")).astype(jnp.float32)
        return red(x * (1.0 + rank), bucket_pd)

    return jax.jit(lambda x: jax.shard_map(
        body, mesh=mesh_pod, in_specs=(P(),), out_specs=P(),
        check_vma=False)(x))(base)


flat_out = np.asarray(_reduce_with("flat"))
hier_out = np.asarray(_reduce_with("hierarchical"))
# mean over 4 DP ranks of (1+rank)·x = 2.5·x / ... both paths must agree
check("hier-matches-analytic",
      float(np.max(np.abs(flat_out - np.asarray(base) * 2.5))) < 1e-5)
check("hier-equals-flat-podmesh",
      float(np.max(np.abs(flat_out - hier_out))) < 1e-5)

ring_out = np.asarray(_reduce_with("ring"))
check("ring-equals-flat-podmesh",
      float(np.max(np.abs(flat_out - ring_out))) < 1e-5)
hier_ring_out = np.asarray(_reduce_with("hierarchical_ring"))
check("hier-ring-reducer-equals-flat-podmesh",
      float(np.max(np.abs(flat_out - hier_ring_out))) < 1e-5)

# 7. ring collectives ≡ psum_scatter / all_gather over a REAL 8-way ring
#    (rank-varying data; device r must own chunk r after RS, and the
#    bidirectional double-buffered variant must match the plain ring)
from repro.kernels.collectives.ops import (
    ring_all_gather,
    ring_allreduce,
    ring_reduce_scatter,
)

mesh_ring = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
ring_shape = {"data": 8}
M = 8 * 192
base_r = jax.random.normal(jax.random.PRNGKey(11), (M,), jnp.float32)


def _ring_vs_psum(bidirectional):
    def body(x):
        rank = jax.lax.axis_index("data").astype(jnp.float32)
        local = x * (1.0 + rank)
        rs_ring = ring_reduce_scatter(local, ("data",), ring_shape,
                                      bidirectional=bidirectional)
        rs_ref = jax.lax.psum_scatter(local, "data",
                                      scatter_dimension=0, tiled=True)
        ag_ring = ring_all_gather(rs_ref, ("data",), ring_shape,
                                  bidirectional=bidirectional)
        ag_ref = jax.lax.all_gather(rs_ref, "data", axis=0, tiled=True)
        ar_ring = ring_allreduce(local, ("data",), ring_shape,
                                 bidirectional=bidirectional)
        ar_ref = jax.lax.psum(local, ("data",))
        return rs_ring, rs_ref, ag_ring, ag_ref, ar_ring, ar_ref

    # per-device shard in, per-device shard out: compare on global views
    return jax.jit(lambda x: jax.shard_map(
        body, mesh=mesh_ring, in_specs=(P("data"),),
        out_specs=(P("data"),) * 6, check_vma=False)(x))(base_r)


for bidi in (False, True):
    tag = "bidi" if bidi else "uni"
    rs_ring, rs_ref, ag_ring, ag_ref, ar_ring, ar_ref = (
        np.asarray(v) for v in _ring_vs_psum(bidi))
    scale = float(np.max(np.abs(rs_ref))) + 1e-8
    check(f"ring-rs-equals-psum-scatter[{tag}]",
          float(np.max(np.abs(rs_ring - rs_ref))) / scale < 1e-6)
    check(f"ring-ag-equals-all-gather[{tag}]",
          float(np.max(np.abs(ag_ring - ag_ref))) < 1e-6 * scale)
    check(f"ring-allreduce-equals-psum[{tag}]",
          float(np.max(np.abs(ar_ring - ar_ref))) / scale < 1e-6)

# 8. hierarchical reducer with its fast-tier bulk bytes routed through
#    the ring kernels (use_ring) ≡ the psum_scatter/all_gather stages
from repro.core.hierarchical import hierarchical_allreduce


def _hier(use_ring):
    def body(x):
        rank = (jax.lax.axis_index("pod") * 2
                + jax.lax.axis_index("data")).astype(jnp.float32)
        return hierarchical_allreduce(
            x * (1.0 + rank), intra_axis="data", inter_axis="pod",
            intra_size=2, use_ring=use_ring)

    return jax.jit(lambda x: jax.shard_map(
        body, mesh=mesh_pod, in_specs=(P(),), out_specs=P(),
        check_vma=False)(x))(base)


check("hier-ring-equals-psum-stages",
      float(np.max(np.abs(np.asarray(_hier(True))
                          - np.asarray(_hier(False))))) < 1e-5)

# 9. compressed_ring ≡ compressed on a single-axis 8-ring: the int8
#    gather phase rides the ring all-gather — pure transport, so the
#    (lossy) values must match the lax.all_gather path bit-for-bit
from repro.core.strategies import make_reducer as _mk_red

big = jax.random.normal(jax.random.PRNGKey(13), (4096,), jnp.float32)
bucket_d8 = Bucket(
    leaves=(LeafInfo(name="c", index=0, shape=(4096,), dtype=jnp.float32,
                     size=4096),),
    reduce_axes=("data",), channel=0, bucket_id=0)


def _comp_with(name):
    red = _mk_red(name, {"data": 8}, mean_axes=("data",))

    def body(x):
        rank = jax.lax.axis_index("data").astype(jnp.float32)
        return red(x * (1.0 + rank), bucket_d8)

    return jax.jit(lambda x: jax.shard_map(
        body, mesh=mesh_ring, in_specs=(P(),), out_specs=P(),
        check_vma=False)(x))(big)


comp_out = np.asarray(_comp_with("compressed"))
comp_ring_out = np.asarray(_comp_with("compressed_ring"))
check("compressed-ring-equals-compressed",
      float(np.max(np.abs(comp_out - comp_ring_out))) == 0.0)

# 10. pipelined StepProgram (DESIGN.md §10) at dp=2 × tp=4: the
#     deferred plan (AGs detached into the next step's top, update
#     shards carried in opt_state["pending"]) is BIT-exact with the
#     scheduled plan over consecutive steps — the real all-gather
#     materializes the shards identically on both paths, so the carried
#     state (the easy thing to get wrong) is fully checked — and the
#     peeled-final-microbatch accumulation is bit-exact with the plain
#     scan while microbatch count leaves the trajectory unchanged.
pipe8 = TokenPipeline(96, 32, 8, seed=5, mesh=mesh8)


def run_steps(mode, n, *, clip_norm=0.0, microbatch=1,
              accum_overlap=True):
    cfg = mk_dense(4)
    params = family_of(cfg).init(jax.random.PRNGKey(2), mk_dense(1))
    b0 = pipe8.batch_at(0)
    if mode == "flat":
        opt = adamw(1e-3)
        sync = GradSyncConfig(strategy="concom", bucket_bytes=1 << 12)
        ts = make_train_step(cfg, mesh8, sync, opt, batch_like=b0,
                             params_like=params, clip_norm=clip_norm,
                             microbatch=microbatch,
                             accum_overlap=accum_overlap)
    else:
        opt = zero1(adamw(1e-3), ("data",), 2)
        sync = GradSyncConfig(strategy="concom", bucket_bytes=1 << 12,
                              exclude_axes=("data",))
        ts = make_train_step(cfg, mesh8, sync, opt, batch_like=b0,
                             params_like=params, zero1_mode=True,
                             zero1_plan=mode, clip_norm=clip_norm,
                             microbatch=microbatch,
                             accum_overlap=accum_overlap)
    ps = jax.device_put(params, ts.shardings(ts.param_specs))
    st = ts.init_opt()
    m = None
    for k in range(n):
        ps, st, m = ts.fn(ps, st, pipe8.batch_at(k), jnp.int32(k))
    return ts, ps, st, m


ts_ds, p_ds, s_ds, m_ds = run_steps("deferred", 2)
_, p_ss, _, m_ss = run_steps("scheduled", 2)
check("pipelined-deferred-ir-phases",
      ts_ds.gradsync.schedule.phase_counts().get("pre", 0) > 1
      and ts_ds.gradsync.program.defer_ag)
check("pipelined-deferred-equals-scheduled-2steps-bitexact",
      worst_diff(ts_ds.finalize(p_ds, s_ds), p_ss) == 0.0)
ts_d3, p_d3, s_d3, _ = run_steps("deferred", 3)
_, p_s3, _, _ = run_steps("scheduled", 3)
check("pipelined-deferred-equals-scheduled-3steps-bitexact",
      worst_diff(ts_d3.finalize(p_d3, s_d3), p_s3) == 0.0)

# clipped: the NORM op stays in the POST program; the grad-norm metric
# and the clipped trajectory both survive the phase split
ts_dc, p_dc, s_dc, m_dc = run_steps("deferred", 2, clip_norm=0.05)
_, p_sc2, _, m_sc2 = run_steps("scheduled", 2, clip_norm=0.05)
check("pipelined-deferred-clip-bitexact",
      worst_diff(ts_dc.finalize(p_dc, s_dc), p_sc2) == 0.0
      and float(m_dc["grad_norm"]) == float(m_sc2["grad_norm"]))

# accumulation-overlapped (peeled final microbatch) ≡ plain scan, and
# microbatch count ≡ unsplit batch (normalization), on real dp groups.
# The peel preserves the exact accumulation order, but the inlined
# final backward compiles outside the scan body — under tp=4 XLA fuses
# its matmul/psum chain differently, so parity is float round-off
# (~1e-7 after 2 steps), not bit-level (it IS bit-exact at dp=1, see
# tests/test_pipelined.py).
_, p_ov, _, m_ov = run_steps("flat", 2, microbatch=4, accum_overlap=True)
_, p_pl, _, m_pl = run_steps("flat", 2, microbatch=4,
                             accum_overlap=False)
check("accum-overlap-equals-plain-scan",
      worst_diff(p_ov, p_pl) < 1e-5
      and float(m_ov["loss"]) == float(m_pl["loss"]))
_, p_m1, _, m_m1 = run_steps("flat", 2, microbatch=1)
check("accum-m4-equals-m1-trajectory",
      worst_diff(p_ov, p_m1) < 1e-5
      and abs(float(m_ov["loss"]) - float(m_m1["loss"])) < 1e-5)

# 11. static analyzer (DESIGN.md §11): the verify=True planning hook is
#     pure analysis over the IR — planning the dp=2 × tp=4 deferred
#     StepProgram with verification on is bit-exact with verification
#     off (every other GradSync in this file already planned with
#     verify=True, the default, so the analyzer blessed all of them)
_, p_von, _ = one_step(mesh8, mk_dense(4), mode="deferred", dp_size=2,
                       verify=True)
_, p_voff, _ = one_step(mesh8, mk_dense(4), mode="deferred", dp_size=2,
                        verify=False)
check("analysis-verify-planning-bitexact",
      worst_diff(p_von, p_voff) == 0.0)

# 12. measured per-op replay (DESIGN.md §12) on the real 2×4 mesh: the
#     one-op-per-dispatch replay must be BIT-exact with the single
#     shard_map program (profile-on ≡ profile-off) and emit exactly one
#     measured OpEvent per IR op.
from repro.obs.cli import build_setup
from repro.obs.measure import measured_gradsync

for strat in ("concom", "rsag"):
    gs_o, grads_o = build_setup(strat, "flat", 64)
    pspecs_o = gs_o.param_specs
    flat_g, gdef = jax.tree_util.tree_flatten(grads_o)
    flat_s = jax.tree_util.tree_leaves(
        pspecs_o, is_leaf=lambda x: isinstance(x, P))
    gput = jax.tree_util.tree_unflatten(gdef, [
        jax.device_put(g, NamedSharding(gs_o.mesh, s))
        for g, s in zip(flat_g, flat_s)])
    ref = jax.jit(lambda g, _gs=gs_o, _ps=pspecs_o: jax.shard_map(
        _gs, mesh=_gs.mesh, in_specs=(_ps,), out_specs=_ps,
        check_vma=False)(g))(gput)
    out_m, tl_m, _ = measured_gradsync(gs_o, grads_o, reps=1)
    check(f"obs-measured-opcount[{strat}]",
          len(tl_m.events) == len(gs_o.schedule.ops) > 0)
    check(f"obs-measured-equals-execute-bitexact[{strat}]",
          worst_diff(out_m, ref) == 0.0)
    check(f"obs-measured-serial-clock[{strat}]",
          abs(tl_m.step_time - sum(e.duration for e in tl_m.events))
          < 1e-9)

# 13. continuous-batching serving (DESIGN.md §14) at dp=2 × tp=4: the
#     paged engine must match the static path bit-for-bit under greedy
#     on real process groups (vocab sharded over tp=4, slots over dp=2),
#     and the vocab-sharded samplers must keep their tie-break and
#     per-request seed contracts across shards.
from repro.runtime import (ContinuousScheduler, SamplingParams, Server,
                           sharded_argmax, sharded_sample)

mk_serve = lambda: tf.TransformerConfig(
    name="dense", n_layers=2, d_model=64, n_heads=8, kv_heads=4, d_ff=128,
    vocab=96, tp=4, attn_chunk=16, dtype=jnp.float32)

# sharded_argmax tie-break: equal maxima on shards 1 and 3 → the LOWEST
# shard (and lowest index within it) must win, deterministically
_v_local = 96 // 4
_tie = np.full((2, 96), -5.0, np.float32)
_tie[:, 1 * _v_local + 3] = 7.0          # shard 1, local index 3
_tie[:, 3 * _v_local + 0] = 7.0          # shard 3, local index 0
_tie[0, 1 * _v_local + 5] = 7.0          # row 0: another tie inside shard 1


def _run_argmax(logits):
    return jax.jit(lambda l: jax.shard_map(
        lambda x: sharded_argmax(x, 4), mesh=mesh8,
        in_specs=(P(None, "model"),), out_specs=P(),
        check_vma=False)(l))(jnp.asarray(logits))


_am = np.asarray(_run_argmax(_tie))
check("serve-argmax-tiebreak-lowest-shard",
      _am[0] == 1 * _v_local + 3 and _am[1] == 1 * _v_local + 3)

# sharded_sample at temperature 0 ≡ sharded_argmax (ties included)
_rng_s = np.random.default_rng(3)
_rand = _rng_s.normal(size=(4, 96)).astype(np.float32)
_rand[2] = _tie[0, :]                      # one all-tied row in the batch


def _run_sample(logits, temps, topks, topps, seeds):
    def body(l, t, k, p, s):
        keys = jax.vmap(jax.random.PRNGKey)(s)
        return sharded_sample(l, 4, keys, t, k, p)
    return jax.jit(lambda *a: jax.shard_map(
        body, mesh=mesh8, in_specs=(P(None, "model"),) + (P(),) * 4,
        out_specs=P(), check_vma=False)(*a))(
        jnp.asarray(logits), jnp.asarray(temps), jnp.asarray(topks),
        jnp.asarray(topps), jnp.asarray(seeds))


_z4 = np.zeros(4, np.float32)
_s0 = _run_sample(_rand, _z4, np.zeros(4, np.int32), np.ones(4, np.float32),
                  np.arange(4, dtype=np.uint32))
check("serve-sample-temp0-equals-argmax",
      np.array_equal(np.asarray(_s0), np.asarray(_run_argmax(_rand))))

# the paged continuous-batching engine vs the static Server, end to end
scfg = mk_serve()
sparams = family_of(scfg).init(jax.random.PRNGKey(7), scfg)
srv8 = Server(scfg, mesh8, sparams, max_len=64)
eng8 = ContinuousScheduler(srv8, slots=8, block_size=16, chunk=4)

_rng_p = np.random.default_rng(11)
sprompts = [_rng_p.integers(1, 96, size=int(L)).astype(np.int32)
            for L in (5, 12, 17, 3, 30, 9)]
souts = eng8.generate_batch(sprompts, 10)
_exact = all(
    np.array_equal(srv8.generate(np.tile(p[None], (2, 1)), 10)[0], o)
    for p, o in zip(sprompts, souts))
check("serve-paged-greedy-bitexact-vs-static", _exact)

ssp = SamplingParams(temperature=0.8, top_k=8, seed=42)
sa = eng8.generate_batch(sprompts[:3], 10, ssp)
sb = eng8.generate_batch(sprompts[:3], 10, ssp)
check("serve-sample-seed-reproducible",
      all(np.array_equal(x, y) for x, y in zip(sa, sb)))
sc = eng8.generate_batch(sprompts[:3], 10,
                         SamplingParams(temperature=0.8, top_k=8, seed=9))
check("serve-sample-seed-differs",
      any(not np.array_equal(x, y) for x, y in zip(sa, sc)))
sk1 = eng8.generate_batch(sprompts[:3], 10,
                          SamplingParams(temperature=0.9, top_k=1, seed=3))
check("serve-sample-topk1-equals-greedy",
      all(np.array_equal(x, y) for x, y in zip(sk1, souts[:3])))

# 14. pipeline parallelism (DESIGN.md §15) at dp=2 × stage=2 × tp=2:
#     the staged wave pipeline over real stage process groups must match
#     the stage=1 reference BIT-exactly — GPipe at any M (same reverse-
#     wave accumulation order; warmup/drain garbage dies in exact-zero
#     where-mask cotangents), 1F1B at M == S (its single chunk IS the
#     GPipe wave).  Chunked 1F1B at M > S re-associates the chunk sum
#     (float round-off, like the §10 accum peel), as does the clip
#     norm's interaction with adamw's compiled update — both held to
#     loose tolerance instead.
from repro.launch.mesh import make_smoke_mesh
from repro.sim.autotune import choose_pp_schedule

mesh_pp2 = make_smoke_mesh(2, 2, stage=2)   # dp2 × stage2 × tp2
mesh_pp1 = make_smoke_mesh(2, 2, stage=1)   # the staged S=1 reference

mk_pp = lambda: tf.TransformerConfig(
    name="dense", n_layers=2, d_model=64, n_heads=8, kv_heads=2,
    d_ff=128, vocab=96, tp=2, attn_chunk=16, dtype=jnp.float32)


def pp_steps(mesh, stage, schedule, microbatch, n=2, clip=0.0):
    cfg = mk_pp()
    params = family_of(cfg).init(jax.random.PRNGKey(2), cfg)
    pipe = TokenPipeline(96, 32, 8, seed=5, mesh=mesh)
    sync = GradSyncConfig(strategy="concom", bucket_bytes=1 << 12)
    ts = make_train_step(cfg, mesh, sync, adamw(1e-3),
                         batch_like=pipe.batch_at(0), params_like=params,
                         clip_norm=clip, microbatch=microbatch,
                         pp_stages=stage, pp_schedule=schedule)
    ps = jax.device_put(params, ts.shardings(ts.param_specs))
    st = ts.init_opt()
    m = None
    for k in range(n):
        ps, st, m = ts.fn(ps, st, pipe.batch_at(k), jnp.int32(k))
    return ps, m


pg2, mg2 = pp_steps(mesh_pp2, 2, "gpipe", 4)
pg1, mg1 = pp_steps(mesh_pp1, 1, "gpipe", 4)
check("pp-gpipe-bitexact-vs-stage1",
      worst_diff(pg2, pg1) == 0.0
      and float(mg2["loss"]) == float(mg1["loss"]))

# 1f1b at M == S: one chunk of S microbatches == the GPipe wave program
pf2, mf2 = pp_steps(mesh_pp2, 2, "1f1b", 2)
pw1, _ = pp_steps(mesh_pp1, 1, "gpipe", 2)
check("pp-1f1b-m-eq-s-bitexact-vs-stage1", worst_diff(pf2, pw1) == 0.0)

# chunked 1f1b at M > S: chunk-sum re-association only (round-off)
pf4, _ = pp_steps(mesh_pp2, 2, "1f1b", 4)
pf4r, _ = pp_steps(mesh_pp1, 1, "1f1b", 4)
check("pp-1f1b-m4-close-vs-stage1", worst_diff(pf4, pf4r) < 1e-5)

# clipped: gnorm is bit-identical across stagings (per-leaf psum in the
# same layer order); the clip×adamw fusion is float round-off
pc2, mc2 = pp_steps(mesh_pp2, 2, "gpipe", 4, clip=0.05)
pc1, mc1 = pp_steps(mesh_pp1, 1, "gpipe", 4, clip=0.05)
check("pp-clip-gnorm-bitexact",
      float(mc2["grad_norm"]) == float(mc1["grad_norm"]))
check("pp-clip-close-vs-stage1", worst_diff(pc2, pc1) < 1e-5)

# staged S=1 vs the plain (no stage axis) accumulation path: same math,
# different program shape — float round-off closeness
mesh_pp0 = make_smoke_mesh(2, 2)
cfg_pl = mk_pp()
params_pl = family_of(cfg_pl).init(jax.random.PRNGKey(2), cfg_pl)
pipe_pl = TokenPipeline(96, 32, 8, seed=5, mesh=mesh_pp0)
ts_pl = make_train_step(
    cfg_pl, mesh_pp0, GradSyncConfig(strategy="concom",
                                     bucket_bytes=1 << 12),
    adamw(1e-3), batch_like=pipe_pl.batch_at(0), params_like=params_pl,
    clip_norm=0.0, microbatch=4)
pp_pl = jax.device_put(params_pl, ts_pl.shardings(ts_pl.param_specs))
st_pl = ts_pl.init_opt()
for k in range(2):
    pp_pl, st_pl, _ = ts_pl.fn(pp_pl, st_pl, pipe_pl.batch_at(k),
                               jnp.int32(k))
check("pp-staged-ref-close-vs-plain-accum",
      worst_diff(pg1, pp_pl) < 1e-4)

# auto resolves to a fixed schedule before compile and matches that
# fixed schedule's trajectory bit-for-bit
pick = choose_pp_schedule(2, 4)
pa2, _ = pp_steps(mesh_pp2, 2, "auto", 4)
pfix, _ = pp_steps(mesh_pp2, 2, pick, 4)
check("pp-auto-equals-resolved-fixed-bitexact",
      worst_diff(pa2, pfix) == 0.0)

print("DONE", flush=True)
