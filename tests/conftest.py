# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py forces 512 devices.
import os
import tempfile
import warnings

warnings.filterwarnings("ignore")

# point the fitted-NetworkModel lookup at an empty dir: a profile written
# by a local `make calibrate-smoke` must not leak into `auto`-ranking
# tests (obs tests override this per-test).  Inherited by the
# subprocess-based multidevice/bench workers via os.environ.
os.environ["REPRO_NETPROFILE_DIR"] = tempfile.mkdtemp(
    prefix="repro-netprofiles-test-")

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh

    return make_smoke_mesh(1, 1)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
