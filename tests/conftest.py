# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py forces 512 devices.
import warnings

warnings.filterwarnings("ignore")

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh

    return make_smoke_mesh(1, 1)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
