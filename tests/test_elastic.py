"""repro.elastic: IR-level regroup/reshard semantics (single device)
plus the full multi-device elastic cycle in a subprocess worker
(tests/_elworker.py — jax fixes the device count at first init)."""
import os
import subprocess
import sys

import repro  # noqa: F401  (applies the jaxcompat shim before jax imports)
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AxisType, PartitionSpec as P

from repro.analysis import ScheduleError, run_passes, verify_schedule
from repro.analysis.mutations import (
    NEW_MESH_RS,
    OLD_MESH_RS,
    synthetic_reshard_schedule,
)
from repro.core.schedule import REGROUP, RESHARD, CommSchedule

CTX = dict(old_mesh_shape=OLD_MESH_RS, new_mesh_shape=NEW_MESH_RS)


# ----------------------------------------------------- transition IR

def test_synthetic_transition_verifies_clean():
    s = synthetic_reshard_schedule()
    verify_schedule(s, **CTX)
    report = run_passes(s, **CTX)
    assert report.ok, report.render()


def test_split_regroup_sides():
    s = synthetic_reshard_schedule(streams=("param", "inner/m"))
    old, new = s.split_regroup()
    assert old.ops[-1].kind == REGROUP
    assert all(op.kind == RESHARD for op in new.ops)
    # cross-side deps were dropped: the new side is self-contained
    new_ids = {op.op_id for op in new.ops}
    for op in new.ops:
        assert set(op.depends_on) <= new_ids
    # each side verifies standalone (old on the old mesh, new on the new)
    run_passes(old, mesh_shape=OLD_MESH_RS)
    run_passes(new, mesh_shape=NEW_MESH_RS)


def test_split_regroup_requires_regroup():
    s = synthetic_reshard_schedule()
    plain = CommSchedule(tuple(op for op in s.ops
                               if op.kind != REGROUP))
    with pytest.raises(ValueError, match="no REGROUP"):
        plain.split_regroup()


def test_reshard_pass_leaf_divisibility():
    # the static divisibility facts fail loud even with no RESHARD ops
    s = synthetic_reshard_schedule()
    with pytest.raises(ScheduleError, match="leaf-indivisible"):
        verify_schedule(s, **CTX,
                        leaf_divisibility={"w0@dim0": (10, 4)})
    verify_schedule(s, **CTX, leaf_divisibility={"w0@dim0": (12, 4)})


def test_reshard_pass_byte_conservation():
    s = synthetic_reshard_schedule()
    # drop one scatter: the new side loses a stream's bytes
    pruned = CommSchedule(s.ops[:-1])
    report = run_passes(pruned, **CTX)
    assert not report.ok
    assert any(f.code in ("leaf-lost", "leaf-size-drift")
               for f in report.findings)


# ------------------------------------------------- sim costing

def test_sim_costs_transition_ops():
    from repro.sim.engine import SimConfig, simulate

    s = synthetic_reshard_schedule()
    merged = {a: max(OLD_MESH_RS.get(a, 1), NEW_MESH_RS.get(a, 1))
              for a in {*OLD_MESH_RS, *NEW_MESH_RS}}
    tl = simulate(s, merged, sim=SimConfig())
    assert len(tl.events) == len(s.ops)
    by_id = {e.op_id: e for e in tl.events}
    for op in s.ops:
        assert by_id[op.op_id].duration > 0
    # the REGROUP barrier starts only after every gather finished
    rg = next(op for op in s.ops if op.kind == REGROUP)
    gather_ends = [by_id[op.op_id].end for op in s.ops
                   if op.kind == RESHARD and op.op_id < rg.op_id]
    assert by_id[rg.op_id].start >= max(gather_ends) - 1e-12


# ------------------------------------------------- KVStore.regroup

def test_kvstore_regroup_records_barrier_ir():
    from repro.core.kvstore import KVStore

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))

    kv = KVStore("concom", reduce_axes=("data",), num_channels=2,
                 mesh_shape={"data": 1})
    traced = {}

    def body(x):
        kv.init(0, x)
        kv.init(1, x)
        kv.push(0, x)
        kv.push(1, x * 2)
        traced["size"] = kv.regroup()
        kv.push(0, x * 3)
        return kv.pull(0)

    out = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                                out_specs=P(), check_vma=False))(
        jnp.ones((8,), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), 3.0)

    s = kv.schedule()
    kinds = [op.kind for op in s.ops]
    assert kinds.count(REGROUP) == 1
    rg = next(op for op in s.ops if op.kind == REGROUP)
    pre = [op.op_id for op in s.ops
           if op.op_id < rg.op_id and op.kind != REGROUP]
    # the barrier joins every outstanding chain tail...
    assert set(rg.depends_on) == set(pre[-2:]) or \
        set(rg.depends_on) <= set(pre)
    # ...and every post-regroup op is anchored on it
    post = [op for op in s.ops if op.op_id > rg.op_id]
    assert post and all(rg.op_id in op.depends_on for op in post)
    assert run_passes(s, mesh_shape={"data": 1}).ok


def test_kvstore_regroup_switches_communicator():
    from repro.core.kvstore import KVStore

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    kv = KVStore("concom", reduce_axes=("data", "model"), num_channels=1,
                 mesh_shape={"data": 1, "model": 1})

    def body(x):
        kv.init(0, x)
        kv.push(0, x)
        kv.regroup(reduce_axes=("data",), mesh_shape={"data": 1})
        kv.push(0, x)
        return kv.pull(0)

    jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                          out_specs=P(), check_vma=False))(
        jnp.ones((4,), jnp.float32))
    assert kv.reduce_axes == ("data",)
    # the trace spans TWO communicators — no single mesh_shape verifies
    # it, so read the IR unverified and check the recorded switch
    s = kv.schedule(verify=False)
    rg = next(op for op in s.ops if op.kind == REGROUP)
    # the barrier itself runs on the OLD communicator's axes
    assert rg.bucket.reduce_axes == ("data", "model")
    # ops after the regroup reduce over the NEW group only
    post = [op for op in s.ops if op.op_id > rg.op_id]
    assert post and all(op.bucket.reduce_axes == ("data",)
                       for op in post)


# ------------------------------------------------- multi-device worker

@pytest.fixture(scope="module")
def worker_output():
    script = os.path.join(os.path.dirname(__file__), "_elworker.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    proc = subprocess.run(
        [sys.executable, script], env=env,
        capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_worker_completed(worker_output):
    assert "DONE" in worker_output


def test_all_elastic_checks_pass(worker_output):
    fails = [l for l in worker_output.splitlines() if l.startswith("FAIL")]
    passes = [l for l in worker_output.splitlines() if l.startswith("PASS")]
    assert not fails, fails
    assert len(passes) >= 18, worker_output
