"""End-to-end system behaviour: fault-tolerant training runtime +
batched serving (deliverables a/b/c integration)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import GradSyncConfig
from repro.data import Prefetcher, TokenPipeline
from repro.models import transformer as tf
from repro.optim import adamw, cosine_warmup, zero1
from repro.runtime import Server, Trainer, make_train_step


@pytest.fixture(scope="module")
def setup(smoke_mesh):
    cfg = tf.TransformerConfig(
        name="sys", n_layers=2, d_model=32, n_heads=4, kv_heads=2,
        d_ff=64, vocab=64, tp=1, attn_chunk=16, dtype=jnp.float32)
    pipe = TokenPipeline(64, 16, 4, seed=11, mesh=smoke_mesh)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(cosine_warmup(3e-3, 5, 100))
    ts = make_train_step(
        cfg, smoke_mesh,
        GradSyncConfig(strategy="depcha", num_channels=2,
                       bucket_bytes=1 << 14),
        opt, batch_like=pipe.batch_at(0), params_like=params)
    return cfg, pipe, params, opt, ts


def test_loss_decreases(setup, tmp_path):
    cfg, pipe, params, opt, ts = setup
    # repeat ONE batch so the loss must fall (overfit sanity)
    class OneBatch:
        def batch_at(self, step):
            return pipe.batch_at(0)
    tr = Trainer(ts, OneBatch(), None, log_every=1000)
    _, _, hist = tr.run(params, opt.init(params), 30)
    assert hist["losses"][-1] < hist["losses"][0] - 0.1, hist["losses"][::10]


def test_failure_recovery_is_deterministic(setup, tmp_path):
    cfg, pipe, params, opt, ts = setup
    opt_state = opt.init(params)

    ck1 = CheckpointManager(str(tmp_path / "a"), every=5, keep=2,
                            blocking=True)
    p1, _, _ = Trainer(ts, pipe, ck1, log_every=1000).run(
        params, opt_state, 12)

    ck2 = CheckpointManager(str(tmp_path / "b"), every=5, keep=2,
                            blocking=True)
    p2, _, hist = Trainer(ts, pipe, ck2, log_every=1000,
                          fail_at=frozenset({8})).run(
        params, opt_state, 12)
    kinds = [e["kind"] for e in hist["events"]]
    assert "failure" in kinds and "recover" in kinds
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_strategies_reach_same_params(setup, smoke_mesh):
    """Every registered strategy is schedule-only: same trained params."""
    from repro.core import strategy_names

    cfg, pipe, params, opt, _ = setup
    finals = []
    for strat in strategy_names():
        ts = make_train_step(
            cfg, smoke_mesh, GradSyncConfig(strategy=strat, num_channels=3,
                                            bucket_bytes=512),
            opt, batch_like=pipe.batch_at(0), params_like=params)
        tr = Trainer(ts, pipe, None, log_every=1000)
        p, _, _ = tr.run(params, opt.init(params), 5)
        finals.append(p)
    for other in finals[1:]:
        for a, b in zip(jax.tree.leaves(finals[0]), jax.tree.leaves(other)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-5, rtol=2e-5)


def test_zero1_matches_plain_adamw(setup, smoke_mesh):
    """At dp=1 ZeRO-1 must be numerically equivalent to the inner opt."""
    cfg, pipe, params, opt, _ = setup
    sync = GradSyncConfig(strategy="concom")
    ts_a = make_train_step(cfg, smoke_mesh, sync, adamw(1e-3),
                           batch_like=pipe.batch_at(0), params_like=params,
                           clip_norm=0)
    optz = zero1(adamw(1e-3), ("data",), 1)
    ts_z = make_train_step(
        cfg, smoke_mesh,
        GradSyncConfig(strategy="concom", exclude_axes=("data",)),
        optz, batch_like=pipe.batch_at(0), params_like=params,
        zero1_mode=True, clip_norm=0)
    oa = adamw(1e-3).init(params)
    oz = ts_z.init_opt()
    b = pipe.batch_at(0)
    pa, _, ma = ts_a.fn(params, oa, b, jnp.int32(0))
    pz, _, mz = ts_z.fn(params, oz, b, jnp.int32(0))
    assert abs(float(ma["loss"]) - float(mz["loss"])) < 1e-6
    for a, z in zip(jax.tree.leaves(pa), jax.tree.leaves(pz)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(z, np.float32), atol=1e-5)


def test_server_generate(setup, smoke_mesh):
    cfg, pipe, params, opt, _ = setup
    srv = Server(cfg, smoke_mesh, params, max_len=32)
    out = srv.generate(np.ones((4, 8), np.int32), 5)
    assert out.shape == (4, 5)
    assert out.dtype == np.int32
    assert np.all((out >= 0) & (out < cfg.vocab_padded))


def test_server_decode_consistent_with_prefill(setup, smoke_mesh):
    """Greedy token from incremental decode == token from re-prefilling
    the extended prompt (KV-cache correctness end-to-end)."""
    cfg, pipe, params, opt, _ = setup
    srv = Server(cfg, smoke_mesh, params, max_len=32)
    prompt = np.arange(1, 9, dtype=np.int32)[None].repeat(2, 0)  # (2, 8)
    out = srv.generate(prompt, 3)
    # re-run: prompt + first generated token → next greedy must equal out[:,1]
    ext = np.concatenate([prompt, out[:, :1]], axis=1)
    out2 = srv.generate(ext, 2)
    np.testing.assert_array_equal(out[:, 1], out2[:, 0])


def test_request_queue_batching(setup, smoke_mesh):
    from repro.runtime.serve_loop import RequestQueue

    cfg, pipe, params, opt, _ = setup
    srv = Server(cfg, smoke_mesh, params, max_len=32)
    q = RequestQueue(srv, batch=4)
    handles = [q.submit(np.arange(1, 6, dtype=np.int32), 3)
               for _ in range(3)]
    served = q.serve_once()
    assert served == 3
    for h in handles:
        out = h.get(timeout=5)
        assert out.shape == (3,)


def test_prefetcher_preserves_order():
    it = iter(range(10))
    out = list(Prefetcher(it, depth=3))
    assert out == list(range(10))


def test_pipeline_determinism(smoke_mesh):
    p1 = TokenPipeline(100, 8, 4, seed=3, mesh=smoke_mesh)
    p2 = TokenPipeline(100, 8, 4, seed=3, mesh=smoke_mesh)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch_at(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
