"""Paged KV-cache: block allocator + block-table bookkeeping (DESIGN.md §14).

The static serving path gives every request a ``max_len``-sized slice of
the sharded cache, so short requests pay long requests' padding and a new
batch shape re-``device_put``s the whole cache.  Here the cache is a pool
of fixed-size *blocks* along the sequence dim; each in-flight request owns
just the blocks its length needs, via a per-slot block table mapping
logical position ``p`` → physical slot ``table[p // block_size] *
block_size + p % block_size``.

Everything in this module is host-side pure Python (allocator, layout
math) — the device pool itself lives in the serving engine
(``repro.runtime.serve_loop``), which consumes these tables as plain
int32 arrays.  Block 0 of every rank's pool is reserved as the *scratch*
block: empty decode slots read and write it so the fixed-width decode
batch never branches on occupancy; nothing real ever maps to it.
"""
from __future__ import annotations

import dataclasses
from collections import deque

SCRATCH_BLOCK = 0


def blocks_for(length: int, block_size: int) -> int:
    """Physical blocks needed to hold ``length`` cache positions."""
    if length <= 0:
        return 0
    return -(-length // block_size)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Shape bookkeeping for one rank's share of the paged pool.

    ``num_blocks`` counts the scratch block; ``max_blocks`` is the block-
    table width (the longest admissible request).  ``seq_capacity`` is
    the gathered sequence extent one decode step sees — callers that
    want bit-exactness with a ``max_len`` static cache should pick
    ``block_size`` dividing ``max_len`` so the extents match.
    """

    block_size: int
    num_blocks: int          # physical blocks per rank, incl. scratch
    max_blocks: int          # block-table width (blocks per request cap)

    def __post_init__(self):
        if self.block_size <= 0:
            raise ValueError(f"block_size must be > 0, got {self.block_size}")
        if self.num_blocks < 2:
            raise ValueError("need at least one non-scratch block")
        if self.max_blocks < 1:
            raise ValueError("max_blocks must be >= 1")

    @property
    def seq_capacity(self) -> int:
        return self.max_blocks * self.block_size

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1        # minus scratch

    @classmethod
    def for_requests(cls, max_len: int, block_size: int,
                     slots: int, *, num_blocks: int | None = None
                     ) -> "PagedLayout":
        """Layout sized so ``slots`` concurrent max-length requests fit
        (the no-overcommit default); ``num_blocks`` overrides to model
        a scarcer pool (admission then blocks on allocator pressure)."""
        per = blocks_for(max_len, block_size)
        return cls(
            block_size=block_size,
            num_blocks=(num_blocks if num_blocks is not None
                        else 1 + slots * per),
            max_blocks=per)


class BlockAllocator:
    """Free-list allocator over one rank's physical blocks.

    All-or-nothing ``alloc`` (a request either gets every block of its
    worst-case length or stays queued — no partial reservations to
    deadlock on), O(1) ``free``.  Block 0 (scratch) is never handed out.
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self._free: deque[int] = deque(range(1, layout.num_blocks))
        self._in_use = 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self._in_use

    @property
    def utilization(self) -> float:
        return self._in_use / max(self.layout.usable_blocks, 1)

    def can_fit(self, length: int) -> bool:
        return blocks_for(length, self.layout.block_size) <= len(self._free)

    def alloc(self, length: int) -> list[int] | None:
        """Blocks for a ``length``-position request, or None if the pool
        cannot fit it right now (caller keeps the request queued)."""
        n = blocks_for(length, self.layout.block_size)
        if n > self.layout.max_blocks:
            raise ValueError(
                f"request needs {n} blocks > max_blocks="
                f"{self.layout.max_blocks} (length {length})")
        if n > len(self._free):
            return None
        out = [self._free.popleft() for _ in range(n)]
        self._in_use += n
        return out

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b == SCRATCH_BLOCK:
                raise ValueError("scratch block cannot be freed")
            self._free.append(b)
        self._in_use -= len(blocks)
        if self._in_use < 0:
            raise ValueError("double free: more blocks freed than allocated")

    def table_row(self, blocks: list[int]) -> list[int]:
        """A fixed-width block-table row: the request's blocks padded
        with the scratch block (positions past its reservation never
        get written — admission caps length at the reservation)."""
        pad = self.layout.max_blocks - len(blocks)
        return list(blocks) + [SCRATCH_BLOCK] * pad
