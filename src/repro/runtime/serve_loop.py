"""Serving runtime: static batcher + continuous-batching engine.

Two serving paths share the sharded params and the vocab-sharded
samplers:

- ``Server``/``RequestQueue`` — the original static batcher: one padded
  batch prefills together and decodes to the batch-wide ``max_new``
  (kept as the reference path and for families without a paged decode
  hook).  Decode tokens stay on device and materialize once per
  ``generate`` (``sync_per_token=True`` restores the old per-token
  host sync, for measuring the delta).

- ``ContinuousScheduler`` — in-flight batching over a paged KV pool
  (DESIGN.md §14): a fixed-width decode batch whose slots are admitted
  and retired per step; new requests prefill (bucketed, bit-exact) into
  free slots, finished/EOS slots retire immediately and their cache
  blocks return to the ``repro.runtime.kvcache`` allocator.  Decode runs
  in chunks of ``chunk`` tokens with ONE host sync per chunk (tokens
  come back ``-1``-masked per slot), and sampling happens on the
  vocab-sharded logits without a full-vocab gather
  (``sharded_sample``: local top-k per shard → all-gather tp×k
  candidates → global categorical).

Decode sharding: slots over DP axes (slot ``w`` is owned by dp rank
``w // W_local``; its cache blocks live in that rank's pool shard),
heads/vocab over "model".
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.registry import family_of
from repro.parallel.sharding import batch_spec, dp_axes_of
from repro.runtime.kvcache import SCRATCH_BLOCK, BlockAllocator, PagedLayout


# ------------------------------------------------------------- samplers
def sharded_argmax(logits_local: jax.Array, tp: int) -> jax.Array:
    """Greedy token from (B, V/tp) vocab-sharded logits → (B,) global ids."""
    if tp == 1:
        return jnp.argmax(logits_local, axis=-1).astype(jnp.int32)
    v_local = logits_local.shape[-1]
    idx = jax.lax.axis_index("model")
    local_max = jnp.max(logits_local, axis=-1)
    local_arg = jnp.argmax(logits_local, axis=-1).astype(jnp.int32) \
        + idx * v_local
    # gather (tp, B) maxes+args, pick deterministically (lowest shard wins ties)
    maxes = jax.lax.all_gather(local_max, "model", axis=0)
    args = jax.lax.all_gather(local_arg, "model", axis=0)
    best = jnp.argmax(maxes, axis=0)
    return jnp.take_along_axis(args, best[None], axis=0)[0]


def sharded_sample(
    logits_local: jax.Array,     # (B, V/tp) f32 vocab-sharded logits
    tp: int,
    keys: jax.Array,             # (B, 2) uint32 per-row PRNG keys
    temperature: jax.Array,      # (B,) f32; 0 → greedy (exact argmax)
    top_k: jax.Array,            # (B,) int32; 0 → no top-k cap
    top_p: jax.Array,            # (B,) f32; 1.0 → no nucleus cap
    *,
    k_cand: int = 16,
) -> jax.Array:
    """Temperature/top-k/top-p sampling on vocab-sharded logits → (B,) ids.

    Generalizes ``sharded_argmax``: each shard keeps its local top
    ``k_cand`` logits, one all-gather moves the tp×k_cand candidates
    (not the vocab), and the categorical draws among them.  Candidates
    are stably ordered by (value desc, shard asc, index asc), so the
    head candidate is exactly ``sharded_argmax``'s pick — at
    temperature 0 the two are identical, ties included.  Sampling is
    exact whenever the effective top-k ≤ ``k_cand`` (per shard);
    an unbounded-tail draw (top_k=0, top_p=1) is truncated to the
    tp×k_cand most likely tokens.
    """
    B, v_local = logits_local.shape
    k_eff = min(k_cand, v_local)
    vals, idx = jax.lax.top_k(logits_local, k_eff)       # (B, k) desc, stable
    idx = idx.astype(jnp.int32)
    if tp > 1:
        shard = jax.lax.axis_index("model")
        idx = idx + shard * v_local
        # (tp, B, k) → (B, tp, k) → (B, tp*k): shard-major candidate order
        vals = jnp.swapaxes(
            jax.lax.all_gather(vals, "model", axis=0), 0, 1
        ).reshape(B, -1)
        idx = jnp.swapaxes(
            jax.lax.all_gather(idx, "model", axis=0), 0, 1
        ).reshape(B, -1)
    K = vals.shape[-1]
    # stable sort keeps shard-asc/index-asc order among equal values —
    # the same tie-break as sharded_argmax
    order = jnp.argsort(-vals, axis=-1, stable=True)
    vals = jnp.take_along_axis(vals, order, axis=-1)
    idx = jnp.take_along_axis(idx, order, axis=-1)
    greedy = idx[:, 0]

    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = vals.astype(jnp.float32) / t
    ranks = jnp.arange(K, dtype=jnp.int32)[None, :]
    kcap = jnp.where(top_k > 0, jnp.minimum(top_k, K), K)[:, None]
    mask = ranks < kcap
    probs = jax.nn.softmax(jnp.where(mask, scaled, -jnp.inf), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # nucleus: keep candidates whose preceding mass is < top_p (the head
    # candidate always survives: its preceding mass is 0)
    mask &= (cum - probs) < top_p[:, None]
    masked = jnp.where(mask, scaled, -jnp.inf)
    draw = jax.vmap(jax.random.categorical)(keys, masked)
    sampled = jnp.take_along_axis(idx, draw[:, None], axis=-1)[:, 0]
    return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs; the default is greedy decoding."""
    temperature: float = 0.0
    top_k: int = 0               # 0 → no cap
    top_p: float = 1.0
    seed: int = 0


# ------------------------------------------------------- static batcher
@dataclasses.dataclass
class ServeFns:
    prefill: Any
    decode: Any
    cache_specs: Any


class Server:
    """Batched greedy-decoding server for any family with serve hooks."""

    def __init__(self, cfg, mesh: Mesh, params, *, max_len: int = 256,
                 batch: int | None = None,
                 metrics: "MetricsRegistry | None" = None):
        from repro.obs import MetricsRegistry

        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.cfg = cfg
        self.mesh = mesh
        self.api = family_of(cfg)
        assert self.api.prefill is not None, f"{cfg.name} has no serve path"
        self.rules = self.api.param_rules(cfg)
        self.pspecs = self.rules.tree_specs(params)
        self.params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 self.pspecs))
        self.max_len = max_len
        self.tp = getattr(cfg, "tp", 1)
        self._fns: dict[int, ServeFns] = {}
        self.dp = dp_axes_of(mesh)
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp])) or 1

    def _build(self, B: int) -> ServeFns:
        cfg, mesh, api = self.cfg, self.mesh, self.api
        bspec = batch_spec(mesh)
        B_local = B // self.dp_size

        batch_entry = bspec[0] if len(bspec) else None
        cspecs = api.decode_state_specs(cfg, batch_entry)

        def prefill_fn(params, tokens):
            logits, cache = api.prefill(params, tokens, cfg)
            tok = sharded_argmax(logits.astype(jnp.float32), cfg.tp)
            return tok, cache

        def decode_fn(params, cache, tok, pos):
            logits, cache = api.decode_step(params, cache, tok, pos, cfg)
            nxt = sharded_argmax(logits.astype(jnp.float32), cfg.tp)
            return nxt, cache

        pf = jax.jit(jax.shard_map(
            prefill_fn, mesh=mesh,
            in_specs=(self.pspecs, bspec),
            out_specs=(bspec, cspecs), check_vma=False))
        dc = jax.jit(jax.shard_map(
            decode_fn, mesh=mesh,
            in_specs=(self.pspecs, cspecs, bspec, P()),
            out_specs=(bspec, cspecs), check_vma=False),
            donate_argnums=(1,))
        return ServeFns(pf, dc, cspecs)

    def _pad_cache(self, cache, prompt_len: int):
        """Grow prefill cache (seq dim = prompt_len) to max_len slots."""
        def pad(leaf):
            if leaf.ndim >= 3 and leaf.shape[2] == prompt_len:
                pad_n = self.max_len - prompt_len
                if pad_n > 0:
                    cfgpad = [(0, 0)] * leaf.ndim
                    cfgpad[2] = (0, pad_n)
                    return jnp.pad(leaf, cfgpad)
            return leaf
        return jax.tree.map(pad, cache)

    def generate(self, prompts: np.ndarray, max_new: int, *,
                 sync_per_token: bool = False) -> np.ndarray:
        """prompts: (B, S) int32 → (B, max_new) greedy continuations.

        Tokens accumulate ON DEVICE and materialize once at the end —
        the decode loop enqueues ``max_new`` steps without a host sync
        per token.  ``sync_per_token=True`` restores the old
        np.asarray-per-step behavior (kept for measuring the delta in
        ``BENCH_serve.json``).
        """
        B, S = prompts.shape
        if B not in self._fns:
            self._fns[B] = self._build(B)
        fns = self._fns[B]
        t_start = time.perf_counter()
        toks = jax.device_put(
            jnp.asarray(prompts, jnp.int32),
            NamedSharding(self.mesh, batch_spec(self.mesh)))
        tok, cache = fns.prefill(self.params, toks)
        needs_pad = any(
            l.ndim >= 3 and l.shape[2] == S
            for l in jax.tree.leaves(cache)) and S != self.max_len
        if needs_pad:
            cache = self._pad_cache(cache, S)
            cache = jax.device_put(
                cache, jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), fns.cache_specs))
        t_prefill = time.perf_counter()
        out = [tok]
        pos = S
        for _ in range(max_new - 1):
            tok, cache = fns.decode(self.params, cache, tok, jnp.int32(pos))
            if sync_per_token:
                np.asarray(tok)
            out.append(tok)
            pos += 1
        result = np.asarray(jnp.stack(out, axis=1))      # ONE device sync
        t_end = time.perf_counter()
        self.metrics.counter("serve.requests_total").inc(B)
        self.metrics.counter("serve.tokens_generated").inc(B * max_new)
        self.metrics.histogram("serve.prefill_s").observe(
            t_prefill - t_start)
        if max_new > 1:
            self.metrics.histogram("serve.decode_per_token_s").observe(
                (t_end - t_prefill) / (max_new - 1))
        self.metrics.gauge("serve.tokens_per_s").set(
            B * max_new / max(t_end - t_start, 1e-9))
        return result


class RequestQueue:
    """Minimal batching front-end: collects up to ``batch`` requests (or
    ``timeout_s``), pads to a common length, serves, returns per-request.

    If ``Server.generate`` raises, the exception instance is delivered
    to EVERY waiter's done queue (callers check
    ``isinstance(result, Exception)``) — waiters never block forever on
    a failed batch."""

    def __init__(self, server: Server, batch: int, timeout_s: float = 0.05):
        self.server = server
        self.batch = batch
        self.timeout_s = timeout_s
        self.q: queue.Queue = queue.Queue()

    def submit(self, prompt: np.ndarray, max_new: int) -> "queue.Queue":
        done: queue.Queue = queue.Queue(maxsize=1)
        self.q.put((prompt, max_new, done))
        return done

    def serve_once(self) -> int:
        """Drain up to ``batch`` requests, run one padded generate."""
        reqs = []
        try:
            reqs.append(self.q.get(timeout=self.timeout_s))
            while len(reqs) < self.batch:
                reqs.append(self.q.get_nowait())
        except queue.Empty:
            pass
        if not reqs:
            return 0
        max_len = max(r[0].shape[0] for r in reqs)
        max_new = max(r[1] for r in reqs)
        n = len(reqs)
        m = self.server.metrics
        m.counter("serve.batches_total").inc()
        m.gauge("serve.batch_fill").set(n / self.batch)
        pad_to = self.batch
        toks = np.zeros((pad_to, max_len), np.int32)
        for i, (p, _, _) in enumerate(reqs):
            toks[i, max_len - p.shape[0]:] = p   # left-pad
        out: np.ndarray | None = None
        err: Exception | None = None
        try:
            out = self.server.generate(toks, max_new)
        except Exception as e:                   # noqa: BLE001 — delivered
            err = e
        for i, (_, mn, done) in enumerate(reqs):
            done.put(err if err is not None else out[i, :mn])
        return n


# -------------------------------------------- continuous-batching engine
@dataclasses.dataclass
class Request:
    """One in-flight generation request (engine-internal state)."""
    rid: int
    prompt: np.ndarray
    max_new: int
    sampling: SamplingParams
    done: queue.Queue
    tokens: list[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    blocks: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0                 # absolute position of ``tok``
    tok: int = 0                 # last token (feeds the next decode step)
    rem: int = 0                 # tokens still to emit (0 → inactive)

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousScheduler:
    """In-flight batching over a paged KV pool (DESIGN.md §14).

    Replaces ``RequestQueue`` for families with a ``decode_paged`` hook:
    a fixed-width decode batch of ``slots`` whose rows are admitted and
    retired independently.  Admission prefills the prompt (right-padded
    to a block-aligned bucket — bit-exact by causality, logits read at
    the true last token via ``last_pos``), samples the first token, and
    scatters the prompt's KV rows into the owner rank's block pool.
    Decode then runs ``chunk`` tokens per launch with one host sync per
    chunk; slots whose budget or EOS hits mid-chunk go inactive on
    device (they rewrite their own scratch row) and retire on the host
    at the chunk boundary, freeing their blocks immediately.

    Bit-exactness with the static path (greedy): pick ``block_size``
    dividing the server's ``max_len`` — the gathered decode extent
    (``max_blocks × block_size``) then equals the static cache's
    ``max_len``, masked positions contribute exactly 0, and write-then-
    attend ordering matches ``decode_step``, so the same prompt yields
    the same tokens.

    Failure semantics match ``RequestQueue``: a raise during admission
    fails that request's done queue; a raise during a decode chunk
    fails every in-flight request (the pool state is indeterminate) and
    the engine resets.
    """

    def __init__(self, server: Server, *, slots: int = 8,
                 block_size: int = 32, chunk: int = 8,
                 num_blocks: int | None = None, k_cand: int = 16,
                 eos_id: int | None = None):
        self.server = server
        self.cfg = server.cfg
        self.mesh = server.mesh
        self.api = server.api
        assert self.api.decode_paged is not None, \
            f"{self.cfg.name}'s family has no paged decode hook"
        if server.max_len % block_size:
            raise ValueError(
                f"block_size {block_size} must divide max_len "
                f"{server.max_len} (bit-exact decode extent)")
        self.dp_size = server.dp_size
        if slots % self.dp_size:
            raise ValueError(f"slots {slots} not divisible by dp={self.dp_size}")
        self.W = slots
        self.W_local = slots // self.dp_size
        self.chunk = chunk
        self.k_cand = k_cand
        self.eos_id = -1 if eos_id is None else int(eos_id)
        self.layout = PagedLayout.for_requests(
            server.max_len, block_size, self.W_local, num_blocks=num_blocks)
        # one allocator per dp rank: slot w lives on rank w // W_local and
        # its blocks index into THAT rank's pool shard
        self.allocators = [BlockAllocator(self.layout)
                           for _ in range(self.dp_size)]
        self.slots = [_Slot() for _ in range(self.W)]
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._backlog: list[Request] = []    # popped but not yet admitted
        self._next_rid = 0
        self.metrics = server.metrics

        bspec = batch_spec(self.mesh)
        self._dp_entry = bspec[0] if len(bspec) else None
        self._row_spec = P(self._dp_entry)
        self._pool_spec = P(None, self._dp_entry, None, "model", None)
        self._vec_sh = NamedSharding(self.mesh, self._row_spec)
        self._tab_sh = NamedSharding(self.mesh, P(self._dp_entry, None))
        self._tables = np.full((self.W, self.layout.max_blocks),
                               SCRATCH_BLOCK, np.int32)
        self.pool_k, self.pool_v = self._init_pool()
        self._prefill_fns: dict[int, Any] = {}
        self._decode_fn = self._build_decode()

    # ----------------------------------------------------------- build
    def _linear_dp_rank(self):
        lin = jnp.int32(0)
        for ax in dp_axes_of(self.mesh):
            lin = lin * self.mesh.shape[ax] + jax.lax.axis_index(ax)
        return lin

    def _init_pool(self):
        cfg, lay = self.cfg, self.cfg.layout
        shape = (cfg.n_self, self.layout.num_blocks, self.layout.block_size,
                 lay.kv_local, cfg.hd)

        def init():
            z = jnp.zeros(shape, cfg.dtype)
            return z, z

        f = jax.jit(jax.shard_map(
            init, mesh=self.mesh, in_specs=(),
            out_specs=(self._pool_spec, self._pool_spec), check_vma=False))
        return f()

    def _build_prefill(self, Sb: int):
        """(prefill+sample, insert) pair for one prompt bucket length."""
        cfg, api, mesh = self.cfg, self.api, self.mesh
        # B=1 prefill runs replicated over dp (every rank computes it;
        # only the owner's pool shard absorbs the insert)
        cspec = P(None, None, None, "model", None)

        def pf(params, tokens, last_pos, temp, topk, topp, seed):
            logits, cache = api.prefill(params, tokens, cfg,
                                        last_pos=last_pos)
            key = jax.random.fold_in(jax.random.PRNGKey(seed), last_pos + 1)
            tok = sharded_sample(
                logits.astype(jnp.float32), cfg.tp, key[None],
                temp[None], topk[None], topp[None], k_cand=self.k_cand)
            return tok, cache

        pf_j = jax.jit(jax.shard_map(
            pf, mesh=mesh,
            in_specs=(self.server.pspecs, P(), P(), P(), P(), P(), P()),
            out_specs=(P(), {"k": cspec, "v": cspec}), check_vma=False))

        def ins(pool_k, pool_v, ck, cv, dest, owner):
            # dest: (Sb,) flat pool rows (owner-local ids); non-owners and
            # padded positions scatter out of range → dropped
            nb, bs = pool_k.shape[1], pool_k.shape[2]
            rows = nb * bs
            dest = jnp.where(self._linear_dp_rank() == owner, dest, rows)
            pk = pool_k.reshape(pool_k.shape[0], rows, *pool_k.shape[3:])
            pv = pool_v.reshape(pool_v.shape[0], rows, *pool_v.shape[3:])
            pk = pk.at[:, dest].set(ck[:, 0], mode="drop")
            pv = pv.at[:, dest].set(cv[:, 0], mode="drop")
            return pk.reshape(pool_k.shape), pv.reshape(pool_v.shape)

        ins_j = jax.jit(jax.shard_map(
            ins, mesh=mesh,
            in_specs=(self._pool_spec, self._pool_spec, cspec, cspec,
                      P(), P()),
            out_specs=(self._pool_spec, self._pool_spec), check_vma=False),
            donate_argnums=(0, 1))
        return pf_j, ins_j

    def _build_decode(self):
        cfg, api = self.cfg, self.api
        eos = self.eos_id
        chunk = self.chunk

        def dc(params, pool_k, pool_v, tables, toks, pos, rem,
               temps, topks, topps, seeds):
            def step(carry, _):
                pool_k, pool_v, toks, pos, rem = carry
                active = rem > 0
                logits, pool_k, pool_v = api.decode_paged(
                    params, pool_k, pool_v, tables, toks, pos, cfg)
                keys = jax.vmap(
                    lambda s, p: jax.random.fold_in(
                        jax.random.PRNGKey(s), p + 1))(seeds, pos)
                nxt = sharded_sample(
                    logits.astype(jnp.float32), cfg.tp, keys,
                    temps, topks, topps, k_cand=self.k_cand)
                out = jnp.where(active, nxt, -1)
                fin = active & (nxt == eos)
                toks = jnp.where(active, nxt, toks)
                pos = jnp.where(active, pos + 1, pos)
                rem = jnp.where(fin, 0, jnp.where(active, rem - 1, 0))
                return (pool_k, pool_v, toks, pos, rem), out

            carry, outs = jax.lax.scan(
                step, (pool_k, pool_v, toks, pos, rem), None, length=chunk)
            pool_k, pool_v = carry[0], carry[1]
            return pool_k, pool_v, outs          # outs: (chunk, W)

        rs = self._row_spec
        return jax.jit(jax.shard_map(
            dc, mesh=self.mesh,
            in_specs=(self.server.pspecs, self._pool_spec, self._pool_spec,
                      P(self._dp_entry, None), rs, rs, rs, rs, rs, rs, rs),
            out_specs=(self._pool_spec, self._pool_spec, P(None, self._dp_entry)),
            check_vma=False), donate_argnums=(1, 2))

    # ------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, max_new: int,
               sampling: SamplingParams | None = None) -> "queue.Queue":
        """Enqueue one request; returns its done queue.  The result is a
        (≤ max_new,) int32 token array, or an Exception instance."""
        done: queue.Queue = queue.Queue(maxsize=1)
        sp = sampling or SamplingParams()
        L = int(prompt.shape[0])
        cap = self.layout.seq_capacity
        if L + max_new > cap or max_new < 1 or L < 1:
            done.put(ValueError(
                f"request needs {L}+{max_new} positions > capacity {cap}"))
            return done
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      int(max_new), sp, done, t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.put(req)
        return done

    def _bucket(self, L: int) -> int:
        bs = self.layout.block_size
        return -(-L // bs) * bs

    def _retire(self, w: int) -> None:
        s = self.slots[w]
        r = s.req
        self.allocators[w // self.W_local].free(s.blocks)
        self._tables[w, :] = SCRATCH_BLOCK
        self.slots[w] = _Slot()
        r.done.put(np.asarray(r.tokens, np.int32))
        self.metrics.histogram("serve.req_latency_s").observe(
            time.perf_counter() - r.t_submit)
        self.metrics.counter("serve.tokens_generated").inc(len(r.tokens))

    def _fail(self, req: Request, err: Exception) -> None:
        req.done.put(err)

    def _admit(self) -> int:
        """Fill free slots from the queue (FIFO, no reordering)."""
        admitted = 0
        while True:
            if not self._backlog:
                try:
                    self._backlog.append(self.queue.get_nowait())
                except queue.Empty:
                    break
            req = self._backlog[0]
            L = len(req.prompt)
            need = L + req.max_new
            w = next(
                (i for i, s in enumerate(self.slots) if s.free
                 and self.allocators[i // self.W_local].can_fit(need)),
                None)
            if w is None:
                break                            # head-of-line blocks: FIFO
            self._backlog.pop(0)
            try:
                self._start(w, req)
                admitted += 1
            except Exception as e:               # noqa: BLE001 — delivered
                self._fail(req, e)
        return admitted

    def _start(self, w: int, req: Request) -> None:
        """Prefill ``req`` into slot ``w``: sample its first token and
        scatter the prompt KV rows into the owner's pool shard."""
        owner = w // self.W_local
        alloc = self.allocators[owner]
        L = len(req.prompt)
        blocks = alloc.alloc(L + req.max_new)
        assert blocks is not None                # _admit checked can_fit
        Sb = self._bucket(L)
        if Sb not in self._prefill_fns:
            self._prefill_fns[Sb] = self._build_prefill(Sb)
        pf, ins = self._prefill_fns[Sb]

        toks = np.zeros((1, Sb), np.int32)
        toks[0, :L] = req.prompt                 # right-pad (causal-exact)
        sp = req.sampling
        try:
            tok, cache = pf(
                self.server.params, jnp.asarray(toks),
                jnp.int32(L - 1), jnp.float32(sp.temperature),
                jnp.int32(sp.top_k), jnp.float32(sp.top_p),
                jnp.int32(sp.seed))
            bs = self.layout.block_size
            row = alloc.table_row(blocks)
            dest = np.full((Sb,), self.layout.num_blocks * bs, np.int32)
            p = np.arange(L)
            dest[:L] = np.asarray(row)[p // bs] * bs + p % bs
            self.pool_k, self.pool_v = ins(
                self.pool_k, self.pool_v, cache["k"], cache["v"],
                jnp.asarray(dest), jnp.int32(owner))
            first = int(np.asarray(tok)[0])
        except Exception:
            alloc.free(blocks)
            raise
        req.tokens.append(first)
        req.t_first = time.perf_counter()
        self.metrics.histogram("serve.ttft_s").observe(
            req.t_first - req.t_submit)
        self.metrics.counter("serve.requests_total").inc()
        s = self.slots[w]
        s.req, s.blocks, s.pos, s.tok = req, blocks, L, first
        s.rem = req.max_new - 1
        if first == self.eos_id:
            s.rem = 0
        self._tables[w, :] = alloc.table_row(blocks)
        if s.rem == 0:
            self._retire(w)

    def _row_arrays(self):
        """Device-side per-slot vectors rebuilt from the host mirror."""
        W = self.W
        toks = np.zeros(W, np.int32)
        pos = np.zeros(W, np.int32)
        rem = np.zeros(W, np.int32)
        temps = np.zeros(W, np.float32)
        topks = np.zeros(W, np.int32)
        topps = np.ones(W, np.float32)
        seeds = np.zeros(W, np.int32)
        for w, s in enumerate(self.slots):
            if s.free:
                continue
            toks[w], pos[w], rem[w] = s.tok, s.pos, s.rem
            sp = s.req.sampling
            temps[w], topks[w] = sp.temperature, sp.top_k
            topps[w], seeds[w] = sp.top_p, sp.seed
        put = lambda a: jax.device_put(a, self._vec_sh)
        return (put(toks), put(pos), put(rem), put(temps), put(topks),
                put(topps), put(seeds))

    def step(self) -> int:
        """Admit waiting requests, decode one chunk, retire finished
        slots.  Returns the number of tokens emitted."""
        self._admit()
        active = [w for w, s in enumerate(self.slots) if not s.free]
        self.metrics.gauge("serve.batch_fill").set(len(active) / self.W)
        self.metrics.gauge("serve.kv_util").set(
            max(a.utilization for a in self.allocators))
        if not active:
            return 0
        toks, pos, rem, temps, topks, topps, seeds = self._row_arrays()
        tables = jax.device_put(self._tables, self._tab_sh)
        try:
            self.pool_k, self.pool_v, outs = self._decode_fn(
                self.server.params, self.pool_k, self.pool_v, tables,
                toks, pos, rem, temps, topks, topps, seeds)
            outs = np.asarray(outs)              # ONE host sync per chunk
        except Exception as e:                   # noqa: BLE001 — delivered
            for w in active:
                self._fail(self.slots[w].req, e)
                self.allocators[w // self.W_local].free(self.slots[w].blocks)
                self._tables[w, :] = SCRATCH_BLOCK
                self.slots[w] = _Slot()
            self.pool_k, self.pool_v = self._init_pool()
            return 0
        emitted = 0
        for w in active:
            s = self.slots[w]
            # replay the device transition on the host mirror
            for t in range(self.chunk):
                tok = int(outs[t, w])
                if tok < 0:
                    break
                emitted += 1
                s.req.tokens.append(tok)
                s.tok, s.pos, s.rem = tok, s.pos + 1, s.rem - 1
                if tok == self.eos_id:
                    s.rem = 0
                if s.rem == 0:
                    break
            if s.rem == 0:
                self._retire(w)
        return emitted

    @property
    def idle(self) -> bool:
        return (self.queue.empty() and not self._backlog
                and all(s.free for s in self.slots))

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        total = 0
        for _ in range(max_steps):
            total += self.step()
            if self.idle:
                return total
        raise RuntimeError("run_until_idle: engine did not drain")

    def generate_batch(self, prompts: list[np.ndarray], max_new: int,
                       sampling: SamplingParams | None = None
                       ) -> list[np.ndarray]:
        """Convenience: submit all, drain, return per-request tokens
        (raises the first per-request error, if any)."""
        dones = [self.submit(p, max_new, sampling) for p in prompts]
        self.run_until_idle()
        out = []
        for d in dones:
            r = d.get_nowait()
            if isinstance(r, Exception):
                raise r
            out.append(r)
        return out
