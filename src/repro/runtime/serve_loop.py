"""Serving runtime: shard_map'd prefill + decode with a batched request
queue (static batching with padding; the cache lives sharded on-device).

Decode sharding: batch over DP axes, heads/vocab over "model".  Greedy
sampling uses a vocab-sharded argmax (no full-vocab gather)."""
from __future__ import annotations

import dataclasses
import queue
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.registry import family_of
from repro.parallel.sharding import batch_spec, dp_axes_of


def sharded_argmax(logits_local: jax.Array, tp: int) -> jax.Array:
    """Greedy token from (B, V/tp) vocab-sharded logits → (B,) global ids."""
    if tp == 1:
        return jnp.argmax(logits_local, axis=-1).astype(jnp.int32)
    v_local = logits_local.shape[-1]
    idx = jax.lax.axis_index("model")
    local_max = jnp.max(logits_local, axis=-1)
    local_arg = jnp.argmax(logits_local, axis=-1).astype(jnp.int32) \
        + idx * v_local
    # gather (tp, B) maxes+args, pick deterministically (lowest shard wins ties)
    maxes = jax.lax.all_gather(local_max, "model", axis=0)
    args = jax.lax.all_gather(local_arg, "model", axis=0)
    best = jnp.argmax(maxes, axis=0)
    return jnp.take_along_axis(args, best[None], axis=0)[0]


@dataclasses.dataclass
class ServeFns:
    prefill: Any
    decode: Any
    cache_specs: Any


class Server:
    """Batched greedy-decoding server for any family with serve hooks."""

    def __init__(self, cfg, mesh: Mesh, params, *, max_len: int = 256,
                 batch: int | None = None,
                 metrics: "MetricsRegistry | None" = None):
        from repro.obs import MetricsRegistry

        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.cfg = cfg
        self.mesh = mesh
        self.api = family_of(cfg)
        assert self.api.prefill is not None, f"{cfg.name} has no serve path"
        self.rules = self.api.param_rules(cfg)
        self.pspecs = self.rules.tree_specs(params)
        self.params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 self.pspecs))
        self.max_len = max_len
        self.tp = getattr(cfg, "tp", 1)
        self._fns: dict[int, ServeFns] = {}
        self.dp = dp_axes_of(mesh)
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp])) or 1

    def _build(self, B: int) -> ServeFns:
        cfg, mesh, api = self.cfg, self.mesh, self.api
        bspec = batch_spec(mesh)
        B_local = B // self.dp_size

        batch_entry = bspec[0] if len(bspec) else None
        cspecs = api.decode_state_specs(cfg, batch_entry)

        def prefill_fn(params, tokens):
            logits, cache = api.prefill(params, tokens, cfg)
            tok = sharded_argmax(logits.astype(jnp.float32), cfg.tp)
            return tok, cache

        def decode_fn(params, cache, tok, pos):
            logits, cache = api.decode_step(params, cache, tok, pos, cfg)
            nxt = sharded_argmax(logits.astype(jnp.float32), cfg.tp)
            return nxt, cache

        pf = jax.jit(jax.shard_map(
            prefill_fn, mesh=mesh,
            in_specs=(self.pspecs, bspec),
            out_specs=(bspec, cspecs), check_vma=False))
        dc = jax.jit(jax.shard_map(
            decode_fn, mesh=mesh,
            in_specs=(self.pspecs, cspecs, bspec, P()),
            out_specs=(bspec, cspecs), check_vma=False),
            donate_argnums=(1,))
        return ServeFns(pf, dc, cspecs)

    def _pad_cache(self, cache, prompt_len: int):
        """Grow prefill cache (seq dim = prompt_len) to max_len slots."""
        def pad(leaf):
            if leaf.ndim >= 3 and leaf.shape[2] == prompt_len:
                pad_n = self.max_len - prompt_len
                if pad_n > 0:
                    cfgpad = [(0, 0)] * leaf.ndim
                    cfgpad[2] = (0, pad_n)
                    return jnp.pad(leaf, cfgpad)
            return leaf
        return jax.tree.map(pad, cache)

    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """prompts: (B, S) int32 → (B, max_new) greedy continuations."""
        import time

        B, S = prompts.shape
        if B not in self._fns:
            self._fns[B] = self._build(B)
        fns = self._fns[B]
        t_start = time.perf_counter()
        toks = jax.device_put(
            jnp.asarray(prompts, jnp.int32),
            NamedSharding(self.mesh, batch_spec(self.mesh)))
        tok, cache = fns.prefill(self.params, toks)
        needs_pad = any(
            l.ndim >= 3 and l.shape[2] == S
            for l in jax.tree.leaves(cache)) and S != self.max_len
        if needs_pad:
            cache = self._pad_cache(cache, S)
            cache = jax.device_put(
                cache, jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), fns.cache_specs))
        t_prefill = time.perf_counter()
        out = [np.asarray(tok)]
        pos = S
        for _ in range(max_new - 1):
            tok, cache = fns.decode(self.params, cache, tok, jnp.int32(pos))
            out.append(np.asarray(tok))
            pos += 1
        t_end = time.perf_counter()
        self.metrics.counter("serve.requests_total").inc(B)
        self.metrics.counter("serve.tokens_generated").inc(B * max_new)
        self.metrics.histogram("serve.prefill_s").observe(
            t_prefill - t_start)
        if max_new > 1:
            self.metrics.histogram("serve.decode_per_token_s").observe(
                (t_end - t_prefill) / (max_new - 1))
        self.metrics.gauge("serve.tokens_per_s").set(
            B * max_new / max(t_end - t_start, 1e-9))
        return np.stack(out, axis=1)


class RequestQueue:
    """Minimal batching front-end: collects up to ``batch`` requests (or
    ``timeout_s``), pads to a common length, serves, returns per-request."""

    def __init__(self, server: Server, batch: int, timeout_s: float = 0.05):
        self.server = server
        self.batch = batch
        self.timeout_s = timeout_s
        self.q: queue.Queue = queue.Queue()

    def submit(self, prompt: np.ndarray, max_new: int) -> "queue.Queue":
        done: queue.Queue = queue.Queue(maxsize=1)
        self.q.put((prompt, max_new, done))
        return done

    def serve_once(self) -> int:
        """Drain up to ``batch`` requests, run one padded generate."""
        reqs = []
        try:
            reqs.append(self.q.get(timeout=self.timeout_s))
            while len(reqs) < self.batch:
                reqs.append(self.q.get_nowait())
        except queue.Empty:
            pass
        if not reqs:
            return 0
        max_len = max(r[0].shape[0] for r in reqs)
        max_new = max(r[1] for r in reqs)
        n = len(reqs)
        m = self.server.metrics
        m.counter("serve.batches_total").inc()
        m.gauge("serve.batch_fill").set(n / self.batch)
        pad_to = self.batch
        toks = np.zeros((pad_to, max_len), np.int32)
        for i, (p, _, _) in enumerate(reqs):
            toks[i, max_len - p.shape[0]:] = p   # left-pad
        out = self.server.generate(toks, max_new)
        for i, (_, mn, done) in enumerate(reqs):
            done.put(out[i, :mn])
        return n
