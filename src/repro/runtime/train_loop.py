"""Training runtime: step factory (shard_map + GradSync strategies) and a
fault-tolerant loop (checkpoint/restart, failure recovery, straggler
detection, elastic re-mesh).

Grad-reduction rule (DESIGN.md; see also the TP-transpose note): after
``jax.grad`` inside shard_map(check_vma=False), every gradient is
``tp ×`` its true per-shard value (psum-transpose inflation), and still
needs a psum over the mesh axes missing from its param spec.  So:

    grads ← grads / tp                 (uniform correction)
    grads ← strategy psums over missing axes (GradSync buckets; depcha
            leaves already reduced inside the backward scan are skipped)
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import GradSync, GradSyncConfig, get_strategy
from repro.models.registry import family_of
from repro.optim.optimizers import (
    Optimizer,
    apply_updates,
    clip_by_global_norm,
)
from repro.optim.zero import (
    scheduled_update,
    shard_size,
    zero1_pending_structs,
    zero1_state_structs,
)
from repro.parallel.sharding import batch_spec, dp_axes_of


class SimulatedFailure(RuntimeError):
    """Injected node failure (testing the recovery path)."""


class TransientStepError(RuntimeError):
    """Injected transient step fault — retryable IN PLACE (rung 1 of the
    elastic policy ladder): the step never committed state, so the same
    step simply runs again up to ``step_retries`` times before
    escalating to checkpoint recovery."""


class RankLost(SimulatedFailure):
    """Injected loss of mesh member(s): THIS mesh cannot continue.  The
    Trainer attaches the last committed state (``.step``/``.params``/
    ``.opt_state``) and re-raises — recovery means a NEW mesh, which is
    the supervisor's job (``repro.elastic.supervisor``), not the
    loop's."""

    def __init__(self, message: str = "rank lost"):
        super().__init__(message)
        self.step: int = 0
        self.params: Any = None
        self.opt_state: Any = None


class RemeshRequest(SimulatedFailure):
    """Straggler-driven shrink request (opt-in via ``remesh_hook``):
    like ``RankLost``, carries the post-step state for the supervisor's
    shrink path — but the state is healthy; the mesh is just slow."""

    def __init__(self, message: str = "remesh requested"):
        super().__init__(message)
        self.step: int = 0
        self.params: Any = None
        self.opt_state: Any = None


def _batch_specs(batch_like: Any, mesh: Mesh) -> Any:
    bspec = batch_spec(mesh)
    return {
        k: (P() if np.ndim(v) == 0 else bspec)
        for k, v in batch_like.items()
    }


def _micro_compute(cfg: Any, batch_like: Any, mesh: Mesh,
                   microbatch: int):
    """PER-MICROBATCH ComputeModel for meta-strategy (auto) ranking —
    derived from the batch shape the step will actually run.  Returns
    None for configs outside the arch registry's FLOP model (auto then
    ranks on comm alone, as before)."""
    try:
        from repro.sim.compute import compute_model_for

        dims = next(np.shape(v) for v in jax.tree.leaves(batch_like)
                    if np.ndim(v) > 0)
        cm = compute_model_for(
            cfg, global_batch=int(dims[0]),
            seq_len=int(dims[1]) if len(dims) > 1 else 1,
            n_devices=int(mesh.devices.size))
        if microbatch > 1:
            cm = dataclasses.replace(cm, t_fwd=cm.t_fwd / microbatch,
                                     t_bwd=cm.t_bwd / microbatch)
        return cm
    except Exception:
        return None


def _opt_state_specs(state_like: Any, params_like: Any, pspecs: Any,
                     mesh: Mesh) -> Any:
    """Specs for optimizer state: param-shaped sub-trees mirror param
    specs; flat ZeRO shards are sharded over the DP axes."""
    params_td = jax.tree_util.tree_structure(params_like)
    dp = dp_axes_of(mesh)
    dp_spec = P(dp if len(dp) > 1 else dp[0]) if dp else P()

    def sub(v):
        td = jax.tree_util.tree_structure(v)
        if td == params_td:
            return pspecs
        return jax.tree.map(lambda _: dp_spec, v)   # zero1 flat shards

    return {k: sub(v) for k, v in state_like.items()}


@dataclasses.dataclass
class TrainStep:
    fn: Callable[..., Any]            # jitted (params, opt_state, batch, i)
    param_specs: Any
    opt_specs: Any
    batch_specs: Any
    mesh: Mesh
    gradsync: GradSync | None
    opt_state_like: Any = None        # global ShapeDtypeStructs
    # deferred StepProgram only: jitted (params, opt_state) -> params
    # that all-gathers + applies the carried update shards, so the last
    # trained step's update lands before an eval/checkpoint/export reads
    # the params (during training the NEXT step's PRE program does this)
    finalize: Callable[..., Any] | None = None

    def shardings(self, tree_specs):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), tree_specs)

    def init_opt(self) -> Any:
        """Zero-initialized optimizer state with the step's shardings.

        Required for ZeRO-1 under TP (the flat shard size depends on the
        LOCAL param shapes, which ``optimizer.init(global_params)`` cannot
        see); valid for every shipped optimizer (states are zero-init)."""
        sh = self.shardings(self.opt_specs)
        return jax.tree.map(
            lambda l, s: jax.device_put(jnp.zeros(l.shape, l.dtype), s),
            self.opt_state_like, sh)


def make_train_step(
    cfg: Any,
    mesh: Mesh,
    sync: GradSyncConfig,
    optimizer: Optimizer,
    *,
    batch_like: Any,
    params_like: Any,
    clip_norm: float = 1.0,
    zero1_mode: bool = False,
    zero1_plan: str = "scheduled",  # "scheduled" | "deferred" | "monolithic"
    microbatch: int = 1,    # grad-accumulation factor (memory §Perf lever)
    accum_overlap: bool = True,  # peel the last microbatch out of the scan
    donate: bool = False,   # enable in production (launcher); off for tests
    pp_stages: int = 1,     # pipeline stages over the "stage" mesh axis
    pp_schedule: str = "auto",   # "auto" | "gpipe" | "1f1b"
) -> TrainStep:
    """Build the jitted, shard_map'd train step for one (arch, mesh, sync).

    ``batch_like``/``params_like`` may be ShapeDtypeStructs (dry-run) or
    concrete arrays (training) — only shapes/dtypes are read here.

    With a zero1-wrapped optimizer, ``zero1_plan="scheduled"`` (default)
    plans the optimizer step as first-class CommSchedule ops: per-bucket
    RS→UPDATE→AG triples planned by the configured strategy, spliced
    after the sync ops in ONE StepProgram schedule (DESIGN.md §9), with
    gradient clipping as a scheduled NORM op (psum'd squared norms, clip
    on shards before the update).  ``"deferred"`` pipelines that program
    across the step boundary (DESIGN.md §10): the all-gathers detach
    into the TOP of the next step — the update shards ride along in
    ``opt_state["pending"]``, each step first gathers + applies them
    (overlapping its own forward) and ends with fresh shards instead of
    a serialized AG tail; ``TrainStep.finalize`` flushes the last
    pending shards when training stops.  ``"monolithic"`` keeps the
    optimizer opaque: one flat RS→update→AG after the full sync (no
    clipping — grads are still DP-partial when a norm could be taken
    locally).

    With ``microbatch > 1`` and ``accum_overlap`` (default) the FINAL
    microbatch is peeled out of the accumulation scan: its backward is
    emitted inline, so each sync/RS bucket can start the moment that
    backward produces its gradients — comm overlaps the last
    microbatch's compute instead of waiting for the whole scan
    (bit-exact with the plain scan: same accumulation order).

    With a "stage" axis in the mesh (DESIGN.md §15) the step runs the
    staged wave pipeline instead of the accumulation scan: ``microbatch``
    doubles as the pipeline microbatch count M, the stacked block params
    are sharded dim-0 over "stage" (each device holds one stage's layer
    slice), and activations hop stage→stage+1 via ppermute inside the
    forward.  ``pp_schedule="gpipe"`` differentiates the full M-wave
    scan in one backward; ``"1f1b"`` splits M into chunks of S
    microbatches with an accumulated ``jax.grad`` per chunk — the 1F1B
    memory shape (≤ S microbatches of activations live at once).
    ``"auto"`` delegates to ``repro.sim.choose_pp_schedule`` (the argmin
    of the analytic pipeline wall over the fixed schedules).  A staged
    run is bit-exact with the stage=1 reference (same mesh family with a
    stage axis of extent 1): off-stage compute is where-masked to exact
    zeros, and cross-stage psums only ever add those zeros.
    """
    api = family_of(cfg)
    rules = api.param_rules(cfg)
    pspecs = rules.tree_specs(params_like)
    pp_axis = "stage"
    pp_active = pp_stages > 1 or pp_axis in mesh.axis_names
    pp_sched = None
    if pp_active:
        if pp_axis not in mesh.axis_names:
            raise ValueError(
                f"pp_stages={pp_stages} needs a {pp_axis!r} mesh axis "
                f"(make_smoke_mesh(..., stage=N)); mesh has "
                f"{mesh.axis_names}")
        if int(mesh.shape[pp_axis]) != pp_stages:
            raise ValueError(
                f"pp_stages={pp_stages} != mesh {pp_axis!r} extent "
                f"{mesh.shape[pp_axis]}")
        if api.pipeline_train_forward is None:
            raise ValueError(
                f"family {api.family!r} has no pipeline_train_forward")
        if getattr(cfg, "depcha_in_scan", False):
            raise ValueError(
                "depcha_in_scan is not supported with pipeline stages")
        n_layers = getattr(cfg, "n_layers", 0)
        if n_layers and n_layers % pp_stages:
            raise ValueError(
                f"n_layers={n_layers} not divisible by "
                f"pp_stages={pp_stages}")
        from repro.parallel.sharding import stage_shard_specs

        pspecs = stage_shard_specs(pspecs, axis=pp_axis)
        # stage-boundary activation payload for the cost model: one
        # microbatch of (local_B, S, d_model) in the compute dtype
        pp_mb = max(int(microbatch), 1)
        try:
            dims = next(np.shape(v) for v in jax.tree.leaves(batch_like)
                        if np.ndim(v) > 0)
            b_local = int(dims[0]) // max(
                int(np.prod([mesh.shape[a] for a in dp_axes_of(mesh)])), 1)
            act_bytes = (b_local // pp_mb
                         * (int(dims[1]) if len(dims) > 1 else 1)
                         * int(getattr(cfg, "d_model", 0))
                         * np.dtype(getattr(cfg, "dtype", np.float32)
                                    ).itemsize)
        except StopIteration:
            act_bytes = 0
        if pp_schedule == "auto":
            from repro.sim.autotune import choose_pp_schedule

            pp_sched = choose_pp_schedule(
                pp_stages, pp_mb, activation_bytes=act_bytes,
                compute=_micro_compute(cfg, batch_like, mesh, 1),
                mesh_shape=dict(zip(mesh.axis_names, mesh.devices.shape)))
        elif pp_schedule in ("gpipe", "1f1b"):
            pp_sched = pp_schedule
        else:
            raise ValueError(
                f"pp_schedule must be 'auto', 'gpipe' or '1f1b', "
                f"got {pp_schedule!r}")
        sync = dataclasses.replace(
            sync, pp_stages=pp_stages, pp_schedule=pp_sched,
            pp_microbatches=pp_mb, pp_activation_bytes=act_bytes)
    bspecs = _batch_specs(batch_like, mesh)
    tp = getattr(cfg, "tp", 1)
    dp = dp_axes_of(mesh)
    if zero1_plan not in ("scheduled", "deferred", "monolithic"):
        raise ValueError(f"unknown zero1_plan {zero1_plan!r}")
    zmeta = getattr(optimizer, "zero1_meta", None)
    zero1_scheduled = bool(zmeta) and zero1_mode \
        and zero1_plan in ("scheduled", "deferred")
    defer_ag = zero1_scheduled and zero1_plan == "deferred"
    if pp_active and zero1_scheduled and clip_norm:
        # the NORM op psums squared shard norms over the DP axes only —
        # under pipeline stages the blocks are stage-sharded and the
        # cross-stage terms would be silently missing from the norm
        raise ValueError(
            "scheduled ZeRO-1 clipping is not supported with pipeline "
            "stages; pass clip_norm=0")

    # skip leaves from the post-backward schedule ONLY when the model is
    # actually emitting their psums inside the backward scan — otherwise
    # a depcha config without depcha_in_scan would leave them unreduced
    in_scan = (api.in_scan_names(params_like)
               if get_strategy(sync.strategy).uses_in_scan
               and getattr(cfg, "depcha_in_scan", False) else frozenset())
    # bucket plan must see LOCAL shard shapes (it runs inside shard_map)
    from repro.parallel.sharding import localize_structs
    grads_local = localize_structs(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     params_like),
        pspecs, mesh)
    if zero1_scheduled:
        sync = dataclasses.replace(
            sync, exclude_axes=tuple(dp), zero1_dp_axes=tuple(dp),
            zero1_clip=bool(clip_norm), zero1_defer_ag=defer_ag,
            zero1_accum=microbatch, zero1_accum_overlap=accum_overlap)
    if get_strategy(sync.strategy).meta and sync.sim_compute is None:
        sync = dataclasses.replace(
            sync, sim_compute=_micro_compute(cfg, batch_like, mesh,
                                             microbatch))
    gs = GradSync(sync, mesh, pspecs, grads_local, in_scan_names=in_scan)

    if zmeta:
        inner_opt, dp_size, _ = zmeta
        if zero1_scheduled:
            local_like = zero1_state_structs(inner_opt, gs.dp_plan, dp_size)
            if defer_ag:
                # the deferred-AG carry: last step's update shards
                local_like["pending"] = zero1_pending_structs(
                    gs.dp_plan, dp_size)
        else:
            # monolithic ZeRO-1: ONE flat shard sized from LOCAL params
            n_local = sum(int(np.prod(l.shape)) for l in
                          jax.tree.leaves(grads_local))
            local_like = {"inner": jax.eval_shape(
                inner_opt.init,
                jax.ShapeDtypeStruct((shard_size(n_local, dp_size),),
                                     jnp.float32))}
        # global view: each local leaf is dp-sharded on dim 0
        opt_state_like = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((l.shape[0] * dp_size,
                                            *l.shape[1:]), l.dtype),
            local_like)
    else:
        opt_state_like = jax.eval_shape(optimizer.init, params_like)
    ospecs = _opt_state_specs(opt_state_like, params_like, pspecs, mesh)

    # deferred-AG: dp bucket_id ↔ pending-state key (both derived from
    # gs.dp_plan, so the pairing is static) + the phase-split schedule
    if defer_ag:
        pend_keys = tuple((b.bucket_id, str(i))
                          for i, b in enumerate(gs.dp_plan.buckets))
        post_sched = gs.program.post_schedule()

        def gather_pending(params, opt_state):
            """PRE program (DESIGN.md §10): all-gather the PREVIOUS
            step's update shards and apply them to the params.  The
            gathers free-fly, overlapping the input pipeline and each
            other; the zero-initialized carry gathers to an identity
            update, so a fresh run's step 0 starts unchanged.  Shared
            by the step prologue and ``finalize`` so the two stay
            bit-identical."""
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            prev = gs.apply_pending(
                zeros, {bid: opt_state["pending"][k]
                        for bid, k in pend_keys})
            return apply_updates(params, prev)

    def step(params, opt_state, batch, step_idx):
        if defer_ag:
            # apply LAST step's deferred update shards before anything
            # reads the params
            params = gather_pending(params, opt_state)
        if pp_active:
            # staged wave pipeline (§15): microbatch IS the pipeline
            # microbatch count M; the batch splits exactly like the
            # accumulation path (global_tokens sees its 1/M share, the
            # summed loss/grads divide by M below)
            def psplit(path, x):
                if np.ndim(x) == 0:
                    if any(getattr(k, "key", None) == "global_tokens"
                           for k in path):
                        x = x / pp_mb
                    return jnp.broadcast_to(x, (pp_mb,))
                b = x.shape[0]
                return x.reshape(pp_mb, b // pp_mb, *x.shape[1:])
            mbs = jax.tree_util.tree_map_with_path(psplit, batch)

            def pipe_loss(p, mb_tree):
                return api.pipeline_train_forward(
                    p, mb_tree, cfg, n_stages=pp_stages,
                    stage_axis=pp_axis)

            if pp_sched == "gpipe":
                # one M-wave scan, one backward — autodiff replays the
                # waves in reverse, the synchronous GPipe flush
                loss, grads = jax.value_and_grad(
                    lambda p: pipe_loss(p, mbs))(params)
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.float32), grads)
            else:
                # 1f1b: chunks of S microbatches, each differentiated on
                # its own — at most S microbatches of activations live
                # at once (the 1F1B in-flight bound)
                loss = jnp.float32(0.0)
                grads = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                for c0 in range(0, pp_mb, pp_stages):
                    chunk = jax.tree.map(
                        lambda v: v[c0:c0 + pp_stages], mbs)
                    l, g = jax.value_and_grad(
                        lambda p: pipe_loss(p, chunk))(params)
                    loss = loss + l
                    grads = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), grads, g)
            loss = loss / pp_mb
            grads = jax.tree.map(lambda g: g / pp_mb, grads)
        elif microbatch > 1:
            # grad accumulation: scan over microbatches — activations live
            # only for one microbatch (temp memory ÷ microbatch).  Each
            # microbatch sees its 1/M share of the batch-level
            # normalizer, and the accumulated loss/grads are divided by
            # M below — the mean over microbatches, NOT the sum, so the
            # effective LR and the reported loss are independent of M.
            def split(path, x):
                if np.ndim(x) == 0:
                    if any(getattr(k, "key", None) == "global_tokens"
                           for k in path):
                        x = x / microbatch
                    return jnp.broadcast_to(x, (microbatch,))
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])
            mbs = jax.tree_util.tree_map_with_path(split, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(
                    lambda p: api.train_forward(p, mb, cfg))(params)
                acc_l, acc_g = acc
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_l + l, acc_g), None

            zero = (jnp.float32(0.0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            # chunk_unroll = exact-HLO-accounting mode (dry-run deltas):
            # unroll so cost_analysis sees every microbatch
            mb_unroll = microbatch if getattr(
                cfg, "chunk_unroll", False) else 1
            if accum_overlap:
                # accumulation-overlapped sync: peel the FINAL
                # microbatch out of the scan so its backward is emitted
                # inline — each sync/RS bucket starts the moment this
                # backward produces its gradients, overlapping the
                # accumulation tail instead of waiting behind the scan.
                # Same accumulation order as the plain scan: bit-exact.
                head = jax.tree.map(lambda v: v[:-1], mbs)
                last = jax.tree.map(lambda v: v[-1], mbs)
                acc, _ = jax.lax.scan(body, zero, head, unroll=mb_unroll)
                (loss, grads), _ = body(acc, last)
            else:
                (loss, grads), _ = jax.lax.scan(body, zero, mbs,
                                                unroll=mb_unroll)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: api.train_forward(p, batch, cfg))(params)
        if tp > 1:   # psum-transpose inflation (module docstring)
            grads = jax.tree.map(lambda g: g / tp, grads)
        if zero1_scheduled:
            # StepProgram: ONE schedule carries the model-axis sync ops
            # AND the per-bucket zero1 RS→UPDATE→AG triples; clipping is
            # the scheduled NORM op (psum'd squared shard norms, applied
            # to the grad shards before each update)
            update_fn, new_state = scheduled_update(
                inner_opt, gs.dp_plan, params, opt_state, step_idx,
                dp_size=dp_size)
            aux: dict = {}
            updates = gs(grads, update_fn=update_fn,
                         clip_norm=float(clip_norm or 0.0), aux=aux,
                         schedule=post_sched if defer_ag else None)
            if defer_ag:
                # the AGs were deferred: carry this step's update shards
                # to the next step's PRE program instead of applying
                new_state["pending"] = {
                    k: aux["update_shards"][bid] for bid, k in pend_keys}
                updates = None
            opt_state = new_state
            gnorm = aux.get("grad_norm", jnp.float32(0.0))
        else:
            # zero1_mode (monolithic): sync.exclude_axes=dp — buckets
            # carry only the model-axis reductions; the DP sum happens
            # in zero1's reduce-scatter inside optimizer.update.
            grads = gs(grads)
            if clip_norm and not zero1_mode:
                if pp_active:
                    # stage-sharded blocks: their squared norms psum
                    # over "stage"; stage-replicated leaves count once
                    from repro.parallel.sharding import flat_spec_axes

                    stg = [pp_axis in flat_spec_axes(s)
                           for s in jax.tree.leaves(pspecs)]

                    def _sq(g, staged):
                        g32 = jnp.square(g.astype(jnp.float32))
                        if staged:
                            # reduce each stacked layer row, psum the
                            # per-leaf partial over "stage" BEFORE the
                            # cross-leaf sum: the scalar then matches
                            # the stage=1 layout bit-for-bit (psum adds
                            # the same per-layer partials in the same
                            # layer order, leaf by leaf)
                            return jax.lax.psum(jnp.sum(jnp.sum(
                                g32.reshape(g32.shape[0], -1), axis=1)),
                                pp_axis)
                        return jnp.sum(g32)

                    sq = [_sq(g, t) for g, t in
                          zip(jax.tree.leaves(grads), stg)]
                    sh = sum(s for s, t in zip(sq, stg) if t)
                    rep = sum(s for s, t in zip(sq, stg) if not t)
                    gnorm = jnp.sqrt(jnp.float32(sh) + jnp.float32(rep))
                    scale = jnp.minimum(
                        1.0, clip_norm / (gnorm + 1e-9))
                    grads = jax.tree.map(
                        lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads)
                else:
                    # (monolithic zero1: grads are still DP-partial
                    # here — use zero1_plan="scheduled" for clipped
                    # ZeRO training)
                    grads, gnorm = clip_by_global_norm(grads, clip_norm)
            else:
                gnorm = jnp.float32(0.0)
            updates, opt_state = optimizer.update(
                grads, opt_state, params, step_idx)
        if updates is not None:
            params = apply_updates(params, updates)
        if pp_active:
            # the staged loss is nonzero only on the last stage — the
            # psum adds the other stages' exact zeros (bit-exact)
            loss = jax.lax.psum(loss, pp_axis)
        loss = jax.lax.psum(loss, dp) if dp else loss
        metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics

    mspecs = {"loss": P(), "grad_norm": P()}
    wrapped = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ospecs, bspecs, P()),
        out_specs=(pspecs, ospecs, mspecs),
        check_vma=False)
    jitted = jax.jit(wrapped, donate_argnums=(0, 1) if donate else ())

    finalize = None
    if defer_ag:
        # flush the carried update shards (same PRE program the next
        # step would run) — for eval/checkpoint-export/parity checks
        finalize = jax.jit(jax.shard_map(
            gather_pending, mesh=mesh, in_specs=(pspecs, ospecs),
            out_specs=pspecs, check_vma=False))

    return TrainStep(jitted, pspecs, ospecs, bspecs, mesh, gs,
                     opt_state_like, finalize=finalize)


class Trainer:
    """Fault-tolerant training driver.

    - checkpoint/restart via CheckpointManager (atomic, async)
    - deterministic data (batch = f(seed, step)) → exact resume
    - failure injection (``fail_at``): simulates node loss at given steps;
      recovery = restore latest checkpoint and replay
    - straggler mitigation: steps slower than ``straggler_factor`` × the
      running median are logged and counted; after ``straggler_patience``
      consecutive hits the (simulated) response is a re-shard event —
      on a real fleet this triggers hot-spare swap-in
    """

    def __init__(self, step_fn: TrainStep, pipeline, ckpt,
                 *, fail_at: frozenset[int] = frozenset(),
                 straggler_factor: float = 3.0,
                 straggler_patience: int = 3,
                 step_retries: int = 0,
                 fault_injector: Callable[[int], None] | None = None,
                 remesh_hook: Callable[[int], str | None] | None = None,
                 log_every: int = 10,
                 printer: Callable[[str], None] = print,
                 metrics: "MetricsRegistry | None" = None,
                 events_path: str | None = None,
                 loss_window: int = 10_000):
        from repro.obs import EventLog, MetricsRegistry

        self.step_fn = step_fn
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.fail_at = set(fail_at)
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        # elastic policy ladder (DESIGN.md §13): transient faults retry
        # the same step in place before escalating to checkpoint
        # recovery; ``fault_injector(step)`` runs at the top of every
        # step attempt (raise TransientStepError / RankLost / sleep to
        # fake a straggler); ``remesh_hook(step)`` decides the response
        # to persistent stragglers ("shrink" → raise RemeshRequest for
        # the supervisor; anything else → log only)
        self.step_retries = step_retries
        self.fault_injector = fault_injector
        self.remesh_hook = remesh_hook
        self.log_every = log_every
        self.printer = printer
        self.step_times: list[float] = []
        self.events: list[dict] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.event_log = EventLog(events_path)
        self.loss_window = loss_window
        # first executed step spans the jit warmup compile — reported
        # separately, excluded from step_times / throughput stats
        self.compile_time: float | None = None

    def _event(self, kind: str, **fields) -> None:
        """Record a lifecycle event in-memory AND on the JSONL stream."""
        self.events.append({"kind": kind, **fields})
        self.event_log.emit(kind, **fields)

    def _place_restored(self, tree: Any, specs: Any) -> Any:
        """Commit restored leaves to the step's shardings.  Host (numpy)
        leaves are device_put; leaves that are already device arrays
        (an ElasticCheckpointer decode) pass through unchanged."""
        sh = self.step_fn.shardings(specs)
        return jax.tree.map(
            lambda v, s: jax.device_put(v, s)
            if isinstance(v, np.ndarray) else v, tree, sh)

    def _guard_pending(self, step: int) -> None:
        """Deferred-plan restore guard: if this step carries an
        ``opt_state["pending"]`` tree, the checkpoint being restored must
        actually contain one — otherwise the resume would silently read
        a zero carry where the saved trajectory had live update shards,
        and the replayed run diverges from the original."""
        like = getattr(self.step_fn, "opt_state_like", None)
        if not isinstance(like, dict) or "pending" not in like:
            return
        manifest = getattr(self.ckpt, "manifest", None)
        if manifest is None:
            return
        try:
            names = manifest(step)
        except (OSError, KeyError, ValueError):
            return      # no manifest to check against — restore decides
        if not any("pending" in n for n in names):
            raise RuntimeError(
                f"checkpoint at step {step} has no opt_state['pending'] "
                f"carry but this zero1_plan='deferred' step requires one "
                f"— resuming would silently drop the deferred updates "
                f"(flush via TrainStep.finalize before saving, or restore "
                f"into a scheduled-plan step)")

    def _recover(self, params, opt_state):
        """Restore-and-replay (rung 2 of the policy ladder).  Returns
        ``(step, params, opt_state)`` or None when no checkpoint
        exists."""
        if self.ckpt is None or self.ckpt.latest() is None:
            return None
        self._guard_pending(self.ckpt.latest())
        s, state = self.ckpt.restore({"params": params, "opt": opt_state})
        params = self._place_restored(state["params"],
                                      self.step_fn.param_specs)
        opt_state = self._place_restored(state["opt"],
                                         self.step_fn.opt_specs)
        self._event("recover", step=s)
        return s, params, opt_state

    def _account_static(self, params, opt_state) -> None:
        """One-time gauges/counters that don't change per step: comm
        bytes per step by op kind/reducer/phase (from the planned
        schedule), a peak-memory proxy (resident params + opt state),
        and the simulator's exposed-comm estimate for this plan."""
        from repro.obs import comm_byte_counters

        state_bytes = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves((params, opt_state))
            if hasattr(x, "shape"))
        self.metrics.gauge("mem.state_bytes").set(state_bytes)
        gs = getattr(self.step_fn, "gradsync", None)
        if gs is None:
            return
        comm_byte_counters(
            gs.schedule, self.metrics,
            itemsize=np.dtype(gs.cfg.comm_dtype).itemsize)
        try:
            from repro.sim.engine import SimConfig, simulate

            tl = simulate(
                gs.schedule, gs.mesh_shape,
                compute=gs.cfg.sim_compute,
                sim=SimConfig(
                    itemsize=np.dtype(gs.cfg.comm_dtype).itemsize,
                    reducer=gs.cfg.reducer,
                    fused_staging=gs.cfg.use_fused_staging))
            self.metrics.gauge("sim.step_time_s").set(tl.step_time)
            self.metrics.gauge("sim.exposed_comm_s").set(tl.exposed_comm)
        except Exception:
            pass    # an estimate must never take down training

    def run(self, params, opt_state, num_steps: int,
            start_step: int = 0) -> tuple[Any, Any, dict]:
        from collections import deque

        from repro.obs import heartbeat_line

        step = start_step
        if self.ckpt is not None and self.ckpt.latest() is not None:
            self._guard_pending(self.ckpt.latest())
            step, state = self.ckpt.restore(
                {"params": params, "opt": opt_state})
            params = self._place_restored(state["params"],
                                          self.step_fn.param_specs)
            opt_state = self._place_restored(state["opt"],
                                             self.step_fn.opt_specs)
            self._event("restore", step=step)
            self.printer(f"[trainer] restored checkpoint at step {step}")

        self._account_static(params, opt_state)
        losses = deque(maxlen=self.loss_window)
        consec_slow = 0
        retries_used = 0
        first_timed = self.compile_time is None
        while step < num_steps:
            batch = self.pipeline.batch_at(step)
            tokens = sum(
                int(np.prod(v.shape)) for k, v in batch.items()
                if k == "tokens") if isinstance(batch, dict) else 0
            t0 = time.perf_counter()
            try:
                # injected faults fire at the top of the attempt — AFTER
                # t0, so a straggler sleep injected here counts in dt
                if self.fault_injector is not None:
                    self.fault_injector(step)
                if step in self.fail_at:
                    self.fail_at.discard(step)
                    raise SimulatedFailure(f"injected node loss @ {step}")
                params, opt_state, metrics = self.step_fn.fn(
                    params, opt_state, batch, jnp.int32(step))
                jax.block_until_ready(metrics["loss"])
                retries_used = 0
            except TransientStepError as e:
                # rung 1: the step never committed state — retry in place
                retries_used += 1
                if retries_used <= self.step_retries:
                    self._event("retry", step=step, attempt=retries_used)
                    self.printer(f"[trainer] transient fault @ {step} "
                                 f"({e}); retry {retries_used}/"
                                 f"{self.step_retries}")
                    continue
                retries_used = 0
                self._event("retry_exhausted", step=step)
                self.printer(f"[trainer] {e}; retries exhausted — "
                             f"recovering from checkpoint")
                recovered = self._recover(params, opt_state)
                if recovered is None:
                    self.printer("[trainer] no checkpoint; restart from 0")
                    step = start_step
                    continue
                step, params, opt_state = recovered
                continue
            except RankLost as e:
                # rung 3 lives OUTSIDE the loop: a lost rank means this
                # mesh is gone — hand the last committed state to the
                # supervisor (repro.elastic) and unwind
                e.step = step
                e.params, e.opt_state = params, opt_state
                self._event("rank_lost", step=step)
                self.printer(f"[trainer] {e}; surrendering to supervisor")
                raise
            except SimulatedFailure as e:
                self._event("failure", step=step)
                self.printer(f"[trainer] {e}; recovering from checkpoint")
                recovered = self._recover(params, opt_state)
                if recovered is None:
                    self.printer("[trainer] no checkpoint; restart from 0")
                    step = start_step
                    continue
                step, params, opt_state = recovered
                continue

            dt = time.perf_counter() - t0
            if first_timed:
                # the first executed step spans the jit warmup compile:
                # report it separately, keep it out of every throughput
                # stat (step_times, histograms, tokens/s, stragglers)
                first_timed = False
                self.compile_time = dt
                self.metrics.gauge("compile_time_s").set(dt)
                self._event("compile", step=step, dt=dt)
            else:
                if len(self.step_times) >= 5:
                    med = statistics.median(self.step_times[-50:])
                    if dt > self.straggler_factor * med:
                        consec_slow += 1
                        self._event("straggler", step=step, dt=dt,
                                    median=med)
                        if consec_slow >= self.straggler_patience:
                            decision = (self.remesh_hook(step)
                                        if self.remesh_hook else None)
                            self._event("remesh_requested", step=step,
                                        decision=decision or "log-only")
                            self.printer(
                                f"[trainer] {consec_slow} consecutive "
                                f"straggler steps — requesting re-shard / "
                                f"hot-spare swap "
                                f"({decision or 'log-only'})")
                            consec_slow = 0
                            if decision == "shrink":
                                # hand the committed post-step state to
                                # the supervisor; resume at step + 1
                                e = RemeshRequest(
                                    f"straggler shrink @ {step}")
                                e.step = step + 1
                                e.params, e.opt_state = params, opt_state
                                raise e
                    else:
                        consec_slow = 0
                self.step_times.append(dt)
                self.metrics.histogram("step_time_s").observe(dt)
                if tokens:
                    self.metrics.counter("tokens_total").inc(tokens)
                    self.metrics.gauge("tokens_per_s").set(tokens / dt)

            loss = float(metrics["loss"])
            gnorm = float(metrics.get("grad_norm", 0.0))
            losses.append(loss)
            self.metrics.counter("steps_total").inc()
            self.metrics.gauge("loss").set(loss)
            self.metrics.gauge("grad_norm").set(gnorm)
            self.event_log.emit(
                "step", step=step, loss=loss, dt=dt, grad_norm=gnorm,
                tokens=tokens, compile_step=self.compile_time == dt)
            if step % self.log_every == 0:
                self.printer(
                    f"[trainer] step {step} loss {losses[-1]:.4f} "
                    f"({dt*1e3:.1f} ms)")
                avg = (sum(self.step_times[-50:])
                       / max(len(self.step_times[-50:]), 1) * 1e3
                       if self.step_times else None)
                self.printer(heartbeat_line(
                    step, loss=loss, step_ms=dt * 1e3, avg_ms=avg,
                    tokens_per_s=(tokens / dt if tokens else None),
                    grad_norm=gnorm, compile_s=self.compile_time))
            step += 1
            if self.ckpt is not None:
                self.ckpt.maybe_save(
                    step, {"params": params, "opt": opt_state})

        if self.ckpt is not None:
            self.ckpt.wait()
        return params, opt_state, {
            "losses": list(losses),
            "events": self.events,
            "compile_time": self.compile_time,
            "metrics": self.metrics.snapshot(),
        }
