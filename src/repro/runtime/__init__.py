from repro.runtime.train_loop import (
    SimulatedFailure,
    Trainer,
    make_train_step,
)
from repro.runtime.serve_loop import Server

__all__ = ["SimulatedFailure", "Server", "Trainer", "make_train_step"]
