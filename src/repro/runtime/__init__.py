from repro.runtime.train_loop import (
    SimulatedFailure,
    Trainer,
    make_train_step,
)
from repro.runtime.serve_loop import (
    ContinuousScheduler,
    RequestQueue,
    SamplingParams,
    Server,
    sharded_argmax,
    sharded_sample,
)
from repro.runtime.kvcache import BlockAllocator, PagedLayout

__all__ = [
    "BlockAllocator",
    "ContinuousScheduler",
    "PagedLayout",
    "RequestQueue",
    "SamplingParams",
    "Server",
    "SimulatedFailure",
    "Trainer",
    "make_train_step",
    "sharded_argmax",
    "sharded_sample",
]
