"""Pytree utilities: stable key-naming of leaves.

The paper's KVStore names every gradient tensor with an integer key
("MXNET linearly orders all the relevant tensors and assigns unique keys,
starting from zero", §3.3).  We reproduce that: leaves of a gradient pytree
are linearly ordered by their tree path, and that order is identical across
workers because the pytree structure is identical (same SPMD program).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:  # FlattenedIndexKey and anything else
            parts.append(str(getattr(p, "key", p)))
    return "/".join(parts)


def flatten_with_names(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    """Flatten ``tree`` to ``[(name, leaf), ...]`` + treedef, in stable order."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(_path_str(path), leaf) for path, leaf in leaves]
    return named, treedef


def named_leaves(tree: Any) -> list[tuple[str, Any]]:
    return flatten_with_names(tree)[0]


def unflatten_from_names(treedef: Any, leaves: list[Any]) -> Any:
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_map_with_names(
    fn: Callable[[str, Any], Any], tree: Any
) -> Any:
    named, treedef = flatten_with_names(tree)
    return unflatten_from_names(treedef, [fn(n, l) for n, l in named])
