from repro.utils.trees import (
    flatten_with_names,
    named_leaves,
    tree_size_bytes,
    unflatten_from_names,
)

__all__ = [
    "flatten_with_names",
    "named_leaves",
    "tree_size_bytes",
    "unflatten_from_names",
]
