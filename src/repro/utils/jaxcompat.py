"""Compatibility shim: run the new-style jax API this repo targets on the
older jax pinned in the container (0.4.x).

The codebase is written against three post-0.4.37 surface changes:

  - ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)``
    (explicit/auto axis types; we only ever pass ``Auto``, which is the
    implicit behavior of older meshes),
  - ``jax.shard_map`` as a top-level export (was
    ``jax.experimental.shard_map.shard_map``),
  - the ``check_vma=`` keyword (renamed from ``check_rep=``).

``apply()`` installs thin adapters for whichever of these are missing and
is a no-op on jax versions that already provide them.  It is called once
from ``repro/__init__.py`` so every entry point (tests, launchers,
benchmarks) sees a uniform API.
"""
from __future__ import annotations

import enum
import functools
import inspect

_APPLIED = False


def apply() -> None:
    global _APPLIED
    if _APPLIED:
        return
    _APPLIED = True

    import jax
    import jax.sharding as jsharding

    if not hasattr(jsharding, "AxisType"):

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jsharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            # old make_mesh has no axis_types; Auto is its only behavior
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      check_rep=None, **kw):
            if check_rep is None:
                check_rep = True if check_vma is None else check_vma
            return _shard_map(f, mesh, in_specs, out_specs,
                              check_rep=check_rep, **kw)

        jax.shard_map = shard_map
