"""Deterministic synthetic data pipeline with host sharding + prefetch.

Determinism is load-bearing for fault tolerance: batches are a pure
function of (seed, step), so a restarted worker resumes mid-epoch by
skipping to the right step — no data-state checkpointing needed (the
restore path in ``runtime.train_loop`` relies on this).

Real deployments swap ``_synth_*`` for a file-backed source keeping the
same (seed, step) → batch contract (e.g. deterministic shard shuffling).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import batch_spec


class TokenPipeline:
    """Synthetic LM token stream: (tokens, labels) of (B, S) int32."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, mesh: Mesh | None = None,
                 extra_specs: dict[str, tuple[tuple[int, ...], Any]] | None = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.mesh = mesh
        self.extra = extra_specs or {}

    def batch_at(self, step: int) -> dict[str, Any]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(
            0, self.vocab, (self.global_batch, self.seq_len + 1),
            dtype=np.int32)
        batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "global_tokens": np.float32(self.global_batch * self.seq_len),
        }
        for name, (shape, dtype) in self.extra.items():
            batch[name] = rng.standard_normal(
                (self.global_batch, *shape)).astype(dtype)
        return self._place(batch)

    def _place(self, batch):
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        bspec = batch_spec(self.mesh)
        out = {}
        for k, v in batch.items():
            spec = P() if np.ndim(v) == 0 else bspec
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def __iter__(self) -> Iterator[dict[str, Any]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ImagePipeline:
    """Synthetic image classification stream (paper's CIFAR/ImageNet)."""

    def __init__(self, img_size: int, num_classes: int, global_batch: int,
                 *, seed: int = 0, mesh: Mesh | None = None):
        self.img_size = img_size
        self.num_classes = num_classes
        self.global_batch = global_batch
        self.seed = seed
        self.mesh = mesh

    def batch_at(self, step: int) -> dict[str, Any]:
        rng = np.random.default_rng((self.seed, step))
        batch = {
            "images": rng.standard_normal(
                (self.global_batch, self.img_size, self.img_size, 3)
            ).astype(np.float32),
            "labels": rng.integers(
                0, self.num_classes, (self.global_batch,), dtype=np.int32),
            "global_tokens": np.float32(self.global_batch),
        }
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        bspec = batch_spec(self.mesh)
        return {
            k: jax.device_put(
                v, NamedSharding(self.mesh, P() if np.ndim(v) == 0 else bspec))
            for k, v in batch.items()
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (the MXNET IO thread-pool analogue)."""

    _DONE = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.err: list[BaseException] = []

        def worker():
            try:
                for item in it:
                    self.q.put(item)
            except BaseException as e:   # surfaced on next()
                self.err.append(e)
            finally:
                self.q.put(self._DONE)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._DONE:
            if self.err:
                raise self.err[0]
            raise StopIteration
        return item
