from repro.data.pipeline import (
    ImagePipeline,
    TokenPipeline,
    Prefetcher,
)

__all__ = ["ImagePipeline", "TokenPipeline", "Prefetcher"]
