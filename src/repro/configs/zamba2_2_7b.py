"""zamba2-2.7b [hybrid] — 54L d=2560 Mamba-2 backbone (d_inner=5120,
head_p=64 → 80 ssm heads, ssm_state=64) + shared attention block
(32H kv=32, hd=80, ff=10240) applied every 6 mamba layers with reused
weights [arXiv:2411.15242; hf].  Hybrid state ⇒ long_500k runs (ssm state
O(1); shared-attn sites use a 4096-slot ring KV cache).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.ssm import SSMConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="zamba2-2.7b",
        n_layers=54, d_model=2560, d_ff=10240, vocab=32000,
        ssm_state=64, head_p=64, expand=2, d_conv=4,
        attn_every=6, n_heads=32, kv_heads=32,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return SSMConfig(**kw)


def make_smoke():
    return SSMConfig(
        name="zamba2-smoke",
        n_layers=4, d_model=64, d_ff=128, vocab=97,
        ssm_state=16, head_p=16, attn_every=2, n_heads=4, kv_heads=4,
        chunk=16, tp=1, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="zamba2-2.7b",
    family="ssm",
    source="arXiv:2411.15242",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=True,
                     long_note="mamba2 O(1) state; shared-attn ring cache"),
    layer_pair=(6, 12, 6),   # one group = 6 mamba + 1 shared-attn site
)
