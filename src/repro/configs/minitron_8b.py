"""minitron-8b [dense] — 32L d=4096 32H (GQA kv=8) ff=16384 vocab=256000,
pruned nemotron: squared-ReLU non-gated FFN [arXiv:2407.14679; hf].
256k vocab exercises the vocab-sharded embedding/xent path hardest.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_NOTE, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="minitron-8b",
        n_layers=32, d_model=4096, n_heads=32, kv_heads=8,
        d_ff=16384, vocab=256000, head_dim=128,
        act="relu2", gated=False, rope_theta=10_000.0,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    return TransformerConfig(
        name="minitron-smoke",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=257, head_dim=16, act="relu2", gated=False,
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="minitron-8b",
    family="transformer",
    source="arXiv:2407.14679",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=False, long_note=FULL_ATTN_NOTE),
)
