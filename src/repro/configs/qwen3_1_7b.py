"""qwen3-1.7b [dense] — 28L d=2048 16H (GQA kv=8) ff=6144 vocab=151936,
qk-norm (per-head RMSNorm on q and k), head_dim=128
[hf:Qwen/Qwen3-8B; hf].
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_NOTE, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="qwen3-1.7b",
        n_layers=28, d_model=2048, n_heads=16, kv_heads=8,
        d_ff=6144, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    return TransformerConfig(
        name="qwen3-smoke",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=97, head_dim=16, qk_norm=True,
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="qwen3-1.7b",
    family="transformer",
    source="hf:Qwen/Qwen3-8B",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=False, long_note=FULL_ATTN_NOTE),
)
