"""granite-moe-1b-a400m [moe] — 24L d=1024 16H (GQA kv=8) per-expert
ff=512, vocab=49155 (padded to 49168 for tp=16), MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_NOTE, lm_shapes
from repro.models.moe import MoECfg
from repro.models.transformer import TransformerConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, kv_heads=8,
        d_ff=512, vocab=49155, head_dim=64,
        rope_theta=10_000.0,
        moe=MoECfg(num_experts=32, top_k=8, d_expert=512),
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    return TransformerConfig(
        name="granite-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=64,
        vocab=97, head_dim=16,
        moe=MoECfg(num_experts=8, top_k=2, d_expert=32,
                   capacity_factor=2.0),
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="granite-moe-1b-a400m",
    family="transformer",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=False, long_note=FULL_ATTN_NOTE),
)
