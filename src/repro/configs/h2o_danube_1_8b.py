"""h2o-danube-1.8b [dense] — 24L d=2560 32H (GQA kv=8) ff=6912 vocab=32000,
llama+mistral mix with sliding-window attention (window 4096)
[arXiv:2401.16818; hf].  SWA ⇒ long_500k runs with a window-bounded ring
KV cache.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

WINDOW = 4096


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="h2o-danube-1.8b",
        n_layers=24, d_model=2560, n_heads=32, kv_heads=8,
        d_ff=6912, vocab=32000, head_dim=80,
        swa_window=WINDOW, rope_theta=10_000.0,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    return TransformerConfig(
        name="h2o-danube-smoke",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=97, head_dim=16, swa_window=16,
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="h2o-danube-1.8b",
    family="transformer",
    source="arXiv:2401.16818",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=True,
                     long_note="SWA ring cache bounded at window=4096"),
)
