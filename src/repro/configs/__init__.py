"""Architecture registry: the 10 assigned archs + the paper's own models.

``--arch <id>`` everywhere resolves through ``get_arch``.
"""
from repro.configs.base import (
    ArchSpec,
    ShapeSpec,
    decode_state_structs,
    image_input_specs,
    lm_shapes,
    param_structs,
    train_input_specs,
)
from repro.configs.granite_moe_1b_a400m import ARCH as _granite
from repro.configs.h2o_danube_1_8b import ARCH as _danube
from repro.configs.inception_bn_imagenet import ARCH as _inception
from repro.configs.kimi_k2_1t_a32b import ARCH as _kimi
from repro.configs.llama_3_2_vision_11b import ARCH as _llama_vision
from repro.configs.minitron_8b import ARCH as _minitron
from repro.configs.musicgen_large import ARCH as _musicgen
from repro.configs.qwen3_1_7b import ARCH as _qwen3
from repro.configs.resnet50_cifar import ARCH as _resnet
from repro.configs.rwkv6_7b import ARCH as _rwkv6
from repro.configs.starcoder2_3b import ARCH as _starcoder2
from repro.configs.zamba2_2_7b import ARCH as _zamba2

ASSIGNED = (
    _llama_vision,
    _musicgen,
    _danube,
    _qwen3,
    _starcoder2,
    _minitron,
    _rwkv6,
    _granite,
    _kimi,
    _zamba2,
)
PAPER_OWN = (_resnet, _inception)

ARCHS = {a.arch_id: a for a in ASSIGNED + PAPER_OWN}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


__all__ = [
    "ARCHS",
    "ASSIGNED",
    "ArchSpec",
    "PAPER_OWN",
    "ShapeSpec",
    "decode_state_structs",
    "get_arch",
    "image_input_specs",
    "lm_shapes",
    "param_structs",
    "train_input_specs",
]
