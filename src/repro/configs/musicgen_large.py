"""musicgen-large [audio] — 48L d=2048 32H (kv=32, i.e. MHA) ff=8192
vocab=2048, decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Modality frontend is a stub: ``frame_embeds`` (B, S, d) precomputed
conditioning embeddings added to token embeddings (prefill/train only;
decode conditions on tokens alone — noted simplification).
Non-gated GELU FFN per the original transformer decoder.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_NOTE, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="musicgen-large",
        n_layers=48, d_model=2048, n_heads=32, kv_heads=32,
        d_ff=8192, vocab=2048, head_dim=64,
        act="gelu", gated=False, frame_embeds=True,
        rope_theta=10_000.0,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    return TransformerConfig(
        name="musicgen-smoke",
        n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=97, head_dim=16, act="gelu", gated=False, frame_embeds=True,
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="musicgen-large",
    family="transformer",
    source="arXiv:2306.05284",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=False, long_note=FULL_ATTN_NOTE),
    extra_inputs=(
        ("frame_embeds", lambda cfg, S: (S, cfg.d_model), jnp.bfloat16),
    ),
)
