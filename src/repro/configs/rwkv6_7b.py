"""rwkv6-7b [ssm] — Finch: 32L d=4096 (attn-free) ff=14336 vocab=65536,
data-dependent per-channel decay [arXiv:2404.05892; hf].  O(1) state ⇒
long_500k decode runs natively.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.rwkv import RWKVConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="rwkv6-7b",
        n_layers=32, d_model=4096, d_ff=14336, vocab=65536,
        head_size=64, lora_w=64, lora_mix=32,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return RWKVConfig(**kw)


def make_smoke():
    return RWKVConfig(
        name="rwkv6-smoke",
        n_layers=2, d_model=64, d_ff=128, vocab=97,
        head_size=16, lora_w=8, lora_mix=4, chunk=16,
        tp=1, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="rwkv6-7b",
    family="rwkv",
    source="arXiv:2404.05892",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=True, long_note="O(1) recurrent state"),
)
