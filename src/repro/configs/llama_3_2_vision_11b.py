"""llama-3.2-vision-11b [vlm] — 40L d=4096 32H (GQA kv=8) ff=14336
vocab=128256, cross-attn image layers (8 of 40, gated) with a stub vision
frontend: ``img_embeds`` (B, 576, d) precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_NOTE, lm_shapes
from repro.models.transformer import TransformerConfig

N_IMG = 576


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="llama-3.2-vision-11b",
        n_layers=40, d_model=4096, n_heads=32, kv_heads=8,
        d_ff=14336, vocab=128256, head_dim=128,
        rope_theta=500_000.0,
        cross_attn_every=4, n_img_tokens=N_IMG,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    return TransformerConfig(
        name="llama-3.2-vision-smoke",
        n_layers=5, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=97, head_dim=16, cross_attn_every=4, n_img_tokens=8,
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="llama-3.2-vision-11b",
    family="transformer",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=False, long_note=FULL_ATTN_NOTE),
    extra_inputs=(
        ("img_embeds", lambda cfg, S: (N_IMG, cfg.d_model), jnp.bfloat16),
    ),
    layer_pair=(5, 10, 5),   # one group = 4 self + 1 cross
)
