"""starcoder2-3b [dense] — 30L d=3072 24H (GQA kv=2) ff=12288 vocab=49152,
RoPE, non-gated GELU FFN [arXiv:2402.19173; hf].

24 heads don't divide tp=16 → padded to 32 heads (DESIGN.md §6; the 8
extra heads are ordinary learned heads — systems-equivalent compute).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_NOTE, lm_shapes
from repro.models.transformer import TransformerConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="starcoder2-3b",
        n_layers=30, d_model=3072, n_heads=24, kv_heads=2,
        d_ff=12288, vocab=49152, head_dim=128,
        act="gelu", gated=False, rope_theta=999_999.0,
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    # keeps the head-padding path live: 3 heads on tp=1 (no padding) plus
    # the padded case is covered by the tp-equivalence test
    return TransformerConfig(
        name="starcoder2-smoke",
        n_layers=2, d_model=48, n_heads=3, kv_heads=1, d_ff=96,
        vocab=97, head_dim=16, act="gelu", gated=False,
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="starcoder2-3b",
    family="transformer",
    source="arXiv:2402.19173",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=False, long_note=FULL_ATTN_NOTE),
)
