"""kimi-k2-1t-a32b [moe] — 61L d=7168 64H (GQA kv=8) per-expert ff=2048,
vocab=163840, MoE 384 experts top-8 + 1 shared expert — trillion-param
MoE (paper-table) [arXiv:2501.kimi2; unverified].

Expert params: 61L × 384e × 3 × 7168×2048 ≈ 1.03T.  EP over "model"
(24 experts/device at tp=16); scan-over-layers keeps the HLO O(1) in
depth so the 1T-param program compiles like a 17B one.  Single-pod
train_4k does NOT fit fp32 Adam state (§Dry-run memory verdicts) — the
multi-pod mesh (or ZeRO-1 + more pods) is the deploy target.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_NOTE, lm_shapes
from repro.models.moe import MoECfg
from repro.models.transformer import TransformerConfig


def make_config(tp: int = 16, dp_axes=("data",), **over):
    kw = dict(
        name="kimi-k2-1t-a32b",
        n_layers=61, d_model=7168, n_heads=64, kv_heads=8,
        d_ff=2048, vocab=163840, head_dim=112,
        rope_theta=50_000.0,
        moe=MoECfg(num_experts=384, top_k=8, d_expert=2048,
                   shared_experts=1),
        tp=tp, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return TransformerConfig(**kw)


def make_smoke():
    return TransformerConfig(
        name="kimi-k2-smoke",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=32,
        vocab=97, head_dim=16,
        moe=MoECfg(num_experts=8, top_k=2, d_expert=32, shared_experts=1,
                   capacity_factor=2.0),
        tp=1, attn_chunk=32, dtype=jnp.float32)


ARCH = ArchSpec(
    arch_id="kimi-k2-1t-a32b",
    family="transformer",
    source="arXiv:2501.kimi2",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=lm_shapes(long_ok=False, long_note=FULL_ATTN_NOTE),
)
