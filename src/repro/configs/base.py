"""Config machinery: ArchSpec (one per assigned architecture) + the
standard LM shape grid + input_specs construction (ShapeDtypeStruct
stand-ins — weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import family_of


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    applicable: bool = True
    note: str = ""


def lm_shapes(long_ok: bool, long_note: str = "") -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("train_4k", "train", 4096, 256),
        ShapeSpec("prefill_32k", "prefill", 32768, 32),
        ShapeSpec("decode_32k", "decode", 32768, 128),
        ShapeSpec("long_500k", "decode", 524288, 1,
                  applicable=long_ok, note=long_note),
    )


FULL_ATTN_NOTE = ("pure full attention: 512k decode KV cache is "
                  "O(seq x layers) with no sub-quadratic structure in the "
                  "assigned config — skipped per assignment rules "
                  "(DESIGN.md §6)")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str
    source: str                                   # citation tag
    make_config: Callable[..., Any]               # (tp, dp_axes, **overrides)
    make_smoke: Callable[[], Any]                 # tiny, tp=1
    shapes: tuple[ShapeSpec, ...]
    # extra per-batch inputs: name -> (per-sample shape fn(cfg, S), dtype)
    extra_inputs: tuple[tuple[str, Callable[[Any, int], tuple[int, ...]], Any], ...] = ()
    # (L_small, L_large, unit): HLO cost accounting pair — XLA counts scan
    # bodies once, so exact totals = f(L_small) + m·(f(L_large)-f(L_small))
    # with m = (n_layers - L_small)/unit.  None → no layer scan (convnets).
    layer_pair: Optional[tuple[int, int, int]] = (1, 2, 1)

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name}")


def _globalize(local_shape: tuple[int, ...], spec, mesh) -> tuple[int, ...]:
    out = list(local_shape)
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            out[dim] *= mesh.shape[a]
    return tuple(out)


def train_input_specs(arch: ArchSpec, cfg, shape: ShapeSpec) -> dict:
    GB, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((GB, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((GB, S), jnp.int32),
        "global_tokens": jax.ShapeDtypeStruct((), jnp.float32),
    }
    for name, shape_fn, dtype in arch.extra_inputs:
        specs[name] = jax.ShapeDtypeStruct((GB, *shape_fn(cfg, S)), dtype)
    return specs


def image_input_specs(cfg, shape: ShapeSpec) -> dict:
    GB = shape.global_batch
    return {
        "images": jax.ShapeDtypeStruct(
            (GB, cfg.img_size, cfg.img_size, 3), jnp.float32),
        "labels": jax.ShapeDtypeStruct((GB,), jnp.int32),
        "global_tokens": jax.ShapeDtypeStruct((), jnp.float32),
    }


def decode_state_structs(arch: ArchSpec, cfg, shape: ShapeSpec, mesh,
                         *, replicate_batch: bool = False) -> Any:
    """Global ShapeDtypeStructs for the decode cache/state."""
    api = family_of(cfg)
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) or 1
    if replicate_batch:
        b_local = shape.global_batch
        batch_entry = None
    else:
        b_local = shape.global_batch // dp_size
        batch_entry = tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)
    local = jax.eval_shape(
        lambda: api.make_decode_state(cfg, b_local, shape.seq_len))
    specs = api.decode_state_specs(cfg, batch_entry)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(
            _globalize(l.shape, s, mesh), l.dtype),
        local, specs), specs


def param_structs(cfg) -> Any:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    api = family_of(cfg)
    return jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0), cfg))
