"""inception-bn-imagenet — the paper's Inception-BN ImageNet-1K model
(§5.2, Fig 14), compact mixed-branch variant.  Pure data-parallel.
"""

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models.resnet import InceptionConfig


def make_config(tp: int = 1, dp_axes=("data",), **over):
    kw = dict(
        name="inception-bn-imagenet",
        num_classes=1000, img_size=224, width_mult=1.0,
        tp=1, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return InceptionConfig(**kw)


def make_smoke():
    return InceptionConfig(
        name="inception-smoke",
        num_classes=10, img_size=32, width_mult=0.25, tp=1)


ARCH = ArchSpec(
    arch_id="inception-bn-imagenet",
    family="inception",
    source="paper §5.2 (Inception-BN)",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=(
        ShapeSpec("train_imagenet", "train", 0, 256),
    ),
    layer_pair=None,
)
