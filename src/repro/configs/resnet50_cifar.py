"""resnet50-cifar — the paper's own CIFAR model (He et al., §5.1).
Pure data-parallel (one worker per device), BatchNorm local per worker —
the exact setting of paper Figs 13/16.
"""

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models.resnet import ResNetConfig


def make_config(tp: int = 1, dp_axes=("data",), **over):
    kw = dict(
        name="resnet50-cifar",
        stages=(3, 4, 6, 3), widths=(256, 512, 1024, 2048),
        num_classes=10, img_size=32,
        tp=1, dp_axes=tuple(dp_axes),
    )
    kw.update(over)
    return ResNetConfig(**kw)


def make_smoke():
    return ResNetConfig(
        name="resnet50-smoke",
        stages=(1, 1), widths=(32, 64), stem_width=16,
        num_classes=10, img_size=16, tp=1)


ARCH = ArchSpec(
    arch_id="resnet50-cifar",
    family="resnet",
    source="arXiv:1512.03385 (paper §5.1)",
    make_config=make_config,
    make_smoke=make_smoke,
    shapes=(
        ShapeSpec("train_cifar", "train", 0, 256),
    ),
    layer_pair=None,   # no layer scan — HLO cost is exact as-is
)
