"""repro: collective embedding in training DAGs (see DESIGN.md).

Importing the package applies ``repro.utils.jaxcompat`` so the new-style
jax API used throughout works on the container's older jax pin.
"""
from repro.utils import jaxcompat as _jaxcompat

_jaxcompat.apply()
