"""Live state reshard as scheduled collectives (DESIGN.md §13).

The problem: checkpoints of ZeRO-1 optimizer state are built from the
"global view" of the per-bucket stat shards — a dp-sharded flat array.
Under tensor parallelism that view is a LIE: each tp rank's shard holds
stats for *its* slice of the params, the values genuinely differ across
tp ranks, and ``device_get`` silently collapses them to one rank's copy.
A plain save/restore of zero1 state under tp > 1 is lossy.

``StateCodec`` fixes this by moving the state through the IR: a *gather*
program (RESHARD ops through the shared ``_OpEmitter``) all-gathers each
bucket's dp shards into the TRUE global view — an f32 tree shaped like
the params, sharded like the params, so per-tp-rank values survive — and
a *scatter* program re-slices that view into the dp shards of any mesh.
``encode ∘ decode`` on the same mesh is bit-exact: pack/unpack are exact
inverses, and pad regions stay exactly zero forever (adamw:
m' = b1·0 + (1-b1)·0 = 0, v likewise; sgd momentum 0; a pending update
at a pad position is -lr·(0/(√0+eps) + wd·0) = 0 because the padded
param is 0 too).

``plan_reshard`` builds the mesh-transition IR — per-stream gathers on
the old mesh, ONE REGROUP barrier every old-group member joins, then
per-stream scatters on the new mesh — with GLOBAL leaf sizes (so byte
conservation is checkable even when tp changes) and per-leaf
divisibility facts for the new mesh.  The reshard analysis pass verifies
it; ``repro.sim`` costs it like any other schedule.

``reshard_state`` is the execution: encode on the old mesh, one host
bounce, decode on the new mesh.  Deferred carries must be flushed
(``TrainStep.finalize``) before a transition — the pending stream is
deliberately NOT part of the transition IR, and the analysis pass
rejects any PRE op that crosses the regroup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.buckets import Bucket, LeafInfo
from repro.core.schedule import (
    REGROUP,
    RESHARD,
    CollectiveOp,
    CommSchedule,
    execute,
)
from repro.utils.trees import flatten_with_names


def _dp_spec(dp_axes: tuple[str, ...]) -> P:
    if not dp_axes:
        return P()
    return P(dp_axes if len(dp_axes) > 1 else dp_axes[0])


def _require_zero1(ts) -> Any:
    gs = ts.gradsync
    if gs is None or gs.dp_plan is None:
        raise ValueError(
            "elastic reshard needs a scheduled ZeRO-1 TrainStep "
            "(gradsync with a dp_plan); non-zero1 optimizer state is "
            "param-shaped and moves through the plain checkpoint path")
    return gs


class StateCodec:
    """Gather/scatter programs between zero1 opt-state shards and the
    TRUE (tp-honest) global view, for one ``TrainStep``.

    One shared gather program and one shared scatter program serve every
    stream ("inner/m", "inner/v", "pending", …): the programs depend
    only on the dp bucket plan, not on which stream's values flow
    through them.
    """

    def __init__(self, ts):
        gs = _require_zero1(ts)
        self.ts = ts
        self.gs = gs
        self.dp_plan = gs.dp_plan
        self.keys = tuple((b.bucket_id, str(i))
                          for i, b in enumerate(self.dp_plan.buckets))
        for b in self.dp_plan.buckets:
            for leaf in b.leaves:
                if np.dtype(leaf.dtype) != np.dtype(np.float32):
                    raise ValueError(
                        f"StateCodec requires f32 params (stat values "
                        f"round-trip through the param-shaped view); "
                        f"leaf {leaf.name!r} is {np.dtype(leaf.dtype)}")
        dp_axes = self.dp_plan.buckets[0].reduce_axes
        self.dp_size = 1
        for a in dp_axes:
            self.dp_size *= int(gs.mesh_shape.get(a, 1))
        self._shard_spec = _dp_spec(dp_axes)
        # stat stream names from the inner state structure (scalar-free
        # for every shipped optimizer: adamw {m,v}, sgd {mom})
        inner0 = ts.opt_state_like["inner"]["0"]
        named, _ = flatten_with_names(inner0)
        self.stat_names = tuple(n for n, _ in named)
        for n, leaf in named:
            if len(leaf.shape) != 1:
                raise ValueError(
                    f"inner stat {n!r} has shape {leaf.shape}; the codec "
                    f"only understands flat (n_shard,) zero1 stat leaves")
        self.has_pending = "pending" in ts.opt_state_like

        # transfer schedules: one RESHARD op per dp bucket.  The SAME
        # schedule serves both directions — ``pending`` presence flips
        # the emitter to the gather side.
        ops = tuple(
            CollectiveOp(op_id=i, bucket=b, chain=i, kind=RESHARD)
            for i, b in enumerate(self.dp_plan.buckets))
        self._sched = CommSchedule(ops).validate()
        self._exec_kw = dict(
            reducer=lambda b, _bk: b,        # no allreduce ops planned
            mesh_shape=gs.mesh_shape,
            use_fused_staging=gs.cfg.use_fused_staging,
            two_phase_impl=gs._two_phase_impl())

        def gather_fn(params, shards):
            # shards: {bucket_id: local (n_shard,) f32} — all-gathered
            # over the dp axes and unpacked into a zeros param tree
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            return execute(self._sched, zeros, self.dp_plan,
                           pending=dict(shards), **self._exec_kw)

        def scatter_fn(tree):
            aux: dict = {}
            execute(self._sched, tree, self.dp_plan, aux=aux,
                    **self._exec_kw)
            return {bid: aux["reshard_shards"][bid]
                    for bid, _ in self.keys}

        pspecs = ts.param_specs
        shard_specs = {bid: self._shard_spec for bid, _ in self.keys}
        self._gather = jax.jit(jax.shard_map(
            gather_fn, mesh=ts.mesh, in_specs=(pspecs, shard_specs),
            out_specs=pspecs, check_vma=False))
        self._scatter = jax.jit(jax.shard_map(
            scatter_fn, mesh=ts.mesh, in_specs=(pspecs,),
            out_specs=shard_specs, check_vma=False))

    # ------------------------------------------------------------ encode

    def _stream_shards(self, opt_state, stream: str) -> dict[int, Any]:
        if stream == "pending":
            return {bid: opt_state["pending"][k] for bid, k in self.keys}
        stat = stream.split("/", 1)[1]
        return {bid: opt_state["inner"][k][stat] for bid, k in self.keys}

    def encode(self, params, opt_state, *,
               include_pending: bool = True) -> dict[str, Any]:
        """Live (params, opt_state) → mesh-portable global trees.

        Returns ``{"params": ..., "stats": {stream: tree}}`` where every
        stats tree is param-shaped f32 with the params' shardings — the
        honest global view that survives any tp layout.
        """
        streams = [f"inner/{s}" for s in self.stat_names]
        if include_pending and self.has_pending:
            streams.append("pending")
        stats = {}
        for stream in streams:
            shards = self._stream_shards(opt_state, stream)
            for bid, arr in shards.items():
                n = next(b.size for b in self.dp_plan.buckets
                         if b.bucket_id == bid)
                want = (n + (-n) % self.dp_size)
                if arr.shape != (want,):
                    raise ValueError(
                        f"stream {stream!r} bucket {bid}: global shard "
                        f"array is {arr.shape}, expected ({want},) — "
                        f"opt_state does not match this codec's dp plan")
            stats[stream] = self._gather(params, shards)
        return {"params": params, "stats": stats}

    def encoded_like(self) -> dict[str, Any]:
        """ShapeDtypeStructs of ``encode``'s output (checkpoint restore
        template): params keep their dtype, stats are f32 param-shaped,
        pending included iff the step carries one."""
        params_like = self._params_like()
        f32_like = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.float32),
            params_like)
        streams = [f"inner/{s}" for s in self.stat_names]
        if self.has_pending:
            streams.append("pending")
        return {"params": params_like,
                "stats": {s: f32_like for s in streams}}

    def _params_like(self):
        # global param structs: local dp_plan leaf shapes scaled back up
        # by the sharded mesh axes of each spec dim
        named_specs, treedef = flatten_with_names(self.ts.param_specs)
        by_name = {}
        for b in self.dp_plan.buckets:
            for leaf in b.leaves:
                by_name[leaf.name] = leaf
        if len(by_name) != len(named_specs):
            raise ValueError(
                "dp plan does not cover every param leaf; the codec "
                "cannot reconstruct the global param structs")
        structs = []
        for name, spec in named_specs:
            leaf = by_name[name]
            shape = list(leaf.shape)
            for dim, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, (tuple, list)) \
                    else (entry,)
                for a in axes:
                    shape[dim] *= int(self.gs.mesh_shape.get(a, 1))
            structs.append(jax.ShapeDtypeStruct(tuple(shape), leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, structs)

    # ------------------------------------------------------------ decode

    def decode(self, encoded: Mapping[str, Any]) -> tuple[Any, Any]:
        """Mesh-portable trees → live (params, opt_state) on THIS codec's
        mesh.  Streams absent from ``encoded["stats"]`` (the pending
        carry after a flush) stay zero-initialized — gathering zeros is
        the identity update, so the first step after a transition starts
        exactly like a fresh deferred run."""
        sh_params = self.ts.shardings(self.ts.param_specs)
        params = jax.device_put(encoded["params"], sh_params)
        f32_sh = jax.tree.map(
            lambda s: NamedSharding(self.ts.mesh, s), self.ts.param_specs)
        opt_state = self.ts.init_opt()
        for stream, tree in encoded["stats"].items():
            if stream != "pending" and stream.split("/", 1)[1] \
                    not in self.stat_names:
                raise ValueError(
                    f"encoded stream {stream!r} has no slot in this "
                    f"step's opt_state (stats: {self.stat_names})")
            if stream == "pending" and not self.has_pending:
                continue        # scheduled step: the carry has no home
            placed = jax.device_put(tree, f32_sh)
            shards = self._scatter(placed)
            if stream == "pending":
                for bid, k in self.keys:
                    opt_state["pending"][k] = shards[bid]
            else:
                stat = stream.split("/", 1)[1]
                for bid, k in self.keys:
                    opt_state["inner"][k][stat] = shards[bid]
        return params, opt_state


# ------------------------------------------------------ transition IR

@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    """One planned mesh transition: the verified IR + its static facts."""

    transition: CommSchedule
    old_mesh_shape: dict[str, int]
    new_mesh_shape: dict[str, int]
    leaf_divisibility: dict[str, tuple[int, int]]
    reshard_bytes: int              # gather-side state moved (f32 bytes)
    streams: tuple[str, ...]


def plan_reshard(old_ts, new_ts, params) -> ReshardPlan:
    """Plan (and statically verify) the old-mesh → new-mesh transition.

    The IR mirrors what ``reshard_state`` executes: per-stream gather
    RESHARDs on the old mesh, ONE REGROUP barrier over every old mesh
    axis (the MXNET-MPI group-rebuild moment) depending on all of them,
    then per-stream scatter RESHARDs on the new mesh anchored on the
    barrier.  Leaves carry GLOBAL sizes and per-stream names
    ("param:<leaf>", "inner/m:<leaf>", …) so byte conservation is
    checkable even when tp changes the local shapes.  The pending carry
    is deliberately absent — it must be flushed before the transition.

    ``params`` is the global param tree (arrays or ShapeDtypeStructs);
    only shapes are read.
    """
    old_gs = _require_zero1(old_ts)
    new_gs = _require_zero1(new_ts)
    named, _ = flatten_with_names(params)
    global_size = {n: (int(np.prod(l.shape)) if l.shape else 1)
                   for n, l in named}

    inner0 = (old_ts.opt_state_like["inner"]["0"])
    stat_names, _ = flatten_with_names(inner0)
    streams = ("param",) + tuple(f"inner/{n}" for n, _ in stat_names)

    def rename(bucket: Bucket, stream: str, bid: int,
               axes: tuple[str, ...]) -> Bucket:
        leaves = tuple(
            LeafInfo(name=f"{stream}:{l.name}", index=i,
                     shape=(global_size[l.name],), dtype=jnp.float32,
                     size=global_size[l.name])
            for i, l in enumerate(bucket.leaves))
        return Bucket(leaves=leaves, reduce_axes=axes, channel=0,
                      bucket_id=bid, comm_dtype=jnp.float32)

    ops: list[CollectiveOp] = []
    for si, stream in enumerate(streams):
        for b in old_gs.dp_plan.buckets:
            oid = len(ops)
            ops.append(CollectiveOp(
                op_id=oid, bucket=rename(b, stream, oid, b.reduce_axes),
                chain=si, kind=RESHARD))
    rg_id = len(ops)
    regroup_bucket = Bucket(
        leaves=(LeafInfo(name="__regroup", index=0, shape=(),
                         dtype=jnp.float32, size=1),),
        reduce_axes=tuple(old_gs.mesh_shape), channel=0,
        bucket_id=rg_id, comm_dtype=jnp.float32)
    ops.append(CollectiveOp(
        op_id=rg_id, bucket=regroup_bucket, chain=0,
        depends_on=tuple(range(rg_id)), kind=REGROUP))
    for si, stream in enumerate(streams):
        for b in new_gs.dp_plan.buckets:
            oid = len(ops)
            ops.append(CollectiveOp(
                op_id=oid, bucket=rename(b, stream, oid, b.reduce_axes),
                chain=si, depends_on=(rg_id,), kind=RESHARD))
    transition = CommSchedule(tuple(ops))

    # static divisibility of every param leaf on the NEW mesh — the
    # scatter side must be able to tile each sharded dim
    new_specs, _ = flatten_with_names(new_gs.param_specs)
    divis: dict[str, tuple[int, int]] = {}
    for (name, leaf), (_, spec) in zip(named, new_specs):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            div = 1
            for a in axes:
                div *= int(new_gs.mesh_shape.get(a, 1))
            divis[f"{name}@dim{dim}"] = (int(leaf.shape[dim]), div)

    reshard_bytes = sum(
        op.bucket.size * 4 for op in ops[:rg_id])

    from repro.analysis import verify_schedule
    verify_schedule(
        transition, mesh_shape=None,
        old_mesh_shape=dict(old_gs.mesh_shape),
        new_mesh_shape=dict(new_gs.mesh_shape),
        leaf_divisibility=divis)

    return ReshardPlan(
        transition=transition,
        old_mesh_shape=dict(old_gs.mesh_shape),
        new_mesh_shape=dict(new_gs.mesh_shape),
        leaf_divisibility=divis,
        reshard_bytes=reshard_bytes,
        streams=streams)


# ------------------------------------------------------ execution

def reshard_state(old_ts, new_ts, params, opt_state, *,
                  old_codec: StateCodec | None = None,
                  new_codec: StateCodec | None = None,
                  include_pending: bool = False) -> tuple[Any, Any]:
    """Move live (params, opt_state) from ``old_ts``'s mesh onto
    ``new_ts``'s: encode on the old mesh (RESHARD gathers), one host
    bounce, decode on the new (RESHARD scatters).

    A deferred step's pending carry must be flushed
    (``TrainStep.finalize``) BEFORE calling this with the default
    ``include_pending=False``; the decoded carry starts at zeros, which
    gathers to the identity update.
    """
    old_codec = old_codec or StateCodec(old_ts)
    new_codec = new_codec or StateCodec(new_ts)
    encoded = old_codec.encode(params, opt_state,
                               include_pending=include_pending)
    host = jax.device_get(encoded)
    return new_codec.decode(host)
