"""Fault-injecting supervisor: the elastic policy ladder above the
Trainer (DESIGN.md §13).

The Trainer owns rungs 1–2 (retry the step in place, restore-and-replay
from checkpoint).  Rungs 3–4 — shrink to a smaller mesh when members are
lost or persistently slow, grow back when capacity returns — need a NEW
mesh, which a loop bound to one mesh cannot build.  ``Supervisor`` runs
the Trainer in segments over a mesh *ladder*, catching ``RankLost`` /
``RemeshRequest`` and executing the transition:

    finalize deferred carry → plan_reshard (verified IR, byte count)
    → reshard_state (old-mesh gathers, host bounce, new-mesh scatters)
    → blocking anchor checkpoint with the NEW mesh's codec

Every transition the faulty run *realizes* is recorded as a script
``(resume_step, mesh_key)``; replaying that script with no faults gives
the clean twin whose final state must be bit-exact with the faulty run —
the parity the elastic smoke benchmark asserts.

``ElasticCheckpointer`` is the checkpoint adapter: it speaks the
Trainer's ``{"params", "opt"}`` protocol but persists the ``StateCodec``
encoding — the tp-honest, param-shaped global view that any mesh in the
ladder can decode, so a checkpoint written on the 8-device mesh restores
on the 4-device mesh and vice versa.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.elastic.reshard import StateCodec, plan_reshard, reshard_state
from repro.runtime.train_loop import (
    RankLost,
    RemeshRequest,
    Trainer,
    TransientStepError,
)


class ElasticCheckpointer:
    """Mesh-portable checkpointing: Trainer protocol, codec encoding.

    ``maybe_save``/``save_now`` encode the live ``{"params", "opt"}``
    state into the codec's global view before handing it to the
    ``CheckpointManager``; ``restore`` loads that view and decodes it
    onto whatever mesh the CURRENT codec targets.  ``attach`` swaps the
    codec at a mesh transition — old checkpoints stay restorable because
    the persisted trees are global (param-shaped), not mesh-local.
    """

    def __init__(self, manager: CheckpointManager, codec: StateCodec):
        self.manager = manager
        self.codec = codec

    def attach(self, codec: StateCodec) -> None:
        self.codec = codec

    def _encode(self, tree: Mapping[str, Any]) -> dict[str, Any]:
        return self.codec.encode(tree["params"], tree["opt"])

    def maybe_save(self, step: int, tree: Mapping[str, Any]) -> bool:
        if step % self.manager.every:
            return False
        return self.manager.maybe_save(step, self._encode(tree))

    def save_now(self, step: int, tree: Mapping[str, Any]) -> None:
        self.manager.save_now(step, self._encode(tree))

    def restore(self, like: Any,
                step: Optional[int] = None) -> tuple[int, Any]:
        # ``like`` (the live mesh-local trees) is ignored: the on-disk
        # structure is the codec's encoded view
        s, encoded = self.manager.restore(self.codec.encoded_like(), step)
        params, opt_state = self.codec.decode(encoded)
        return s, {"params": params, "opt": opt_state}

    def latest(self) -> Optional[int]:
        return self.manager.latest()

    def wait(self) -> None:
        self.manager.wait()

    def manifest(self, step: int) -> list[str]:
        return self.manager.manifest(step)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """What the supervisor injects, and how the ladder responds.

    Each step-keyed fault fires ONCE (the replayed step after recovery
    runs clean, as a recovered fleet would).  ``ckpt_io_faults`` is a
    budget of transient ``OSError``s raised at the start of checkpoint
    save/restore attempts — the manager's retry-with-backoff must absorb
    them without ever corrupting the atomic rename protocol.
    """

    rank_loss: frozenset[int] = frozenset()     # RankLost at these steps
    transient: frozenset[int] = frozenset()     # TransientStepError once
    step_retries: int = 1                       # rung-1 budget per step
    ckpt_io_faults: int = 0                     # OSError budget (total)
    ckpt_retries: int = 3                       # manager retry budget
    straggler: frozenset[int] = frozenset()     # sleep at these steps
    straggler_s: float = 0.0
    straggler_shrink: bool = False              # opt-in rung 3 for stragglers


@dataclasses.dataclass
class Transition:
    """One realized mesh transition (also the clean-replay script row)."""

    resume_step: int
    from_key: str
    to_key: str
    reason: str
    reshard_bytes: int
    latency_s: float


class Supervisor:
    """Run a Trainer across a mesh ladder, injecting and surviving
    faults.

    ``build(key)`` returns ``(train_step, pipeline, init_params)`` for a
    mesh key; builds are memoized (jit cost is paid once per mesh).
    ``ladder`` orders the keys largest-first — ``ladder[0]`` is the full
    mesh, a shrink moves one rung down, a grow-back returns one rung up
    after ``grow_back_after`` steps on the smaller mesh.  The batch
    schedule must be identical across rungs (same dp extent) or the
    replayed trajectory would diverge — that invariant is the builder's
    contract, not checked here.

    ``script`` replays a recorded transition schedule with no faults:
    the clean twin of a faulty run.  Bit-exact parity between the two is
    the supervisor's correctness criterion (asserted by
    ``benchmarks/elastic_smoke.py`` and ``tests/_elworker.py``).
    """

    def __init__(self, build: Callable[[str], tuple[Any, Any, Any]],
                 ladder: tuple[str, ...], ckpt_root: str,
                 *, plan: FaultPlan | None = None,
                 script: tuple[tuple[int, str], ...] | None = None,
                 every: int = 4, grow_back_after: int = 4,
                 straggler_factor: float = 3.0,
                 straggler_patience: int = 3,
                 printer: Callable[[str], None] = print,
                 metrics=None, events_path: str | None = None):
        from repro.obs import EventLog, MetricsRegistry

        if len(ladder) < 1:
            raise ValueError("mesh ladder must name at least one mesh")
        self.build = build
        self.ladder = tuple(ladder)
        self.ckpt_root = ckpt_root
        self.plan = plan or FaultPlan()
        self.script = tuple(script) if script is not None else None
        self.every = every
        self.grow_back_after = grow_back_after
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.printer = printer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.event_log = EventLog(events_path)
        self.events: list[dict] = []
        self.transitions: list[Transition] = []
        self._built: dict[str, tuple[Any, Any, Any]] = {}
        self._codecs: dict[str, StateCodec] = {}
        self._fired: set[tuple[str, int]] = set()
        self._ckpt_io_left = 0 if self.script is not None \
            else self.plan.ckpt_io_faults

    # ------------------------------------------------------------ events

    def _event(self, kind: str, **fields) -> None:
        self.events.append({"kind": kind, **fields})
        self.event_log.emit(kind, **fields)

    # ------------------------------------------------------- mesh builds

    def _get(self, key: str) -> tuple[Any, Any, Any]:
        if key not in self._built:
            self._built[key] = self.build(key)
        return self._built[key]

    def _codec(self, key: str) -> StateCodec:
        if key not in self._codecs:
            ts, _, _ = self._get(key)
            self._codecs[key] = StateCodec(ts)
        return self._codecs[key]

    # --------------------------------------------------- fault injectors

    def _step_injector(self) -> Callable[[int], None] | None:
        if self.script is not None:
            return None                       # clean twin: no faults
        plan = self.plan

        def inject(step: int) -> None:
            if step in plan.transient and ("t", step) not in self._fired:
                self._fired.add(("t", step))
                raise TransientStepError(f"injected transient @ {step}")
            if step in plan.rank_loss and ("r", step) not in self._fired:
                self._fired.add(("r", step))
                raise RankLost(f"injected rank loss @ {step}")
            if step in plan.straggler and ("s", step) not in self._fired:
                self._fired.add(("s", step))
                time.sleep(plan.straggler_s)

        return inject

    def _ckpt_injector(self, op: str) -> None:
        if self._ckpt_io_left > 0:
            self._ckpt_io_left -= 1
            raise OSError(f"injected checkpoint I/O fault ({op})")

    def _remesh_hook(self, step: int) -> str | None:
        if self.script is not None:
            return None                       # clean twin: log only
        return "shrink" if self.plan.straggler_shrink else None

    # --------------------------------------------------------- transition

    def _transition(self, resume_step: int, from_key: str, to_key: str,
                    params, opt_state, ckpt: ElasticCheckpointer,
                    reason: str):
        """Move live state ``from_key`` → ``to_key`` and anchor it."""
        t0 = time.perf_counter()
        old_ts, _, _ = self._get(from_key)
        new_ts, _, _ = self._get(to_key)

        if old_ts.finalize is not None:
            # flush the deferred carry: the pending update shards land
            # in the params NOW; the transition IR (and the reshard
            # analysis pass) forbid a PRE op crossing the regroup
            params = old_ts.finalize(params, opt_state)

        rplan = plan_reshard(old_ts, new_ts, self._codec(from_key)
                             ._params_like())
        params, opt_state = reshard_state(
            old_ts, new_ts, params, opt_state,
            old_codec=self._codec(from_key),
            new_codec=self._codec(to_key),
            include_pending=False)   # flushed above → decode zeros it

        ckpt.attach(self._codec(to_key))
        ckpt.save_now(resume_step, {"params": params, "opt": opt_state})

        dt = time.perf_counter() - t0
        tr = Transition(resume_step=resume_step, from_key=from_key,
                        to_key=to_key, reason=reason,
                        reshard_bytes=rplan.reshard_bytes, latency_s=dt)
        self.transitions.append(tr)
        self.metrics.histogram("recovery_latency_s").observe(dt)
        self.metrics.counter("reshard_bytes_total").inc(
            rplan.reshard_bytes)
        self._event("transition", step=resume_step, from_mesh=from_key,
                    to_mesh=to_key, reason=reason,
                    reshard_bytes=rplan.reshard_bytes, latency_s=dt)
        self.printer(
            f"[supervisor] {reason}: {from_key} → {to_key} @ step "
            f"{resume_step} ({rplan.reshard_bytes} B resharded, "
            f"{dt*1e3:.0f} ms)")
        return params, opt_state

    # --------------------------------------------------------------- run

    def run(self, num_steps: int) -> tuple[Any, Any, dict]:
        """Train ``num_steps`` steps across the ladder; returns the
        final ``(params, opt_state, report)``.  The report carries the
        realized transition script — feed it back as ``script=`` to
        replay the same mesh trajectory with no faults."""
        rung = 0
        key = self.ladder[rung]
        ts, pipeline, params = self._get(key)
        opt_state = ts.init_opt()
        ckpt = ElasticCheckpointer(
            CheckpointManager(
                self.ckpt_root, every=self.every, keep=0, blocking=True,
                retries=self.plan.ckpt_retries,
                fault_injector=self._ckpt_injector),
            self._codec(key))

        scripted = list(self.script) if self.script is not None else None
        grow_at: int | None = None
        segments = 0
        while True:
            segments += 1
            if segments > 64:
                raise RuntimeError(
                    "supervisor exceeded 64 trainer segments — "
                    "fault plan or script is not converging")
            # next planned boundary: a scripted transition or grow-back
            if scripted:
                seg_end = min(num_steps, scripted[0][0])
            elif grow_at is not None:
                seg_end = min(num_steps, grow_at)
            else:
                seg_end = num_steps

            ts, pipeline, _ = self._get(key)
            trainer = Trainer(
                ts, pipeline, ckpt,
                step_retries=self.plan.step_retries,
                fault_injector=self._step_injector(),
                remesh_hook=self._remesh_hook,
                straggler_factor=self.straggler_factor,
                straggler_patience=self.straggler_patience,
                printer=self.printer, metrics=self.metrics,
                log_every=10_000)
            try:
                params, opt_state, _ = trainer.run(
                    params, opt_state, seg_end)
            except (RemeshRequest, RankLost) as e:
                self.events.extend(trainer.events)
                if rung + 1 >= len(self.ladder):
                    raise RuntimeError(
                        "mesh ladder exhausted: no smaller mesh to "
                        "shrink to") from e
                reason = ("straggler_shrink"
                          if isinstance(e, RemeshRequest) else "rank_loss")
                down = self.ladder[rung + 1]
                params, opt_state = self._transition(
                    e.step, key, down, e.params, e.opt_state, ckpt,
                    reason)
                rung += 1
                key = down
                grow_at = e.step + self.grow_back_after
                continue
            self.events.extend(trainer.events)

            if seg_end >= num_steps:
                break
            if scripted and scripted[0][0] == seg_end:
                _, to_key = scripted.pop(0)
                to_rung = self.ladder.index(to_key)
                params, opt_state = self._transition(
                    seg_end, key, to_key, params, opt_state, ckpt,
                    "scripted")
                rung, key = to_rung, to_key
                # the script IS the mesh trajectory — never derive a
                # grow-back the faulty run didn't realize
                grow_at = None
                continue
            if grow_at is not None and seg_end == grow_at:
                up = self.ladder[rung - 1]
                params, opt_state = self._transition(
                    seg_end, key, up, params, opt_state, ckpt,
                    "grow_back")
                rung -= 1
                key = up
                grow_at = None
                continue

        ckpt.wait()
        report = {
            "events": self.events,
            "transitions": [dataclasses.asdict(t)
                            for t in self.transitions],
            "script": tuple((t.resume_step, t.to_key)
                            for t in self.transitions),
            "final_mesh": key,
            "metrics": self.metrics.snapshot(),
        }
        return params, opt_state, report
