"""repro.elastic — online elastic training (DESIGN.md §13).

The MXNET-MPI companion paper (PAPERS.md, arxiv 1801.03855) extends the
source paper's fixed communicator with MPI *groups* inside a
parameter-server task model: workers regroup when membership changes.
This package makes that first-class and *scheduled*:

  reshard.py    — ``StateCodec`` (gather/scatter programs that move live
                  ZeRO-1 opt-state through the shared ``_OpEmitter`` as
                  RESHARD ops), ``plan_reshard`` (the transition IR:
                  gathers → REGROUP barrier → scatters, verified by the
                  reshard analysis pass and costed by ``repro.sim``),
                  and ``reshard_state`` (the old-mesh → new-mesh state
                  transfer).
  supervisor.py — ``Supervisor``: wraps ``Trainer`` with a fault plan
                  (rank loss, checkpoint-I/O faults, stragglers) and the
                  policy ladder retry → restore → shrink → grow-back,
                  driving full mesh cycles with bit-exact resume.
"""
from repro.elastic.reshard import (
    ReshardPlan,
    StateCodec,
    plan_reshard,
    reshard_state,
)
from repro.elastic.supervisor import (
    ElasticCheckpointer,
    FaultPlan,
    Supervisor,
)

__all__ = [
    "ElasticCheckpointer",
    "FaultPlan",
    "ReshardPlan",
    "StateCodec",
    "Supervisor",
    "plan_reshard",
    "reshard_state",
]
