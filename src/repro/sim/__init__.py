"""repro.sim: discrete-event CommSchedule simulator + cost-model autotuner
(DESIGN.md §7).

Predicts, without hardware, how each collective-embedding strategy's
dependency structure lands on a timeline: per-op start/end, exposed
communication, overlap fraction, and step time — from an alpha-beta
network model (``netmodel``), a FLOP-derived compute model (``compute``)
and an event-driven executor over the CommSchedule IR (``engine``).

Importing this package registers the ``auto`` strategy (``autotune``):
``--strategy auto`` plans by simulating every fixed strategy and
delegating to the predicted winner.

    PYTHONPATH=src python -m repro.sim --arch resnet50-cifar
    PYTHONPATH=src python -m repro.sim --arch qwen3-1.7b --autotune
"""
from repro.sim.autotune import (
    Prediction,
    choose_pp_schedule,
    flat_step_schedule,
    grid_search,
    last_auto_report,
    plan_auto,
    rank_step_plans,
    rank_strategies,
    sim_config_for,
    simulate_strategy,
)
from repro.sim.compute import (
    ComputeModel,
    HardwareModel,
    PipelineTimeline,
    StagingModel,
    UpdateModel,
    compute_model_for,
    count_params,
    fwd_flops,
    pipeline_timeline,
)
from repro.sim.engine import (
    OpEvent,
    PipelinedTimeline,
    SimConfig,
    Timeline,
    simulate,
    simulate_pipelined,
)
from repro.sim.netmodel import DCN, ICI, LinkModel, NetworkModel, default_network
from repro.sim.serve import (
    DecodeModel,
    plan_decode,
    rank_decode_plans,
    simulate_decode,
)
from repro.sim.trace import (
    ascii_timeline,
    chrome_trace,
    chrome_trace_events,
    write_chrome_trace,
)

__all__ = [
    "ComputeModel",
    "DCN",
    "DecodeModel",
    "HardwareModel",
    "ICI",
    "LinkModel",
    "NetworkModel",
    "OpEvent",
    "PipelineTimeline",
    "PipelinedTimeline",
    "Prediction",
    "SimConfig",
    "StagingModel",
    "Timeline",
    "UpdateModel",
    "ascii_timeline",
    "choose_pp_schedule",
    "chrome_trace",
    "chrome_trace_events",
    "compute_model_for",
    "count_params",
    "default_network",
    "flat_step_schedule",
    "fwd_flops",
    "grid_search",
    "last_auto_report",
    "pipeline_timeline",
    "plan_auto",
    "plan_decode",
    "rank_decode_plans",
    "rank_step_plans",
    "rank_strategies",
    "sim_config_for",
    "simulate",
    "simulate_decode",
    "simulate_pipelined",
    "simulate_strategy",
    "write_chrome_trace",
]
