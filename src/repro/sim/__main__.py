import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Simulate every collective-embedding strategy for one (arch ×
shape × mesh) cell — predicted timelines, exposed communication and the
auto-tuned winner, all on CPU in seconds (no compile, no hardware).

  PYTHONPATH=src python -m repro.sim --arch resnet50-cifar
  PYTHONPATH=src python -m repro.sim --arch qwen3-1.7b --shape train_4k \
      --mesh multi --autotune --trace results/sim_trace.json
"""

import argparse

import repro  # noqa: F401  (jaxcompat shim before jax.sharding imports)
import jax  # noqa: F401

from repro.configs import get_arch
from repro.configs.base import param_structs
from repro.core.registry import fixed_strategy_names
from repro.core.buckets import make_bucket_plan
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models.registry import family_of
from repro.parallel.sharding import dp_axes_of, localize_structs
from repro.sim import (
    SimConfig,
    ascii_timeline,
    compute_model_for,
    grid_search,
    last_auto_report,
    plan_auto,
    rank_step_plans,
    simulate,
    simulate_strategy,
    write_chrome_trace,
)


def _make_mesh(spec: str):
    import jax
    from jax.sharding import AxisType

    if spec == "single":
        return make_production_mesh(multi_pod=False)
    if spec == "multi":
        return make_production_mesh(multi_pod=True)
    dims = tuple(int(v) for v in spec.split("x"))
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes,
                         axis_types=(AxisType.Auto,) * len(dims))


def main():
    ap = argparse.ArgumentParser(
        description=DOC, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="shape name (default: the arch's train shape)")
    ap.add_argument("--mesh", default="single",
                    help="single | multi | DxM | PxDxM")
    ap.add_argument("--channels", type=int, default=4)
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--reducer", default="flat")
    ap.add_argument("--comm-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--staging", default="fused",
                    choices=["fused", "leafwise"],
                    help="CopyFromTo cost model: fused kernels vs "
                         "per-leaf pack/unpack")
    ap.add_argument("--autotune", action="store_true",
                    help="grid-search strategy × channels × bucket size")
    ap.add_argument("--zero1", action="store_true",
                    help="simulate the full-step ZeRO-1 StepProgram "
                         "(per-bucket RS→UPDATE→AG, plus the pipelined "
                         "deferred-AG variant) vs the flat allreduce + "
                         "monolithic update baseline")
    ap.add_argument("--clip", action="store_true",
                    help="with --zero1: plan the scheduled grad-norm "
                         "NORM op gating the updates")
    ap.add_argument("--accum", type=int, default=1,
                    help="grad-accumulation factor M: cost the "
                         "M-microbatch scan (M× compute; releases only "
                         "from the FINAL microbatch's backward — the "
                         "peeled-tail training shape)")
    ap.add_argument("--no-accum-overlap", action="store_true",
                    help="with --accum: releases at the scan's very end "
                         "(no peeled final microbatch)")
    ap.add_argument("--trace", default="",
                    help="write a Chrome-trace JSON of all timelines")
    ap.add_argument("--ascii", action="store_true",
                    help="render the best strategy's timeline")
    args = ap.parse_args()

    import jax.numpy as jnp

    arch = get_arch(args.arch)
    shape = arch.shape(args.shape) if args.shape else next(
        (s for s in arch.shapes if s.kind == "train"), arch.shapes[0])
    mesh = _make_mesh(args.mesh)
    mesh_shape = mesh_shape_dict(mesh)
    n_devices = 1
    for s in mesh_shape.values():
        n_devices *= s

    cfg = arch.make_config(tp=mesh_shape.get("model", 1),
                           dp_axes=dp_axes_of(mesh))
    params_sds = param_structs(cfg)
    pspecs = family_of(cfg).param_rules(cfg).tree_specs(params_sds)
    # GradSync runs inside shard_map: the comm payload is the LOCAL shard
    params_sds = localize_structs(params_sds, pspecs, mesh)
    compute = compute_model_for(
        cfg, global_batch=shape.global_batch, seq_len=shape.seq_len,
        n_devices=n_devices)
    # with --accum the step's FLOPs stay those of the full global batch;
    # the per-microbatch model is 1/M of it, and the folded model places
    # the releases where the accumulation scan actually produces them
    micro = compute
    if args.accum > 1:
        import dataclasses as _dc2

        micro = _dc2.replace(compute, t_fwd=compute.t_fwd / args.accum,
                             t_bwd=compute.t_bwd / args.accum)
        compute = micro.with_accum(args.accum,
                                   overlap_tail=not args.no_accum_overlap)
    itemsize = 2 if args.comm_dtype == "bf16" else 4
    comm_dtype = jnp.bfloat16 if args.comm_dtype == "bf16" else jnp.float32
    sim = SimConfig(window=args.window, itemsize=itemsize,
                    reducer=args.reducer,
                    fused_staging=args.staging == "fused")
    plan = make_bucket_plan(
        params_sds, pspecs, mesh,
        bucket_bytes=int(args.bucket_mb * 1024 * 1024),
        num_channels=args.channels, comm_dtype=comm_dtype)

    print(f"[sim] {args.arch} × {shape.name} × {args.mesh} "
          f"({'x'.join(f'{k}={v}' for k, v in mesh_shape.items())}), "
          f"{plan.total_bytes / 1e6:.1f} MB grads in "
          f"{len(plan.buckets)} buckets, "
          f"t_fwd={compute.t_fwd * 1e3:.2f} ms "
          f"t_bwd={compute.t_bwd * 1e3:.2f} ms"
          + (f" (accum M={args.accum}, releases in the final "
             f"microbatch's backward)" if args.accum > 1 else ""))

    print("strategy,ops,chains,step_ms,comm_ms,exposed_ms,overlap_pct")
    timelines = {}
    for name in fixed_strategy_names():
        schedule, tl = simulate_strategy(
            name, plan, mesh_shape, compute=compute, sim=sim)
        timelines[name] = tl
        print(f"{name},{len(schedule.ops)},{schedule.num_chains},"
              f"{tl.step_time * 1e3:.3f},{tl.total_comm * 1e3:.3f},"
              f"{tl.exposed_comm * 1e3:.3f},"
              f"{tl.overlap_fraction * 100:.1f}")

    auto_schedule = plan_auto(plan, context={
        "mesh_shape": mesh_shape, "reducer": args.reducer,
        "itemsize": itemsize, "compute": compute,
        "fused_staging": args.staging == "fused"})
    report = last_auto_report()
    auto_tl = simulate(auto_schedule, mesh_shape, compute=compute, sim=sim)
    timelines["auto"] = auto_tl
    print(f"[sim] auto → {report['winner']} "
          f"(predicted {report['ranking'][0][1] * 1e3:.3f} ms/step)")

    # fused vs leafwise CopyFromTo on the winner's schedule — the §8
    # staging cost the fused kernels remove (import dataclasses locally
    # to keep the CLI's import cost down)
    import dataclasses as _dc
    both = {
        mode: simulate(auto_schedule, mesh_shape, compute=compute,
                       sim=_dc.replace(sim, fused_staging=mode == "fused"))
        for mode in ("fused", "leafwise")}
    print(f"[sim] staging ({report['winner']}): "
          f"fused {both['fused'].step_time * 1e3:.3f} ms/step vs "
          f"leafwise {both['leafwise'].step_time * 1e3:.3f} ms/step "
          f"(Δ {(both['leafwise'].step_time - both['fused'].step_time) * 1e6:.1f} us)")

    if args.zero1:
        # the full-step StepProgram arc on one leaderboard: pipelined
        # deferred-AG (PRE gathers hidden under the next forward) vs
        # zero1 RS→UPDATE→AG triples vs flat allreduce + ONE monolithic
        # update (same wire bytes, progressively less of them exposed)
        # — UPDATE/NORM ops costed by the engine
        from repro.core.stepprogram import zero1_bucket_plan

        dp = dp_axes_of(mesh)
        if not dp:
            raise SystemExit("[sim] --zero1 needs a data-parallel axis")
        dp_plan = zero1_bucket_plan(
            params_sds, pspecs, mesh, dp_axes=dp,
            bucket_bytes=int(args.bucket_mb * 1024 * 1024),
            num_channels=args.channels)
        ranked = rank_step_plans(
            dp_plan, mesh_shape, dp_axes=dp, clip=args.clip,
            compute=micro, sim=sim, accum=args.accum,
            accum_overlap=not args.no_accum_overlap)
        print("step_plan,ops,update_ops,step_ms,exposed_ms,overlap_pct")
        for name, tl in ranked:
            ups = sum(1 for e in tl.events if e.kind == "update")
            print(f"{name},{len(tl.events)},{ups},"
                  f"{tl.step_time * 1e3:.3f},"
                  f"{tl.exposed_comm * 1e3:.3f},"
                  f"{tl.overlap_fraction * 100:.1f}")
            timelines[name] = tl
        best_d = next(t for n, t in ranked if n.startswith("deferred:"))
        best_z = next(t for n, t in ranked if n.startswith("zero1:"))
        best_f = next(t for n, t in ranked if n.startswith("flat:"))
        print(f"[sim] deferred-pipelined {best_d.step_time * 1e3:.3f} "
              f"(exposed {best_d.exposed_comm * 1e3:.3f}) vs "
              f"zero1-scheduled {best_z.step_time * 1e3:.3f} "
              f"(exposed {best_z.exposed_comm * 1e3:.3f}) vs "
              f"flat+monolithic {best_f.step_time * 1e3:.3f} ms/step")

    if args.ascii:
        best = report["winner"]
        print(f"[sim] timeline: {best}")
        print(ascii_timeline(timelines[best]))

    if args.autotune:
        preds = grid_search(
            params_sds, pspecs, mesh, mesh_shape=mesh_shape,
            compute=compute, sim=sim, comm_dtype=comm_dtype)
        print("tuned: strategy,channels,bucket_mb,step_ms,overlap_pct")
        for p in preds[:10]:
            print(f"tuned: {p.strategy},{p.num_channels},"
                  f"{p.bucket_bytes / (1 << 20):.0f},"
                  f"{p.step_time * 1e3:.3f},"
                  f"{p.overlap_fraction * 100:.1f}")
        best = preds[0]
        print(f"[sim] best config: --strategy {best.strategy} "
              f"--channels {best.num_channels} "
              f"--bucket-mb {best.bucket_bytes / (1 << 20):.0f}")

    if args.trace:
        os.makedirs(os.path.dirname(args.trace) or ".", exist_ok=True)
        write_chrome_trace(args.trace, timelines)
        n_events = sum(len(t.events) for t in timelines.values())
        print(f"[sim] wrote {args.trace} ({n_events} op events, "
              f"open in chrome://tracing or Perfetto)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
