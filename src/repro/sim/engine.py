"""Discrete-event executor for CommSchedule IR (DESIGN.md §7).

Walks the schedule's token chains exactly as the runtime emitter would —
an op may start once (a) every ``depends_on`` op finished (chain
serialization: funnel = 1 chain, concom/priority = ``num_channels``
concurrent chains, rsag = RS chain + free-flying AGs), (b) its bucket's
gradients exist (``ComputeModel`` release times), and (c) an in-flight
slot is free (the bounded OUTSTANDING window of paper Fig 8).  Op
durations come from the alpha-beta ``NetworkModel``.

For in-scan strategies (depcha) the chain edges are dropped and releases
snap to scan-step boundaries: each layer's psum is emitted inside the
backward scan, gated only by the scan itself — ``drop_chain_deps`` +
``per_stage_release`` in ``SimConfig`` (cross-bucket edges vanish;
same-bucket data edges — RS→UPDATE→AG, and every NORM edge — always
survive).

StepProgram kinds (DESIGN.md §9) are costed too: an UPDATE op prices
the sharded optimizer math (``ComputeModel.update`` HBM model over the
1/group shard), a NORM op the scalar latency-bound allreduce of the
squared grad norms; neither pays staging.

The run is fully deterministic: ties break on op_id, no wall-clock, no
randomness — the same schedule always yields the same timeline.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Mapping

import numpy as np

from repro.core.schedule import (
    ALLREDUCE,
    ALL_GATHER,
    DECODE,
    NORM,
    RECV,
    REDUCE_SCATTER,
    REGROUP,
    RESHARD,
    SEND,
    UPDATE,
    CommSchedule,
)

from repro.sim.compute import ComputeModel
from repro.sim.netmodel import NetworkModel, default_network


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Knobs of one simulation run (strategy semantics + wire format)."""

    window: int = 8              # max collectives in flight (Fig 8 window)
    itemsize: int = 4            # comm dtype bytes (f32=4, bf16=2)
    reducer: str = "flat"        # default reducer for untagged ops
    drop_chain_deps: bool = False    # in-scan: no cross-bucket chains
    per_stage_release: bool = False  # in-scan: release at scan-step ends
    fused_staging: bool = True       # CopyFromTo: fused kernels vs leafwise


@dataclasses.dataclass(frozen=True)
class OpEvent:
    """One simulated collective: the timeline row for a CollectiveOp."""

    op_id: int
    bucket_id: int
    chain: int
    kind: str
    nbytes: int
    release: float      # bucket gradients ready
    start: float        # deps + release + window slot satisfied
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class Timeline:
    """The simulated step: per-op events + step-level metrics."""

    events: tuple[OpEvent, ...]
    t_fwd: float
    t_bwd: float

    @property
    def compute_end(self) -> float:
        return self.t_fwd + self.t_bwd

    @property
    def comm_end(self) -> float:
        return max((e.end for e in self.events), default=0.0)

    @property
    def step_time(self) -> float:
        return max(self.compute_end, self.comm_end)

    @property
    def total_comm(self) -> float:
        return sum(e.duration for e in self.events)

    @property
    def exposed_comm(self) -> float:
        """Communication the step waits on after compute finishes."""
        return max(0.0, self.comm_end - self.compute_end)

    @property
    def overlap_fraction(self) -> float:
        """Share of communication hidden behind compute."""
        if self.total_comm <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.exposed_comm / self.total_comm)

    def stats(self) -> dict:
        return {
            "num_ops": len(self.events),
            "step_time": self.step_time,
            "compute_time": self.compute_end,
            "comm_time": self.total_comm,
            "exposed_comm": self.exposed_comm,
            "overlap_fraction": self.overlap_fraction,
        }


def simulate(
    schedule: CommSchedule,
    mesh_shape: Mapping[str, int],
    *,
    compute: ComputeModel | None = None,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    release_times: Mapping[int, float] | None = None,
) -> Timeline:
    """Execute ``schedule`` as a discrete-event timeline.

    Emits exactly one ``OpEvent`` per CollectiveOp; events are returned
    in start-time order (ties by op_id).

    ``release_times`` (op_id → earliest start) overrides the bucket
    release for the listed ops — pipeline plans use it to gate each
    SEND/RECV on its producing slot's compute end
    (``sim.compute.pipeline_timeline().op_release``), which also keeps a
    pp bucket_id from aliasing a same-numbered sync bucket's release.

    SEND/RECV run with rendezvous semantics: the SEND is the sender's
    local pack (staging only — the payload parks, exactly the emitter's
    behavior), and the paired RECV — which carries the SEND in its
    ``depends_on`` — is the synchronization point where the ppermute
    hop executes: it starts at max(sender packed, receiver ready,
    release) and pays the p2p wire plus the unpack.
    """
    net = net or default_network()
    sim = sim or SimConfig()
    compute = compute or ComputeModel(t_fwd=0.0, t_bwd=0.0)

    # gradient-ready times come from the wire ops' buckets only: UPDATE/
    # AG ops share their RS bucket (same release), while synthetic
    # buckets (NORM scalar, the flat baseline's full-buffer update) are
    # gated by their deps, not a release of their own.  Each leaf counts
    # ONCE: in a spliced StepProgram the dp buckets re-carry the sync
    # buckets' leaves, and double-counting them would both skew the sync
    # releases (vs the same schedule without zero1) and push the dp
    # releases artificially late.
    seen_leaves: set[str] = set()
    eff_sizes: list[tuple[int, int]] = []
    for bid, bucket in sorted({op.bucket.bucket_id: op.bucket
                               for op in schedule.ops
                               if op.kind in (ALLREDUCE, REDUCE_SCATTER)
                               }.items()):
        fresh = sum(l.size for l in bucket.leaves
                    if l.name not in seen_leaves)
        seen_leaves.update(l.name for l in bucket.leaves)
        eff_sizes.append((bid, fresh))
    releases = compute.bucket_release_times(
        eff_sizes, per_stage=sim.per_stage_release)

    by_id = {op.op_id: op for op in schedule.ops}

    def deps_of(op) -> tuple[int, ...]:
        if not sim.drop_chain_deps:
            return op.depends_on
        # in-scan semantics: only data deps survive — the same bucket's
        # RS→UPDATE→AG spine, every NORM edge (the scalar norm needs all
        # shards; clipped updates need the norm), and cross-chain edges
        # (a StepProgram dp RS waiting on the sync op that produces its
        # leaves).  Chain-ordering edges are same-chain by construction.
        return tuple(
            d for d in op.depends_on
            if (op.kind in (ALL_GATHER, UPDATE)
                and by_id[d].bucket.bucket_id == op.bucket.bucket_id)
            or op.kind == NORM or by_id[d].kind == NORM
            or by_id[d].chain != op.chain)

    def itemsize_of(op) -> int:
        # zero1 buckets pin their own wire dtype (f32) independent of
        # the sync schedule's comm dtype
        if op.bucket.comm_dtype is not None:
            return np.dtype(op.bucket.comm_dtype).itemsize
        return sim.itemsize

    def group_of(op) -> int:
        g = 1
        for a in op.bucket.reduce_axes:
            g *= int(mesh_shape.get(a, 1))
        return max(g, 1)

    # elastic transitions (DESIGN.md §13): a RESHARD after the first
    # REGROUP is the scatter side — local pack + slice on the NEW mesh,
    # no wire time (the gather side already paid the all-gather)
    first_rg = next((i for i, op in enumerate(schedule.ops)
                     if op.kind == REGROUP), None)
    scatter_ids = frozenset(
        op.op_id for op in (schedule.ops[first_rg + 1:]
                            if first_rg is not None else ())
        if op.kind == RESHARD)

    def duration(op) -> float:
        nbytes = op.bucket.size * itemsize_of(op)
        if op.kind == UPDATE:
            # sharded optimizer math: an HBM pass over the 1/group shard
            return compute.update.update_time(nbytes / group_of(op))
        if op.kind == DECODE:
            # decode-step compute for one node: memory-bandwidth-bound at
            # batch≈1 — an HBM pass over the node's LOCAL param bytes
            # (op.bucket.size carries the local element count); reuse the
            # UpdateModel's HBM bandwidth with a 1-read pass
            return (nbytes / compute.update.hbm_bw
                    + compute.update.overhead)
        if op.kind == SEND:
            # local pack only: the wire move happens at the paired RECV
            return net.staging_time(
                SEND, nbytes, len(op.bucket.leaves),
                fused=sim.fused_staging)
        if op.kind == RECV:
            # rendezvous point: one ppermute hop + the unpack
            return net.collective_time(
                RECV, nbytes, op.bucket.reduce_axes,
                mesh_shape) + net.staging_time(
                RECV, nbytes, len(op.bucket.leaves),
                fused=sim.fused_staging)
        if op.kind in (NORM, REGROUP):
            # scalar psum (squared norms / the regroup barrier):
            # latency-bound allreduce
            return net.allreduce_time(
                max(nbytes, sim.itemsize), op.bucket.reduce_axes,
                mesh_shape)
        if op.kind == RESHARD:
            if op.op_id in scatter_ids:
                return net.staging_time(
                    REDUCE_SCATTER, nbytes, len(op.bucket.leaves),
                    fused=sim.fused_staging)
            # gather side: an all-gather of the dp shard + staging out
            return net.collective_time(
                ALL_GATHER, nbytes, op.bucket.reduce_axes, mesh_shape,
                reducer=op.reducer or sim.reducer) + net.staging_time(
                ALL_GATHER, nbytes, len(op.bucket.leaves),
                fused=sim.fused_staging)
        # wire time + the op's share of CopyFromTo staging (pack/unpack;
        # fused vs leafwise is a GradSyncConfig knob the tuner must see)
        return net.collective_time(
            op.kind, nbytes, op.bucket.reduce_axes, mesh_shape,
            reducer=op.reducer or sim.reducer) + net.staging_time(
            op.kind, nbytes, len(op.bucket.leaves),
            fused=sim.fused_staging)

    def release_of(op) -> float:
        if release_times is not None and op.op_id in release_times:
            return release_times[op.op_id]
        return releases.get(op.bucket.bucket_id, compute.t_fwd)

    pending = {op.op_id: len(deps_of(op)) for op in schedule.ops}
    children: dict[int, list[int]] = {}
    dep_ready = {op.op_id: release_of(op) for op in schedule.ops}
    for op in schedule.ops:
        for d in deps_of(op):
            children.setdefault(d, []).append(op.op_id)

    avail: list[tuple[float, int]] = []       # (ready_time, op_id)
    running: list[tuple[float, int]] = []     # (end_time, op_id)
    events: list[OpEvent] = []
    now = 0.0

    for op in schedule.ops:
        if pending[op.op_id] == 0:
            heapq.heappush(avail, (dep_ready[op.op_id], op.op_id))

    def finish_one() -> float:
        nonlocal now
        end, oid = heapq.heappop(running)
        now = max(now, end)
        for child in children.get(oid, ()):
            dep_ready[child] = max(dep_ready[child], end)
            pending[child] -= 1
            if pending[child] == 0:
                heapq.heappush(avail, (dep_ready[child], child))
        return end

    while avail or running:
        if avail and len(running) < sim.window:
            ready_time, oid = avail[0]
            start = max(ready_time, now)
            # a completion before `start` may unlock an earlier-ready op
            if running and running[0][0] <= start:
                finish_one()
                continue
            heapq.heappop(avail)
            now = start
            op = by_id[oid]
            end = start + duration(op)
            heapq.heappush(running, (end, oid))
            events.append(OpEvent(
                op_id=oid, bucket_id=op.bucket.bucket_id, chain=op.chain,
                kind=op.kind, nbytes=op.bucket.size * itemsize_of(op),
                release=release_of(op),
                start=start, end=end))
        else:
            finish_one()

    events.sort(key=lambda e: (e.start, e.op_id))
    return Timeline(events=tuple(events),
                    t_fwd=compute.t_fwd, t_bwd=compute.t_bwd)


@dataclasses.dataclass(frozen=True)
class PipelinedTimeline(Timeline):
    """A deferred-AG (phase-split) step in steady state (DESIGN.md §10).

    ``t_fwd``/``t_bwd`` describe the possibly-PUSHED compute (the
    forward start slips when the PRE gathers outrun their overlap
    window), while ``pure_compute`` is what compute alone would take —
    so ``exposed_comm`` counts BOTH ends of the pipeline: time the
    forward waited on last step's gathers at the head, and time the
    step waited on its own sync/RS/update tail.
    """

    pure_compute: float = 0.0

    @property
    def exposed_comm(self) -> float:
        return max(0.0, self.step_time - self.pure_compute)


def simulate_pipelined(
    post: CommSchedule,
    pre: CommSchedule,
    mesh_shape: Mapping[str, int],
    *,
    compute: ComputeModel,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    pre_window: float | None = None,
) -> PipelinedTimeline:
    """Steady-state timeline of one pipelined step.

    ``pre`` holds last step's deferred all-gathers: their update-shard
    inputs were carried across the boundary, so every op is released at
    t=0 and they overlap the forward (and each other).  ``pre_window``
    is the compute time available to hide them — the forward of the
    first microbatch that reads the params (defaults to
    ``compute.t_fwd``); gathers that outrun it push the whole step.
    ``post`` (sync + RS + NORM + UPDATE) then executes against the
    pushed compute's release times exactly like a plain step.
    """
    idle = ComputeModel(t_fwd=0.0, t_bwd=0.0)
    pre_tl = simulate(pre, mesh_shape, compute=idle, net=net, sim=sim)
    window = compute.t_fwd if pre_window is None else pre_window
    push = max(0.0, pre_tl.comm_end - window)
    shifted = dataclasses.replace(compute, t_fwd=compute.t_fwd + push)
    post_tl = simulate(post, mesh_shape, compute=shifted, net=net, sim=sim)
    events = tuple(sorted(pre_tl.events + post_tl.events,
                          key=lambda e: (e.start, e.op_id)))
    return PipelinedTimeline(
        events=events, t_fwd=shifted.t_fwd, t_bwd=compute.t_bwd,
        pure_compute=compute.end)
