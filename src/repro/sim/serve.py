"""repro.sim.serve: decode-time cost model — per-token serving
collectives as CommSchedule IR (DESIGN.md §14).

The continuous-batching runtime (``repro.runtime.serve_loop``) emits, per
decode token, the same collective structure every step: each layer's
local matmuls (memory-bandwidth-bound at decode batch sizes) followed by
two tensor-parallel psums (attention ``wo`` output, FFN output), then the
lm_head projection and the sampler's candidate all-gather.  That is a
dependency-chained program of the SAME shape the training planners build
— so decode plans are expressed in the same IR (a DECODE compute node per
layer group, explicit ALLREDUCE/ALL_GATHER wire ops) and ranked through
the same discrete-event simulator (``repro.sim.engine``) and static
verifier (``repro.analysis``) as training strategies.

Plan shape (``plan_decode``), one token:

    layer 0: DECODE(params) → AR(attn out, model) → AR(ffn out, model)
    layer 1: DECODE(params) → ...                           (chained)
    head:    DECODE(lm_head params) → DECODE(candidates) → AG(model)

The sampler tail varies by variant — the candidate payload the all-gather
moves is what distinguishes them:

    argmax  — one (value, index) pair per row: ``sharded_argmax``
    topk    — k_cand pairs per row: ``sharded_sample``'s candidate set
    full    — the whole vocab row: the naive full-logit gather the
              sharded sampler exists to avoid

``rank_decode_plans`` verifies each variant statically (deadlock / SPMD /
accounting passes) and ranks by simulated per-token latency, mirroring
``rank_strategies`` for training plans.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core.buckets import Bucket, LeafInfo
from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    DECODE,
    CollectiveOp,
    CommSchedule,
)
from repro.sim.compute import ComputeModel, UpdateModel, count_params
from repro.sim.engine import SimConfig, Timeline, simulate
from repro.sim.netmodel import NetworkModel

MODEL_AXIS = "model"

#: sampler tail variants ``plan_decode`` knows how to lay out
SAMPLERS = ("argmax", "topk", "full")


@dataclasses.dataclass(frozen=True)
class DecodeModel:
    """Per-token decode signature of one model on one mesh.

    Everything the planner/coster needs, independent of jax: local
    (per-device) parameter element counts — decode compute at small
    batch is an HBM pass over the weights — plus the activation widths
    the tp psums move.
    """

    n_layers: int
    layer_params_local: int    # per-layer param elements on ONE device
    head_params_local: int     # lm_head param elements on ONE device
    d_model: int
    vocab: int
    tp: int = 1                # tensor-parallel group (the psum width)
    dp: int = 1                # data-parallel replicas (batch rows split)
    batch: int = 1             # in-flight decode width W (global)

    @property
    def batch_local(self) -> int:
        """Decode rows resident on one dp replica."""
        return max(1, math.ceil(self.batch / max(self.dp, 1)))

    @classmethod
    def for_config(cls, cfg, mesh_shape: Mapping[str, int], *,
                   batch: int = 1) -> "DecodeModel":
        """Derive the signature from a registered model config + mesh.

        Per-layer local params come from ``count_params`` minus the
        embedding/lm_head tables, split across layers and the tp group —
        exact enough for a bandwidth model, with no tracing.
        """
        total = count_params(cfg)
        tp = max(int(getattr(cfg, "tp", 1)), 1)
        dp = 1
        for a, s in mesh_shape.items():
            if a != MODEL_AXIS:
                dp *= int(s)
        tables = 2 * cfg.vocab * cfg.d_model       # embed + lm_head
        layer_total = max(total - tables, 0) // max(cfg.n_layers, 1)
        return cls(
            n_layers=int(cfg.n_layers),
            layer_params_local=math.ceil(layer_total / tp),
            head_params_local=math.ceil(cfg.vocab * cfg.d_model / tp),
            d_model=int(cfg.d_model),
            vocab=int(cfg.vocab),
            tp=tp, dp=max(dp, 1), batch=int(batch))


def _bucket(bid: int, name: str, size: int,
            axes: tuple[str, ...]) -> Bucket:
    return Bucket(
        leaves=(LeafInfo(name=name, index=bid, shape=(size,),
                         dtype=None, size=int(size)),),
        reduce_axes=axes, channel=0, bucket_id=bid)


def plan_decode(model: DecodeModel, *, sampler: str = "topk",
                k_cand: int = 16) -> CommSchedule:
    """One decode token as a CommSchedule (see module docstring).

    The program is a single dependency chain — decode collectives are
    inherently serial per token (each layer's psum feeds the next
    layer's matmul), which is also what makes every rank's issue order
    trivially SPMD-consistent.
    """
    if sampler not in SAMPLERS:
        raise ValueError(
            f"unknown sampler {sampler!r}; expected one of {SAMPLERS}")
    ops: list[CollectiveOp] = []
    bid = 0
    act = model.batch_local * model.d_model    # psum payload per replica

    def emit(kind: str, bucket: Bucket) -> CollectiveOp:
        deps = (ops[-1].op_id,) if ops else ()
        op = CollectiveOp(op_id=len(ops), bucket=bucket, chain=0,
                          depends_on=deps, kind=kind)
        ops.append(op)
        return op

    tp_axes = (MODEL_AXIS,) if model.tp > 1 else ()
    for i in range(model.n_layers):
        emit(DECODE, _bucket(bid, f"layer{i}.params",
                             model.layer_params_local, ()))
        bid += 1
        if tp_axes:
            emit(ALLREDUCE, _bucket(bid, f"layer{i}.attn_out", act,
                                    tp_axes))
            bid += 1
            emit(ALLREDUCE, _bucket(bid, f"layer{i}.ffn_out", act,
                                    tp_axes))
            bid += 1

    emit(DECODE, _bucket(bid, "head.params", model.head_params_local, ()))
    bid += 1

    # the sampler tail: a local candidate-producing DECODE node and the
    # all-gather that moves its payload across the tp group.  The pair
    # shares ONE bucket (the gathered payload), mirroring how training
    # RS/AG pairs share theirs — which is exactly what the accounting
    # pass checks (``ag-no-producer`` / ``rs-ag-asymmetry``).
    rows = model.batch_local
    if sampler == "argmax":
        cand = rows * 2 * max(model.tp, 1)          # (val, idx) per shard
    elif sampler == "topk":
        cand = rows * 2 * k_cand * max(model.tp, 1)
    else:                                           # full-vocab gather
        cand = rows * model.vocab
    payload = _bucket(bid, f"sampler.{sampler}", cand, tp_axes)
    emit(DECODE, payload)
    if tp_axes:
        emit(ALL_GATHER, payload)
    return CommSchedule(ops=tuple(ops))


def simulate_decode(
    schedule: CommSchedule,
    mesh_shape: Mapping[str, int],
    *,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    update: UpdateModel | None = None,
) -> Timeline:
    """One decode token as a discrete-event timeline.

    Decode has no fwd/bwd release ramp — every op is gated purely by its
    dependency chain — so the compute model is idle and DECODE nodes
    carry all compute cost (HBM passes over local param bytes, priced by
    the engine's DECODE branch against ``UpdateModel.hbm_bw``).
    """
    compute = ComputeModel(t_fwd=0.0, t_bwd=0.0,
                           update=update or UpdateModel())
    return simulate(schedule, mesh_shape, compute=compute, net=net,
                    sim=sim)


def rank_decode_plans(
    model: DecodeModel,
    mesh_shape: Mapping[str, int],
    *,
    samplers: Sequence[str] = SAMPLERS,
    k_cand: int = 16,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    update: UpdateModel | None = None,
    verify: bool = True,
) -> list[dict]:
    """Rank sampler-tail variants by simulated per-token latency.

    The decode analogue of ``rank_strategies``: each variant's schedule
    is built, statically verified (deadlock / SPMD / accounting — a
    decode plan is IR like any other), simulated, and scored.  Returns
    dicts sorted fastest-first:

        {"sampler", "token_time", "tokens_per_s", "comm_time",
         "schedule", "timeline", "findings"}
    """
    from repro.analysis.passes import (
        check_accounting,
        check_deadlock,
        check_spmd,
    )

    out: list[dict] = []
    for name in samplers:
        sched = plan_decode(model, sampler=name, k_cand=k_cand)
        findings = []
        if verify:
            findings = (check_deadlock(sched)
                        + check_spmd(sched, mesh_shape)
                        + check_accounting(sched))
            if findings:
                raise ValueError(
                    f"decode plan {name!r} failed static verification:\n"
                    + "\n".join(f.render() for f in findings))
        tl = simulate_decode(sched, mesh_shape, net=net, sim=sim,
                             update=update)
        token_time = tl.step_time
        out.append({
            "sampler": name,
            "token_time": token_time,
            "tokens_per_s": (model.batch / token_time
                             if token_time > 0 else float("inf")),
            "comm_time": tl.total_comm,
            "schedule": sched,
            "timeline": tl,
            "findings": findings,
        })
    out.sort(key=lambda r: r["token_time"])
    return out
