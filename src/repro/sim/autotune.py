"""Cost-model autotuning: simulate every candidate embedding, pick the
winner (DESIGN.md §7).

Two entry points:

  ``rank_strategies``  — simulate every *fixed* registered strategy on one
      BucketPlan and return (name, Timeline) sorted by predicted step
      time.  Strategy semantics come from registry metadata: in-scan
      strategies simulate with per-scan-step releases and no cross-bucket
      chain edges.

  ``grid_search``      — the full strategy × num_channels × bucket_bytes
      sweep over freshly built BucketPlans; returns ranked
      ``Prediction`` rows whose best row is directly a GradSyncConfig
      choice.

Importing this module registers the ``auto`` strategy: a *meta* planner
that simulates all fixed candidates on the plan it is handed and
delegates to the winner's schedule.  ``GradSync`` passes meta strategies
a ``context`` mapping (mesh_shape / reducer / itemsize / compute) so the
simulation sees the real topology; without context the planner falls
back to an 8-way group per axis — still a valid schedule, just a less
calibrated choice.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from repro.core.buckets import Bucket, BucketPlan, make_bucket_plan
from repro.core.pipeline_program import (
    STAGE_AXIS,
    bucket_stage_map,
    compose_step,
    plan_pipeline,
)
from repro.core.registry import (
    fixed_strategy_names,
    get_strategy,
    register_strategy,
)
from repro.core.schedule import UPDATE, CollectiveOp, CommSchedule
from repro.core.stepprogram import zero1_schedule

from repro.sim.compute import ComputeModel, pipeline_timeline
from repro.sim.engine import (
    SimConfig,
    Timeline,
    simulate,
    simulate_pipelined,
)
from repro.sim.netmodel import NetworkModel, default_network


def sim_config_for(name: str, base: SimConfig | None = None, *,
                   in_scan_active: bool = True) -> SimConfig:
    """Map a strategy's registry metadata onto simulator semantics.

    ``in_scan_active=False`` disables the in-scan advantage (per-stage
    releases, no chain edges) even for ``uses_in_scan`` strategies — used
    when the execution being predicted will NOT emit in-scan psums (e.g.
    ``auto`` delegating: the model's ``depcha_in_scan`` keys off the
    configured strategy, so a delegated depcha runs as plain chains)."""
    info = get_strategy(name)
    base = base or SimConfig()
    flag = info.uses_in_scan and in_scan_active
    return dataclasses.replace(
        base, drop_chain_deps=flag, per_stage_release=flag)


def simulate_strategy(
    name: str,
    plan: BucketPlan,
    mesh_shape: Mapping[str, int],
    *,
    compute: ComputeModel | None = None,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    skip_names: frozenset[str] = frozenset(),
    in_scan_active: bool = True,
) -> tuple[CommSchedule, Timeline]:
    """Plan ``name`` on ``plan`` and execute it in the simulator."""
    schedule = get_strategy(name).plan(plan, skip_names=skip_names)
    timeline = simulate(
        schedule, mesh_shape, compute=compute, net=net,
        sim=sim_config_for(name, sim, in_scan_active=in_scan_active))
    return schedule, timeline


def rank_strategies(
    plan: BucketPlan,
    mesh_shape: Mapping[str, int],
    *,
    compute: ComputeModel | None = None,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    skip_names: frozenset[str] = frozenset(),
    strategies: Sequence[str] | None = None,
    in_scan_active: bool = True,
) -> list[tuple[str, Timeline]]:
    """Every fixed strategy's predicted timeline, best first.

    (Whole-step ZeRO-1 rankings live in ``rank_step_plans`` — the
    deferred/zero1/flat family leaderboard ``auto`` consults.)
    """
    names = tuple(strategies) if strategies else fixed_strategy_names()
    out = []
    for name in names:
        _, tl = simulate_strategy(
            name, plan, mesh_shape, compute=compute, net=net, sim=sim,
            skip_names=skip_names, in_scan_active=in_scan_active)
        out.append((name, tl))
    out.sort(key=lambda p: (p[1].step_time, p[0]))
    return out


def flat_step_schedule(
    plan: BucketPlan,
    strategy: str = "concom",
    *,
    skip_names: frozenset[str] = frozenset(),
) -> CommSchedule:
    """The monolithic baseline the StepProgram replaces: the strategy's
    allreduce schedule followed by ONE full-buffer UPDATE op that waits
    on every sync op (the opaque ``optimizer.update`` post-script)."""
    base = get_strategy(strategy).plan(plan, skip_names=skip_names)
    ops = list(base.ops)
    if not ops:
        return base
    tails = {op.op_id for op in ops}
    for op in ops:
        tails -= set(op.depends_on)
    all_leaves = tuple(l for op in ops for l in op.bucket.leaves)
    full = Bucket(leaves=all_leaves, reduce_axes=(),
                  channel=max(op.chain for op in ops) + 1,
                  bucket_id=max(op.bucket.bucket_id for op in ops) + 1,
                  comm_dtype=ops[0].bucket.comm_dtype)
    ops.append(CollectiveOp(
        op_id=max(op.op_id for op in ops) + 1, bucket=full,
        chain=full.channel, depends_on=tuple(sorted(tails)), kind=UPDATE))
    return CommSchedule(tuple(ops)).validate()


def rank_step_plans(
    dp_plan: BucketPlan,
    mesh_shape: Mapping[str, int],
    *,
    dp_axes: tuple[str, ...],
    clip: bool = False,
    compute: ComputeModel | None = None,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    strategies: Sequence[str] | None = None,
    accum: int = 1,
    accum_overlap: bool = True,
    pp: Mapping[str, Any] | None = None,
) -> list[tuple[str, Timeline]]:
    """Step-plan families × strategies, ranked by predicted step time.

    Rows are labelled ``deferred:<strategy>`` (pipelined StepProgram:
    the all-gathers split into a PRE program hidden under the NEXT
    step's forward — simulated in steady state), ``zero1:<strategy>``
    (per-bucket RS→UPDATE→AG triples, same-step) and ``flat:<strategy>``
    (the strategy's allreduce schedule + one full-buffer update) — the
    §9/§10 arc on one leaderboard: same wire bytes, progressively less
    of them exposed.

    ``compute`` is the PER-MICROBATCH model when ``accum`` > 1: the
    M-microbatch accumulation scan is folded in (releases only from the
    final microbatch's backward — during it with ``accum_overlap``, the
    peeled-tail training shape, else at the scan's end), and the
    deferred PRE window is the FIRST microbatch's forward.

    ``pp`` ({"stages", "microbatches", "virtual", "activation_bytes",
    "stage_axis"}) with stages > 1 adds ``pp:<sched>:<strategy>`` rows:
    each fixed pipeline schedule is planned (``plan_pipeline``), costed
    analytically (``pipeline_timeline`` — wire from the NetworkModel's
    p2p hop), composed with the strategy's ZeRO-1 triple
    (``compose_step``) and executed in the engine with per-op release
    times — SEND/RECV gated on their producing slot, sync ops on their
    owning stage's gradient release — so bucket reduce-scatters overlap
    the drain bubble exactly as the joint plan allows.  The pipeline
    wall (compute + bubble + lockstep wire) stands in for ``t_fwd`` so
    a pp row's step_time is max(pipeline wall, sync comm end).
    """
    names = tuple(strategies) if strategies else fixed_strategy_names()
    base_compute = compute or ComputeModel(t_fwd=0.0, t_bwd=0.0)
    eff = base_compute.with_accum(accum, overlap_tail=accum_overlap)
    out: list[tuple[str, Timeline]] = []
    for name in names:
        base = get_strategy(name).plan(dp_plan)
        zs = zero1_schedule(base, dp_axes=tuple(dp_axes), clip=clip)
        scfg = sim_config_for(name, sim, in_scan_active=False)
        out.append((f"zero1:{name}",
                    simulate(zs, mesh_shape, compute=eff, net=net,
                             sim=scfg)))
        fs = flat_step_schedule(dp_plan, name)
        out.append((f"flat:{name}",
                    simulate(fs, mesh_shape, compute=eff, net=net,
                             sim=scfg)))
        zd = zero1_schedule(base, dp_axes=tuple(dp_axes), clip=clip,
                            defer_ag=True)
        post, pre = zd.split_phases()
        out.append((f"deferred:{name}",
                    simulate_pipelined(
                        post, pre, mesh_shape, compute=eff, net=net,
                        sim=scfg, pre_window=base_compute.t_fwd)))
    ppc = dict(pp or {})
    stages = int(ppc.get("stages", 1) or 1)
    if stages > 1:
        virtual = int(ppc.get("virtual", 1) or 1)
        n_mb = int(ppc.get("microbatches") or
                   (accum if accum > 1 else 2 * stages))
        act = int(ppc.get("activation_bytes", 0) or 0)
        axis = ppc.get("stage_axis", STAGE_AXIS)
        # per-microbatch compute scales to the whole step; the pipeline
        # timeline re-splits it into M × S_tot per-stage slots
        whole = (dataclasses.replace(
            base_compute, t_fwd=base_compute.t_fwd * accum,
            t_bwd=base_compute.t_bwd * accum)
            if accum > 1 else base_compute)
        net_ = net or default_network()
        wire = net_.p2p_time(act, axis, mesh_shape)
        scheds = (("gpipe", "1f1b") if virtual == 1 else ("interleaved",))
        for sched in scheds:
            pplan = plan_pipeline(
                stages, n_mb, kind=sched,
                virtual=virtual if sched == "interleaved" else 1,
                activation_bytes=act, stage_axis=axis)
            ptl = pipeline_timeline(pplan, whole, wire_time=wire)
            for name in names:
                base = get_strategy(name).plan(dp_plan)
                zs = zero1_schedule(base, dp_axes=tuple(dp_axes),
                                    clip=clip)
                joint, id_map = compose_step(pplan, zs)
                stage_of = bucket_stage_map(pplan, zs)
                last = max(ptl.stage_grad_release)
                rel = dict(ptl.op_release)
                for op in zs.ops:
                    s = stage_of.get(op.bucket.bucket_id)
                    rel[id_map[op.op_id]] = (
                        ptl.stage_grad_release[s] if s is not None
                        else last)
                cm = dataclasses.replace(
                    whole, t_fwd=ptl.wall, t_bwd=0.0)
                scfg = sim_config_for(name, sim, in_scan_active=False)
                out.append((f"pp:{sched}:{name}",
                            simulate(joint, mesh_shape, compute=cm,
                                     net=net, sim=scfg,
                                     release_times=rel)))
    out.sort(key=lambda p: (p[1].step_time, p[0]))
    return out


def choose_pp_schedule(
    n_stages: int,
    n_microbatches: int,
    *,
    virtual: int = 1,
    activation_bytes: int = 0,
    compute: ComputeModel | None = None,
    net: NetworkModel | None = None,
    mesh_shape: Mapping[str, int] | None = None,
    stage_axis: str = STAGE_AXIS,
) -> str:
    """The executed counterpart of the ``pp:<sched>`` ranking: argmin of
    the analytic pipeline wall over the fixed schedules a runtime with
    ``pp_schedule="auto"`` could execute.  By construction the choice is
    never worse than any fixed schedule under the same cost model (ties
    break lexicographically — "1f1b" before "gpipe")."""
    net = net or default_network()
    shape = dict(mesh_shape or {stage_axis: n_stages})
    wire = net.p2p_time(activation_bytes, stage_axis, shape)
    cm = compute or ComputeModel(t_fwd=1.0, t_bwd=2.0)
    cands = ("gpipe", "1f1b") if virtual == 1 else ("interleaved",)

    def wall(kind: str) -> float:
        pplan = plan_pipeline(
            n_stages, n_microbatches, kind=kind,
            virtual=virtual if kind == "interleaved" else 1,
            activation_bytes=activation_bytes, stage_axis=stage_axis)
        return pipeline_timeline(pplan, cm, wire_time=wire).wall

    return min(cands, key=lambda k: (wall(k), k))


# ------------------------------------------------------------------ auto

# the last auto decision, for introspection (CLI/benchmarks/tests)
_LAST_AUTO: dict[str, Any] = {}


def last_auto_report() -> dict[str, Any]:
    """{"winner": name, "ranking": [(name, step_time), ...]} of the most
    recent ``auto`` plan; empty before the first plan."""
    return dict(_LAST_AUTO)


def _resolve_network(ctx: Mapping[str, Any],
                     mesh_shape: Mapping[str, int]):
    """The NetworkModel ``auto`` ranks with, by preference: one passed
    in the context, else a calibrated per-mesh profile fitted from
    measured runs (``repro.obs.calibrate``, ``python -m repro.obs
    --fit``), else the built-in defaults.  Returns (net_or_None,
    source_tag) — the tag lands in ``last_auto_report()["net"]`` so a
    plan is auditable about which cost model chose its winner."""
    net = ctx.get("net")
    if net is not None:
        return net, "context"
    try:
        from repro.obs.calibrate import fitted_network

        net, path = fitted_network(mesh_shape)
    except Exception:
        net, path = None, None
    if net is not None:
        return net, f"fitted:{path}"
    return None, "default"


def _candidates(reducer: str) -> tuple[str, ...]:
    # two-phase strategies emit raw RS/AG ops that would silently ignore
    # a non-flat reducer (same rule GradSync enforces) — not candidates
    return tuple(
        n for n in fixed_strategy_names()
        if reducer == "flat" or not get_strategy(n).two_phase)


@register_strategy(
    "auto", meta=True,
    doc="simulate every fixed strategy, delegate to the predicted winner")
def plan_auto(
    plan: BucketPlan,
    *,
    skip_names: frozenset[str] = frozenset(),
    context: Mapping[str, Any] | None = None,
) -> CommSchedule:
    """Plan by simulation: run every fixed candidate through the
    discrete-event engine on this exact BucketPlan, return the winner's
    schedule.  ``context`` (supplied by GradSync for meta strategies)
    carries mesh_shape / reducer / itemsize / an optional ComputeModel.

    When GradSync is planning a ZeRO-1 StepProgram it adds a ``zero1``
    mapping ({"dp_axes", "dp_size", "clip", "defer"}) — the candidates
    are then ranked via ``rank_step_plans`` across ALL THREE step-plan
    families (``deferred:<s>`` / ``zero1:<s>`` / ``flat:<s>``, UPDATE
    ops costed, the deferred rows in pipelined steady state).  ``auto``
    delegates to the best strategy WITHIN the family the caller will
    execute (``defer`` → the pipelined rows, else the same-step zero1
    rows — a deferred-only win must not pick a strategy the scheduled
    execution can't realize); that family lands in
    ``last_auto_report()["plan"]`` and the full ranking in the report."""
    ctx = dict(context or {})
    mesh_shape = ctx.get("mesh_shape") or {
        a: 8 for b in plan.buckets for a in b.reduce_axes}
    reducer = ctx.get("reducer", "flat")
    sim = SimConfig(itemsize=int(ctx.get("itemsize", 4)), reducer=reducer,
                    fused_staging=bool(ctx.get("fused_staging", True)))
    net, net_source = _resolve_network(ctx, mesh_shape)
    zero1 = ctx.get("zero1")
    if zero1 is not None:
        pp = dict(ctx.get("pp") or {})
        pp_stages = int(pp.get("stages", 1) or 1)
        ranked = rank_step_plans(
            plan, mesh_shape, dp_axes=tuple(zero1["dp_axes"]),
            clip=bool(zero1.get("clip", False)),
            compute=ctx.get("compute"), net=net, sim=sim,
            accum=int(zero1.get("accum", 1)),
            accum_overlap=bool(zero1.get("accum_overlap", True)),
            pp=pp if pp_stages > 1 else None)
        # the winner must come from the family the caller will EXECUTE
        # (pipeline context → the joint pp rows, narrowed to the fixed
        # schedule when one is pinned — "auto" spans all of them, so it
        # can never rank worse than the best fixed row; otherwise
        # zero1_plan="deferred" → pipelined rows, else same-step rows);
        # the full ranking stays in the report for visibility,
        # including the flat baseline no zero1 run executes
        pp_sched = None
        if pp_stages > 1:
            sched = pp.get("schedule") or "auto"
            prefix = "pp:" if sched == "auto" else f"pp:{sched}:"
            row = next(n for n, _ in ranked if n.startswith(prefix))
            _, pp_sched, winner = row.split(":", 2)
            family = f"pp:{pp_sched}"
        else:
            family = "deferred" if zero1.get("defer") else "zero1"
            winner = next(n for n, _ in ranked
                          if n.startswith(family + ":")).split(":", 1)[1]
        _LAST_AUTO.clear()
        _LAST_AUTO.update({
            "winner": winner,
            "plan": family,
            "ranking": [(n, tl.step_time) for n, tl in ranked],
            "zero1": True,
            "net": net_source,
            **({"pp_schedule": pp_sched} if pp_sched else {}),
        })
        return get_strategy(winner).plan(plan, skip_names=skip_names)
    # in-scan psums are keyed on the CONFIGURED strategy, so a delegated
    # depcha runs as plain chains — rank it with the semantics the
    # delegated execution can actually realize (in-scan only counts when
    # the caller really dropped in-scan leaves from this plan)
    ranked = rank_strategies(
        plan, mesh_shape,
        compute=ctx.get("compute"), net=net, sim=sim,
        skip_names=skip_names,
        strategies=_candidates(reducer),
        in_scan_active=bool(skip_names))
    winner = ranked[0][0]
    _LAST_AUTO.clear()
    _LAST_AUTO.update({
        "winner": winner,
        "ranking": [(n, tl.step_time) for n, tl in ranked],
        "zero1": False,
        "net": net_source,
    })
    return get_strategy(winner).plan(plan, skip_names=skip_names)


# ----------------------------------------------------------- grid search

@dataclasses.dataclass(frozen=True)
class Prediction:
    """One grid cell: a (strategy, channels, bucket size) candidate and
    its simulated outcome."""

    strategy: str
    num_channels: int
    bucket_bytes: int
    step_time: float
    exposed_comm: float
    overlap_fraction: float
    num_ops: int

    def as_row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def grid_search(
    grads_like: Any,
    param_specs: Any,
    mesh,
    *,
    mesh_shape: Mapping[str, int],
    compute: ComputeModel | None = None,
    net: NetworkModel | None = None,
    sim: SimConfig | None = None,
    strategies: Sequence[str] | None = None,
    channels: Sequence[int] = (1, 2, 4, 8),
    bucket_bytes: Sequence[int] = (1 << 20, 4 << 20, 16 << 20),
    comm_dtype=None,
    skip_names: frozenset[str] = frozenset(),
) -> list[Prediction]:
    """Simulate the full strategy × num_channels × bucket_bytes grid.

    Returns predictions sorted best-first; ``[0]`` is the tuned choice
    (its fields map 1:1 onto GradSyncConfig).  Single-chain strategies
    collapse the channel dimension (their plan ignores channels).
    """
    import jax.numpy as jnp

    net = net or default_network()
    names = tuple(strategies) if strategies else fixed_strategy_names()
    out: list[Prediction] = []
    for bb in bucket_bytes:
        for ch in channels:
            plan = make_bucket_plan(
                grads_like, param_specs, mesh,
                bucket_bytes=bb, num_channels=ch,
                comm_dtype=comm_dtype if comm_dtype is not None
                else jnp.float32)
            for name in names:
                if get_strategy(name).single_chain and ch != channels[0]:
                    continue        # funnel ignores channels: sim once
                _, tl = simulate_strategy(
                    name, plan, mesh_shape, compute=compute, net=net,
                    sim=sim, skip_names=skip_names)
                out.append(Prediction(
                    strategy=name, num_channels=ch, bucket_bytes=bb,
                    step_time=tl.step_time, exposed_comm=tl.exposed_comm,
                    overlap_fraction=tl.overlap_fraction,
                    num_ops=len(tl.events)))
    out.sort(key=lambda p: (p.step_time, p.strategy,
                            p.num_channels, p.bucket_bytes))
    return out
