"""Timeline exports: Chrome-trace JSON and a terminal ASCII render.

``chrome_trace_events`` emits the chrome://tracing / Perfetto format
("traceEvents" with phase "X" complete events, microsecond timestamps):
one process row per simulated strategy, one thread row per dependency
chain, plus a compute track showing forward/backward so overlap is
visible at a glance.
"""
from __future__ import annotations

import json
from typing import Any, Mapping

from repro.sim.engine import Timeline

_US = 1e6


def chrome_trace_events(timeline: Timeline, *, pid: int = 0,
                        label: str = "schedule") -> list[dict[str, Any]]:
    ev: list[dict[str, Any]] = [
        {"ph": "M", "pid": pid, "name": "process_name",
         "args": {"name": label}},
        {"ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
         "args": {"name": "compute"}},
    ]
    if timeline.t_fwd > 0:
        ev.append({"ph": "X", "pid": pid, "tid": 0, "name": "forward",
                   "ts": 0.0, "dur": timeline.t_fwd * _US})
    if timeline.t_bwd > 0:
        ev.append({"ph": "X", "pid": pid, "tid": 0, "name": "backward",
                   "ts": timeline.t_fwd * _US, "dur": timeline.t_bwd * _US})
    for ch in sorted({e.chain for e in timeline.events}):
        ev.append({"ph": "M", "pid": pid, "tid": ch + 1,
                   "name": "thread_name",
                   "args": {"name": f"chain {ch}"}})
    for e in timeline.events:
        ev.append({
            "ph": "X", "pid": pid, "tid": e.chain + 1,
            "name": f"{e.kind}:b{e.bucket_id}",
            "ts": e.start * _US, "dur": e.duration * _US,
            "args": {"op_id": e.op_id, "bytes": e.nbytes,
                     "release": e.release * _US},
        })
    return ev


def chrome_trace(timelines: Mapping[str, Timeline]) -> dict[str, Any]:
    """Multiple strategies side by side (one pid per strategy)."""
    events: list[dict[str, Any]] = []
    for pid, (name, tl) in enumerate(sorted(timelines.items())):
        events.extend(chrome_trace_events(tl, pid=pid, label=name))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, timelines: Mapping[str, Timeline]) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(timelines), f)


def ascii_timeline(timeline: Timeline, *, width: int = 64) -> str:
    """Per-chain bars on a shared time axis (for terminal output)."""
    span = max(timeline.step_time, 1e-12)
    scale = width / span
    lines = []
    cend = min(width, int(round(timeline.compute_end * scale)))
    lines.append("compute  |" + "=" * cend + " " * (width - cend) + "|")
    chains: dict[int, list] = {}
    for e in timeline.events:
        chains.setdefault(e.chain, []).append(e)
    for ch in sorted(chains):
        row = [" "] * width
        for e in chains[ch]:
            a = min(width - 1, int(e.start * scale))
            b = min(width, max(a + 1, int(round(e.end * scale))))
            glyph = {"allreduce": "#", "reduce_scatter": "<",
                     "all_gather": ">"}.get(e.kind, "#")
            for i in range(a, b):
                row[i] = glyph
        lines.append(f"chain {ch:>2} |" + "".join(row) + "|")
    lines.append(
        f"step {timeline.step_time * 1e3:.3f} ms  "
        f"exposed {timeline.exposed_comm * 1e3:.3f} ms  "
        f"overlap {timeline.overlap_fraction * 100:.0f}%")
    return "\n".join(lines)
