"""Alpha-beta collective cost models over the mesh topology (DESIGN.md §7).

Shi et al.'s DAG model of S-SGD (arXiv 1805.03812) predicts collective
timelines from ``t = steps · (alpha + shard_bytes / beta)`` per ring step;
we instantiate that per mesh axis so a collective over ("pod", "data")
pays DCN latency/bandwidth on the "pod" hops and ICI on the "data" hops.

Everything here is pure Python over numbers — no jax, no devices — so a
full strategy × channels × bucket-size sweep simulates in milliseconds.

Cost conventions (ring algorithm over group ``g`` with ``n`` bytes):
  allreduce       2(g-1) steps, shard n/g          (reduce-scatter + all-gather)
  reduce_scatter   (g-1) steps, shard n/g
  all_gather       (g-1) steps, shard n/g
  all_to_all       (g-1) steps, shard n/g
Multi-axis groups decompose axis-by-axis (fastest link first), the exact
lowering of a flat psum over a product group: full payload rides every
tier.  The *hierarchical* reducer instead reduce-scatters over the fast
tier first, so only 1/g_fast of the payload crosses the slow tier; the
*compressed* reducer moves ~n/4 wire bytes (int8 + block scales) plus two
HBM-bound quantize passes — both reproduce the cost structure of the real
reducers in ``repro.core``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.sim.compute import StagingModel

# compression wire format (mirrors repro.core.compression)
_COMP_BLOCK = 256          # elements per scale block
_COMP_RATIO = 0.25 + 4.0 / (4 * _COMP_BLOCK)   # int8 + f32 scale per block


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One interconnect tier: per-hop latency (alpha) + bandwidth (beta)."""

    name: str
    bandwidth: float     # bytes/s per device per direction
    latency: float       # seconds per ring step


# TPU-flavoured defaults (v5e-era numbers, same source as benchmarks/
# roofline.py): ICI within a pod, DCN between pods.
ICI = LinkModel("ici", bandwidth=4.5e10, latency=1e-6)
DCN = LinkModel("dcn", bandwidth=2.5e9, latency=25e-6)


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Mesh-axis → link-tier map plus reducer-specific cost overheads."""

    links: tuple[tuple[str, LinkModel], ...] = (("pod", DCN),)
    default_link: LinkModel = ICI
    quantize_bw: float = 819e9   # bytes/s; HBM-bound quantize/dequant pass
    staging: StagingModel = StagingModel()   # CopyFromTo pack/unpack cost

    def link(self, axis: str) -> LinkModel:
        for name, lk in self.links:
            if name == axis:
                return lk
        return self.default_link

    # ------------------------------------------------------------ rings
    def _ring(self, nbytes: float, group: int, link: LinkModel,
              steps_factor: float) -> float:
        if group <= 1:
            return 0.0
        steps = steps_factor * (group - 1)
        return steps * (link.latency + (nbytes / group) / link.bandwidth)

    def _axis_groups(self, axes: tuple[str, ...],
                     mesh_shape: Mapping[str, int]) -> list[tuple[str, int]]:
        """(axis, size) with size>1, fastest link first (per-axis rings
        run back-to-back; order only matters for shrinking payloads)."""
        out = [(a, int(mesh_shape.get(a, 1))) for a in axes
               if int(mesh_shape.get(a, 1)) > 1]
        return sorted(out, key=lambda p: -self.link(p[0]).bandwidth)

    # ------------------------------------------------------ collectives
    def allreduce_time(self, nbytes: float, axes: tuple[str, ...],
                       mesh_shape: Mapping[str, int], *,
                       reducer: str = "flat") -> float:
        groups = self._axis_groups(axes, mesh_shape)
        if not groups:
            return 0.0
        # prefix match: the *_ring variants move the same wire bytes on
        # the same tiers — kernel ownership changes who issues the DMAs,
        # not the alpha-beta schedule (same rule as the "ring" reducer)
        if reducer.startswith("hierarchical"):
            t = self._hierarchical_time(nbytes, groups)
            if t is not None:
                return t
        if reducer.startswith("compressed"):
            t = self._compressed_time(nbytes, groups)
            if t is not None:
                return t
        # flat psum over the product group: full payload on every tier
        return sum(self._ring(nbytes, g, self.link(a), 2.0)
                   for a, g in groups)

    def reduce_scatter_time(self, nbytes: float, axes: tuple[str, ...],
                            mesh_shape: Mapping[str, int]) -> float:
        t, n = 0.0, float(nbytes)
        for a, g in self._axis_groups(axes, mesh_shape):
            t += self._ring(n, g, self.link(a), 1.0)
            n /= g                      # each tier shrinks the shard
        return t

    def all_gather_time(self, nbytes: float, axes: tuple[str, ...],
                        mesh_shape: Mapping[str, int]) -> float:
        # mirror image of reduce_scatter: payload grows tier by tier, so
        # the total is identical — computed the same way for clarity
        return self.reduce_scatter_time(nbytes, axes, mesh_shape)

    def all_to_all_time(self, nbytes: float, axes: tuple[str, ...],
                        mesh_shape: Mapping[str, int]) -> float:
        return sum(self._ring(nbytes, g, self.link(a), 1.0)
                   for a, g in self._axis_groups(axes, mesh_shape))

    def p2p_time(self, nbytes: float, axis: str,
                 mesh_shape: Mapping[str, int]) -> float:
        """One point-to-point hop along ``axis`` (a pipeline SEND/RECV
        pair's ppermute): single alpha plus the full payload once —
        every rank sends to its neighbor concurrently, so the ring
        step count is 1 regardless of the axis extent."""
        if int(mesh_shape.get(axis, 1)) <= 1:
            return 0.0
        lk = self.link(axis)
        return lk.latency + nbytes / lk.bandwidth

    # ------------------------------------------------- reducer variants
    def _hierarchical_time(self, nbytes: float,
                           groups: list[tuple[str, int]]) -> float | None:
        """RS(fast tiers) → AR(slow tiers, 1/g_fast payload) → AG(fast)."""
        fast_bw = max(self.link(a).bandwidth for a, _ in groups)
        fast = [(a, g) for a, g in groups
                if self.link(a).bandwidth >= fast_bw]
        slow = [(a, g) for a, g in groups
                if self.link(a).bandwidth < fast_bw]
        if not slow:
            return None                 # single tier: same as flat
        g_fast = 1
        t, n = 0.0, float(nbytes)
        for a, g in fast:
            t += self._ring(n, g, self.link(a), 1.0)   # reduce-scatter
            n /= g
            g_fast *= g
        for a, g in slow:
            t += self._ring(n, g, self.link(a), 2.0)   # allreduce shard
        for a, g in reversed(fast):
            n *= g
            t += self._ring(n, g, self.link(a), 1.0)   # all-gather
        return t

    def _compressed_time(self, nbytes: float,
                         groups: list[tuple[str, int]]) -> float | None:
        """quantize → all-to-all int8 → local reduce → requantize →
        all-gather int8 (repro.core.compression's two-phase scheme)."""
        g = 1
        for _, s in groups:
            g *= s
        # the real reducer falls back to flat psum for small buffers
        if nbytes < 4 * _COMP_BLOCK * g:
            return None
        wire = nbytes * _COMP_RATIO
        t = sum(self._ring(wire, gg, self.link(a), 1.0) for a, gg in groups)
        t += sum(self._ring(wire, gg, self.link(a), 1.0)
                 for a, gg in groups)   # all-gather phase, same volume
        t += 3.0 * nbytes / self.quantize_bw   # 2×quantize + 1×dequant
        return t

    def collective_time(self, kind: str, nbytes: float,
                        axes: tuple[str, ...],
                        mesh_shape: Mapping[str, int], *,
                        reducer: str = "flat") -> float:
        """Dispatch on the CommSchedule op kind (schedule.py constants).

        The ``ring`` reducer costs as flat: the alpha-beta ring IS this
        model's assumed algorithm — owning it at the kernel level changes
        who issues the DMAs, not the wire schedule."""
        if kind == "allreduce":
            return self.allreduce_time(nbytes, axes, mesh_shape,
                                       reducer=reducer)
        if kind == "reduce_scatter":
            return self.reduce_scatter_time(nbytes, axes, mesh_shape)
        if kind == "all_gather":
            return self.all_gather_time(nbytes, axes, mesh_shape)
        if kind in ("send", "recv"):
            return self.p2p_time(nbytes, axes[0] if axes else "stage",
                                 mesh_shape)
        raise ValueError(f"unknown collective kind {kind!r}")

    def staging_time(self, kind: str, nbytes: float, num_leaves: int, *,
                     fused: bool = True) -> float:
        """CopyFromTo cost around one CommSchedule op: allreduce pays
        pack AND unpack; a reduce-scatter only packs, an all-gather only
        unpacks (the RS/AG pair splits the round trip; same split for a
        SEND/RECV pair — pack at the SEND, unpack at the RECV)."""
        one = self.staging.stage_time(nbytes, num_leaves, fused=fused)
        if kind == "allreduce":
            return 2.0 * one
        if kind in ("reduce_scatter", "all_gather", "send", "recv"):
            return one
        raise ValueError(f"unknown collective kind {kind!r}")


def default_network() -> NetworkModel:
    """The standard topology: "pod" rides DCN, every other axis ICI."""
    return NetworkModel()
