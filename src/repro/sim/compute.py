"""Per-bucket compute timing: when does each gradient bucket become
ready during the backward pass? (DESIGN.md §7)

The simulator needs two things from the compute side:
  - ``t_fwd`` / ``t_bwd`` — step-level forward/backward durations, derived
    from model FLOPs (``repro.configs``/``repro.models``) and a hardware
    model (same v5e numbers as benchmarks/roofline.py);
  - per-bucket *release times* — buckets are created in gradient-ready
    order (``make_bucket_plan(reverse=True)``), so bucket ``i`` is
    released once its cumulative share of the backward has run.  For
    in-scan strategies (depcha) releases snap to scan-step boundaries:
    the psum is emitted at the END of its layer's backward step.

FLOP models (forward, whole step):
  LM families      2 · params · tokens           (dense matmul bound)
  conv families    params · img² / 256 · images  (calibrated on the
                   paper's models: ResNet-50/CIFAR → ~1.0e8 flops/image,
                   ResNet-50/ImageNet → ~5e9, cf. benchmarks/
                   paper_figures.py measured 1.0e8 / 4.1e9)
Backward ≈ 2 × forward throughout (the standard 1:2 fwd:bwd split).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Per-chip compute throughput (v5e, same source as roofline.py)."""

    peak_flops: float = 197e12
    mfu: float = 0.4             # realistic matmul utilization

    @property
    def flops(self) -> float:
        return self.peak_flops * self.mfu


@dataclasses.dataclass(frozen=True)
class StagingModel:
    """HBM cost of CopyFromTo staging (pack/unpack around a collective).

    Staging is compute-side work — a local HBM pass per direction — not
    network time, so it lives here next to the FLOP model.  Two modes
    (DESIGN.md §8):

      fused     — ONE kernel per direction reads the leaves and writes
                  the cast (+loss-scaled) comm buffer: ~2·nbytes of HBM
                  traffic and a single launch.
      leafwise  — per-leaf ravel+cast then concatenate (and per-leaf
                  slice+cast back): two passes over the payload
                  (~4·nbytes) plus one copy op PER LEAF.
    """

    hbm_bw: float = 819e9        # bytes/s (same v5e source as quantize_bw)
    leaf_overhead: float = 0.5e-6  # per copy-op dispatch/launch cost
    fused_passes: float = 2.0      # read + write, once
    leafwise_passes: float = 4.0   # cast pass + concatenate pass

    def stage_time(self, nbytes: float, num_leaves: int, *,
                   fused: bool) -> float:
        """One direction (pack OR unpack) of one bucket's staging."""
        passes = self.fused_passes if fused else self.leafwise_passes
        ops = 1 if fused else max(int(num_leaves), 1)
        return passes * nbytes / self.hbm_bw + ops * self.leaf_overhead


@dataclasses.dataclass(frozen=True)
class UpdateModel:
    """HBM cost of one scheduled optimizer UPDATE op (DESIGN.md §9).

    The sharded update is pure elementwise math: read the gradient
    shard, the param shard and the optimizer moments, write the update
    and the new moments — ~7 passes over the shard for AdamW-class
    optimizers — plus a dispatch overhead.  ZeRO-1 shrinks the shard by
    the dp group size, which is exactly what this model prices against
    the monolithic full-buffer update.
    """

    hbm_bw: float = 819e9        # bytes/s (same v5e source as staging)
    passes: float = 7.0          # g, p, m, v reads + u, m, v writes
    overhead: float = 2e-6       # per-op dispatch/launch cost

    def update_time(self, shard_bytes: float) -> float:
        return self.passes * shard_bytes / self.hbm_bw + self.overhead


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """Step-level compute durations + bucket release-time policy."""

    t_fwd: float
    t_bwd: float
    n_stages: int = 1        # backward scan steps (layers); release grain
    update: UpdateModel = UpdateModel()   # UPDATE-op (shard math) cost

    def bucket_release_times(
        self,
        bucket_sizes: Sequence[tuple[int, int]],
        *,
        per_stage: bool = False,
    ) -> dict[int, float]:
        """bucket_id → time its gradients exist.

        ``bucket_sizes`` is (bucket_id, elems); bucket_ids ascend in
        gradient-ready order (the bucketer's creation order).  With
        ``per_stage`` the release snaps up to the owning scan step's end
        (in-scan psums are emitted per layer, not per element).
        """
        total = sum(s for _, s in bucket_sizes)
        if total <= 0:
            return {bid: self.t_fwd for bid, _ in bucket_sizes}
        out: dict[int, float] = {}
        cum = 0
        for bid, size in sorted(bucket_sizes):
            cum += size
            frac = cum / total
            if per_stage and self.n_stages > 1:
                frac = math.ceil(frac * self.n_stages) / self.n_stages
            out[bid] = self.t_fwd + self.t_bwd * frac
        return out

    @property
    def end(self) -> float:
        return self.t_fwd + self.t_bwd

    def with_accum(self, accum: int, *,
                   overlap_tail: bool = True) -> "ComputeModel":
        """Fold an M-microbatch gradient-accumulation scan into the step.

        ``self`` is the PER-MICROBATCH model.  The returned model spans
        the whole M-microbatch step: with ``overlap_tail`` (the peeled
        final microbatch of DESIGN.md §10) the first M-1 microbatches
        become pure head compute and releases happen during the FINAL
        microbatch's backward — the only place the runtime can emit
        them, since the accumulated gradients do not exist earlier.
        Without it (plain scan) every release waits for the entire scan.
        """
        if accum <= 1:
            return self
        micro = self.t_fwd + self.t_bwd
        if overlap_tail:
            return dataclasses.replace(
                self, t_fwd=(accum - 1) * micro + self.t_fwd)
        return dataclasses.replace(self, t_fwd=accum * micro, t_bwd=0.0)


@dataclasses.dataclass(frozen=True)
class PipelineTimeline:
    """One pipeline step's analytic timeline (DESIGN.md §15).

    ``op_release`` maps every SEND/RECV op of the plan to the time its
    payload exists (the producing slot's compute end) — feed it to
    ``sim.engine.simulate(release_times=...)`` so the rendezvous pairs
    start no earlier than the stage compute that produces them.
    ``stage_grad_release`` is per GLOBAL stage: the end of that stage's
    final backward slot, i.e. when its gradients exist and the bucket
    reduce-scatters wired by ``compose_step`` may begin.
    """

    wall: float              # last slot (or lockstep wave) retires
    fwd_wall: float          # forward phase wall (gpipe: flush point)
    pure_compute: float      # per-device compute alone (no bubble/wire)
    op_release: dict         # op_id -> payload-ready time
    stage_grad_release: tuple[float, ...]   # per global stage

    @property
    def bubble_fraction(self) -> float:
        """Idle share of the wall: 1 − pure_compute / wall.  For the
        lockstep GPipe model at wire_time=0 this is exactly
        (S−1)/(M+S−1)."""
        if self.wall <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.pure_compute / self.wall)


def pipeline_timeline(plan, compute: ComputeModel, *,
                      wire_time: float = 0.0) -> PipelineTimeline:
    """Cost a ``core.pipeline_program.PipelinePlan`` against ``compute``.

    ``compute.t_fwd``/``t_bwd`` are WHOLE-step durations; each of the
    ``M × S_tot`` forward (backward) slots takes an even share.
    ``wire_time`` is one boundary crossing (the SEND/RECV ppermute hop,
    priced by ``NetworkModel.p2p_time`` — passed as a number so this
    module stays import-free of the network side).

    gpipe        lockstep wave model: every wave is ``t_slot + wire``
                 across all stages (the executed wave pipeline's scan
                 step IS a ppermute barrier), so the wire rides the
                 critical path of every wave.
    1f1b /       deterministic replay of ``plan.commits`` with real
    interleaved  durations: a slot starts at max(device clock, input
                 arrival), where arrivals pay ``wire_time`` once per
                 boundary crossing.  In steady state the transfer
                 overlaps the neighbor's compute — the source of the
                 measured 1F1B win beyond the shorter drain.
    """
    S, M, v = plan.n_stages, plan.n_microbatches, plan.virtual
    S_tot = S * v
    slots = max(1, M * S_tot)
    tf = compute.t_fwd / slots
    tb = compute.t_bwd / slots
    w = max(0.0, float(wire_time))
    pure = M * v * (tf + tb)      # one device's share: v stages × M slots

    slot_end: dict[tuple[str, int, int], float] = {}
    if plan.kind == "gpipe":
        wave_f, wave_b = tf + w, tb + w
        t_flush = (M + S - 1) * wave_f
        for m in range(M):
            for g in range(S):
                slot_end[("F", g, m)] = (g + m) * wave_f + tf
                slot_end[("B", g, m)] = (
                    t_flush + ((S - 1 - g) + m) * wave_b + tb)
        fwd_wall = t_flush
        wall = t_flush + (M + S - 1) * wave_b
    else:
        dev_clock = [0.0] * S
        fwd_wall = wall = 0.0
        for dev, slot in plan.commits:
            g, m = slot.stage, slot.mb
            if slot.phase == "F":
                ready = (0.0 if g == 0
                         else slot_end[("F", g - 1, m)] + w)
                end = max(dev_clock[dev], ready) + tf
                fwd_wall = max(fwd_wall, end)
            else:
                ready = (slot_end[("F", g, m)] if g == S_tot - 1
                         else slot_end[("B", g + 1, m)] + w)
                end = max(dev_clock[dev], ready) + tb
            slot_end[(slot.phase, g, m)] = end
            dev_clock[dev] = end
            wall = max(wall, end)

    # SEND and its RECV both release when the producing slot's compute
    # ends: for a forward boundary that is F(g, m) itself; for a
    # backward boundary the producing slot is the CONSUMER-side mapping
    # recorded in op_slot (send slots produce, recv slots consume — the
    # recv still cannot fire before the payload exists, which the
    # paired-send release plus the SEND→RECV data edge enforces).
    op_release: dict[int, float] = {}
    for op_id, (role, slot) in plan.op_slot.items():
        g, m = slot.stage, slot.mb
        if role == "send":
            op_release[op_id] = slot_end[(slot.phase, g, m)]
        else:
            src = (("F", g - 1, m) if slot.phase == "F"
                   else ("B", g + 1, m))
            op_release[op_id] = slot_end[src]

    grad_release = tuple(slot_end[("B", g, M - 1)] for g in range(S_tot))
    return PipelineTimeline(
        wall=wall, fwd_wall=fwd_wall, pure_compute=pure,
        op_release=op_release, stage_grad_release=grad_release)


def count_params(cfg) -> int:
    """Total parameter elements via eval_shape (no device allocation)."""
    import jax

    from repro.configs.base import param_structs

    return sum(
        int(math.prod(l.shape)) if l.shape else 1
        for l in jax.tree_util.tree_leaves(param_structs(cfg)))


def fwd_flops(cfg, *, global_batch: int, seq_len: int,
              params: int | None = None) -> float:
    """Whole-step forward FLOPs for any registered model family."""
    from repro.models.registry import family_of

    p = params if params is not None else count_params(cfg)
    family = family_of(cfg).family
    if family in ("resnet", "inception"):
        return p * (cfg.img_size ** 2) / 256.0 * global_batch
    return 2.0 * p * global_batch * max(seq_len, 1)


def n_stages_of(cfg) -> int:
    """Backward scan steps: layers for scanned families, stages for convnets."""
    n = getattr(cfg, "n_layers", None)
    if n:
        return int(n)
    stages = getattr(cfg, "stages", None)
    if stages:
        return int(sum(stages))
    return 1


def compute_model_for(cfg, *, global_batch: int, seq_len: int,
                      n_devices: int,
                      hw: HardwareModel | None = None) -> ComputeModel:
    """Derive the step's compute timeline from model FLOPs and the mesh
    size (compute is data-parallel: per-device share of the step)."""
    hw = hw or HardwareModel()
    f = fwd_flops(cfg, global_batch=global_batch, seq_len=seq_len)
    t_fwd = f / (max(n_devices, 1) * hw.flops)
    return ComputeModel(t_fwd=t_fwd, t_bwd=2.0 * t_fwd,
                        n_stages=n_stages_of(cfg))
