"""Per-bucket compute timing: when does each gradient bucket become
ready during the backward pass? (DESIGN.md §7)

The simulator needs two things from the compute side:
  - ``t_fwd`` / ``t_bwd`` — step-level forward/backward durations, derived
    from model FLOPs (``repro.configs``/``repro.models``) and a hardware
    model (same v5e numbers as benchmarks/roofline.py);
  - per-bucket *release times* — buckets are created in gradient-ready
    order (``make_bucket_plan(reverse=True)``), so bucket ``i`` is
    released once its cumulative share of the backward has run.  For
    in-scan strategies (depcha) releases snap to scan-step boundaries:
    the psum is emitted at the END of its layer's backward step.

FLOP models (forward, whole step):
  LM families      2 · params · tokens           (dense matmul bound)
  conv families    params · img² / 256 · images  (calibrated on the
                   paper's models: ResNet-50/CIFAR → ~1.0e8 flops/image,
                   ResNet-50/ImageNet → ~5e9, cf. benchmarks/
                   paper_figures.py measured 1.0e8 / 4.1e9)
Backward ≈ 2 × forward throughout (the standard 1:2 fwd:bwd split).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Per-chip compute throughput (v5e, same source as roofline.py)."""

    peak_flops: float = 197e12
    mfu: float = 0.4             # realistic matmul utilization

    @property
    def flops(self) -> float:
        return self.peak_flops * self.mfu


@dataclasses.dataclass(frozen=True)
class StagingModel:
    """HBM cost of CopyFromTo staging (pack/unpack around a collective).

    Staging is compute-side work — a local HBM pass per direction — not
    network time, so it lives here next to the FLOP model.  Two modes
    (DESIGN.md §8):

      fused     — ONE kernel per direction reads the leaves and writes
                  the cast (+loss-scaled) comm buffer: ~2·nbytes of HBM
                  traffic and a single launch.
      leafwise  — per-leaf ravel+cast then concatenate (and per-leaf
                  slice+cast back): two passes over the payload
                  (~4·nbytes) plus one copy op PER LEAF.
    """

    hbm_bw: float = 819e9        # bytes/s (same v5e source as quantize_bw)
    leaf_overhead: float = 0.5e-6  # per copy-op dispatch/launch cost
    fused_passes: float = 2.0      # read + write, once
    leafwise_passes: float = 4.0   # cast pass + concatenate pass

    def stage_time(self, nbytes: float, num_leaves: int, *,
                   fused: bool) -> float:
        """One direction (pack OR unpack) of one bucket's staging."""
        passes = self.fused_passes if fused else self.leafwise_passes
        ops = 1 if fused else max(int(num_leaves), 1)
        return passes * nbytes / self.hbm_bw + ops * self.leaf_overhead


@dataclasses.dataclass(frozen=True)
class UpdateModel:
    """HBM cost of one scheduled optimizer UPDATE op (DESIGN.md §9).

    The sharded update is pure elementwise math: read the gradient
    shard, the param shard and the optimizer moments, write the update
    and the new moments — ~7 passes over the shard for AdamW-class
    optimizers — plus a dispatch overhead.  ZeRO-1 shrinks the shard by
    the dp group size, which is exactly what this model prices against
    the monolithic full-buffer update.
    """

    hbm_bw: float = 819e9        # bytes/s (same v5e source as staging)
    passes: float = 7.0          # g, p, m, v reads + u, m, v writes
    overhead: float = 2e-6       # per-op dispatch/launch cost

    def update_time(self, shard_bytes: float) -> float:
        return self.passes * shard_bytes / self.hbm_bw + self.overhead


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """Step-level compute durations + bucket release-time policy."""

    t_fwd: float
    t_bwd: float
    n_stages: int = 1        # backward scan steps (layers); release grain
    update: UpdateModel = UpdateModel()   # UPDATE-op (shard math) cost

    def bucket_release_times(
        self,
        bucket_sizes: Sequence[tuple[int, int]],
        *,
        per_stage: bool = False,
    ) -> dict[int, float]:
        """bucket_id → time its gradients exist.

        ``bucket_sizes`` is (bucket_id, elems); bucket_ids ascend in
        gradient-ready order (the bucketer's creation order).  With
        ``per_stage`` the release snaps up to the owning scan step's end
        (in-scan psums are emitted per layer, not per element).
        """
        total = sum(s for _, s in bucket_sizes)
        if total <= 0:
            return {bid: self.t_fwd for bid, _ in bucket_sizes}
        out: dict[int, float] = {}
        cum = 0
        for bid, size in sorted(bucket_sizes):
            cum += size
            frac = cum / total
            if per_stage and self.n_stages > 1:
                frac = math.ceil(frac * self.n_stages) / self.n_stages
            out[bid] = self.t_fwd + self.t_bwd * frac
        return out

    @property
    def end(self) -> float:
        return self.t_fwd + self.t_bwd

    def with_accum(self, accum: int, *,
                   overlap_tail: bool = True) -> "ComputeModel":
        """Fold an M-microbatch gradient-accumulation scan into the step.

        ``self`` is the PER-MICROBATCH model.  The returned model spans
        the whole M-microbatch step: with ``overlap_tail`` (the peeled
        final microbatch of DESIGN.md §10) the first M-1 microbatches
        become pure head compute and releases happen during the FINAL
        microbatch's backward — the only place the runtime can emit
        them, since the accumulated gradients do not exist earlier.
        Without it (plain scan) every release waits for the entire scan.
        """
        if accum <= 1:
            return self
        micro = self.t_fwd + self.t_bwd
        if overlap_tail:
            return dataclasses.replace(
                self, t_fwd=(accum - 1) * micro + self.t_fwd)
        return dataclasses.replace(self, t_fwd=accum * micro, t_bwd=0.0)


def count_params(cfg) -> int:
    """Total parameter elements via eval_shape (no device allocation)."""
    import jax

    from repro.configs.base import param_structs

    return sum(
        int(math.prod(l.shape)) if l.shape else 1
        for l in jax.tree_util.tree_leaves(param_structs(cfg)))


def fwd_flops(cfg, *, global_batch: int, seq_len: int,
              params: int | None = None) -> float:
    """Whole-step forward FLOPs for any registered model family."""
    from repro.models.registry import family_of

    p = params if params is not None else count_params(cfg)
    family = family_of(cfg).family
    if family in ("resnet", "inception"):
        return p * (cfg.img_size ** 2) / 256.0 * global_batch
    return 2.0 * p * global_batch * max(seq_len, 1)


def n_stages_of(cfg) -> int:
    """Backward scan steps: layers for scanned families, stages for convnets."""
    n = getattr(cfg, "n_layers", None)
    if n:
        return int(n)
    stages = getattr(cfg, "stages", None)
    if stages:
        return int(sum(stages))
    return 1


def compute_model_for(cfg, *, global_batch: int, seq_len: int,
                      n_devices: int,
                      hw: HardwareModel | None = None) -> ComputeModel:
    """Derive the step's compute timeline from model FLOPs and the mesh
    size (compute is data-parallel: per-device share of the step)."""
    hw = hw or HardwareModel()
    f = fwd_flops(cfg, global_batch=global_batch, seq_len=seq_len)
    t_fwd = f / (max(n_devices, 1) * hw.flops)
    return ComputeModel(t_fwd=t_fwd, t_bwd=2.0 * t_fwd,
                        n_stages=n_stages_of(cfg))
