"""The six analysis passes (DESIGN.md §11, §13).

Every pass is a pure function ``CommSchedule (+ context) -> [Finding]``:
no jax, no tracing, no devices — a schedule with hundreds of ops checks
in well under a millisecond, so the passes run on EVERY plan
(``GradSyncConfig.verify``, on by default) without showing up in setup
time.  Reachability is computed once as per-op ancestor bitmasks
(python ints), so the pairwise ordering checks are O(1) lookups.

Error classes are machine-readable ``Finding.code`` strings; the
mutation corpus (``repro.analysis.mutations``) asserts each class is
caught by the pass that owns it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    DECODE,
    KINDS,
    NORM,
    PHASES,
    POST,
    PRE,
    RECV,
    REDUCE_SCATTER,
    REGROUP,
    RESHARD,
    SEND,
    UPDATE,
    CommSchedule,
    np_itemsize,
)

PASS_NAMES = ("deadlock", "spmd", "carry", "accounting", "donation",
              "reshard")

# kinds whose issue order on a shared communicator must be rank-uniform
# (an ALL_GATHER is the second half of a matched pair — it attaches to
# its producing RS/UPDATE and free-flies, the paper's OUTSTANDING window;
# a REGROUP barrier is itself a collective every member must reach in
# the same program position)
_SERIAL_KINDS = (ALLREDUCE, REDUCE_SCATTER, NORM, REGROUP)


@dataclasses.dataclass(frozen=True)
class Witness:
    """Printable evidence for one finding (the 'topological witness')."""

    title: str
    lines: tuple[str, ...] = ()

    def render(self) -> str:
        return "\n".join((self.title,) + tuple(f"  {l}" for l in self.lines))


@dataclasses.dataclass(frozen=True)
class Finding:
    """One failed check: which pass, which error class, which ops."""

    pass_name: str
    code: str            # machine-readable error class
    message: str
    ops: tuple[int, ...] = ()
    witness: Witness | None = None

    def render(self) -> str:
        out = f"[{self.pass_name}:{self.code}] {self.message}"
        if self.witness is not None:
            out += "\n" + self.witness.render()
        return out


class ScheduleError(ValueError):
    """A schedule failed static verification (raised by ``verify_schedule``
    and the ``verify=`` planning hooks)."""

    def __init__(self, findings: Sequence[Finding]):
        self.findings = tuple(findings)
        super().__init__("\n".join(f.render() for f in self.findings))

    @property
    def pass_name(self) -> str:
        return self.findings[0].pass_name

    @property
    def code(self) -> str:
        return self.findings[0].code


def _op_str(op) -> str:
    deps = ",".join(str(d) for d in op.depends_on)
    return (f"op {op.op_id} {op.kind} bucket={op.bucket.bucket_id} "
            f"chain={op.chain} phase={op.phase} deps=[{deps}]")


# ------------------------------------------------------------- structure

def structural_findings(schedule: CommSchedule) -> list[Finding]:
    """Program-order soundness: what ``CommSchedule.validate`` enforces.

    ``validate`` routes through this function (one implementation, two
    entry points) so the shallow check and the analyzer cannot drift.
    """
    out: list[Finding] = []
    seen: set[int] = set()
    all_ids = {op.op_id for op in schedule.ops}
    for op in schedule.ops:
        if op.op_id in seen:
            out.append(Finding(
                "deadlock", "duplicate-op-id",
                f"duplicate op_id {op.op_id}", (op.op_id,)))
        if op.kind not in KINDS:
            out.append(Finding(
                "deadlock", "unknown-kind",
                f"op {op.op_id}: unknown kind {op.kind!r}", (op.op_id,)))
        if op.phase not in PHASES:
            out.append(Finding(
                "deadlock", "unknown-phase",
                f"op {op.op_id}: unknown phase {op.phase!r}", (op.op_id,)))
        if op.bucket.bucket_id < 0:
            out.append(Finding(
                "deadlock", "unknown-bucket",
                f"op {op.op_id}: negative bucket_id "
                f"{op.bucket.bucket_id}", (op.op_id,)))
        for d in op.depends_on:
            if d == op.op_id:
                out.append(Finding(
                    "deadlock", "self-dependency",
                    f"op {op.op_id} depends on itself", (op.op_id,)))
            elif d not in all_ids:
                out.append(Finding(
                    "deadlock", "dangling-dep",
                    f"op {op.op_id} depends on {d}, which is not in the "
                    f"schedule (dangling chain-dep reference)",
                    (op.op_id,)))
            elif d not in seen:
                out.append(Finding(
                    "deadlock", "non-topological",
                    f"op {op.op_id} depends on {d}, which does not "
                    f"precede it (schedule must be topologically "
                    f"ordered)", (op.op_id, d)))
        seen.add(op.op_id)
    return out


def _ancestor_masks(schedule: CommSchedule) -> dict[int, int]:
    """op_id -> bitmask (over tuple positions) of transitive ancestors.

    Only meaningful on structurally sound schedules (deps precede);
    callers gate on ``structural_findings`` first.
    """
    pos = {op.op_id: i for i, op in enumerate(schedule.ops)}
    anc: dict[int, int] = {}
    for op in schedule.ops:
        m = 0
        for d in op.depends_on:
            m |= anc.get(d, 0) | (1 << pos[d])
        anc[op.op_id] = m
    return anc


def _reaches(anc: Mapping[int, int], pos: Mapping[int, int],
             src: int, dst: int) -> bool:
    """True if ``dst`` transitively depends on ``src`` (src →* dst)."""
    return bool(anc.get(dst, 0) >> pos[src] & 1)


def _find_cycle(schedule: CommSchedule) -> list[int] | None:
    """A dependency cycle as an op_id path, or None."""
    deps = {op.op_id: [d for d in op.depends_on
                       if d != op.op_id and any(
                           o.op_id == d for o in schedule.ops)]
            for op in schedule.ops}
    state: dict[int, int] = {}          # 0 unseen / 1 on stack / 2 done
    parent: dict[int, int] = {}

    for root in deps:
        if state.get(root):
            continue
        stack = [(root, iter(deps[root]))]
        state[root] = 1
        while stack:
            node, it = stack[-1]
            advanced = False
            for d in it:
                if state.get(d, 0) == 1:     # back edge → cycle
                    cyc = [d, node]
                    cur = node
                    while cur != d:
                        cur = parent[cur]
                        cyc.append(cur)
                    return list(reversed(cyc))
                if state.get(d, 0) == 0:
                    state[d] = 1
                    parent[d] = node
                    stack.append((d, iter(deps[d])))
                    advanced = True
                    break
            if not advanced:
                state[node] = 2
                stack.pop()
    return None


# ------------------------------------------------- pass 1: deadlock/cycle

def check_deadlock(schedule: CommSchedule) -> list[Finding]:
    """Cycle / stuck-schedule detection over the union dependency graph:
    chain deps (``depends_on``), data deps (two ops touching the same
    leaf read/write the same slot of the CURRENT flat outputs), and the
    cross-step PRE→POST carry edges (a PRE op's result only exists in
    the NEXT step, so a POST op depending on one can never be released).
    """
    out = structural_findings(schedule)
    by_id = {op.op_id: op for op in schedule.ops}

    # true cycles (possible once op_ids stop being tuple-ordered) — the
    # witness is the cycle path the topological sort gets stuck on
    cyc = _find_cycle(schedule)
    if cyc is not None:
        lines = tuple(_op_str(by_id[i]) for i in cyc)
        out.append(Finding(
            "deadlock", "cycle",
            f"dependency cycle through ops {cyc} — no topological order "
            f"exists; every rank deadlocks waiting on the cycle",
            tuple(cyc),
            Witness("cycle (each op waits on the next):", lines)))
        return out           # reachability is meaningless below a cycle

    # cross-step carry edges: POST(step N) → PRE(executes step N+1) →
    # unrolled, a POST op depending on a PRE op closes a two-step cycle
    pre_ids = {op.op_id for op in schedule.ops if op.phase == PRE}
    for op in schedule.ops:
        bad = pre_ids.intersection(op.depends_on)
        if op.phase != PRE and bad:
            lines = tuple(_op_str(by_id[i]) for i in sorted(bad))
            out.append(Finding(
                "deadlock", "cross-step-cycle",
                f"post op {op.op_id} depends on deferred (PRE) op(s) "
                f"{sorted(bad)} — a deferred result does not exist until "
                f"the next step, so this step can never release it",
                (op.op_id,) + tuple(sorted(bad)),
                Witness("cross-step carry cycle:",
                        (_op_str(op),) + lines)))

    if any(f.code in ("non-topological", "dangling-dep", "duplicate-op-id")
           for f in out):
        return out           # ancestor masks need a sound tuple order

    # data deps: ops sharing a leaf read/write the same flat-output slot
    # — the emitter consumes the CURRENT value, so every later toucher
    # must be ordered after every earlier one (checked pairwise on
    # consecutive touchers; reachability is transitive).  PRE ops read
    # carried state, not the in-step outputs of their leaf-mates.
    anc = _ancestor_masks(schedule)
    pos = {op.op_id: i for i, op in enumerate(schedule.ops)}
    touch: dict[str, list] = {}
    for op in schedule.ops:
        if op.phase == PRE:
            continue
        for leaf in op.bucket.leaves:
            touch.setdefault(leaf.name, []).append(op)
    for name, ops in touch.items():
        for a, b in zip(ops, ops[1:]):
            if not _reaches(anc, pos, a.op_id, b.op_id):
                out.append(Finding(
                    "deadlock", "missing-data-edge",
                    f"ops {a.op_id} and {b.op_id} both stage leaf "
                    f"{name!r} but carry no dependency path — op "
                    f"{b.op_id} may read the slot before op {a.op_id} "
                    f"wrote it",
                    (a.op_id, b.op_id),
                    Witness(f"unordered writers of leaf {name!r}:",
                            (_op_str(a), _op_str(b)))))

    out.extend(_check_rendezvous(schedule, anc, pos))
    return out


def _check_rendezvous(schedule: CommSchedule, anc, pos) -> list[Finding]:
    """SEND/RECV pairing and rendezvous deadlock (DESIGN.md §15).

    A boundary crossing is ONE ppermute executed at the RECV: every SEND
    needs exactly one RECV on the same bucket (and vice versa), and the
    RECV must carry the SEND in ``depends_on`` — the payload's data
    edge, which also makes a crossed rendezvous unconstructible (the
    recv can never precede its send).  When the data edges are missing,
    two pairs can still CROSS: each pair's send transitively waits on
    the OTHER pair's recv, so neither payload is ever packed — each hop
    blocks on a payload only the other hop's completion would produce.
    The op-level graph is acyclic (adding the data edges back closes
    the cycle), so this is checked pairwise on ancestor reachability.
    """
    sends = [op for op in schedule.ops if op.kind == SEND]
    recvs = [op for op in schedule.ops if op.kind == RECV]
    if not sends and not recvs:
        return []
    out: list[Finding] = []
    s_by_bucket: dict[int, list] = {}
    r_by_bucket: dict[int, list] = {}
    for op in sends:
        s_by_bucket.setdefault(op.bucket.bucket_id, []).append(op)
    for op in recvs:
        r_by_bucket.setdefault(op.bucket.bucket_id, []).append(op)

    for bid, ops in sorted(s_by_bucket.items()):
        n_recv = len(r_by_bucket.get(bid, ()))
        if len(ops) > 1 or n_recv > 1:
            out.append(Finding(
                "deadlock", "send-unmatched",
                f"bucket {bid} carries {len(ops)} SEND / {n_recv} RECV "
                f"op(s) — a boundary crossing is exactly one matched "
                f"pair per bucket",
                tuple(o.op_id for o in ops)))
        elif n_recv == 0:
            out.append(Finding(
                "deadlock", "send-unmatched",
                f"SEND {ops[0].op_id} (bucket {bid}) has no matching "
                f"RECV — the packed payload is never moved and the "
                f"receiving stage waits forever",
                (ops[0].op_id,),
                Witness("send without a receiver:", (_op_str(ops[0]),))))
    for bid, ops in sorted(r_by_bucket.items()):
        if bid not in s_by_bucket:
            out.append(Finding(
                "deadlock", "recv-unmatched",
                f"RECV {ops[0].op_id} (bucket {bid}) has no matching "
                f"SEND — there is no payload to move; the ppermute "
                f"blocks every rank of the stage axis",
                tuple(o.op_id for o in ops),
                Witness("recv without a sender:", (_op_str(ops[0]),))))

    pairs: list[tuple] = []            # (send, recv) matched 1:1
    for bid, ops in sorted(s_by_bucket.items()):
        rs = r_by_bucket.get(bid, ())
        if len(ops) == 1 and len(rs) == 1:
            snd, rcv = ops[0], rs[0]
            if snd.op_id not in rcv.depends_on:
                out.append(Finding(
                    "deadlock", "recv-missing-send-edge",
                    f"RECV {rcv.op_id} does not depend on its SEND "
                    f"{snd.op_id} (bucket {bid}) — the hop may execute "
                    f"before the payload is packed",
                    (rcv.op_id, snd.op_id),
                    Witness("pair without the payload data edge:",
                            (_op_str(snd), _op_str(rcv)))))
            if snd.shift != rcv.shift or \
                    snd.bucket.reduce_axes != rcv.bucket.reduce_axes:
                out.append(Finding(
                    "deadlock", "send-recv-shift-mismatch",
                    f"SEND {snd.op_id} (shift={snd.shift}, "
                    f"axes={snd.bucket.reduce_axes}) and RECV "
                    f"{rcv.op_id} (shift={rcv.shift}, "
                    f"axes={rcv.bucket.reduce_axes}) disagree on the "
                    f"hop — the two halves describe different "
                    f"ppermutes", (snd.op_id, rcv.op_id)))
            pairs.append((snd, rcv))

    # crossed rendezvous: pair A's send waits on pair B's recv AND pair
    # B's send waits on pair A's recv — with the data edges this would
    # be a cycle (caught above); without them only this pairwise
    # reachability check sees it.  Valid plans always carry the data
    # edges, which make t(recv) ≥ t(send) and the pattern impossible.
    for i, (sa, ra) in enumerate(pairs):
        for sb, rb in pairs[i + 1:]:
            if _reaches(anc, pos, rb.op_id, sa.op_id) and \
                    _reaches(anc, pos, ra.op_id, sb.op_id):
                out.append(Finding(
                    "deadlock", "crossed-send-recv",
                    f"SEND/RECV pairs (buckets "
                    f"{sa.bucket.bucket_id}, {sb.bucket.bucket_id}) "
                    f"are crossed: each pair's send transitively waits "
                    f"on the other pair's recv, so neither payload is "
                    f"ever packed — both hops block forever",
                    (sa.op_id, ra.op_id, sb.op_id, rb.op_id),
                    Witness("crossed rendezvous pairs:",
                            (_op_str(sa), _op_str(ra),
                             _op_str(sb), _op_str(rb)))))
    return out


# --------------------------------------------- pass 2: SPMD consistency

def _family(reducer: str) -> str:
    """Reducer family prefix: 'hierarchical_ring' → 'hierarchical'."""
    if not reducer:
        return "flat"
    for fam in ("hierarchical", "compressed", "ring", "flat"):
        if reducer == fam or reducer.startswith(fam + "_"):
            return fam
    return reducer


def reducer_stages(op, default_reducer: str = "flat",
                   ) -> tuple[tuple[str, tuple[str, ...]], ...]:
    """The wire collectives one op expands into, per reducer family —
    what each rank actually issues on the network (DESIGN.md §3, §8)."""
    axes = op.bucket.reduce_axes
    if op.kind in (UPDATE, DECODE):
        return ()                       # local math, no wire payload
    if op.kind == SEND:
        return ()                       # local pack; the RECV hops
    if op.kind == RECV:
        # the pair's single wire event: the ppermute every rank of the
        # stage axis joins at the RECV
        return (("ppermute", axes),)
    if op.kind != ALLREDUCE:
        return ((op.kind, axes),)
    fam = _family(op.reducer or default_reducer)
    if fam == "hierarchical" and "pod" in axes and "data" in axes:
        rest = tuple(a for a in axes if a not in ("pod", "data"))
        stages = ((REDUCE_SCATTER, ("data",)), (ALLREDUCE, ("pod",)),
                  (ALL_GATHER, ("data",)))
        return stages + (((ALLREDUCE, rest),) if rest else ())
    if fam == "compressed":
        # quantize → all-to-all int8 shards → local reduce → all-gather
        return (("all_to_all", axes), (ALL_GATHER, axes))
    return ((ALLREDUCE, axes),)


def _groups_of(rank: tuple[int, ...], axes: tuple[str, ...],
               axis_names: tuple[str, ...]) -> tuple:
    """The communicator instance ``rank`` belongs to for a collective
    over ``axes``: its coordinates on the complement axes."""
    return tuple((a, c) for a, c in zip(axis_names, rank) if a not in axes)


def check_spmd(
    schedule: CommSchedule,
    mesh_shape: Mapping[str, int] | None = None,
    *,
    default_reducer: str = "flat",
    rank_programs: Mapping[tuple[int, ...], Sequence[int]] | None = None,
) -> list[Finding]:
    """SPMD-consistency: every rank of a communicator group must issue
    the same collective sequence per channel.

    Two checks:
      (1) serialization — ALLREDUCE / REDUCE_SCATTER / NORM ops on one
          communicator (reduce_axes, channel) must be totally ordered by
          dependency paths.  An unordered pair means two engine chains
          can issue on the same communicator in either order — ranks may
          disagree, the paper's funnel-vs-concurrent deadlock.  Matched
          second-phase ALL_GATHERs are exempt: they attach to their
          producing RS/UPDATE and free-fly (the OUTSTANDING window).
      (2) per-rank issue simulation — each rank's issue order
          (``rank_programs`` override, else the shared schedule order)
          is expanded through the reducer families' stage collectives
          and grouped by communicator *instance*; every member of an
          instance must see the identical sequence.  This is where
          hierarchical/compressed stage structure and KVStore barrier
          joins are checked on real group boundaries.
    """
    out: list[Finding] = []
    if structural_findings(schedule):
        return out           # ordering checks need a sound tuple order

    by_id = {op.op_id: op for op in schedule.ops}
    anc = _ancestor_masks(schedule)
    pos = {op.op_id: i for i, op in enumerate(schedule.ops)}

    if mesh_shape is not None:
        for op in schedule.ops:
            missing = [a for a in op.bucket.reduce_axes
                       if a not in mesh_shape]
            if missing:
                out.append(Finding(
                    "spmd", "unknown-axis",
                    f"op {op.op_id} reduces over axes {missing} absent "
                    f"from the mesh {dict(mesh_shape)}", (op.op_id,)))

    # (1) total serialization per communicator (reduce_axes, channel)
    comms: dict[tuple, list] = {}
    for op in schedule.ops:
        if op.kind in _SERIAL_KINDS:
            key = (op.bucket.reduce_axes, op.bucket.channel)
            comms.setdefault(key, []).append(op)
    for (axes, channel), ops in comms.items():
        for a, b in zip(ops, ops[1:]):
            if not _reaches(anc, pos, a.op_id, b.op_id):
                seq_a = [o.op_id for o in ops if o.chain == a.chain]
                seq_b = [o.op_id for o in ops if o.chain == b.chain]
                out.append(Finding(
                    "spmd", "concurrent-collectives",
                    f"ops {a.op_id} and {b.op_id} issue on the same "
                    f"communicator (axes={axes}, channel={channel}) "
                    f"with no dependency path — ranks may issue them "
                    f"in different orders and deadlock (the "
                    f"funnel-vs-concurrent hazard)",
                    (a.op_id, b.op_id),
                    Witness(
                        f"unordered collectives on (axes={axes}, "
                        f"channel={channel}):",
                        (_op_str(a), _op_str(b),
                         f"chain {a.chain} issues: {seq_a}",
                         f"chain {b.chain} issues: {seq_b}"))))

    # (2) per-rank issue sequences per communicator INSTANCE
    if mesh_shape is not None and not out:
        axis_names = tuple(mesh_shape)
        sizes = [int(mesh_shape[a]) for a in axis_names]
        ranks: list[tuple[int, ...]] = [()]
        for s in sizes:
            ranks = [r + (c,) for r in ranks for c in range(s)]
        if rank_programs is None:
            order = tuple(op.op_id for op in schedule.ops)
            rank_programs = {r: order for r in ranks}
        seqs: dict[tuple, dict[tuple[int, ...], list[tuple]]] = {}
        for rank in ranks:
            for oid in rank_programs.get(rank, ()):
                op = by_id.get(oid)
                if op is None:
                    continue
                for si, (kind, axes) in enumerate(
                        reducer_stages(op, default_reducer)):
                    if any(a not in mesh_shape for a in axes):
                        continue       # reported above as unknown-axis
                    inst = (axes, op.bucket.channel,
                            _groups_of(rank, axes, axis_names))
                    sig = (op.bucket.bucket_id, kind, si, op.bucket.size)
                    seqs.setdefault(inst, {}).setdefault(
                        rank, []).append(sig)
        for inst, per_rank in seqs.items():
            ref_rank = min(per_rank)
            ref = per_rank[ref_rank]
            for rank, seq in per_rank.items():
                if seq != ref:
                    axes, channel, group = inst
                    out.append(Finding(
                        "spmd", "rank-divergence",
                        f"ranks {ref_rank} and {rank} issue different "
                        f"collective sequences on communicator "
                        f"(axes={axes}, channel={channel}, "
                        f"group={group}) — mismatched collectives "
                        f"deadlock the group",
                        (),
                        Witness(
                            f"per-rank issue sequences on axes={axes} "
                            f"channel={channel}:",
                            (f"rank {ref_rank}: {ref}",
                             f"rank {rank}: {seq}"))))
                    break
    return out


# ------------------------------------------------ pass 3: carry soundness

def check_carry(schedule: CommSchedule, *,
                expect_defer: bool | None = None) -> list[Finding]:
    """Soundness of the cross-step carry (``zero1_plan="deferred"``).

    In steady state the SAME program runs every step, so the predecessor
    schedule is the schedule itself: every PRE ALL_GATHER must be
    covered by a POST UPDATE producing the same bucket / dtype / shard
    size, and the two bucket sets must match EXACTLY — a PRE gather
    without an UPDATE reads ``opt_state["pending"]`` uninitialized; an
    UPDATE whose gather neither ran in-step nor deferred leaves the
    carry half-written (or double-applies under a mixed split).
    """
    out: list[Finding] = []
    pre_ops = [op for op in schedule.ops if op.phase == PRE]
    if expect_defer is False and pre_ops:
        out.append(Finding(
            "carry", "unexpected-defer",
            f"schedule carries {len(pre_ops)} PRE op(s) but was planned "
            f"without defer_ag — nothing will execute them next step",
            tuple(op.op_id for op in pre_ops)))

    for op in pre_ops:
        if op.kind != ALL_GATHER:
            out.append(Finding(
                "carry", "mis-tagged-phase",
                f"op {op.op_id} ({op.kind}) is tagged PRE — only "
                f"ALL_GATHER ops may defer across the step boundary "
                f"(their shard inputs ride opt_state['pending']); a "
                f"deferred {op.kind} has no carried input to read",
                (op.op_id,)))

    pre_ags = [op for op in pre_ops if op.kind == ALL_GATHER]
    seen: dict[int, int] = {}
    for op in pre_ags:
        if op.bucket.bucket_id in seen:
            out.append(Finding(
                "carry", "duplicate-pre-gather",
                f"ops {seen[op.bucket.bucket_id]} and {op.op_id} both "
                f"defer a gather of bucket {op.bucket.bucket_id} — the "
                f"carry holds ONE shard per bucket",
                (seen[op.bucket.bucket_id], op.op_id)))
        seen.setdefault(op.bucket.bucket_id, op.op_id)

    updates = {op.bucket.bucket_id: op for op in schedule.ops
               if op.kind == UPDATE and op.phase == POST}
    by_id = {op.op_id: op for op in schedule.ops}

    for op in pre_ags:
        upd = updates.get(op.bucket.bucket_id)
        if upd is None:
            out.append(Finding(
                "carry", "orphaned-pre-gather",
                f"PRE all-gather {op.op_id} reads bucket "
                f"{op.bucket.bucket_id} from the carry, but no POST "
                f"UPDATE produces that bucket's shard — "
                f"opt_state['pending'] would be read uninitialized",
                (op.op_id,),
                Witness("deferred gather without a producer:",
                        (_op_str(op),
                         f"POST UPDATE buckets: {sorted(updates)}"))))
            continue
        if upd.bucket.size != op.bucket.size:
            out.append(Finding(
                "carry", "carry-shard-mismatch",
                f"PRE gather {op.op_id} expects {op.bucket.size} "
                f"elements of bucket {op.bucket.bucket_id} but UPDATE "
                f"{upd.op_id} produces {upd.bucket.size}",
                (op.op_id, upd.op_id)))
        if (np.dtype(op.bucket.comm_dtype or np.float32)
                != np.dtype(upd.bucket.comm_dtype or np.float32)):
            out.append(Finding(
                "carry", "carry-dtype-mismatch",
                f"PRE gather {op.op_id} reads bucket "
                f"{op.bucket.bucket_id} as "
                f"{np.dtype(op.bucket.comm_dtype or np.float32).name} "
                f"but UPDATE {upd.op_id} writes "
                f"{np.dtype(upd.bucket.comm_dtype or np.float32).name}",
                (op.op_id, upd.op_id)))
        if upd.bucket.reduce_axes != op.bucket.reduce_axes:
            out.append(Finding(
                "carry", "carry-axes-mismatch",
                f"PRE gather {op.op_id} gathers over "
                f"{op.bucket.reduce_axes} but UPDATE {upd.op_id}'s "
                f"shard was scattered over {upd.bucket.reduce_axes}",
                (op.op_id, upd.op_id)))

    # exact bucket-set equality: once ANY gather defers, every update
    # shard must cross the boundary — an update consumed by a POST
    # gather in the same schedule would ALSO be re-applied from the
    # carry next step (double-apply), and an update with no gather at
    # all leaves the carry half-written
    if pre_ags:
        deferred = {op.bucket.bucket_id for op in pre_ags}
        for bid, upd in sorted(updates.items()):
            if bid in deferred:
                continue
            post_consumers = [
                op for op in schedule.ops
                if op.kind == ALL_GATHER and op.phase == POST
                and op.bucket.bucket_id == bid
                and any(by_id[d].kind == UPDATE for d in op.depends_on
                        if d in by_id)]
            code = ("mixed-defer" if post_consumers
                    else "half-written-carry")
            why = ("is also gathered in-step — the carry would "
                   "double-apply it next step"
                   if post_consumers else
                   "is never gathered (neither in-step nor deferred) — "
                   "the carry is half-written")
            out.append(Finding(
                "carry", code,
                f"UPDATE {upd.op_id} produces bucket {bid} while other "
                f"buckets defer, but bucket {bid} {why}",
                (upd.op_id,),
                Witness("deferred bucket set mismatch:",
                        (f"PRE-gathered buckets:  {sorted(deferred)}",
                         f"UPDATE-produced buckets: "
                         f"{sorted(updates)}"))))
    return out


# --------------------------------------- pass 4: byte/dtype accounting

def check_accounting(schedule: CommSchedule, *,
                     plan_comm_dtype=None,
                     default_reducer: str = "flat") -> list[Finding]:
    """RS/AG pair symmetry, reducer/dtype legality, byte bookkeeping."""
    out: list[Finding] = []
    by_id = {op.op_id: op for op in schedule.ops}

    def eff_dtype(bucket):
        d = bucket.comm_dtype
        if d is None:
            d = plan_comm_dtype
        return None if d is None else np.dtype(d)

    try:
        from repro.core.registry import reducer_names
        known = set(reducer_names())
    except Exception:        # registry unpopulated in exotic contexts
        known = None

    consumers: dict[int, list] = {}
    for op in schedule.ops:
        for d in op.depends_on:
            dep = by_id.get(d)
            if dep is not None and \
                    dep.bucket.bucket_id == op.bucket.bucket_id:
                consumers.setdefault(d, []).append(op)

    for op in schedule.ops:
        if op.reducer:
            if known is not None and op.reducer not in known:
                out.append(Finding(
                    "accounting", "unknown-reducer",
                    f"op {op.op_id} tagged with unregistered reducer "
                    f"{op.reducer!r}", (op.op_id,)))
            if op.kind != ALLREDUCE:
                out.append(Finding(
                    "accounting", "ignored-reducer-tag",
                    f"op {op.op_id} ({op.kind}) carries reducer tag "
                    f"{op.reducer!r}, but the emitter only honors "
                    f"reducer tags on ALLREDUCE ops — the tag would be "
                    f"silently ignored", (op.op_id,)))
        if op.kind == ALLREDUCE and \
                _family(op.reducer or default_reducer) == "compressed":
            d = eff_dtype(op.bucket)
            if d is not None and d.kind != "f":
                out.append(Finding(
                    "accounting", "comm-dtype-illegal",
                    f"op {op.op_id} uses the compressed reducer family "
                    f"on a {d.name} wire — block quantization requires "
                    f"a float comm dtype", (op.op_id,)))
        if op.kind == UPDATE:
            d = eff_dtype(op.bucket)
            if d is None or d != np.dtype(np.float32):
                out.append(Finding(
                    "accounting", "update-dtype",
                    f"UPDATE op {op.op_id} runs on a "
                    f"{d.name if d is not None else 'unpinned'} bucket "
                    f"— ZeRO-1 shard math must pin comm_dtype=f32 to "
                    f"match the monolithic optimizer bit-for-bit",
                    (op.op_id,)))

        if op.kind == REDUCE_SCATTER:
            cons = [c for c in consumers.get(op.op_id, ())
                    if c.kind in (ALL_GATHER, UPDATE)]
            if not cons:
                out.append(Finding(
                    "accounting", "rs-unconsumed",
                    f"reduce-scatter {op.op_id} produces a shard of "
                    f"bucket {op.bucket.bucket_id} that no same-bucket "
                    f"ALL_GATHER/UPDATE consumes — the reduced bytes "
                    f"are dropped and the leaves keep stale gradients",
                    (op.op_id,)))

        if op.kind == ALL_GATHER:
            srcs = [by_id[d] for d in op.depends_on if d in by_id
                    and by_id[d].bucket.bucket_id == op.bucket.bucket_id
                    and by_id[d].kind in (REDUCE_SCATTER, UPDATE, DECODE)]
            if not srcs and op.phase != PRE:
                out.append(Finding(
                    "accounting", "ag-no-producer",
                    f"all-gather {op.op_id} has no same-bucket "
                    f"REDUCE_SCATTER/UPDATE dep and is not deferred — "
                    f"there is no shard to gather", (op.op_id,)))
            for src in srcs:
                if src.bucket.size != op.bucket.size or \
                        src.bucket.reduce_axes != op.bucket.reduce_axes:
                    out.append(Finding(
                        "accounting", "rs-ag-asymmetry",
                        f"all-gather {op.op_id} "
                        f"(size={op.bucket.size}, "
                        f"axes={op.bucket.reduce_axes}) does not mirror "
                        f"its producer {src.op_id} "
                        f"(size={src.bucket.size}, "
                        f"axes={src.bucket.reduce_axes})",
                        (op.op_id, src.op_id)))
                da, db = eff_dtype(op.bucket), eff_dtype(src.bucket)
                if da is not None and db is not None and da != db:
                    out.append(Finding(
                        "accounting", "rs-ag-dtype",
                        f"all-gather {op.op_id} ({da.name}) and its "
                        f"producer {src.op_id} ({db.name}) disagree on "
                        f"the wire dtype", (op.op_id, src.op_id)))

        if op.kind == RECV:
            srcs = [by_id[d] for d in op.depends_on if d in by_id
                    and by_id[d].kind == SEND
                    and by_id[d].bucket.bucket_id == op.bucket.bucket_id]
            for src in srcs:
                if src.bucket.size != op.bucket.size:
                    out.append(Finding(
                        "accounting", "send-recv-bytes",
                        f"stage boundary bucket {op.bucket.bucket_id}: "
                        f"SEND {src.op_id} packs {src.bucket.size} "
                        f"elements but RECV {op.op_id} delivers "
                        f"{op.bucket.size} — the two halves of the hop "
                        f"disagree on the payload size",
                        (src.op_id, op.op_id),
                        Witness("asymmetric stage boundary:",
                                (_op_str(src), _op_str(op)))))
                da, db = eff_dtype(op.bucket), eff_dtype(src.bucket)
                if da is not None and db is not None and da != db:
                    out.append(Finding(
                        "accounting", "send-recv-dtype",
                        f"RECV {op.op_id} ({da.name}) and its SEND "
                        f"{src.op_id} ({db.name}) disagree on the "
                        f"boundary wire dtype", (op.op_id, src.op_id)))

    # bookkeeping self-consistency: the stats the sim/benchmarks consume
    itemsize = 4 if plan_comm_dtype is None else \
        np.dtype(plan_comm_dtype).itemsize
    if sum(schedule.chain_bytes(itemsize).values()) != \
            schedule.comm_bytes(itemsize):
        out.append(Finding(
            "accounting", "chain-bytes-drift",
            "chain_bytes does not sum to comm_bytes — the per-channel "
            "budget and the sim disagree on total payload"))
    want = sum(op.bucket.size * np_itemsize(op.bucket.comm_dtype, itemsize)
               for op in schedule.ops if op.phase == PRE)
    if schedule.deferred_bytes(itemsize) != want:
        out.append(Finding(
            "accounting", "deferred-bytes-drift",
            f"deferred_bytes() = {schedule.deferred_bytes(itemsize)} "
            f"but the PRE ops carry {want} bytes"))
    return out


# -------------------------------------- pass 5: donation/aliasing hazard

def check_donation(schedule: CommSchedule,
                   donated_buckets: Iterable[int] = ()) -> list[Finding]:
    """A staged buffer that is donated in step N and read by a PRE op at
    the top of step N+1 aliases freed memory — the gather would read a
    buffer XLA already reused."""
    donated = frozenset(donated_buckets)
    out: list[Finding] = []
    for op in schedule.ops:
        if op.phase == PRE and op.bucket.bucket_id in donated:
            out.append(Finding(
                "donation", "donated-pre-read",
                f"bucket {op.bucket.bucket_id}'s staged buffer is "
                f"donated, but PRE op {op.op_id} reads it at the top "
                f"of the NEXT step — the buffer may be reused before "
                f"the deferred gather consumes it",
                (op.op_id,),
                Witness("donated buffer crossing the step boundary:",
                        (_op_str(op),
                         f"donated buckets: {sorted(donated)}"))))
    return out


# ----------------------------------- pass 6: reshard/regroup soundness

def check_reshard(
    schedule: CommSchedule,
    *,
    old_mesh_shape: Mapping[str, int] | None = None,
    new_mesh_shape: Mapping[str, int] | None = None,
    leaf_divisibility: Mapping[str, tuple[int, int]] | None = None,
) -> list[Finding]:
    """Soundness of an elastic transition (DESIGN.md §13).

    A transition schedule is gather-side RESHARD ops (old mesh), ONE
    REGROUP barrier the old communicator joins, then scatter-side
    RESHARD ops (new mesh) — ``split_regroup`` executes the two sides as
    separate programs.  Checked statically:

      - every schedule with RESHARD ops carries a REGROUP (the
        group-rebuild moment; without it the two meshes race),
      - no PRE (deferred) op crosses a regroup — the carry is flushed
        via ``TrainStep.finalize`` BEFORE the old mesh dissolves, so a
        deferred op in a transition schedule reads state that no longer
        exists,
      - the barrier is real: every old-side op is an ancestor of the
        REGROUP, and every post-regroup RESHARD depends on it,
      - gather axes exist on the OLD mesh, scatter axes on the NEW,
      - byte conservation per leaf: every gathered leaf is scattered
        exactly once with the same total size (state neither lost,
        duplicated, nor conjured across the transition),
      - static divisibility of each param leaf's sharded dim by the new
        mesh (``leaf_divisibility``: leaf → (dim_size, divisor), built
        by the planner from the new mesh's specs).

    On schedules with no RESHARD/REGROUP ops (every plain training
    plan) this returns [] immediately.
    """
    reshard_ops = [op for op in schedule.ops if op.kind == RESHARD]
    regroups = [op for op in schedule.ops if op.kind == REGROUP]
    out: list[Finding] = []
    if leaf_divisibility:
        for name, (dim, div) in sorted(leaf_divisibility.items()):
            if div and dim % div:
                out.append(Finding(
                    "reshard", "leaf-indivisible",
                    f"leaf {name!r}: sharded dim of size {dim} is not "
                    f"divisible by the new mesh's axis product {div} — "
                    f"the scatter side cannot tile it"))
    if not reshard_ops and not regroups:
        return out
    if structural_findings(schedule):
        return out           # side/ordering analysis needs sound order

    if reshard_ops and not regroups:
        out.append(Finding(
            "reshard", "regroup-missing",
            f"schedule moves state with {len(reshard_ops)} RESHARD "
            f"op(s) but has no REGROUP barrier — the old and new "
            f"communicators are never synchronized",
            tuple(op.op_id for op in reshard_ops)))

    for op in schedule.ops:
        if regroups and op.phase == PRE:
            out.append(Finding(
                "reshard", "pre-crosses-regroup",
                f"op {op.op_id} ({op.kind}) is tagged PRE in a "
                f"transition schedule — deferred carries must be "
                f"flushed (TrainStep.finalize) before the regroup; a "
                f"PRE op here reads opt_state['pending'] of a mesh "
                f"that no longer exists",
                (op.op_id,),
                Witness("deferred op crossing the regroup barrier:",
                        (_op_str(op),))))

    pos = {op.op_id: i for i, op in enumerate(schedule.ops)}
    first_rg = regroups[0] if regroups else None
    cut = pos[first_rg.op_id] if first_rg is not None else len(schedule.ops)
    gathers = [op for op in reshard_ops if pos[op.op_id] < cut]
    scatters = [op for op in reshard_ops if pos[op.op_id] > cut]

    if first_rg is not None:
        anc = _ancestor_masks(schedule)
        for op in schedule.ops[:cut]:
            if not _reaches(anc, pos, op.op_id, first_rg.op_id):
                out.append(Finding(
                    "reshard", "op-escapes-regroup",
                    f"op {op.op_id} precedes the REGROUP barrier (op "
                    f"{first_rg.op_id}) but the barrier does not "
                    f"transitively depend on it — the old mesh may "
                    f"dissolve while the op is still in flight",
                    (op.op_id, first_rg.op_id),
                    Witness("old-side op the barrier does not join:",
                            (_op_str(op), _op_str(first_rg)))))
        for op in scatters:
            if not _reaches(anc, pos, first_rg.op_id, op.op_id):
                out.append(Finding(
                    "reshard", "reshard-after-regroup-unanchored",
                    f"scatter-side RESHARD {op.op_id} does not depend "
                    f"on the REGROUP barrier (op {first_rg.op_id}) — "
                    f"it could run before the old mesh quiesced",
                    (op.op_id, first_rg.op_id)))

    for ops, shape, side in ((gathers, old_mesh_shape, "old"),
                             (scatters, new_mesh_shape, "new")):
        if shape is None:
            continue
        for op in ops:
            missing = [a for a in op.bucket.reduce_axes if a not in shape]
            if missing:
                out.append(Finding(
                    "reshard", "reshard-axis-unknown",
                    f"{side}-side RESHARD {op.op_id} moves state over "
                    f"axes {missing} absent from the {side} mesh "
                    f"{dict(shape)}", (op.op_id,)))

    # byte conservation per leaf name, gather side vs scatter side
    if regroups:
        def tally(ops):
            sizes: dict[str, int] = {}
            counts: dict[str, int] = {}
            for op in ops:
                for leaf in op.bucket.leaves:
                    sizes[leaf.name] = sizes.get(leaf.name, 0) + leaf.size
                    counts[leaf.name] = counts.get(leaf.name, 0) + 1
            return sizes, counts

        g_sizes, g_counts = tally(gathers)
        s_sizes, s_counts = tally(scatters)
        for name, cnt in sorted({**g_counts, **s_counts}.items()):
            if max(g_counts.get(name, 0), s_counts.get(name, 0)) > 1:
                out.append(Finding(
                    "reshard", "leaf-duplicated",
                    f"leaf {name!r} is moved more than once on one side "
                    f"of the transition (gathered "
                    f"{g_counts.get(name, 0)}×, scattered "
                    f"{s_counts.get(name, 0)}×)"))
        if gathers and scatters:
            for name in sorted(set(g_sizes) - set(s_sizes)):
                out.append(Finding(
                    "reshard", "leaf-lost",
                    f"leaf {name!r} is gathered off the old mesh but "
                    f"never scattered onto the new one — "
                    f"{g_sizes[name]} elements of state are dropped",
                    tuple(op.op_id for op in gathers
                          if any(l.name == name for l in op.bucket.leaves))))
            for name in sorted(set(s_sizes) - set(g_sizes)):
                out.append(Finding(
                    "reshard", "leaf-unsourced",
                    f"leaf {name!r} is scattered onto the new mesh but "
                    f"never gathered off the old one — the scatter "
                    f"reads uninitialized state",
                    tuple(op.op_id for op in scatters
                          if any(l.name == name for l in op.bucket.leaves))))
            for name in sorted(set(g_sizes) & set(s_sizes)):
                if g_sizes[name] != s_sizes[name]:
                    out.append(Finding(
                        "reshard", "leaf-size-drift",
                        f"leaf {name!r}: gather side moves "
                        f"{g_sizes[name]} elements but scatter side "
                        f"expects {s_sizes[name]} — byte conservation "
                        f"across the transition is violated"))
    return out
