"""Analyzer entry points: run all six passes, report or raise.

``verify_schedule`` is the planning-time hook (GradSync / KVStore,
``verify=True`` by default): first finding raises ``ScheduleError``
with its witness.  ``run_passes`` is the collecting variant the CLI and
benchmarks use — every finding, as an ``AnalysisReport`` that renders
to text or a machine-readable dict.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Sequence

from repro.core.schedule import CommSchedule

from repro.analysis.passes import (
    PASS_NAMES,
    Finding,
    ScheduleError,
    check_accounting,
    check_carry,
    check_deadlock,
    check_donation,
    check_reshard,
    check_spmd,
)


@dataclasses.dataclass(frozen=True)
class AnalysisReport:
    """Every finding from one analyzer run over one schedule."""

    findings: tuple[Finding, ...]
    num_ops: int

    @property
    def ok(self) -> bool:
        return not self.findings

    @property
    def error_classes(self) -> tuple[str, ...]:
        """Distinct ``pass:code`` labels, first-seen order (the verdict
        column in benchmarks/schedule_analysis.py)."""
        out: list[str] = []
        for f in self.findings:
            label = f"{f.pass_name}:{f.code}"
            if label not in out:
                out.append(label)
        return tuple(out)

    def by_pass(self, pass_name: str) -> tuple[Finding, ...]:
        return tuple(f for f in self.findings
                     if f.pass_name == pass_name)

    def render(self) -> str:
        if self.ok:
            return f"OK ({self.num_ops} ops, all passes clean)"
        return "\n".join(f.render() for f in self.findings)

    def to_dict(self) -> dict[str, Any]:
        """JSON-ready form for the CLI report."""
        return {
            "ok": self.ok,
            "num_ops": self.num_ops,
            "findings": [
                {
                    "pass": f.pass_name,
                    "code": f.code,
                    "message": f.message,
                    "ops": list(f.ops),
                    "witness": (f.witness.render()
                                if f.witness is not None else None),
                }
                for f in self.findings
            ],
        }

    def raise_if_failed(self) -> "AnalysisReport":
        if self.findings:
            raise ScheduleError(self.findings)
        return self


def run_passes(
    schedule: CommSchedule,
    *,
    mesh_shape: Mapping[str, int] | None = None,
    default_reducer: str = "flat",
    plan_comm_dtype: Any = None,
    expect_defer: bool | None = None,
    donated_buckets: Iterable[int] = (),
    rank_programs: Mapping[tuple[int, ...], Sequence[int]] | None = None,
    old_mesh_shape: Mapping[str, int] | None = None,
    new_mesh_shape: Mapping[str, int] | None = None,
    leaf_divisibility: Mapping[str, tuple[int, int]] | None = None,
    passes: Sequence[str] = PASS_NAMES,
) -> AnalysisReport:
    """Run the requested passes over ``schedule`` and collect findings.

    Context mirrors what planning knows statically:
      mesh_shape       — axis name → size (rank enumeration for the SPMD
                         pass; skipped when None, e.g. inside a traced
                         KVStore region that never saw a mesh).
      default_reducer  — the reducer untagged ALLREDUCE ops resolve to.
      plan_comm_dtype  — BucketPlan-level wire dtype (buckets may pin
                         their own override).
      expect_defer     — planner intent: False means PRE ops are a bug
                         even if internally consistent.
      donated_buckets  — bucket_ids whose staged buffers are donated.
      rank_programs    — per-rank issue-order override (mutation corpus;
                         real planning is SPMD so all ranks share the
                         schedule's tuple order).
      old_mesh_shape / new_mesh_shape / leaf_divisibility
                       — elastic-transition context for the reshard
                         pass (DESIGN.md §13): the dissolving and
                         forming mesh shapes, and per-leaf
                         (dim_size, divisor) static divisibility facts
                         from the new mesh's specs.
    """
    findings: list[Finding] = []
    for name in passes:
        if name == "deadlock":
            findings += check_deadlock(schedule)
        elif name == "spmd":
            findings += check_spmd(
                schedule, mesh_shape, default_reducer=default_reducer,
                rank_programs=rank_programs)
        elif name == "carry":
            findings += check_carry(schedule, expect_defer=expect_defer)
        elif name == "accounting":
            findings += check_accounting(
                schedule, plan_comm_dtype=plan_comm_dtype,
                default_reducer=default_reducer)
        elif name == "donation":
            findings += check_donation(schedule, donated_buckets)
        elif name == "reshard":
            findings += check_reshard(
                schedule, old_mesh_shape=old_mesh_shape,
                new_mesh_shape=new_mesh_shape,
                leaf_divisibility=leaf_divisibility)
        else:
            raise ValueError(f"unknown analysis pass {name!r}")
    return AnalysisReport(tuple(findings), num_ops=len(schedule.ops))


def verify_schedule(
    schedule: CommSchedule,
    *,
    mesh_shape: Mapping[str, int] | None = None,
    default_reducer: str = "flat",
    plan_comm_dtype: Any = None,
    expect_defer: bool | None = None,
    donated_buckets: Iterable[int] = (),
    rank_programs: Mapping[tuple[int, ...], Sequence[int]] | None = None,
    old_mesh_shape: Mapping[str, int] | None = None,
    new_mesh_shape: Mapping[str, int] | None = None,
    leaf_divisibility: Mapping[str, tuple[int, int]] | None = None,
) -> AnalysisReport:
    """``run_passes`` that raises ``ScheduleError`` (with the witness in
    its message) if any pass found anything — the ``verify=`` hook."""
    return run_passes(
        schedule,
        mesh_shape=mesh_shape,
        default_reducer=default_reducer,
        plan_comm_dtype=plan_comm_dtype,
        expect_defer=expect_defer,
        donated_buckets=donated_buckets,
        rank_programs=rank_programs,
        old_mesh_shape=old_mesh_shape,
        new_mesh_shape=new_mesh_shape,
        leaf_divisibility=leaf_divisibility,
    ).raise_if_failed()
