"""Ground-truth-BAD schedules: the analyzer's own test corpus.

A verifier tested only on green inputs proves nothing — each entry here
injects one known-bad schedule (dropped chain edge, rank-swapped RS
order, mis-tagged phase, orphaned PRE gather, duplicate op id, …) and
names the pass + error class that OWNS it.  Tests assert every mutation
is caught by exactly that pass with that code, so the corpus pins the
analyzer's behavior against regressions in both directions: a pass that
stops firing fails, and a pass that starts firing on the valid baseline
cases fails too.

Every mutation starts from a schedule a real planner produced (or the
hand-rolled equivalent) and applies one ``dataclasses.replace``-style
edit, so the corpus stays honest about what "one bug away from
shipping" looks like.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.core.buckets import Bucket, BucketPlan, LeafInfo
from repro.core.pipeline_program import compose_step, plan_pipeline
from repro.core.registry import get_strategy
from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    POST,
    PRE,
    RECV,
    REDUCE_SCATTER,
    REGROUP,
    RESHARD,
    SEND,
    CollectiveOp,
    CommSchedule,
)
from repro.core.stepprogram import zero1_schedule

MESH = {"data": 8}
PP_MESH = {"data": 8, "stage": 2}
OLD_MESH_RS = {"data": 2, "model": 4}
NEW_MESH_RS = {"data": 2, "model": 2}


def synthetic_plan(n_buckets: int = 4, num_channels: int = 2,
                   leaves_per_bucket: int = 2,
                   pin=None) -> BucketPlan:
    """Round-robin-channel BucketPlan like ``make_bucket_plan`` builds
    (the tests/test_schedule_ir.py idiom)."""
    buckets, idx = [], 0
    for bid in range(n_buckets):
        leaves = tuple(
            LeafInfo(name=f"g{idx + j}", index=idx + j, shape=(4,),
                     dtype=jnp.float32, size=4)
            for j in range(leaves_per_bucket))
        idx += leaves_per_bucket
        buckets.append(Bucket(
            leaves=leaves, reduce_axes=("data",),
            channel=bid % num_channels, bucket_id=bid, comm_dtype=pin))
    return BucketPlan(buckets=tuple(buckets), treedef=None,
                      num_leaves=idx, comm_dtype=jnp.float32)


def _zero1(strategy: str = "concom", *, defer: bool,
           clip: bool = False) -> CommSchedule:
    plan = synthetic_plan(pin=jnp.float32)
    base = get_strategy(strategy).plan(plan)
    return zero1_schedule(base, dp_axes=("data",), clip=clip,
                          defer_ag=defer)


def _replace_op(s: CommSchedule, op_id: int, **changes) -> CommSchedule:
    ops = tuple(dataclasses.replace(op, **changes)
                if op.op_id == op_id else op for op in s.ops)
    return CommSchedule(ops)


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One known-bad schedule and the pass/class that must catch it."""

    name: str
    owner: str               # the pass that owns this error class
    code: str                # the Finding.code it must raise
    description: str
    build: Callable[[], tuple[CommSchedule, dict[str, Any]]]
    # build() -> (schedule, run_passes context kwargs)


def _dropped_chain_edge():
    # funnel = ONE serialized chain; dropping an edge leaves two
    # allreduces racing on the same communicator
    s = get_strategy("funnel").plan(synthetic_plan(num_channels=1))
    victim = s.ops[2].op_id
    return _replace_op(s, victim, depends_on=()), {"mesh_shape": MESH}


def _rank_swapped_rs_order():
    # the schedule itself is valid — the divergence is per-rank issue
    # order (one rank runs the MPI_Group funnel backwards)
    s = get_strategy("concom").plan(synthetic_plan(num_channels=1))
    order = tuple(op.op_id for op in s.ops)
    programs = {(r,): order for r in range(MESH["data"])}
    programs[(MESH["data"] - 1,)] = tuple(reversed(order))
    return s, {"mesh_shape": MESH, "rank_programs": programs}


def _unknown_axis():
    s = get_strategy("concom").plan(synthetic_plan())
    op = s.ops[0]
    bad = dataclasses.replace(op.bucket, reduce_axes=("nodata",))
    return _replace_op(s, op.op_id, bucket=bad), {"mesh_shape": MESH}


def _mis_tagged_phase():
    # an UPDATE tagged PRE has no carried input to read next step
    s = _zero1(defer=True)
    upd = next(op for op in s.ops if op.kind == "update")
    return _replace_op(s, upd.op_id, phase=PRE), {"expect_defer": True}


def _orphaned_pre_gather():
    # a deferred gather for a bucket no UPDATE produces: the carry slot
    # it reads was never written
    s = _zero1(defer=True)
    ghost = Bucket(
        leaves=(LeafInfo(name="ghost", index=99, shape=(4,),
                         dtype=jnp.float32, size=4),),
        reduce_axes=("data",), channel=0, bucket_id=77,
        comm_dtype=jnp.float32)
    extra = CollectiveOp(
        op_id=max(op.op_id for op in s.ops) + 1, bucket=ghost,
        chain=0, kind=ALL_GATHER, phase=PRE)
    return CommSchedule(s.ops + (extra,)), {"expect_defer": True}


def _half_written_carry():
    # one bucket's gather dropped while the rest defer: its UPDATE lands
    # in the carry but nothing ever gathers it
    s = _zero1(defer=True)
    victim = next(op.op_id for op in s.ops
                  if op.kind == ALL_GATHER and op.phase == PRE)
    ops = tuple(op for op in s.ops if op.op_id != victim)
    return CommSchedule(ops), {"expect_defer": True}


def _mixed_defer():
    # one gather flipped back to POST while its siblings defer: that
    # bucket is applied in-step AND re-applied from the carry
    s = _zero1(defer=True)
    victim = next(op.op_id for op in s.ops
                  if op.kind == ALL_GATHER and op.phase == PRE)
    return _replace_op(s, victim, phase=POST), {"expect_defer": True}


def _duplicate_op_id():
    s = get_strategy("concom").plan(synthetic_plan())
    dup = dataclasses.replace(s.ops[-1], op_id=s.ops[0].op_id)
    return CommSchedule(s.ops[:-1] + (dup,)), {}


def _dependency_cycle():
    s = get_strategy("funnel").plan(synthetic_plan(num_channels=1))
    first, second = s.ops[0].op_id, s.ops[1].op_id
    return _replace_op(s, first, depends_on=(second,)), {}


def _post_reads_pre():
    # unrolled across steps this is a cycle: the POST op waits on a
    # result that only exists after the step it belongs to finishes
    s = _zero1(defer=True)
    pre_ag = next(op for op in s.ops
                  if op.kind == ALL_GATHER and op.phase == PRE)
    extra = CollectiveOp(
        op_id=max(op.op_id for op in s.ops) + 1, bucket=pre_ag.bucket,
        chain=pre_ag.chain, depends_on=(pre_ag.op_id,),
        kind=ALLREDUCE, phase=POST)
    return CommSchedule(s.ops + (extra,)), {"expect_defer": True}


def _missing_data_edge():
    # two ops on different channels stage the same leaf with no path —
    # the later one may read the flat-output slot before it is written
    plan = synthetic_plan(n_buckets=2, num_channels=2)
    b0, b1 = plan.buckets
    b1 = dataclasses.replace(b1, leaves=b0.leaves)
    ops = (CollectiveOp(op_id=0, bucket=b0, chain=0),
           CollectiveOp(op_id=1, bucket=b1, chain=1))
    return CommSchedule(ops), {"mesh_shape": MESH}


def _rs_without_consumer():
    s = get_strategy("rsag").plan(synthetic_plan())
    ag = next(op for op in s.ops if op.kind == ALL_GATHER)
    return _replace_op(s, ag.op_id, depends_on=()), {"mesh_shape": MESH}


def _ag_dtype_mismatch():
    s = get_strategy("rsag").plan(synthetic_plan(pin=jnp.float32))
    ag = next(op for op in s.ops if op.kind == ALL_GATHER)
    bad = dataclasses.replace(ag.bucket, comm_dtype=jnp.bfloat16)
    return _replace_op(s, ag.op_id, bucket=bad), {"mesh_shape": MESH}


def _reducer_tag_on_two_phase():
    s = get_strategy("rsag").plan(synthetic_plan())
    rs = next(op for op in s.ops if op.kind == REDUCE_SCATTER)
    return (_replace_op(s, rs.op_id, reducer="hierarchical"),
            {"mesh_shape": MESH})


def _compressed_int_wire():
    s = get_strategy("concom").plan(synthetic_plan(pin=jnp.int8))
    ops = tuple(dataclasses.replace(op, reducer="compressed")
                for op in s.ops)
    return CommSchedule(ops), {"mesh_shape": MESH}


def _update_bucket_not_f32():
    s = _zero1(defer=False)
    upd = next(op for op in s.ops if op.kind == "update")
    bad = dataclasses.replace(upd.bucket, comm_dtype=jnp.bfloat16)
    return _replace_op(s, upd.op_id, bucket=bad), {}


def _unknown_reducer():
    s = get_strategy("concom").plan(synthetic_plan())
    return (_replace_op(s, s.ops[0].op_id, reducer="bogus"),
            {"mesh_shape": MESH})


def synthetic_reshard_schedule(
        streams: tuple[str, ...] = ("param", "inner/m"),
) -> CommSchedule:
    """A hand-rolled elastic transition like ``plan_reshard`` emits:
    per-stream gather RESHARDs (old mesh), ONE REGROUP barrier joining
    them all, then per-stream scatter RESHARDs (new mesh)."""
    def bucket(bid: int, stream: str) -> Bucket:
        leaves = tuple(
            LeafInfo(name=f"{stream}:w{j}", index=j, shape=(16,),
                     dtype=jnp.float32, size=16)
            for j in range(2))
        return Bucket(leaves=leaves, reduce_axes=("data",),
                      channel=0, bucket_id=bid, comm_dtype=jnp.float32)

    ops: list[CollectiveOp] = []
    for si, stream in enumerate(streams):
        ops.append(CollectiveOp(
            op_id=si, bucket=bucket(si, stream), chain=si,
            kind=RESHARD))
    rg_id = len(streams)
    regroup_bucket = Bucket(
        leaves=(LeafInfo(name="__regroup", index=0, shape=(),
                         dtype=jnp.float32, size=1),),
        reduce_axes=("data", "model"), channel=0, bucket_id=rg_id,
        comm_dtype=jnp.float32)
    ops.append(CollectiveOp(
        op_id=rg_id, bucket=regroup_bucket, chain=0,
        depends_on=tuple(range(len(streams))), kind=REGROUP))
    for si, stream in enumerate(streams):
        oid = rg_id + 1 + si
        ops.append(CollectiveOp(
            op_id=oid, bucket=bucket(oid, stream), chain=si,
            depends_on=(rg_id,), kind=RESHARD))
    return CommSchedule(tuple(ops))


_RS_CTX = {"old_mesh_shape": OLD_MESH_RS, "new_mesh_shape": NEW_MESH_RS}


def _pre_crosses_regroup():
    # the acceptance-criteria mutation: a deferred op inside a
    # transition schedule reads a carry of the mesh being dissolved
    s = synthetic_reshard_schedule()
    victim = s.ops[-1].op_id
    return _replace_op(s, victim, phase=PRE), dict(_RS_CTX)


def _reshard_leaf_lost():
    # one stream gathered off the old mesh but never scattered onto
    # the new one — state silently dropped across the transition
    s = synthetic_reshard_schedule()
    ops = s.ops[:-1]
    return CommSchedule(ops), dict(_RS_CTX)


def _reshard_op_escapes_regroup():
    # the barrier forgets one gather: the old mesh may dissolve while
    # that RESHARD is still in flight
    s = synthetic_reshard_schedule()
    rg = next(op for op in s.ops if op.kind == REGROUP)
    return (_replace_op(s, rg.op_id,
                        depends_on=tuple(rg.depends_on[1:])),
            dict(_RS_CTX))


def _pp_unmatched_send():
    # the final RECV of a 2-stage GPipe round dropped: the cotangent the
    # last stage packed is never delivered — stage 0 waits forever
    s = plan_pipeline(2, 1, kind="gpipe", activation_bytes=64).schedule
    assert s.ops[-1].kind == RECV
    return CommSchedule(s.ops[:-1]), {"mesh_shape": PP_MESH}


def _pp_bucket(bid: int, name: str) -> Bucket:
    return Bucket(
        leaves=(LeafInfo(name=name, index=0, shape=(16,),
                         dtype=jnp.float32, size=16),),
        reduce_axes=("stage",), channel=0, bucket_id=bid,
        comm_dtype=jnp.float32)


def _pp_crossed_pairs():
    # two boundary crossings interleaved recv-first on both chains:
    # each pair's send transitively waits on the OTHER pair's recv, so
    # neither payload is ever packed (pair B's data edge is necessarily
    # missing — with it the crossing would be an outright cycle)
    ba, bb = _pp_bucket(0, "pp/act/a"), _pp_bucket(1, "pp/act/b")
    ops = (
        CollectiveOp(op_id=0, bucket=bb, chain=1, kind=RECV, shift=1),
        CollectiveOp(op_id=1, bucket=ba, chain=0, depends_on=(0,),
                     kind=SEND, shift=1),
        CollectiveOp(op_id=2, bucket=ba, chain=1, depends_on=(1,),
                     kind=RECV, shift=1),
        CollectiveOp(op_id=3, bucket=bb, chain=0, depends_on=(2,),
                     kind=SEND, shift=1),
    )
    return CommSchedule(ops), {"mesh_shape": PP_MESH}


def _pp_boundary_bytes():
    # the RECV's bucket half the SEND's size: the two stages disagree on
    # the boundary tensor — the delivered activation would be truncated
    s = plan_pipeline(2, 1, kind="gpipe", activation_bytes=64).schedule
    rcv = next(op for op in s.ops if op.kind == RECV)
    leaf = rcv.bucket.leaves[0]
    half = dataclasses.replace(leaf, shape=(leaf.size // 2,),
                               size=leaf.size // 2)
    bad = dataclasses.replace(rcv.bucket, leaves=(half,))
    return _replace_op(s, rcv.op_id, bucket=bad), {"mesh_shape": PP_MESH}


def _donated_pre_read():
    s = _zero1(defer=True)
    pre = next(op for op in s.ops if op.phase == PRE)
    return s, {"expect_defer": True,
               "donated_buckets": frozenset({pre.bucket.bucket_id})}


MUTATIONS: tuple[Mutation, ...] = (
    Mutation("dropped-chain-edge", "spmd", "concurrent-collectives",
             "funnel chain edge removed → two allreduces race on one "
             "communicator", _dropped_chain_edge),
    Mutation("rank-swapped-rs-order", "spmd", "rank-divergence",
             "one rank issues the (valid) schedule in reverse order",
             _rank_swapped_rs_order),
    Mutation("unknown-axis", "spmd", "unknown-axis",
             "op reduces over an axis the mesh does not have",
             _unknown_axis),
    Mutation("mis-tagged-phase", "carry", "mis-tagged-phase",
             "an UPDATE op tagged PRE (only gathers may defer)",
             _mis_tagged_phase),
    Mutation("orphaned-pre-gather", "carry", "orphaned-pre-gather",
             "deferred gather whose bucket no UPDATE produces",
             _orphaned_pre_gather),
    Mutation("half-written-carry", "carry", "half-written-carry",
             "one bucket's gather dropped while the rest defer",
             _half_written_carry),
    Mutation("mixed-defer", "carry", "mixed-defer",
             "one gather flipped POST while its siblings defer "
             "(double-apply)", _mixed_defer),
    Mutation("duplicate-op-id", "deadlock", "duplicate-op-id",
             "two ops share an op_id", _duplicate_op_id),
    Mutation("dependency-cycle", "deadlock", "cycle",
             "first funnel op made to depend on the second",
             _dependency_cycle),
    Mutation("post-reads-pre", "deadlock", "cross-step-cycle",
             "a POST op depends on a deferred (PRE) result",
             _post_reads_pre),
    Mutation("missing-data-edge", "deadlock", "missing-data-edge",
             "two ops stage the same leaf with no dependency path",
             _missing_data_edge),
    Mutation("rs-without-consumer", "accounting", "rs-unconsumed",
             "reduce-scatter whose shard nothing gathers or updates",
             _rs_without_consumer),
    Mutation("ag-dtype-mismatch", "accounting", "rs-ag-dtype",
             "all-gather disagrees with its producer on the wire dtype",
             _ag_dtype_mismatch),
    Mutation("reducer-tag-on-two-phase", "accounting",
             "ignored-reducer-tag",
             "reducer tag on a REDUCE_SCATTER op (silently ignored by "
             "the emitter)", _reducer_tag_on_two_phase),
    Mutation("compressed-int-wire", "accounting", "comm-dtype-illegal",
             "compressed reducer on an int8 wire (quantizer needs "
             "floats)", _compressed_int_wire),
    Mutation("update-bucket-not-f32", "accounting", "update-dtype",
             "UPDATE bucket not pinned to f32 shard math",
             _update_bucket_not_f32),
    Mutation("unknown-reducer", "accounting", "unknown-reducer",
             "op tagged with an unregistered reducer",
             _unknown_reducer),
    Mutation("pp-unmatched-send", "deadlock", "send-unmatched",
             "a pipeline SEND whose RECV was dropped — the payload is "
             "packed but never delivered", _pp_unmatched_send),
    Mutation("pp-crossed-pairs", "deadlock", "crossed-send-recv",
             "two SEND/RECV pairs crossed recv-first on both chains "
             "(mutual rendezvous wait)", _pp_crossed_pairs),
    Mutation("pp-boundary-bytes", "accounting", "send-recv-bytes",
             "stage-boundary RECV sized differently from its SEND",
             _pp_boundary_bytes),
    Mutation("donated-pre-read", "donation", "donated-pre-read",
             "deferred gather reads a bucket whose buffer is donated",
             _donated_pre_read),
    Mutation("pre-crosses-regroup", "reshard", "pre-crosses-regroup",
             "an op tagged PRE inside an elastic transition schedule "
             "(deferred carry crossing the regroup barrier)",
             _pre_crosses_regroup),
    Mutation("reshard-leaf-lost", "reshard", "leaf-lost",
             "a gathered stream never scattered onto the new mesh",
             _reshard_leaf_lost),
    Mutation("reshard-op-escapes-regroup", "reshard",
             "op-escapes-regroup",
             "the REGROUP barrier does not join one old-side gather",
             _reshard_op_escapes_regroup),
)


def valid_cases() -> list[tuple[str, CommSchedule, dict[str, Any]]]:
    """Unmutated baselines the analyzer must pass CLEAN — the zero-
    false-positive half of the corpus contract."""
    out: list[tuple[str, CommSchedule, dict[str, Any]]] = []
    plan = synthetic_plan(n_buckets=6, num_channels=3)
    for name in ("funnel", "concom", "depcha", "priority", "rsag"):
        out.append((name, get_strategy(name).plan(plan),
                    {"mesh_shape": MESH, "expect_defer": False,
                     "plan_comm_dtype": jnp.float32}))
    for strat in ("concom", "rsag"):
        for defer in (False, True):
            out.append((
                f"zero1-{strat}-defer{int(defer)}",
                _zero1(strat, defer=defer, clip=True),
                {"mesh_shape": MESH, "expect_defer": defer,
                 "plan_comm_dtype": jnp.float32}))
    out.append(("reshard-transition", synthetic_reshard_schedule(),
                dict(_RS_CTX)))
    for kind in ("gpipe", "1f1b"):
        pp = plan_pipeline(2, 4, kind=kind, activation_bytes=64)
        out.append((f"pp-{kind}", pp.schedule,
                    {"mesh_shape": PP_MESH, "expect_defer": False,
                     "plan_comm_dtype": jnp.float32}))
    pp = plan_pipeline(2, 4, kind="1f1b", activation_bytes=64)
    joint, _ = compose_step(pp, _zero1("concom", defer=False))
    out.append(("pp-1f1b-zero1-joint", joint,
                {"mesh_shape": PP_MESH, "expect_defer": False,
                 "plan_comm_dtype": jnp.float32}))
    return out
