"""Static CommSchedule verifier (DESIGN.md §11).

The paper's central hazard — "incorrect designs can easily lead to
deadlocks or program crashes" when collectives are embedded in a
training DAG — becomes *checkable* here: six pure-Python analysis
passes run over any ``CommSchedule``/``StepProgram`` BEFORE anything is
traced, and reject malformed schedules with a printable witness instead
of a cryptic XLA error (or silent wrong numbers).

Passes (``repro.analysis.passes``):
  deadlock    — cycle / stuck-schedule detection over the union of chain
                deps, data deps (ops reading the CURRENT flat outputs)
                and cross-step PRE→POST carry edges, with a topological
                witness on failure.
  spmd        — per-rank issue-order simulation per mesh-axis group:
                every rank in a communicator group must issue the same
                collective sequence per channel (the paper's
                funnel-vs-concurrent deadlock scenario), with reducer
                families expanded into their stage collectives.
  carry       — ``zero1_plan="deferred"`` soundness: every PRE
                all-gather is covered by a POST UPDATE producing the
                same bucket/dtype/shard, with exact bucket-set equality
                so ``opt_state["pending"]`` is never read uninitialized
                or half-written.
  accounting  — RS/AG pair symmetry, ``comm_dtype`` legality per
                reducer family, deferred-bytes consistency.
  donation    — staged buffers both donated and read by a PRE op of the
                next step.
  reshard     — elastic-transition soundness (DESIGN.md §13): RESHARD
                ops bracketed by a REGROUP barrier, no PRE op crossing
                the regroup, byte conservation per leaf across the old
                and new meshes, static divisibility on the new mesh.

Entry points:
  ``verify_schedule``  — raise ``ScheduleError`` on the first finding
                         (the ``verify=`` hook in GradSync / KVStore).
  ``run_passes``       — collect every finding into an
                         ``AnalysisReport`` (CLI / benchmarks).
  ``python -m repro.analyze`` — lint the full strategy × reducer ×
                         channels × zero1-plan registry cross-product.
"""
from repro.analysis.passes import (
    PASS_NAMES,
    Finding,
    ScheduleError,
    Witness,
    check_accounting,
    check_carry,
    check_deadlock,
    check_donation,
    check_reshard,
    check_spmd,
    structural_findings,
)
from repro.analysis.verifier import (
    AnalysisReport,
    run_passes,
    verify_schedule,
)

__all__ = [
    "AnalysisReport",
    "Finding",
    "PASS_NAMES",
    "ScheduleError",
    "Witness",
    "check_accounting",
    "check_carry",
    "check_deadlock",
    "check_donation",
    "check_reshard",
    "check_spmd",
    "run_passes",
    "structural_findings",
    "verify_schedule",
]
