"""``python -m repro.analyze`` — lint every plannable schedule.

Exhaustively plans the registry cross-product

    strategy × reducer × num_channels × zero1 plan × accum

on two mesh topologies (the 8-fake-device dp mesh and the dp=2 × tp=4
smoke mesh) through the REAL planning path (``GradSync``), runs all
five analysis passes on each resulting schedule, and reports.  Exit
code 0 iff every plannable cell is clean — cells a constructor contract
rejects up front (e.g. two-phase strategies with a hierarchical
reducer) are counted separately, not failures.

Everything is static: the mesh is a stand-in carrying only axis names
and sizes, gradients are ShapeDtypeStructs — no devices, no tracing, no
XLA.  ``--json PATH`` writes the machine-readable report.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.sim.autotune  # noqa: F401  (registers the "auto" strategy)
from repro.core.kvstore import GradSync, GradSyncConfig
from repro.core.registry import reducer_names, strategy_names

from repro.analysis.verifier import run_passes


class StaticMesh:
    """Mesh stand-in: axis names + sizes, no devices.  Enough for
    ``make_bucket_plan`` / ``missing_axes`` / ``GradSync`` planning."""

    def __init__(self, shape: dict[str, int]):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    def __repr__(self):
        return f"StaticMesh({self.shape})"


def _model(model_axis: str | None):
    """A small transformer-ish gradient pytree + param specs: a few MiB
    across mixed shapes so bucketing produces multiple buckets per
    channel; ``model_axis`` shards the matmul weights (their specs then
    exclude that axis from the reduce set, like real TP)."""
    mp = model_axis
    shapes = {
        "embed": ((1024, 128), P()),
        "w_in": ((128, 512), (P(None, mp) if mp else P())),
        "w_out": ((512, 128), (P(mp, None) if mp else P())),
        "b_in": ((512,), (P(mp) if mp else P())),
        "b_out": ((128,), P()),
        "head": ((128, 1024), P()),
        "scale": ((), P()),
    }
    grads = {k: jax.ShapeDtypeStruct(s, jnp.float32)
             for k, (s, _) in shapes.items()}
    specs = {k: spec for k, (_, spec) in shapes.items()}
    return grads, specs


MESHES: dict[str, tuple[dict[str, int], str | None]] = {
    # name -> (axis sizes, model-sharding axis)
    "dp8": ({"data": 8}, None),
    "smoke-dp2tp4": ({"data": 2, "model": 4}, "model"),
}


def lint_cell(mesh_name: str, strategy: str, reducer: str,
              num_channels: int, zero1: str, accum: int) -> dict[str, Any]:
    """Plan one cross-product cell and run the analyzer on the result."""
    mesh_shape, model_axis = MESHES[mesh_name]
    mesh = StaticMesh(mesh_shape)
    grads, specs = _model(model_axis)
    dp_axes = ("data",) if zero1 != "none" else ()
    cfg = GradSyncConfig(
        strategy=strategy,
        reducer=reducer,
        bucket_bytes=256 * 1024,
        num_channels=num_channels,
        exclude_axes=dp_axes,
        zero1_dp_axes=dp_axes,
        zero1_clip=zero1 != "none",
        zero1_defer_ag=zero1 == "deferred",
        zero1_accum=accum,
        verify=False,            # run_passes below collects ALL findings
    )
    cell = {
        "mesh": mesh_name, "strategy": strategy, "reducer": reducer,
        "channels": num_channels, "zero1": zero1, "accum": accum,
    }
    try:
        gs = GradSync(cfg, mesh, specs, grads)
    except ValueError as e:
        # constructor contract (e.g. two-phase × hierarchical): the cell
        # is unreachable by construction, not an analyzer failure
        return {**cell, "status": "rejected", "reason": str(e)}
    report = run_passes(
        gs.schedule,
        mesh_shape=gs.mesh_shape,
        default_reducer=cfg.reducer,
        plan_comm_dtype=cfg.comm_dtype,
        expect_defer=cfg.zero1_defer_ag,
    )
    status = "ok" if report.ok else "error"
    return {**cell, "status": status, **report.to_dict()}


def iter_cells():
    for mesh_name in MESHES:
        for strategy in strategy_names():
            for reducer in reducer_names():
                for num_channels in (1, 4):
                    for zero1 in ("none", "scheduled", "deferred"):
                        for accum in (1, 4):
                            yield (mesh_name, strategy, reducer,
                                   num_channels, zero1, accum)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analyze",
        description="statically lint the full strategy x reducer x "
                    "channels x zero1 x accum registry cross-product")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--verbose", action="store_true",
                    help="print every cell, not just failures")
    args = ap.parse_args(argv)

    cells = [lint_cell(*c) for c in iter_cells()]
    n_ok = sum(c["status"] == "ok" for c in cells)
    n_rej = sum(c["status"] == "rejected" for c in cells)
    bad = [c for c in cells if c["status"] == "error"]

    def _label(c):
        return (f"{c['mesh']}/{c['strategy']}/{c['reducer']}"
                f"/ch{c['channels']}/{c['zero1']}/acc{c['accum']}")

    for c in cells:
        if c["status"] == "error":
            classes = sorted({f"{f['pass']}:{f['code']}"
                              for f in c["findings"]})
            print(f"ERROR    {_label(c)}: {classes}")
            for f in c["findings"]:
                print(f"         {f['message']}")
        elif args.verbose:
            print(f"{c['status']:8s} {_label(c)}")

    print(f"repro.analyze: {len(cells)} cells — {n_ok} ok, "
          f"{n_rej} rejected by contract, {len(bad)} analyzer errors")

    if args.json:
        from repro.obs import bench_metadata

        with open(args.json, "w") as f:
            json.dump({"meta": bench_metadata(),
                       "cells": cells,
                       "summary": {"total": len(cells), "ok": n_ok,
                                   "rejected": n_rej,
                                   "errors": len(bad)}}, f, indent=2)
        print(f"report written to {args.json}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
