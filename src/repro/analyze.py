"""``python -m repro.analyze`` — the static schedule linter
(see ``repro.analysis.cli`` for what gets checked)."""
from repro.analysis.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
