"""Shared provenance metadata for every artifact the repo writes
(BENCH_*.json, fitted network profiles, traces) — DESIGN.md §12.

Calibration fits a cost model FROM these artifacts, so it must be able
to trust where a row came from: which mesh, which jax/jaxlib, which
platform, when.  One helper, one schema version, every writer.
"""
from __future__ import annotations

import platform
from typing import Any, Mapping

from repro.obs.events import utc_now

SCHEMA_VERSION = 1


def bench_metadata(
    mesh_shape: Mapping[str, int] | None = None,
    **extra: Any,
) -> dict[str, Any]:
    """The metadata header embedded in every BENCH_*.json / profile.

    jax/jaxlib versions and the backend are best-effort: the analysis
    CLI writes BENCH_analyze.json without importing jax, and a header
    must never be the reason an artifact fails to write.
    """
    meta: dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "utc": utc_now(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax
        import jaxlib

        meta["jax_version"] = jax.__version__
        meta["jaxlib_version"] = jaxlib.__version__
        meta["backend"] = jax.default_backend()
        meta["device_count"] = jax.device_count()
    except Exception:
        meta["jax_version"] = None
    if mesh_shape is not None:
        meta["mesh_shape"] = dict(mesh_shape)
    meta.update(extra)
    return meta
