"""``python -m repro.obs`` — measure, diff, trace, and fit (DESIGN.md §12).

Builds a small sharded param stack on a 2x4 (data, model) mesh of fake
CPU devices (``__main__`` forces 8 via XLA_FLAGS, same pattern as
``python -m repro.sim``), plans the configured strategy's schedule, then:

  --diff         per-op sim-vs-measured table, largest divergence first
  --trace PATH   one merged Chrome/Perfetto trace: a simulated and a
                 measured track for the SAME schedule (`make trace-smoke`)
  --fit          measure across bucket sizes, fit the alpha-beta
                 NetworkModel, write the per-mesh profile `auto` prefers
                 (`make calibrate-smoke`)
"""
from __future__ import annotations

import argparse
import json
from typing import Any


def build_setup(strategy: str, reducer: str, bucket_kib: int):
    """A GradSync + random global grads over a synthetic 4-layer param
    stack (TP-sharded matmuls + replicated norms — all three grad
    reduce-axis groups appear)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.kvstore import GradSync, GradSyncConfig
    from repro.parallel.sharding import localize_structs

    if jax.device_count() < 8:
        raise SystemExit(
            "need 8 devices — run as `python -m repro.obs` (which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    d_model, d_ff = 128, 512
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    for i in range(4):
        params[f"layer{i}.wi"] = jnp.zeros((d_model, d_ff), jnp.float32)
        specs[f"layer{i}.wi"] = P(None, "model")
        params[f"layer{i}.wo"] = jnp.zeros((d_ff, d_model), jnp.float32)
        specs[f"layer{i}.wo"] = P("model", None)
        params[f"layer{i}.scale"] = jnp.zeros((d_model,), jnp.float32)
        specs[f"layer{i}.scale"] = P()
    cfg = GradSyncConfig(strategy=strategy, reducer=reducer,
                         mean_axes=("data",),
                         bucket_bytes=bucket_kib << 10)
    structs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    gs = GradSync(cfg, mesh, specs, localize_structs(structs, specs, mesh))
    key = jax.random.PRNGKey(0)
    grads = {k: jax.random.normal(jax.random.fold_in(key, i),
                                  v.shape, v.dtype)
             for i, (k, v) in enumerate(sorted(params.items()))}
    return gs, grads


def _sim_timeline(gs):
    import numpy as np

    from repro.sim.engine import SimConfig, simulate

    return simulate(
        gs.schedule, gs.mesh_shape,
        sim=SimConfig(itemsize=np.dtype(gs.cfg.comm_dtype).itemsize,
                      reducer=gs.cfg.reducer,
                      fused_staging=gs.cfg.use_fused_staging))


def _measure(gs, grads, reps: int):
    from repro.obs.measure import measured_gradsync

    _, timeline, info = measured_gradsync(gs, grads, reps=reps)
    return timeline, info


def cmd_diff(gs, sim_tl, meas_tl) -> None:
    """Op-by-op divergence table, largest |log ratio| first."""
    sim_by = {e.op_id: e for e in sim_tl.events}
    rows = []
    for ev in meas_tl.events:
        se = sim_by[ev.op_id]
        sim_us = se.duration * 1e6
        meas_us = ev.duration * 1e6
        ratio = meas_us / sim_us if sim_us > 0 else float("inf")
        rows.append((ev.op_id, ev.kind, ev.bucket_id, sim_us, meas_us,
                     ratio))
    rows.sort(key=lambda r: abs(__import__("math").log(max(r[5], 1e-12))),
              reverse=True)
    print(f"{'op':>4} {'kind':<16} {'bucket':>6} {'sim_us':>10} "
          f"{'meas_us':>10} {'meas/sim':>9}")
    for op_id, kind, bid, s, m, r in rows:
        print(f"{op_id:>4} {kind:<16} {bid:>6} {s:>10.1f} {m:>10.1f} "
              f"{r:>9.2f}")
    print(f"total sim {sim_tl.step_time * 1e6:.1f}us  "
          f"measured(serial) {meas_tl.step_time * 1e6:.1f}us  "
          f"largest divergence: op {rows[0][0]} ({rows[0][1]}) "
          f"x{rows[0][5]:.2f}" if rows else "no events")


def cmd_trace(gs, sim_tl, meas_tl, path: str, strategy: str) -> None:
    from repro.sim.trace import write_chrome_trace

    write_chrome_trace(path, {
        f"measured:{strategy}": meas_tl,
        f"simulated:{strategy}": sim_tl,
    })
    ok = len(sim_tl.events) == len(meas_tl.events) == len(gs.schedule.ops)
    print(f"wrote {path}: simulated track {len(sim_tl.events)} ops, "
          f"measured track {len(meas_tl.events)} ops, IR "
          f"{len(gs.schedule.ops)} ops — "
          f"{'match' if ok else 'MISMATCH'}")


def cmd_fit(args) -> str:
    """Measure across bucket sizes and both transport families, fit the
    NetworkModel, persist the per-mesh profile."""
    from repro.obs.calibrate import (
        REL_RESIDUAL_MAX,
        fit_network,
        save_profile,
    )
    from repro.obs.measure import measurement_rows

    rows: list[dict] = []
    mesh_shape = None
    for strategy in ("concom", "rsag"):     # allreduce rows + RS/AG rows
        for kib in (16, 64, 256):
            gs, grads = build_setup(strategy, args.reducer, kib)
            mesh_shape = gs.mesh_shape
            meas_tl, _ = _measure(gs, grads, args.reps)
            rows.extend(measurement_rows(gs.schedule, meas_tl, mesh_shape))
    model, info = fit_network(rows)
    path = save_profile(model, mesh_shape, dir=args.profile_dir, info=info)
    print(f"fitted {len(rows)} rows -> {path}")
    print(json.dumps(info["axes"], indent=1, sort_keys=True))
    print(f"rms residual {info['rms_residual_s'] * 1e6:.2f}us "
          f"({info['rel_residual'] * 100:.0f}% of signal) — "
          f"quality {info['quality']}")
    if info["quality"] != "ok":
        print("WARNING: poor fit (residual exceeds "
              f"{REL_RESIDUAL_MAX * 100:.0f}% of the measured signal) — "
              "profile saved for inspection, but `auto` will ignore it")
    return path


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="measured per-op telemetry: diff/trace/fit")
    p.add_argument("--strategy", default="concom")
    p.add_argument("--reducer", default="flat")
    p.add_argument("--bucket-kib", type=int, default=64)
    p.add_argument("--reps", type=int, default=3,
                   help="timed dispatches per op (min taken)")
    p.add_argument("--diff", action="store_true",
                   help="print the per-op sim-vs-measured table")
    p.add_argument("--trace", metavar="PATH",
                   help="write a merged sim+measured Chrome trace")
    p.add_argument("--fit", action="store_true",
                   help="fit the NetworkModel and write the profile")
    p.add_argument("--profile-dir", default=None,
                   help="profile output dir (default "
                        "$REPRO_NETPROFILE_DIR or results/netprofiles)")
    args = p.parse_args(argv)

    if args.fit:
        cmd_fit(args)
        return

    gs, grads = build_setup(args.strategy, args.reducer, args.bucket_kib)
    sim_tl = _sim_timeline(gs)
    meas_tl, info = _measure(gs, grads, args.reps)
    if args.trace:
        cmd_trace(gs, sim_tl, meas_tl, args.trace, args.strategy)
    if args.diff or not args.trace:
        cmd_diff(gs, sim_tl, meas_tl)
