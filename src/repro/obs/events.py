"""Structured JSONL event stream + heartbeat line (DESIGN.md §12).

One event per line, each a self-describing JSON object with a ``kind``
and a UTC timestamp — the train loop emits a ``step`` event per
optimizer step plus lifecycle events (failure / restore / straggler /
remesh), and anything downstream (trend tooling, the calibration CLI)
can replay the stream without knowing the writer's version.
"""
from __future__ import annotations

import json
import time
from datetime import datetime, timezone
from typing import Any, IO


def utc_now() -> str:
    return datetime.now(timezone.utc).isoformat()


class EventLog:
    """Append-only JSONL writer.

    ``path`` may be a filesystem path (opened in append mode, so
    restarted runs extend the same stream) or an open file-like object
    (tests pass io.StringIO).  Each ``emit`` writes one line and
    flushes — a crashed run keeps every completed step's row.
    """

    def __init__(self, path: str | IO[str] | None):
        self._own = isinstance(path, str)
        self._f: IO[str] | None = (
            open(path, "a") if isinstance(path, str) else path)

    def emit(self, kind: str, **fields: Any) -> None:
        if self._f is None:
            return
        row = {"kind": kind, "t_utc": utc_now(), "t_mono": time.monotonic()}
        row.update(fields)
        self._f.write(json.dumps(row, sort_keys=True) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None and self._own:
            self._f.close()
        self._f = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def heartbeat_line(step: int, *, loss: float | None = None,
                   step_ms: float | None = None,
                   avg_ms: float | None = None,
                   tokens_per_s: float | None = None,
                   grad_norm: float | None = None,
                   compile_s: float | None = None) -> str:
    """One human-readable status line per reporting interval.

    Emitted by the train loop next to its per-step print; every field is
    optional so serve/bench loops can reuse the format.
    """
    parts = [f"[obs] step {step}"]
    if loss is not None:
        parts.append(f"loss {loss:.4f}")
    if step_ms is not None:
        parts.append(f"step {step_ms:.1f}ms")
    if avg_ms is not None:
        parts.append(f"avg {avg_ms:.1f}ms")
    if tokens_per_s is not None:
        parts.append(f"{tokens_per_s:,.0f} tok/s")
    if grad_norm is not None:
        parts.append(f"gnorm {grad_norm:.3f}")
    if compile_s is not None:
        parts.append(f"(compile {compile_s:.2f}s excluded)")
    return " ".join(parts)
