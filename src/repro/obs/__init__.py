"""repro.obs — measured-path telemetry (DESIGN.md §12).

The subsystem that looks *back* at what actually ran:

  metrics     — counters/gauges/histograms + the shared host timer
  events      — JSONL event stream + heartbeat line
  provenance  — the metadata header every BENCH_*/profile artifact embeds
  measure     — per-op measured replay emitting sim-compatible Timelines
  calibrate   — alpha-beta NetworkModel fits + per-mesh fitted profiles

``measure`` (and anything importing jax) is imported lazily so the
pure-host pieces stay usable from no-jax contexts (the analysis CLI).
"""
from repro.obs.events import EventLog, heartbeat_line, utc_now
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    host_time_us,
)
from repro.obs.provenance import SCHEMA_VERSION, bench_metadata

__all__ = [
    "Counter",
    "EventLog",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SCHEMA_VERSION",
    "bench_metadata",
    "comm_byte_counters",
    "heartbeat_line",
    "host_time_us",
    "measured_gradsync",
    "measured_timeline",
    "utc_now",
]

_LAZY = {
    "measured_gradsync": "repro.obs.measure",
    "measured_timeline": "repro.obs.measure",
    "measurement_rows": "repro.obs.measure",
    "fit_network": "repro.obs.calibrate",
    "fit_staging": "repro.obs.calibrate",
    "fitted_network": "repro.obs.calibrate",
    "load_profile": "repro.obs.calibrate",
    "save_profile": "repro.obs.calibrate",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


def comm_byte_counters(schedule, registry: MetricsRegistry,
                       itemsize: int = 4) -> None:
    """Account one execution of ``schedule`` into byte counters keyed
    ``comm_bytes.<kind>.<reducer>.<phase>`` (RS/AG pairs each count their
    own wire pass; UPDATE/NORM move no payload)."""
    from repro.core.schedule import (
        ALL_GATHER,
        ALLREDUCE,
        REDUCE_SCATTER,
        np_itemsize,
    )

    for op in schedule.ops:
        if op.kind not in (ALLREDUCE, REDUCE_SCATTER, ALL_GATHER):
            continue
        nb = op.bucket.size * np_itemsize(op.bucket.comm_dtype, itemsize)
        tag = op.reducer or "default"
        registry.counter(
            f"comm_bytes.{op.kind}.{tag}.{op.phase}").inc(nb)
