"""Entry point: force 8 fake CPU devices BEFORE jax loads (same pattern
as ``python -m repro.sim``) so the 2x4 (data, model) mesh exists on any
host, then hand off to the CLI."""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import repro  # noqa: F401,E402  (jax compat shim before jax imports)
from repro.obs.cli import main  # noqa: E402

main()
