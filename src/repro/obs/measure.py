"""Measured per-op replay: host-timed execution of a CommSchedule, one
jitted dispatch per op, emitting the SAME ``Timeline`` structure the
simulator produces (DESIGN.md §12).

The production path runs the whole schedule inside one jitted shard_map
program — XLA may overlap ops, so per-op time is invisible from the
host.  The replay drives the identical ``_OpEmitter`` one op at a time:
each op becomes its own compiled program whose carried state (gradient
tree, RS/UPDATE shards, NORM clip scales) is passed explicitly between
dispatches as sharded global arrays.  Compilation happens untimed
(``lower().compile()``); each op then executes exactly once under
``time.perf_counter`` + ``block_until_ready`` — so at ``reps=1`` the
replayed outputs are bit-exact with the single-program execution (the
profile-on ≡ profile-off guarantee ``tests/test_obs.py`` asserts).

The resulting ``Timeline`` lays ops end-to-end on a serial clock: it
deliberately measures per-op cost, not overlap (overlap is what the
simulator models; diffing the two is the point — ``python -m repro.obs
--diff``).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Mapping

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    NORM,
    REDUCE_SCATTER,
    REGROUP,
    RESHARD,
    UPDATE,
    CommSchedule,
    _OpEmitter,
    np_itemsize,
    op_scope_name,
)
from repro.sim.engine import OpEvent, Timeline


def _shard_pspec(axes: tuple[str, ...]) -> P:
    """PartitionSpec of an RS/UPDATE shard: dim 0 split over the op's
    reduce axes, in axis order — the same tiling ``psum_scatter(...,
    tiled=True)`` produces and the ZeRO-1 opt-state specs use."""
    axes = tuple(axes)
    return P(axes) if axes else P()


def _is_pspec(x: Any) -> bool:
    return isinstance(x, P)


def measured_timeline(
    schedule: CommSchedule,
    grads: Any,
    plan: Any,
    *,
    mesh,
    param_specs: Any,
    reducer: Callable,
    reducers: Mapping[str, Callable] | None = None,
    mesh_shape: Mapping[str, int] | None = None,
    mean_axes: tuple[str, ...] = (),
    use_fused_staging: bool = True,
    loss_scale: float = 1.0,
    two_phase_impl: str = "psum",
    update_fn: Callable | None = None,
    clip_norm: float = 0.0,
    pending: Mapping[int, jax.Array] | None = None,
    reps: int = 1,
) -> tuple[Any, Timeline, dict[str, Any]]:
    """Replay ``schedule`` over ``grads`` one op per dispatch.

    ``grads`` / ``pending`` are GLOBAL arrays (or host values); each op
    runs as its own ``jit(shard_map(...))`` program over ``mesh``.
    Returns ``(out_tree, timeline, info)`` where ``out_tree`` matches
    what ``execute`` would return, ``timeline`` is a
    ``repro.sim.engine.Timeline`` with one measured ``OpEvent`` per IR
    op (serial clock), and ``info`` carries ``grad_norm`` /
    ``update_shards`` / per-op seconds.

    ``reps > 1`` re-dispatches each (pure) op program and keeps the
    minimum time — outputs are unchanged, only the clock steadies.
    """
    if mesh_shape is None:
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    itemsize = (np.dtype(plan.comm_dtype).itemsize
                if plan.comm_dtype is not None else 4)
    by_id = {op.op_id: op for op in schedule.ops}

    em_kwargs = dict(
        reducer=reducer, reducers=reducers, mesh_shape=mesh_shape,
        mean_axes=mean_axes, use_fused_staging=use_fused_staging,
        loss_scale=loss_scale, two_phase_impl=two_phase_impl,
        update_fn=update_fn, clip_norm=clip_norm)

    # commit the tree to its train-time shardings so every per-op
    # program lowers against the real layout
    flat_g, gdef = jax.tree_util.tree_flatten(grads)
    flat_s = jax.tree_util.tree_leaves(param_specs, is_leaf=_is_pspec)
    tree = jax.tree_util.tree_unflatten(gdef, [
        jax.device_put(g, NamedSharding(mesh, s))
        for g, s in zip(flat_g, flat_s)])

    shard_vals: dict[int, jax.Array] = {}
    shard_n: dict[int, int] = {}          # host-side unpadded sizes
    clip_vals: dict[int, jax.Array] = {}
    update_shards: dict[int, jax.Array] = {}
    grad_norm = None
    per_op_s: dict[int, float] = {}
    events: list[OpEvent] = []
    cursor = 0.0

    for op in schedule.ops:
        dshard_ids = sorted(d for d in op.depends_on if d in shard_vals)
        dclip_ids = sorted(d for d in op.depends_on if d in clip_vals)
        pend_arr = None
        if op.kind in (ALL_GATHER, RESHARD) and pending is not None:
            has_src = any(
                d in shard_vals
                and by_id[d].bucket.bucket_id == op.bucket.bucket_id
                for d in op.depends_on)
            if not has_src and op.bucket.bucket_id in pending:
                pend_arr = pending[op.bucket.bucket_id]

        args = (tree,
                {d: shard_vals[d] for d in dshard_ids},
                {d: clip_vals[d] for d in dclip_ids},
                {0: pend_arr} if pend_arr is not None else {})
        in_specs = (
            param_specs,
            {d: _shard_pspec(by_id[d].bucket.reduce_axes)
             for d in dshard_ids},
            {d: P() for d in dclip_ids},
            ({0: _shard_pspec(op.bucket.reduce_axes)}
             if pend_arr is not None else {}))

        def one(tree_in, dshards, dclips, dpend, _op=op):
            em = _OpEmitter(schedule, plan, aux={}, pending=None,
                            **em_kwargs)
            em.shards = {d: (a, shard_n[d]) for d, a in dshards.items()}
            em.clip_scales = dict(dclips)
            if dpend:
                em.pending = {_op.bucket.bucket_id: dpend[0]}
            flat = list(jax.tree_util.tree_leaves(tree_in))
            with jax.named_scope(op_scope_name(_op)):
                em.emit(_op, flat)
            out_tree = jax.tree_util.tree_unflatten(plan.treedef, flat)
            if _op.kind in (REDUCE_SCATTER, UPDATE):
                return em.shards[_op.op_id][0]
            if _op.kind == RESHARD and not dpend:
                return em.shards[_op.op_id][0]   # scatter side: new shard
            if _op.kind == REGROUP:
                return em.aux["regroup_done"]
            if _op.kind == NORM:
                norm = em.aux["grad_norm"]
                if _op.op_id in em.clip_scales:
                    return norm, em.clip_scales[_op.op_id]
                return norm
            return out_tree        # ALLREDUCE / ALL_GATHER / RESHARD gather

        if op.kind in (REDUCE_SCATTER, UPDATE):
            out_specs: Any = _shard_pspec(op.bucket.reduce_axes)
        elif op.kind == RESHARD and pend_arr is None:
            out_specs = _shard_pspec(op.bucket.reduce_axes)
        elif op.kind == REGROUP:
            out_specs = P()
        elif op.kind == NORM:
            out_specs = (P(), P()) if clip_norm > 0 else P()
        else:
            out_specs = param_specs

        jitted = jax.jit(jax.shard_map(
            one, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False))
        compiled = jitted.lower(*args).compile()   # untimed warmup

        with jax.profiler.TraceAnnotation(op_scope_name(op)):
            t0 = time.perf_counter()
            out = jax.block_until_ready(compiled(*args))
            dt = time.perf_counter() - t0
        for _ in range(reps - 1):                  # pure → idempotent
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(*args))
            dt = min(dt, time.perf_counter() - t0)

        if op.kind == REDUCE_SCATTER:
            shard_vals[op.op_id] = out
            shard_n[op.op_id] = op.bucket.size
        elif op.kind == RESHARD and pend_arr is None:
            shard_vals[op.op_id] = out           # new-mesh dp shard
            shard_n[op.op_id] = op.bucket.size
        elif op.kind == REGROUP:
            pass                                 # barrier scalar: no state
        elif op.kind == UPDATE:
            srcs = [d for d in op.depends_on if d in shard_vals
                    and by_id[d].bucket.bucket_id == op.bucket.bucket_id]
            shard_vals[op.op_id] = out
            shard_n[op.op_id] = shard_n[srcs[0]]
            update_shards[op.bucket.bucket_id] = out
        elif op.kind == NORM:
            if clip_norm > 0:
                grad_norm, clip_vals[op.op_id] = out
            else:
                grad_norm = out
        else:
            tree = out

        nb = op.bucket.size * np_itemsize(op.bucket.comm_dtype, itemsize)
        per_op_s[op.op_id] = dt
        events.append(OpEvent(
            op_id=op.op_id, bucket_id=op.bucket.bucket_id, chain=op.chain,
            kind=op.kind, nbytes=nb, release=cursor, start=cursor,
            end=cursor + dt))
        cursor += dt

    info = {"grad_norm": grad_norm, "update_shards": update_shards,
            "per_op_s": per_op_s}
    return tree, Timeline(events=tuple(events), t_fwd=0.0, t_bwd=0.0), info


def measured_gradsync(
    gs, grads: Any, *, update_fn: Callable | None = None,
    clip_norm: float = 0.0, schedule: CommSchedule | None = None,
    pending: Mapping[int, jax.Array] | None = None, reps: int = 1,
) -> tuple[Any, Timeline, dict[str, Any]]:
    """``measured_timeline`` wired from a configured ``GradSync`` — the
    measured twin of ``gs(grads)``."""
    return measured_timeline(
        schedule if schedule is not None else gs.schedule,
        grads, gs.plan, mesh=gs.mesh, param_specs=gs.param_specs,
        reducer=gs.reducer, mesh_shape=gs.mesh_shape,
        mean_axes=gs.cfg.mean_axes,
        use_fused_staging=gs.cfg.use_fused_staging,
        loss_scale=gs.cfg.loss_scale,
        two_phase_impl=gs._two_phase_impl(),
        update_fn=update_fn, clip_norm=clip_norm,
        pending=pending, reps=reps)


def measurement_rows(
    schedule: CommSchedule, timeline: Timeline,
    mesh_shape: Mapping[str, int],
) -> list[dict[str, Any]]:
    """Flatten a measured Timeline into calibration rows (one dict per
    wire op) for ``repro.obs.calibrate.fit_network``."""
    by_id = {op.op_id: op for op in schedule.ops}
    rows = []
    for ev in timeline.events:
        op = by_id[ev.op_id]
        if op.kind not in (ALLREDUCE, REDUCE_SCATTER, ALL_GATHER):
            continue
        rows.append({
            "kind": op.kind,
            "nbytes": ev.nbytes,
            "axes": tuple(op.bucket.reduce_axes),
            "mesh_shape": dict(mesh_shape),
            "num_leaves": len(op.bucket.leaves),
            "t": ev.duration,
        })
    return rows
