"""Metrics registry: counters / gauges / histograms for the measured
path (DESIGN.md §12).

Deliberately tiny and dependency-free (no jax import): the registry is
host-side bookkeeping that the train loop, serve loop, and benchmarks
update between dispatches.  Labels are encoded in the metric name
(``comm_bytes.allreduce.flat.post``) — a flat namespace keeps
``snapshot()`` a plain JSON-ready dict that the event log and heartbeat
can emit verbatim.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable


class Counter:
    """Monotonically accumulating value (bytes moved, steps run)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Last-write-wins value (current loss, tokens/s of the last step)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float | None = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming distribution with a bounded sample window.

    Count/total/min/max are exact over every observation; percentiles
    are computed over the most recent ``window`` samples (enough for
    step-time p50/p99 without unbounded growth — the same reason the
    train loop bounds its loss history).
    """

    __slots__ = ("count", "total", "min", "max", "_window")

    def __init__(self, window: int = 4096) -> None:
        self.count: int = 0
        self.total: float = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self._window: deque[float] = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self._window.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100] over the retained window (nearest-rank)."""
        if not self._window:
            return 0.0
        xs = sorted(self._window)
        k = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[k]

    def summary(self) -> dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named metrics with create-on-first-use semantics.

    Re-requesting a name returns the SAME instrument; requesting an
    existing name as a different type raises (silent shadowing is how
    dashboards end up lying).
    """

    def __init__(self) -> None:
        self._metrics: dict[str, Any] = {}

    def _get(self, name: str, cls: type, factory: Callable[[], Any]):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = factory()
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, Gauge)

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(window))

    def snapshot(self) -> dict[str, Any]:
        """JSON-ready view: counters/gauges → number, histograms → summary
        dict.  Keys sorted for deterministic serialization."""
        out: dict[str, Any] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out


def host_time_us(fn: Callable[..., Any], *args: Any, reps: int = 3) -> float:
    """Median host wall time of ``fn(*args)`` in microseconds.

    One untimed warmup call absorbs jit compilation, then ``reps`` timed
    calls each fenced with ``jax.block_until_ready`` — the single timing
    convention shared by ``benchmarks/run.py`` and the obs CLI.
    """
    import jax

    jax.block_until_ready(fn(*args))        # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
