"""Fit the alpha-beta ``NetworkModel`` (and ``StagingModel``) from
measured per-op rows, and persist fitted per-mesh profiles the ``auto``
strategy prefers over the built-in defaults (DESIGN.md §12).

The simulator prices a flat collective over group ``g`` as per-axis
rings: ``steps · (alpha_a + (n/g)/beta_a)`` with ``steps = 2(g-1)`` for
allreduce and ``(g-1)`` for RS/AG, payload shrinking tier by tier
(``repro.sim.netmodel``).  Measured time is therefore LINEAR in the
per-axis unknowns ``[alpha_a, 1/beta_a]``:

    t_row = sum_a steps_a(row) · alpha_a + wire_a(row) · (1/beta_a)

so fitting is one least-squares solve over rows spanning several bucket
sizes — the perf-modeling approach of arXiv 1711.05979.  Rows that
carry ``num_leaves`` get the (default or fitted) staging cost
subtracted first, since measured walls include CopyFromTo.

A fitted profile is a small JSON keyed by mesh shape
(``netprofile_data2_model4.json``) under ``$REPRO_NETPROFILE_DIR``
(default ``results/netprofiles``); ``fitted_network(mesh_shape)`` is
the lookup ``sim/autotune.py`` calls before falling back to
``default_network()``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Mapping, Sequence

import numpy as np

from repro.obs.provenance import SCHEMA_VERSION, bench_metadata
from repro.sim.compute import StagingModel
from repro.sim.netmodel import LinkModel, NetworkModel, default_network

_WIRE_KINDS = ("allreduce", "reduce_scatter", "all_gather")

DEFAULT_PROFILE_DIR = "results/netprofiles"
PROFILE_DIR_ENV = "REPRO_NETPROFILE_DIR"

# Quality gate: a fit whose rms residual exceeds this fraction of the
# rms measured (staging-subtracted) row time explains too little of the
# data to rank plans with — e.g. a CPU-host smoke run, where dispatch
# jitter dwarfs the wire terms.  Such profiles are still persisted
# (quality: "poor" recorded in the doc, useful as a CI artifact and for
# --diff forensics) but `fitted_network` treats them as absent, so a
# bad calibration can never silently distort `auto`'s ranking.
REL_RESIDUAL_MAX = 0.25


def mesh_key(mesh_shape: Mapping[str, int]) -> str:
    return "_".join(f"{a}{n}" for a, n in sorted(mesh_shape.items()))


def profile_dir(override: str | None = None) -> str:
    return (override if override is not None
            else os.environ.get(PROFILE_DIR_ENV, DEFAULT_PROFILE_DIR))


def profile_path(mesh_shape: Mapping[str, int],
                 dir: str | None = None) -> str:
    return os.path.join(profile_dir(dir),
                        f"netprofile_{mesh_key(mesh_shape)}.json")


# ------------------------------------------------------------- features

def ring_features(kind: str, nbytes: float, axes: Sequence[str],
                  mesh_shape: Mapping[str, int], *,
                  ref: NetworkModel | None = None,
                  ) -> dict[str, tuple[float, float]]:
    """axis → (steps, wire_bytes): the linear-model coefficients of one
    measured row, mirroring ``NetworkModel``'s flat cost EXACTLY (same
    per-axis decomposition, same fastest-link-first ordering for the
    shrinking RS/AG payload) so a fit over synthetic rows generated from
    a known model recovers it to numerical precision."""
    if kind not in _WIRE_KINDS:
        raise ValueError(f"not a wire kind: {kind!r}")
    ref = ref or default_network()
    groups = ref._axis_groups(tuple(axes), mesh_shape)
    out: dict[str, tuple[float, float]] = {}
    if kind == "allreduce":
        for a, g in groups:
            steps = 2.0 * (g - 1)
            out[a] = (steps, steps * nbytes / g)
    else:                                   # reduce_scatter / all_gather
        n = float(nbytes)
        for a, g in groups:
            steps = float(g - 1)
            out[a] = (steps, steps * n / g)
            n /= g
    return out


def _staging_of(row: Mapping[str, Any], staging: StagingModel) -> float:
    """The CopyFromTo share of a measured wall (0 when the row carries
    no staging info — e.g. synthetic wire-only rows)."""
    leaves = row.get("num_leaves")
    if not leaves:
        return 0.0
    one = staging.stage_time(row["nbytes"], int(leaves),
                             fused=bool(row.get("fused", True)))
    return 2.0 * one if row["kind"] == "allreduce" else one


# ------------------------------------------------------------------ fit

def fit_network(
    rows: Sequence[Mapping[str, Any]],
    *,
    staging: StagingModel | None = None,
    ref: NetworkModel | None = None,
) -> tuple[NetworkModel, dict[str, Any]]:
    """Least-squares fit of per-axis (latency, bandwidth) from measured
    rows ``{kind, nbytes, axes, mesh_shape, t[, num_leaves, fused]}``.

    Returns ``(model, info)``: the fitted ``NetworkModel`` (fitted axes
    become explicit links; anything else falls back to ``ref``'s
    default link) and a fit report (per-axis params, rms residual, row
    count).  Needs rows at >= 2 distinct sizes per axis to separate
    alpha from beta — fewer rows make lstsq minimum-norm, not wrong.

    The RS/AG shrinking payload depends on the fastest-link-first axis
    ORDER, which depends on the bandwidths being fitted — so the solve
    iterates: features under the current ordering, refit, re-derive the
    ordering from the fitted bandwidths, until stable (multi-axis rows
    converge in 2-3 rounds; single-axis fits in one).
    """
    ref = ref or default_network()
    st = staging or ref.staging

    def solve(order_ref: NetworkModel):
        feats = []
        axes_order: list[str] = []
        for row in rows:
            f = ring_features(row["kind"], row["nbytes"], row["axes"],
                              row["mesh_shape"], ref=order_ref)
            feats.append(f)
            for a in f:
                if a not in axes_order:
                    axes_order.append(a)
        if not axes_order:
            raise ValueError(
                "no rows with a group size > 1 — nothing to fit")
        col = {a: i for i, a in enumerate(axes_order)}
        A = np.zeros((len(rows), 2 * len(axes_order)))
        b = np.zeros(len(rows))
        for i, (row, f) in enumerate(zip(rows, feats)):
            for a, (steps, wire) in f.items():
                A[i, 2 * col[a]] = steps          # alpha_a coefficient
                A[i, 2 * col[a] + 1] = wire       # 1/beta_a coefficient
            b[i] = row["t"] - _staging_of(row, st)
        x, *_ = np.linalg.lstsq(A, b, rcond=None)
        residual = float(np.sqrt(np.mean((A @ x - b) ** 2)))
        links = []
        params: dict[str, dict[str, float]] = {}
        for a in axes_order:
            alpha = max(float(x[2 * col[a]]), 0.0)
            inv_beta = max(float(x[2 * col[a] + 1]), 1e-15)
            bw = 1.0 / inv_beta
            links.append((a, LinkModel(a, bandwidth=bw, latency=alpha)))
            params[a] = {"latency": alpha, "bandwidth": bw}
        model = NetworkModel(
            links=tuple(links), default_link=ref.default_link,
            quantize_bw=ref.quantize_bw, staging=st)
        return model, params, residual

    model, params, residual = solve(ref)
    for _ in range(3):
        prev = residual
        model, params, residual = solve(model)
        if residual >= prev * (1.0 - 1e-9):   # ordering stabilized
            break
    # the target vector is staging-subtracted row time — independent of
    # the axis ordering, so computed once for the quality verdict
    target_rms = float(np.sqrt(np.mean(
        [(row["t"] - _staging_of(row, st)) ** 2 for row in rows])))
    rel = residual / target_rms if target_rms > 0 else float("inf")
    info = {"axes": params, "rms_residual_s": residual,
            "rel_residual": rel,
            "quality": "ok" if rel <= REL_RESIDUAL_MAX else "poor",
            "n_rows": len(rows)}
    return model, info


def fit_staging(
    rows: Sequence[Mapping[str, Any]],
    *,
    ref: StagingModel | None = None,
) -> tuple[StagingModel, dict[str, Any]]:
    """Fit ``(hbm_bw, leaf_overhead)`` from staging-only rows
    ``{nbytes, num_leaves, fused, t}`` (one direction each), keeping
    ``ref``'s pass-count convention (2 fused / 4 leafwise)."""
    ref = ref or StagingModel()
    A = np.zeros((len(rows), 2))
    b = np.zeros(len(rows))
    for i, row in enumerate(rows):
        fused = bool(row.get("fused", True))
        passes = ref.fused_passes if fused else ref.leafwise_passes
        ops = 1 if fused else max(int(row["num_leaves"]), 1)
        A[i, 0] = passes * row["nbytes"]      # 1/hbm_bw coefficient
        A[i, 1] = ops                         # leaf_overhead coefficient
        b[i] = row["t"]
    x, *_ = np.linalg.lstsq(A, b, rcond=None)
    inv_bw = max(float(x[0]), 1e-18)
    leaf = max(float(x[1]), 0.0)
    model = StagingModel(hbm_bw=1.0 / inv_bw, leaf_overhead=leaf,
                         fused_passes=ref.fused_passes,
                         leafwise_passes=ref.leafwise_passes)
    residual = float(np.sqrt(np.mean((A @ x - b) ** 2))) if len(rows) else 0.0
    target_rms = float(np.sqrt(np.mean(b ** 2))) if len(rows) else 0.0
    rel = residual / target_rms if target_rms > 0 else float("inf")
    info = {"hbm_bw": model.hbm_bw, "leaf_overhead": leaf,
            "rms_residual_s": residual, "rel_residual": rel,
            "quality": "ok" if rel <= REL_RESIDUAL_MAX else "poor",
            "n_rows": len(rows)}
    return model, info


# -------------------------------------------------------------- profiles

def save_profile(
    model: NetworkModel,
    mesh_shape: Mapping[str, int],
    *,
    dir: str | None = None,
    info: Mapping[str, Any] | None = None,
) -> str:
    """Persist a fitted model as the per-mesh JSON profile; returns the
    path ``fitted_network`` will find it at."""
    path = profile_path(mesh_shape, dir)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    doc = {
        "schema_version": SCHEMA_VERSION,
        "meta": bench_metadata(mesh_shape),
        "links": {a: {"bandwidth": lk.bandwidth, "latency": lk.latency}
                  for a, lk in model.links},
        "default_link": {"name": model.default_link.name,
                         "bandwidth": model.default_link.bandwidth,
                         "latency": model.default_link.latency},
        "quantize_bw": model.quantize_bw,
        "staging": {"hbm_bw": model.staging.hbm_bw,
                    "leaf_overhead": model.staging.leaf_overhead,
                    "fused_passes": model.staging.fused_passes,
                    "leafwise_passes": model.staging.leafwise_passes},
        "fit": dict(info or {}),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return path


def load_profile(path: str) -> NetworkModel:
    with open(path) as f:
        doc = json.load(f)
    links = tuple(
        (a, LinkModel(a, bandwidth=d["bandwidth"], latency=d["latency"]))
        for a, d in sorted(doc["links"].items()))
    dl = doc["default_link"]
    st = doc["staging"]
    return NetworkModel(
        links=links,
        default_link=LinkModel(dl["name"], bandwidth=dl["bandwidth"],
                               latency=dl["latency"]),
        quantize_bw=doc["quantize_bw"],
        staging=StagingModel(hbm_bw=st["hbm_bw"],
                             leaf_overhead=st["leaf_overhead"],
                             fused_passes=st["fused_passes"],
                             leafwise_passes=st["leafwise_passes"]))


def fitted_network(
    mesh_shape: Mapping[str, int],
    dir: str | None = None,
) -> tuple[NetworkModel | None, str | None]:
    """The fitted profile for this mesh if one exists — ``(model, path)``
    or ``(None, None)``.  Unreadable/corrupt profiles are treated as
    absent (a stale artifact must never break planning), and so are
    profiles whose recorded fit ``quality`` is ``"poor"`` (residual >
    ``REL_RESIDUAL_MAX`` of the measured signal): ranking plans against
    a fit that does not explain its own calibration data is worse than
    the built-in defaults."""
    path = profile_path(mesh_shape, dir)
    if not os.path.exists(path):
        return None, None
    try:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("fit", {}).get("quality", "ok") != "ok":
            return None, None
        return load_profile(path), path
    except Exception:
        return None, None
