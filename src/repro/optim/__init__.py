from repro.optim.optimizers import (
    Optimizer,
    adamw,
    apply_updates,
    clip_by_global_norm,
    sgd,
)
from repro.optim.schedules import (
    constant_lr,
    cosine_warmup,
    linear_scaling_rule,
)
from repro.optim.zero import (
    scheduled_update,
    shard_size,
    zero1,
    zero1_pending_structs,
    zero1_state_structs,
)

__all__ = [
    "Optimizer",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "constant_lr",
    "cosine_warmup",
    "linear_scaling_rule",
    "scheduled_update",
    "sgd",
    "shard_size",
    "zero1",
    "zero1_pending_structs",
    "zero1_state_structs",
]
