"""Optimizers, optax-style pure (init, update) pairs — no external deps.

SGD + momentum is the paper's optimizer (§2.1); AdamW for the LM archs.
Master weights/moments are fp32 regardless of param dtype (bf16 params are
round-tripped through the update in fp32).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (updates, new_state)
    zero1_meta: Any = None      # (inner, dp_size) when ZeRO-1 wrapped


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def sgd(lr: Callable[[jax.Array], jax.Array] | float,
        momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        g32 = _f32(grads)
        mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], g32)
        if nesterov:
            eff = jax.tree.map(lambda m, g: momentum * m + g, mom, g32)
        else:
            eff = mom
        lr_t = lr_fn(step)
        updates = jax.tree.map(lambda e: -lr_t * e, eff)
        return updates, {"mom": mom}

    return Optimizer(init, update)


def adamw(lr: Callable[[jax.Array], jax.Array] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        g32 = _f32(grads)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], g32)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state["v"], g32)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
        lr_t = lr_fn(step)
        updates = jax.tree.map(
            lambda mh, vh, p: -lr_t * (
                mh / (jnp.sqrt(vh) + eps)
                + weight_decay * p.astype(jnp.float32)),
            mh, vh, params)
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(
    grads: Any, max_norm: float,
    psum_axes: tuple[str, ...] = (),
) -> tuple[Any, jax.Array]:
    """Clip by the global grad norm.  ``psum_axes`` sums the squared
    norm over mesh axes whose shards each hold a disjoint slice of the
    tree (pipeline stages: each device sees only its layer slice), so
    every shard applies the same scale."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    if psum_axes:
        sq = jax.lax.psum(sq, psum_axes)
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)
