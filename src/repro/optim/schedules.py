"""LR schedules, incl. the paper's linear-scaling rule (§5.2: lr 0.1 →
1.0 at 256 workers, i.e. lr ∝ number of workers)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * (step + 1) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def linear_scaling_rule(base_lr: float, base_workers: int, workers: int):
    """Paper §5.2: scale the initial LR linearly with worker count."""
    return base_lr * workers / base_workers
