"""ZeRO-1: reduce-scatter gradient sync + sharded optimizer state +
all-gather of updates (beyond-paper lever; DESIGN.md §3).

Wire cost per step and DP group of size n (bytes of gradient G):
  flat allreduce:       2G(n-1)/n        (the paper's scheme)
  zero1 RS + AG:         G(n-1)/n + G(n-1)/n  == same wire bytes, but
                         optimizer state and update math drop to 1/n per
                         device (memory) and the RS replaces the psum in
                         the same DAG slot — so the *collective schedule*
                         strategies apply unchanged.

Implementation: all gradients are flattened into one fp32 buffer, padded
to n; ``psum_scatter`` gives each DP rank its 1/n shard; the inner
optimizer updates the shard (state is shard-sized); ``all_gather``
rebuilds the full update vector.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import Optimizer


def _flatten(tree: Any) -> tuple[jax.Array, list]:
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate(
        [jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, leaves


def _unflatten_like(flat: jax.Array, tree: Any) -> Any:
    leaves, td = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(jax.lax.dynamic_slice_in_dim(flat, off, n, 0)
                   .reshape(l.shape).astype(jnp.float32))
        off += n
    return jax.tree_util.tree_unflatten(td, out)


def zero1(inner: Optimizer, dp_axes: tuple[str, ...], dp_size: int) -> Optimizer:
    """Wrap ``inner`` so state/update math runs on a 1/dp_size shard.

    Must run inside shard_map.  The *unreduced* grads go in (the RS is the
    sync); pass strategy-synced grads only with sync disabled for DP axes.
    """
    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def init(params):
        """NOTE: valid only when ``params`` has the same (local) shapes the
        update will see — i.e. dp_size==1 or no TP sharding.  For the
        general case use ``TrainStep.init_opt`` (runtime/train_loop.py),
        which builds the sharded flat state from the local shard sizes."""
        flat, _ = _flatten(params)
        n = flat.shape[0]
        pad = (-n) % dp_size
        shard = (n + pad) // dp_size
        pseudo = jnp.zeros((shard,), jnp.float32)
        return {"inner": inner.init(pseudo)}

    def update(grads, state, params, step):
        flat_g, _ = _flatten(grads)
        flat_p, _ = _flatten(params)
        n = flat_g.shape[0]            # LOCAL flat size (inside shard_map)
        pad = (-n) % dp_size
        if pad:
            flat_g = jnp.pad(flat_g, (0, pad))
            flat_p = jnp.pad(flat_p, (0, pad))
        # (1) reduce-scatter: each rank owns the reduced 1/n shard
        g_shard = jax.lax.psum_scatter(
            flat_g, axis, scatter_dimension=0, tiled=True)
        idx = jax.lax.axis_index(axis)
        shard = g_shard.shape[0]
        p_shard = jax.lax.dynamic_slice_in_dim(
            flat_p, idx * shard, shard, 0)
        # (2) sharded optimizer math
        upd_shard, new_inner = inner.update(
            g_shard, state["inner"], p_shard, step)
        # (3) all-gather updates
        flat_u = jax.lax.all_gather(upd_shard, axis, axis=0, tiled=True)
        flat_u = flat_u[:n] if pad else flat_u
        updates = _unflatten_like(flat_u, params)
        return updates, {"inner": new_inner}

    return Optimizer(init, update, zero1_meta=(inner, dp_size))
