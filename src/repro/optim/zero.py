"""ZeRO-1 on the CommSchedule IR: reduce-scatter gradient sync + sharded
optimizer state + all-gather of updates (beyond-paper lever; DESIGN.md
§3, §9).

Wire cost per step and DP group of size n (bytes of gradient G):
  flat allreduce:       2G(n-1)/n        (the paper's scheme)
  zero1 RS + AG:         G(n-1)/n + G(n-1)/n  == same wire bytes, but
                         optimizer state and update math drop to 1/n per
                         device (memory) and the RS replaces the psum in
                         the same DAG slot — so the *collective schedule*
                         strategies apply unchanged.

Two execution shapes, both riding ``repro.core.schedule.execute`` (this
module emits NO raw ``psum_scatter``/``all_gather`` of its own):

  monolithic — ``zero1(...)`` wraps an inner optimizer whose ``update``
      packs every gradient leaf into ONE f32 bucket and runs a 3-op
      RS→UPDATE→AG CommSchedule through the shared emitter.  Drop-in
      ``Optimizer`` API; the whole step still serializes behind one
      collective pair.
  scheduled  — the StepProgram path (``repro.core.stepprogram``):
      GradSync plans per-bucket RS→UPDATE→AG triples with the registered
      strategies, and ``scheduled_update`` below supplies the per-bucket
      shard math (param shard slice + inner update + state carry) that
      the executor's UPDATE ops call.  Bucket k's update overlaps bucket
      k+1's reduce-scatter; bit-exact with the monolithic path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import Bucket, BucketPlan, LeafInfo, pack
from repro.core.schedule import (
    ALL_GATHER,
    REDUCE_SCATTER,
    UPDATE,
    CollectiveOp,
    CommSchedule,
    execute,
)
from repro.optim.optimizers import Optimizer
from repro.utils.trees import flatten_with_names


def _leaf_size(leaf) -> int:
    return int(np.prod(leaf.shape)) if leaf.shape else 1


def shard_size(n: int, dp_size: int) -> int:
    """Per-rank shard of an ``n``-element buffer padded to ``dp_size``."""
    return (n + (-n) % dp_size) // dp_size


def _dp_index(dp_axes: tuple[str, ...]) -> jax.Array:
    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return jax.lax.axis_index(axis)


def _param_shard(bucket: Bucket, params_flat, dp_size: int,
                 n_shard: int) -> jax.Array:
    """This rank's slice of the bucket's packed (padded) f32 params."""
    p_buf = pack(bucket, params_flat, jnp.float32)
    pad = (-p_buf.shape[0]) % dp_size
    if pad:
        p_buf = jnp.pad(p_buf, (0, pad))
    idx = _dp_index(bucket.reduce_axes)
    return jax.lax.dynamic_slice_in_dim(p_buf, idx * n_shard, n_shard, 0)


def zero1(inner: Optimizer, dp_axes: tuple[str, ...], dp_size: int, *,
          param_specs: Any = None, mesh: Any = None) -> Optimizer:
    """Wrap ``inner`` so state/update math runs on a 1/dp_size shard.

    Must run inside shard_map.  The *unreduced* grads go in (the RS is the
    sync); pass strategy-synced grads only with sync disabled for DP axes.

    ``init`` derives the shard size from the LOCAL parameter shapes —
    the same rule ``TrainStep.init_opt`` uses.  Inside shard_map the
    params it sees are already local; when calling from the host on
    GLOBAL (TP-sharded) params, pass ``param_specs``/``mesh`` so the
    shapes are localized first.
    """

    def _local_sizes(params) -> int:
        structs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        if param_specs is not None and mesh is not None:
            from repro.parallel.sharding import localize_structs

            structs = localize_structs(structs, param_specs, mesh)
        return sum(_leaf_size(l) for l in jax.tree.leaves(structs))

    def init(params):
        n_shard = shard_size(_local_sizes(params), dp_size)
        return {"inner": inner.init(jnp.zeros((n_shard,), jnp.float32))}

    def update(grads, state, params, step):
        named, treedef = flatten_with_names(grads)
        infos = tuple(
            LeafInfo(name=n, index=i, shape=tuple(l.shape),
                     dtype=jnp.float32, size=_leaf_size(l))
            for i, (n, l) in enumerate(named))
        bucket = Bucket(leaves=infos, reduce_axes=tuple(dp_axes),
                        channel=0, bucket_id=0, comm_dtype=jnp.float32)
        plan = BucketPlan(buckets=(bucket,), treedef=treedef,
                          num_leaves=len(infos), comm_dtype=jnp.float32)
        schedule = CommSchedule((
            CollectiveOp(op_id=0, bucket=bucket, chain=0,
                         kind=REDUCE_SCATTER),
            CollectiveOp(op_id=1, bucket=bucket, chain=0,
                         depends_on=(0,), kind=UPDATE),
            CollectiveOp(op_id=2, bucket=bucket, chain=0,
                         depends_on=(1,), kind=ALL_GATHER),
        )).validate()
        # only the PRODUCT of the axis sizes matters to the emitter (pad
        # + shard math); the collectives themselves read the real groups
        # from the enclosing shard_map
        mesh_shape = {a: 1 for a in dp_axes}
        mesh_shape[dp_axes[0]] = dp_size
        params_flat = jax.tree.leaves(params)
        carry: dict[str, Any] = {}

        def update_fn(op, g_shard):
            p_shard = _param_shard(op.bucket, params_flat, dp_size,
                                   g_shard.shape[0])
            upd, carry["inner"] = inner.update(
                g_shard, state["inner"], p_shard, step)
            return upd

        updates = execute(
            schedule, grads, plan,
            reducer=lambda b, _bk: b,       # no allreduce ops planned
            mesh_shape=mesh_shape, update_fn=update_fn)
        return updates, {"inner": carry["inner"]}

    return Optimizer(init, update,
                     zero1_meta=(inner, dp_size, tuple(dp_axes)))


# ------------------------------------------------- scheduled (StepProgram)

def zero1_state_structs(inner: Optimizer, dp_plan: BucketPlan,
                        dp_size: int) -> Any:
    """Local (per-dp-rank) ShapeDtypeStructs of the per-bucket sharded
    state the scheduled path carries: ``{"inner": {"<k>": state_k}}``
    with state_k shaped like ``inner.init`` of bucket k's shard."""
    out = {}
    for i, b in enumerate(dp_plan.buckets):
        n_shard = shard_size(b.size, dp_size)
        out[str(i)] = jax.eval_shape(
            inner.init, jax.ShapeDtypeStruct((n_shard,), jnp.float32))
    return {"inner": out}


def zero1_pending_structs(dp_plan: BucketPlan, dp_size: int) -> Any:
    """Local ShapeDtypeStructs of the deferred-AG carry (DESIGN.md §10):
    one f32 update shard per dp bucket, keyed like the inner state.
    Zero-initialized by ``TrainStep.init_opt`` — gathering zeros at step
    0 is the identity update, so a fresh deferred run starts exactly
    like a scheduled one."""
    return {
        str(i): jax.ShapeDtypeStruct(
            (shard_size(b.size, dp_size),), jnp.float32)
        for i, b in enumerate(dp_plan.buckets)}


def scheduled_update(inner: Optimizer, dp_plan: BucketPlan, params: Any,
                     state: Any, step: jax.Array, *, dp_size: int):
    """The UPDATE-op callback for a StepProgram schedule.

    Returns ``(update_fn, new_state)``: ``update_fn(op, g_shard)`` slices
    this rank's param shard for the op's bucket, runs the inner
    optimizer on the reduced gradient shard, records the bucket's new
    inner state in ``new_state["inner"]`` and returns the update shard
    (which the schedule's all-gather then materializes).  ``new_state``
    is complete once every UPDATE op has executed.
    """
    params_flat = jax.tree.leaves(params)
    key_of = {b.bucket_id: str(i) for i, b in enumerate(dp_plan.buckets)}
    new_state: dict[str, dict] = {"inner": {}}

    def update_fn(op, g_shard):
        key = key_of[op.bucket.bucket_id]
        p_shard = _param_shard(op.bucket, params_flat, dp_size,
                               g_shard.shape[0])
        upd, ns = inner.update(g_shard, state["inner"][key], p_shard, step)
        new_state["inner"][key] = ns
        return upd

    return update_fn, new_state
