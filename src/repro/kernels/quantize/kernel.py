"""int8 block-quantize / dequantize Pallas TPU kernels.

The compute hot-spot of the compressed-allreduce reducer
(``repro.core.compression``): every gradient bucket is quantized before
the wire and dequantized after.  Block = 256 elements (one VREG-friendly
lane row); tile = (ROWS, 256) in VMEM, 8×128-aligned.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
ROWS = 64          # rows of 256-elem blocks per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)            # (ROWS, BLOCK)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    y = x / scale[:, None]
    q_ref[...] = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...][:, None]


def quantize_blocks_kernel(x: jax.Array, *, interpret: bool = False):
    """x: (n_blocks, BLOCK) f32 → (int8 same shape, scales (n_blocks,))."""
    n = x.shape[0]
    rows = min(ROWS, n)
    assert n % rows == 0, (n, rows)
    return pl.pallas_call(
        _quant_kernel,
        grid=(n // rows,),
        in_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_blocks_kernel(q: jax.Array, s: jax.Array, *,
                             interpret: bool = False):
    n = q.shape[0]
    rows = min(ROWS, n)
    assert n % rows == 0, (n, rows)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n // rows,),
        in_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, s)
