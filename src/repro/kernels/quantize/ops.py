"""jit'd wrappers over 1-D buffers (the comm-buf layout GradSync uses)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.quantize.kernel import (
    BLOCK,
    dequantize_blocks_kernel,
    quantize_blocks_kernel,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blocks(buf: jax.Array, *, interpret: bool = False):
    """buf: (n,) f32, n % 256 == 0 → (q (n,) int8, scales (n/256,))."""
    x = buf.reshape(-1, BLOCK)
    q, s = quantize_blocks_kernel(x, interpret=interpret)
    return q.reshape(-1), s


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_blocks(q: jax.Array, s: jax.Array, *,
                      interpret: bool = False):
    x = dequantize_blocks_kernel(q.reshape(-1, BLOCK), s,
                                 interpret=interpret)
    return x.reshape(-1)
