from repro.kernels.quantize.ops import dequantize_blocks, quantize_blocks

__all__ = ["dequantize_blocks", "quantize_blocks"]
