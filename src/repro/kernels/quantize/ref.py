"""Pure-jnp oracle — mirrors repro.core.compression blockwise math."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (n_blocks, 256) f32 → (int8, scales (n_blocks,))."""
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s[:, None]
