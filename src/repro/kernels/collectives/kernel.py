"""Pallas TPU kernels for the CommSchedule staging + collective hot path.

The two per-step costs every embedding design pays (paper Figs 6, 9, 11)
are the ``CopyFromTo(g, comm_buf)`` staging and the allreduce itself.
This module owns both at the kernel level:

  pack / unpack     — ONE grid gathers all of a bucket's leaves into the
                      1-D comm buffer, fusing the ``comm_dtype`` cast and
                      the optional loss-scale (one HBM pass, one kernel
                      launch, instead of per-leaf ravel+cast+concatenate
                      and per-leaf slice+cast on the way back).
  ring accumulate   — the per-hop combine of the chunked ring
                      reduce-scatter (received shard += local chunk, in
                      the comm dtype), chunked to VREG-aligned blocks so
                      large buckets stream through VMEM.
  ring RS / AG      — inter-chip rings over ``make_async_remote_copy``
                      with two VMEM message slots (hop ``s``'s slot is
                      never overwritten by hop ``s+1``'s incoming copy).
                      Hops are issued conservatively (start → wait →
                      combine; splitting the send/recv waits to overlap
                      the accumulate is the marked real-TPU bring-up
                      refinement).  TPU-only: the transport needs real
                      ICI; every other backend (and interpret mode) runs
                      the ``ppermute``-based ref rings in
                      ``repro.kernels.collectives.ref`` — whose
                      ``bidirectional`` halves ARE the double-buffered
                      two-messages-in-flight path — and XLA lowers each
                      hop to the same ICI DMAs.

All kernels are interpret-mode verifiable (tests/test_collectives.py)
except the RDMA rings, which require real neighbors; their algorithm is
covered by the ref rings' equivalence tests against
``psum_scatter``/``all_gather`` on the 8-fake-device mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only transport (Mosaic RDMA); absent on some backends
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

# renamed across jax versions (TPUCompilerParams → CompilerParams)
_COMPILER_PARAMS = (getattr(pltpu, "CompilerParams", None)
                    or getattr(pltpu, "TPUCompilerParams", None))

# VREG-aligned block (8 sublanes × 128 lanes) for chunked ring grids
RING_CHUNK = 8 * 128


# -------------------------------------------------------- fused staging

def _pack_kernel(*refs, sizes, scale):
    """Gather every leaf into its slice of the 1-D comm buffer.

    One grid step owns the whole bucket: offsets are compile-time
    constants, so each leaf is a single contiguous VMEM write with the
    dtype cast (and loss-scale) fused in — no intermediate per-leaf
    buffers, no concatenate.
    """
    out_ref = refs[-1]
    off = 0
    for ref, n in zip(refs[:-1], sizes):
        x = ref[...]
        if scale != 1.0:
            x = (x.astype(jnp.float32) * scale)
        out_ref[off:off + n] = x.astype(out_ref.dtype)
        off += n


def pack_bucket_kernel(leaves, comm_dtype, *, scale: float = 1.0,
                       interpret: bool = False) -> jax.Array:
    """leaves: list of 1-D arrays → (sum(sizes),) ``comm_dtype`` buffer."""
    sizes = tuple(int(l.shape[0]) for l in leaves)
    return pl.pallas_call(
        functools.partial(_pack_kernel, sizes=sizes, scale=scale),
        out_shape=jax.ShapeDtypeStruct((sum(sizes),), comm_dtype),
        interpret=interpret,
    )(*leaves)


def _unpack_kernel(buf_ref, *out_refs, sizes, scale):
    """Scatter the reduced buffer back into per-leaf outputs (cast-back
    and inverse loss-scale fused into the single read of each slice)."""
    off = 0
    for ref, n in zip(out_refs, sizes):
        x = buf_ref[off:off + n]
        if scale != 1.0:
            x = x.astype(jnp.float32) * scale
        ref[...] = x.astype(ref.dtype)
        off += n


def unpack_bucket_kernel(buf, sizes, dtypes, *, scale: float = 1.0,
                         interpret: bool = False):
    """buf: (n,) comm buffer → list of 1-D leaf arrays (given dtypes)."""
    sizes = tuple(int(s) for s in sizes)
    return pl.pallas_call(
        functools.partial(_unpack_kernel, sizes=sizes, scale=scale),
        out_shape=[jax.ShapeDtypeStruct((s,), d)
                   for s, d in zip(sizes, dtypes)],
        interpret=interpret,
    )(buf)


# ----------------------------------------------------- ring accumulate

def _accum_kernel(msg_ref, chunk_ref, out_ref):
    out_ref[...] = msg_ref[...] + chunk_ref[...]


def ring_accum_kernel(msg: jax.Array, chunk: jax.Array, *,
                      interpret: bool = False) -> jax.Array:
    """One ring hop's combine: received partial shard += local chunk.

    Chunked over ``RING_CHUNK`` blocks when the shard is block-aligned so
    arbitrarily large buckets stream through VMEM; falls back to a single
    whole-shard block otherwise (small tails).
    """
    n = msg.shape[0]
    if n > RING_CHUNK and n % RING_CHUNK == 0:
        grid = (n // RING_CHUNK,)
        spec = pl.BlockSpec((RING_CHUNK,), lambda i: (i,))
        return pl.pallas_call(
            _accum_kernel, grid=grid, in_specs=[spec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((n,), msg.dtype),
            interpret=interpret,
        )(msg, chunk)
    return pl.pallas_call(
        _accum_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), msg.dtype),
        interpret=interpret,
    )(msg, chunk)


# ------------------------------------------- RDMA rings (TPU transport)
#
# comm_buf holds TWO message slots so consecutive hops never alias: hop
# s's payload (slot s%2) stays intact while hop s+1's copy lands in the
# other slot.  Each hop currently start()s and wait()s its RDMA before
# combining — correct but serial within a hop; overlap of the incoming
# copy with the VPU add (wait only the recv semaphore, drain sends
# lazily) requires real-ICI validation and is deliberately left to
# TPU bring-up.  The pipelining shipped today is the bidirectional ref
# rings (ref.py): two half-width messages in flight per hop.

def _ring_rs_kernel(x_ref, out_ref, comm_buf, send_sem, recv_sem):
    """Ring reduce-scatter over axis 0 of the device ring.

    x_ref: (g, c) local chunks; out_ref: (c,) fully-reduced chunk owned
    by this device (device r ends owning chunk r, matching tiled
    ``psum_scatter``).
    """
    g = pl.num_programs(0)
    my_id = pl.program_id(0)
    dst = (my_id + 1) % g

    # hop 0's payload: our own value of chunk (r - 1)
    comm_buf[0] = x_ref[(my_id - 1) % g]
    for s in range(1, g):
        slot = s % 2
        prev = (s - 1) % 2
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[prev],
            dst_ref=comm_buf.at[slot],
            send_sem=send_sem.at[prev],
            recv_sem=recv_sem.at[slot],
            device_id=(dst,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()
        # received partial of chunk (r - 1 - s); add our contribution
        comm_buf[slot] = comm_buf[slot] + x_ref[(my_id - 1 - s) % g]
    out_ref[...] = comm_buf[(g - 1) % 2]


def _ring_ag_kernel(shard_ref, out_ref, comm_buf, send_sem, recv_sem):
    """Ring all-gather: device r starts with chunk r, ends with all g."""
    g = pl.num_programs(0)
    my_id = pl.program_id(0)
    dst = (my_id + 1) % g

    out_ref[my_id] = shard_ref[...]
    comm_buf[0] = shard_ref[...]
    for s in range(1, g):
        slot = s % 2
        prev = (s - 1) % 2
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[prev],
            dst_ref=comm_buf.at[slot],
            send_sem=send_sem.at[prev],
            recv_sem=recv_sem.at[slot],
            device_id=(dst,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()
        out_ref[(my_id - s) % g] = comm_buf[slot]


def ring_reduce_scatter_tpu(x2d: jax.Array) -> jax.Array:  # pragma: no cover
    """x2d: (g, c) per-device chunk view → (c,) reduced shard.  Requires a
    real TPU ring (one program per device along grid axis 0)."""
    if pltpu is None:
        raise NotImplementedError("RDMA ring requires pallas TPU support")
    g, c = x2d.shape
    return pl.pallas_call(
        _ring_rs_kernel,
        grid=(g,),
        out_shape=jax.ShapeDtypeStruct((c,), x2d.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, c), x2d.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=_COMPILER_PARAMS(collective_id=0),
    )(x2d)


def ring_all_gather_tpu(shard: jax.Array, g: int) -> jax.Array:  # pragma: no cover
    """shard: (c,) owned chunk → (g, c) gathered buffer (ravel to 1-D)."""
    if pltpu is None:
        raise NotImplementedError("RDMA ring requires pallas TPU support")
    c = shard.shape[0]
    return pl.pallas_call(
        _ring_ag_kernel,
        grid=(g,),
        out_shape=jax.ShapeDtypeStruct((g, c), shard.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, c), shard.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=_COMPILER_PARAMS(collective_id=1),
    )(shard)
