"""Pure-jnp oracles for the collectives kernels.

``leafwise_pack``/``leafwise_unpack`` mirror the seed's per-leaf staging
(``repro.core.buckets.pack``/``unpack`` semantics plus the optional
loss-scale): per-leaf ravel + cast, one concatenate, per-leaf slice +
cast back.  They are both the parity oracle for the fused kernels and
the runtime fallback for buckets the fused path cannot take (odd
dtypes).

``ring_reduce_scatter_ref``/``ring_all_gather_ref`` are the chunked,
``ppermute``-based rings: g-1 neighbor hops over one mesh axis, each hop
one ``lax.ppermute`` (XLA lowers it to the ICI DMA the RDMA kernels
issue by hand) plus an accumulate.  ``bidirectional=True`` splits every
chunk in half and runs a clockwise and a counter-clockwise ring at once
— two messages in flight per hop (the double-buffering), using both link
directions.  Device ``r`` ends owning chunk ``r``, matching tiled
``psum_scatter``/``all_gather`` exactly.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp


# -------------------------------------------------------------- staging

def leafwise_pack(leaves: Sequence[jax.Array], comm_dtype, *,
                  scale: float = 1.0) -> jax.Array:
    """Per-leaf cast + concatenate (the seed emission, paper's CopyFromTo)."""
    parts = []
    for x in leaves:
        x = jnp.ravel(x)
        if scale != 1.0:
            x = x.astype(jnp.float32) * scale
        parts.append(x.astype(comm_dtype))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def leafwise_unpack(buf: jax.Array, sizes: Sequence[int], dtypes, *,
                    scale: float = 1.0) -> list[jax.Array]:
    """Static per-leaf slice + cast back (1-D pieces, caller reshapes)."""
    out = []
    off = 0
    for n, dt in zip(sizes, dtypes):
        x = jax.lax.slice(buf, (off,), (off + n,))
        if scale != 1.0:
            x = x.astype(jnp.float32) * scale
        out.append(x.astype(dt))
        off += n
    return out


# ---------------------------------------------------------- ring (1 axis)

def _fwd_perm(g: int) -> list[tuple[int, int]]:
    return [(i, (i + 1) % g) for i in range(g)]


def _bwd_perm(g: int) -> list[tuple[int, int]]:
    return [(i, (i - 1) % g) for i in range(g)]


def _chunk(x2d: jax.Array, idx) -> jax.Array:
    """Row ``idx`` (traced device-dependent index) of the (g, c) view."""
    return jax.lax.dynamic_slice_in_dim(x2d, idx, 1, 0)[0]


def _ring_rs_one_way(x2d: jax.Array, axis: str, g: int, forward: bool,
                     accum: Callable) -> jax.Array:
    """One directional ring: g-1 hops, device r ends owning chunk r."""
    r = jax.lax.axis_index(axis)
    perm = _fwd_perm(g) if forward else _bwd_perm(g)
    sgn = 1 if forward else -1
    # hop 0's payload: our own value of chunk r ∓ 1
    msg = _chunk(x2d, (r - sgn) % g)
    for s in range(1, g):
        msg = jax.lax.ppermute(msg, axis, perm)
        # received the partial of chunk r ∓ (s+1); add our contribution
        msg = accum(msg, _chunk(x2d, (r - sgn * (s + 1)) % g))
    return msg


def ring_reduce_scatter_ref(
    x: jax.Array, axis: str, g: int, *,
    bidirectional: bool = True,
    accum: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add,
) -> jax.Array:
    """(n,) per-device buffer (n % g == 0) → (n/g,) reduced shard.

    ``accum`` is the per-hop combine — ``jnp.add`` here, the Pallas
    ``ring_accum_kernel`` when driven from ``ops``.
    """
    if g == 1:
        return x
    c = x.shape[0] // g
    x2d = x.reshape(g, c)
    h = c // 2
    if not bidirectional or h == 0:
        return _ring_rs_one_way(x2d, axis, g, True, accum)
    # two half-width rings in flight per hop: cw on [:h], ccw on [h:]
    lo = _ring_rs_one_way(x2d[:, :h], axis, g, True, accum)
    hi = _ring_rs_one_way(x2d[:, h:], axis, g, False, accum)
    return jnp.concatenate([lo, hi])


def _ring_ag_one_way(shard: jax.Array, axis: str, g: int,
                     forward: bool) -> jax.Array:
    """(c,) owned chunk → (g, c): g-1 hops circulate every chunk."""
    r = jax.lax.axis_index(axis)
    perm = _fwd_perm(g) if forward else _bwd_perm(g)
    sgn = 1 if forward else -1
    out = jnp.zeros((g,) + shard.shape, shard.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, shard[None], r, 0)
    msg = shard
    for s in range(1, g):
        msg = jax.lax.ppermute(msg, axis, perm)
        # hop s delivers chunk r ∓ s
        out = jax.lax.dynamic_update_slice_in_dim(
            out, msg[None], (r - sgn * s) % g, 0)
    return out


def ring_all_gather_ref(
    shard: jax.Array, axis: str, g: int, *, bidirectional: bool = True,
) -> jax.Array:
    """(c,) owned shard (device r owns chunk r) → (g*c,) full buffer."""
    if g == 1:
        return shard
    c = shard.shape[0]
    h = c // 2
    if not bidirectional or h == 0:
        return _ring_ag_one_way(shard, axis, g, True).reshape(-1)
    lo = _ring_ag_one_way(shard[:h], axis, g, True)
    hi = _ring_ag_one_way(shard[h:], axis, g, False)
    return jnp.concatenate([lo, hi], axis=1).reshape(-1)
