"""Fused comm-staging + ring collective kernels (DESIGN.md §8)."""
from repro.kernels.collectives.ops import (
    fused_pack,
    fused_unpack,
    ring_all_gather,
    ring_allreduce,
    ring_reduce_scatter,
    staging_supported,
)

__all__ = [
    "fused_pack",
    "fused_unpack",
    "ring_all_gather",
    "ring_allreduce",
    "ring_reduce_scatter",
    "staging_supported",
]
