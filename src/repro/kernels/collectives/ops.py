"""Fused comm-staging + ring collectives: the public API.

Three implementation tiers, selected per call (``impl=``) or
automatically by backend:

  kernel   — the Pallas kernels (``kernel.py``).  The real path on TPU;
             interpret mode everywhere else (tests).
  xla      — a fused XLA emission: pack concatenates in the source dtype
             and runs ONE cast(+loss-scale) pass over the whole buffer;
             unpack is static ``lax.slice`` + cast (fusion-friendly —
             no dynamic offsets).  The production path on CPU/GPU, and
             measurably faster than leafwise (benchmarks/run.py
             ``pack`` section).
  leafwise — the seed's per-leaf emission (``ref.py``), kept as the
             oracle and the fallback for buckets the fused path cannot
             take (non-float dtypes).

The ring collectives run the chunked, bidirectional (double-buffered)
``ppermute`` rings from ``ref.py`` — on TPU each hop lowers to the same
ICI DMA the RDMA kernels issue by hand — with the per-hop accumulate
optionally routed through the Pallas ``ring_accum_kernel``.  Device
``r`` owns chunk ``r`` after reduce-scatter, so they are drop-in for
``psum_scatter``/``all_gather`` (tiled) anywhere in the repo: the
``ring`` reducer, rsag's two-phase ops, the hierarchical fast-tier
stages and compressed's gather phase.
"""
from __future__ import annotations

import functools
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.collectives import ref
from repro.kernels.collectives.kernel import (
    pack_bucket_kernel,
    ring_accum_kernel,
    unpack_bucket_kernel,
)

_FLOATS = (jnp.float32, jnp.bfloat16, jnp.float16, jnp.float64)


def staging_supported(leaf_dtypes, comm_dtype) -> bool:
    """Fused staging handles float↔float casts; anything else (int grads,
    complex) falls back to the leafwise ref path."""
    dts = tuple(leaf_dtypes) + (comm_dtype,)
    return all(jnp.dtype(d) in [jnp.dtype(f) for f in _FLOATS] for d in dts)


def _auto_impl() -> str:
    return "kernel" if jax.default_backend() == "tpu" else "xla"


# -------------------------------------------------------------- staging

def fused_pack(bucket, flat_leaves: Sequence[jax.Array], comm_dtype, *,
               scale: float = 1.0, impl: str | None = None,
               interpret: bool = False) -> jax.Array:
    """CopyFromTo(g, comm_buf), fused: one staging pass over the bucket.

    ``bucket``: a ``repro.core.buckets.Bucket``; ``flat_leaves``: the flat
    gradient list it indexes into.  ``scale`` is the optional loss-scale
    folded into the cast.
    """
    impl = impl or _auto_impl()
    leaves = [jnp.ravel(flat_leaves[l.index]) for l in bucket.leaves]
    if impl == "kernel":
        return pack_bucket_kernel(
            leaves, comm_dtype, scale=scale,
            interpret=interpret or jax.default_backend() != "tpu")
    if impl == "xla":
        if len({l.dtype for l in leaves}) == 1:
            buf = leaves[0] if len(leaves) == 1 else jnp.concatenate(leaves)
            if scale != 1.0:
                buf = buf.astype(jnp.float32) * scale
            return buf.astype(comm_dtype)
        # mixed-dtype bucket: per-leaf cast keeps rounding identical to
        # the leafwise oracle (concat would promote first)
        return ref.leafwise_pack(leaves, comm_dtype, scale=scale)
    if impl == "leafwise":
        return ref.leafwise_pack(leaves, comm_dtype, scale=scale)
    raise ValueError(f"unknown staging impl {impl!r}")


def fused_unpack(bucket, buf: jax.Array, flat_out: list, *,
                 scale: float = 1.0, impl: str | None = None,
                 interpret: bool = False) -> None:
    """CopyFromTo(recv_buf, g), fused: scatter the reduced buffer back
    into ``flat_out`` (cast-back + inverse loss-scale in the same pass)."""
    impl = impl or _auto_impl()
    sizes = [l.size for l in bucket.leaves]
    dtypes = [l.dtype for l in bucket.leaves]
    if impl == "kernel":
        pieces = unpack_bucket_kernel(
            buf, sizes, dtypes, scale=scale,
            interpret=interpret or jax.default_backend() != "tpu")
    elif impl in ("xla", "leafwise"):
        pieces = ref.leafwise_unpack(buf, sizes, dtypes, scale=scale)
    else:
        raise ValueError(f"unknown staging impl {impl!r}")
    for l, piece in zip(bucket.leaves, pieces):
        flat_out[l.index] = piece.reshape(l.shape)


# ---------------------------------------------------------------- rings

def _ring_axes(axes: Sequence[str],
               mesh_shape: Mapping[str, int]) -> list[tuple[str, int]]:
    return [(a, int(mesh_shape[a])) for a in axes
            if int(mesh_shape.get(a, 1)) > 1]


def group_size(axes: Sequence[str], mesh_shape: Mapping[str, int]) -> int:
    g = 1
    for _, s in _ring_axes(axes, mesh_shape):
        g *= s
    return g


def _accum(use_kernel: bool, interpret: bool):
    if not use_kernel:
        return jnp.add
    return functools.partial(ring_accum_kernel, interpret=interpret)


def ring_reduce_scatter(
    buf: jax.Array, axes: tuple[str, ...],
    mesh_shape: Mapping[str, int], *,
    bidirectional: bool = True, use_accum_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """(n,) buffer, n divisible by the group size → (n/g,) shard.

    Multi-axis groups decompose axis-by-axis in the given order (shards
    shrink per tier); ``ring_all_gather`` reverses the same order, so the
    pair composes to a ring allreduce over the product group.
    """
    interpret = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    accum = _accum(use_accum_kernel, interpret)
    for a, g in _ring_axes(axes, mesh_shape):
        buf = ref.ring_reduce_scatter_ref(
            buf, a, g, bidirectional=bidirectional, accum=accum)
    return buf


def ring_all_gather(
    shard: jax.Array, axes: tuple[str, ...],
    mesh_shape: Mapping[str, int], *, bidirectional: bool = True,
) -> jax.Array:
    """(n/g,) owned shard → (n,) full buffer (reverse of the RS order)."""
    for a, g in reversed(_ring_axes(axes, mesh_shape)):
        shard = ref.ring_all_gather_ref(
            shard, a, g, bidirectional=bidirectional)
    return shard


def ring_allreduce(
    buf: jax.Array, axes: tuple[str, ...],
    mesh_shape: Mapping[str, int], *,
    bidirectional: bool = True, use_accum_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Chunked ring allreduce = ring RS → ring AG (pads internally)."""
    g = group_size(axes, mesh_shape)
    if g == 1:
        return buf
    n = buf.shape[0]
    pad = (-n) % g
    if pad:
        buf = jnp.pad(buf, (0, pad))
    shard = ring_reduce_scatter(
        buf, axes, mesh_shape, bidirectional=bidirectional,
        use_accum_kernel=use_accum_kernel, interpret=interpret)
    full = ring_all_gather(shard, axes, mesh_shape,
                           bidirectional=bidirectional)
    return full[:n] if pad else full
