"""jit'd wrapper for the RWKV-6 chunk kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv_chunk_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_chunk(r, k, v, logw, u, state, *, interpret: bool = False):
    """One WKV chunk.  r,k,v,logw: (B, C, H, N); u: (H, N);
    state: (B, H, N, N) → (y (B,C,H,N) f32, new state)."""
    B, C, H, N = r.shape
    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, C, N)
    u_b = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)
    y, s1 = wkv_chunk_kernel(
        flat(r), flat(k), flat(v), flat(logw), u_b,
        state.reshape(B * H, N, N), interpret=interpret)
    return (y.reshape(B, H, C, N).transpose(0, 2, 1, 3),
            s1.reshape(B, H, N, N))
