"""Sequential-recurrence oracle for the WKV chunk kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, logw, u, state):
    """Step-by-step recurrence.  r,k,v,logw: (BH, C, N); u: (BH, 1, N);
    state: (BH, N, N).  Returns (y (BH,C,N) f32, final state)."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logw = logw.astype(jnp.float32)
    u = u.astype(jnp.float32)[:, 0]

    def step(S, xs):
        rt, kt, vt, lwt = xs                       # (BH, N)
        # y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
        kv = kt[:, :, None] * vt[:, None, :]       # (BH, N, N)
        y = jnp.einsum("bn,bnm->bm", rt, S + u[:, :, None] * kv)
        S = S * jnp.exp(lwt)[:, :, None] + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    S, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), S
