"""RWKV-6 chunked-WKV Pallas TPU kernel (one chunk step).

The inner loop of ``repro.models.rwkv.wkv_chunked``: per (batch·head) the
chunk computes the intra-chunk decay-weighted attention, the inter-chunk
state read, and the state update — all in VMEM (C ≤ 64, N = 64: every
tile is ≤ 64×64 f32).  Grid = (BH,), one program per head-row.

  y[t] = (r_t · W_{t-1}) S0 + Σ_{s<t} (r_t · W_{t-1}/W_s · k_s) v_s
         + (r_t · u·k_t) v_t
  S1   = D(W_C) S0 + Σ_s D(W_C/W_s) k_s v_s^T
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                y_ref, s1_ref):
    r = r_ref[0].astype(jnp.float32)      # (C, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)    # (C, N), log-decay <= 0
    u = u_ref[0].astype(jnp.float32)      # (1, N) bonus
    s0 = s0_ref[0].astype(jnp.float32)    # (N, N)

    C = r.shape[0]
    L = jnp.cumsum(lw, axis=0)            # (C, N)
    Lprev = L - lw

    r_dec = r * jnp.exp(Lprev)
    y = jax.lax.dot_general(               # inter-chunk read
        r_dec, s0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    att = jax.lax.dot_general(             # intra-chunk scores
        r_dec, k * jnp.exp(-L), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    att = jnp.where(s_idx < t_idx, att, 0.0)
    diag = jnp.sum(r * (u * k), axis=1)    # bonus
    y = y + jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v

    wc = L[C - 1]                           # (N,)
    k_dec = k * jnp.exp(wc[None, :] - L)
    s1 = s0 * jnp.exp(wc)[:, None] + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)
    s1_ref[0] = s1


def wkv_chunk_kernel(r, k, v, logw, u, state, *, interpret: bool = False):
    """r,k,v,logw: (BH, C, N); u: (BH, 1, N); state: (BH, N, N).
    Returns (y (BH, C, N) f32, new state (BH, N, N) f32)."""
    BH, C, N = r.shape
    return pl.pallas_call(
        _wkv_kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, C, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, C, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, logw, u, state)
