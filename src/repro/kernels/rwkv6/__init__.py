from repro.kernels.rwkv6.ops import wkv_chunk

__all__ = ["wkv_chunk"]
