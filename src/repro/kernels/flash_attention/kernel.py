"""Flash-attention forward Pallas TPU kernel.

TPU adaptation (DESIGN.md hardware-adaptation): the GPU flash algorithm's
warp-level softmax reductions become VMEM-resident row statistics; tiling
is chosen so each (block_q × d) and (block_k × d) tile sits in VMEM with
MXU-aligned dims (multiples of 128 for the contracting dim, 8×128 lanes
for f32).  Grid = (batch·heads, q_blocks, k_blocks); the k-block axis is
the innermost (sequential) grid dim, accumulating into VMEM scratch, with
init on the first k-step and the normalized write-out on the last.

Shapes: q, k, v: (BH, S, D) — GQA head mapping is done by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref,
                acc_ref, m_ref, l_ref,
                *, scale: float, block_q: int, block_k: int,
                causal: bool, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        if causal:
            q_pos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    if causal:
        # skip fully-masked k-blocks (above the diagonal)
        @pl.when(ik * block_k <= (iq + 1) * block_q - 1)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, D) → (BH, S, D)."""
    BH, S, D = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q = S // block_q
    n_k = S // block_k
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
