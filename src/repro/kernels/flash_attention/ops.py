"""jit'd public wrapper: GQA-shaped (B, S, H, D) API over the MHA kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, interpret: bool = False,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) → (B, S, Hq, D).

    GQA: kv heads are repeated to match q heads before the kernel (the
    kernel operates per fused batch·head row).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    o = flash_attention_fwd(qf, kf, vf, causal=causal,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return o.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
