"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q, k, v: (BH, S, D) → (BH, S, D); fp32 math, dense softmax."""
    BH, S, D = q.shape
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
