"""Mixture-of-Experts FFN with expert parallelism over the "model" axis.

Activations are replicated across TP inside a block (DESIGN.md §5), so
dispatch needs NO collective: every device routes all local tokens, keeps
the slots bound for its own expert shard, runs its experts, and a single
psum(model) combines contributions — the same wire cost as one
row-parallel matmul.  Token→slot assignment is sort-based (no (T, E, C)
one-hot cube; kimi-k2 is 384 experts × 64k tokens/device).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, MODEL_AXIS, swiglu


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int
    shared_experts: int = 0      # dense experts always active (kimi-k2: 1)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


def capacity(tokens: int, cfg: MoECfg) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(c, 4)


def moe_ffn(
    p: dict[str, jax.Array],
    x: jax.Array,                # (T, d) local tokens, replicated over model
    cfg: MoECfg,
    tp: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (T, d), aux_loss scalar)."""
    T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    e_local = E // tp if tp > 1 else E
    C = capacity(T, cfg)

    # ---- route (replicated) ----
    logits = (x.astype(jnp.float32)) @ p["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                     # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        jnp.ones((T * K,), jnp.float32)
    ) / (T * K)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- sort-based slot assignment ----
    flat_e = expert_ids.reshape(-1)                                     # (T*K,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos_in_e < C
    # scatter token rows into this device's expert slots
    start = jax.lax.axis_index(MODEL_AXIS) * e_local if tp > 1 else 0
    local_e = sorted_e - start
    mine = keep & (local_e >= 0) & (local_e < e_local)
    slot = jnp.where(mine, local_e * C + pos_in_e, e_local * C)         # drop
    tok = (order // K).astype(jnp.int32)
    buf = jnp.zeros((e_local * C, d), x.dtype)
    buf = buf.at[slot].add(
        jnp.where(mine[:, None], x[tok], 0), mode="drop"
    )
    h = buf.reshape(e_local, C, d)

    # ---- expert FFN (E_local, C, d) ----
    if "w_up" in p:   # gated (SwiGLU) experts
        g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(x.dtype))
        a = swiglu(g, u)
    else:
        a = ACTIVATIONS["gelu"](
            jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(x.dtype))
        )
    y = jnp.einsum("ecf,efd->ecd", a, p["w_down"].astype(x.dtype))
    y = y.reshape(e_local * C, d)

    # ---- combine: gather slots back to (T*K), weight by gate, segment-sum
    gathered = jnp.where(
        mine[:, None],
        jnp.take(y, jnp.minimum(slot, e_local * C - 1), axis=0),
        0,
    )
    gates_sorted = gate_vals.reshape(-1)[order]
    contrib = gathered * gates_sorted[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    if tp > 1:
        out = jax.lax.psum(out, MODEL_AXIS)        # sum over expert shards
        aux = aux  # aux identical on all model-ranks (replicated routing)

    # ---- shared (always-on) experts, row/col TP like a dense MLP ----
    if cfg.shared_experts and "ws_g" in p:
        a = swiglu(x @ p["ws_g"].astype(x.dtype),
                   x @ p["ws_u"].astype(x.dtype))  # col-parallel pair
        shared = a @ p["ws_down"].astype(x.dtype)  # row-parallel
        shared = jax.lax.psum(shared, MODEL_AXIS) if tp > 1 else shared
        out = out + shared
    return out, aux
