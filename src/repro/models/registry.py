"""Uniform model-family API: every family exposes the same six hooks so
the launcher / dry-run / train loop are family-agnostic."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.models import resnet as resnet_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tf_lib


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    family: str
    init: Callable[..., Any]
    param_rules: Callable[[Any], Any]
    in_scan_names: Callable[[Any], frozenset[str]]
    train_forward: Callable[..., jax.Array]
    prefill: Optional[Callable[..., Any]] = None
    decode_step: Optional[Callable[..., Any]] = None
    make_decode_state: Optional[Callable[..., Any]] = None
    decode_state_specs: Optional[Callable[..., Any]] = None
    # paged (block-table) decode for the continuous-batching engine;
    # families without it fall back to the static serving path
    decode_paged: Optional[Callable[..., Any]] = None
    # staged wave-pipeline loss (DESIGN.md §15) for pp_stages > 1;
    # families without it reject pipeline training
    pipeline_train_forward: Optional[Callable[..., Any]] = None


def _tf_make_state(cfg, batch, max_len):
    # sliding-window archs: ring cache bounded at the window size
    if getattr(cfg, "swa_window", None):
        max_len = min(max_len, cfg.swa_window)
    return tf_lib.make_cache(cfg, batch, max_len)


def _rwkv_make_state(cfg, batch, max_len):
    return rwkv_lib.make_state(cfg, batch)


def _ssm_make_state(cfg, batch, max_len):
    return ssm_lib.make_state(cfg, batch, attn_window=min(max_len, 4096))


FAMILIES: dict[str, ModelAPI] = {
    "transformer": ModelAPI(
        family="transformer",
        init=tf_lib.init_params,
        param_rules=tf_lib.param_rules,
        in_scan_names=tf_lib.in_scan_param_names,
        train_forward=tf_lib.train_forward,
        prefill=tf_lib.prefill,
        decode_step=tf_lib.decode_step,
        make_decode_state=_tf_make_state,
        decode_state_specs=tf_lib.decode_state_specs,
        decode_paged=tf_lib.decode_step_paged,
        pipeline_train_forward=tf_lib.pipeline_train_forward,
    ),
    "rwkv": ModelAPI(
        family="rwkv",
        init=rwkv_lib.init_params,
        param_rules=rwkv_lib.param_rules,
        in_scan_names=rwkv_lib.in_scan_param_names,
        train_forward=rwkv_lib.train_forward,
        prefill=rwkv_lib.prefill,
        decode_step=rwkv_lib.decode_step,
        make_decode_state=_rwkv_make_state,
        decode_state_specs=rwkv_lib.decode_state_specs,
    ),
    "ssm": ModelAPI(
        family="ssm",
        init=ssm_lib.init_params,
        param_rules=ssm_lib.param_rules,
        in_scan_names=ssm_lib.in_scan_param_names,
        train_forward=ssm_lib.train_forward,
        prefill=ssm_lib.prefill,
        decode_step=ssm_lib.decode_step,
        make_decode_state=_ssm_make_state,
        decode_state_specs=ssm_lib.decode_state_specs,
    ),
    "resnet": ModelAPI(
        family="resnet",
        init=resnet_lib.init_params,
        param_rules=resnet_lib.param_rules,
        in_scan_names=resnet_lib.in_scan_param_names,
        train_forward=resnet_lib.train_forward,
    ),
    "inception": ModelAPI(
        family="inception",
        init=resnet_lib.init_inception,
        param_rules=lambda cfg: resnet_lib.param_rules(cfg),
        in_scan_names=resnet_lib.in_scan_param_names,
        train_forward=resnet_lib.inception_train_forward,
    ),
}


def family_of(cfg) -> ModelAPI:
    if isinstance(cfg, tf_lib.TransformerConfig):
        return FAMILIES["transformer"]
    if isinstance(cfg, rwkv_lib.RWKVConfig):
        return FAMILIES["rwkv"]
    if isinstance(cfg, ssm_lib.SSMConfig):
        return FAMILIES["ssm"]
    if isinstance(cfg, resnet_lib.ResNetConfig):
        return FAMILIES["resnet"]
    if isinstance(cfg, resnet_lib.InceptionConfig):
        return FAMILIES["inception"]
    raise TypeError(f"unknown config type {type(cfg)}")
