"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with
data-dependent per-channel decay.  TP: heads sharded over "model";
the tiny ddlerp/LoRA modulation params are replicated and their outputs
sliced to the local channel shard.

The WKV recurrence is evaluated in chunked-parallel form (chunk=C):
  S_t = diag(w_t) S_{t-1} + k_t v_t^T          (per head, S: (N, N))
  y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
Intra-chunk terms use log-space decay differences (numerically safe:
all exponents <= 0); inter-chunk state carries through a lax.scan.
``repro/kernels/rwkv6`` is the Pallas TPU kernel for the chunk step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.overlap import scan_layers
from repro.models.common import (
    MODEL_AXIS,
    dense_init,
    embed_lookup,
    rms_norm,
    sharded_softmax_xent,
    split_rngs,
)
from repro.parallel.sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    head_size: int = 64
    lora_w: int = 64
    lora_mix: int = 32
    dtype: Any = jnp.bfloat16
    tp: int = 1
    chunk: int = 32
    remat: str = "dots"
    scan_unroll: int = 1
    depcha_in_scan: bool = False
    dp_axes: tuple[str, ...] = ("data",)
    chunk_unroll: bool = False
    depcha_reducer: str = "flat"
    intra_size: int = 16

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_size

    @property
    def heads_local(self) -> int:
        return self.n_heads // self.tp if self.tp > 1 else self.n_heads

    @property
    def d_local(self) -> int:
        return self.d_model // self.tp if self.tp > 1 else self.d_model

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // self.tp) * self.tp


def init_params(rng, cfg: RWKVConfig) -> dict:
    d, L, dt = cfg.d_model, cfg.n_layers, cfg.dtype
    r = split_rngs(rng, 16)
    blocks = {
        "ln1": jnp.ones((L, d), dt),
        "ln2": jnp.ones((L, d), dt),
        # ddlerp mix coefficients (5 targets: r, k, v, w, g) + base
        "mu_x": jnp.zeros((L, d), dt),
        "mu_rkvwg": jnp.zeros((L, 5, d), dt),
        "lora_mix_a": dense_init(r[0], (L, d, 5 * cfg.lora_mix), d, dt),
        "lora_mix_b": jnp.zeros((L, 5, cfg.lora_mix, d), dt),
        # time-mix projections (column-sharded over heads)
        "wr": dense_init(r[1], (L, d, d), d, dt),
        "wk": dense_init(r[2], (L, d, d), d, dt),
        "wv": dense_init(r[3], (L, d, d), d, dt),
        "wg": dense_init(r[4], (L, d, d), d, dt),
        "wo": dense_init(r[5], (L, d, d), d, dt),
        # decay: w = exp(-exp(w0 + lora)); bonus u
        "w0": jnp.full((L, d), -5.0, jnp.float32),
        "lora_w_a": dense_init(r[6], (L, d, cfg.lora_w), d, dt),
        "lora_w_b": jnp.zeros((L, cfg.lora_w, d), dt),
        "u": jnp.zeros((L, d), jnp.float32),
        "ln_x": jnp.ones((L, d), dt),           # per-head groupnorm scale
        # channel-mix
        "mu_ck": jnp.zeros((L, d), dt),
        "mu_cr": jnp.zeros((L, d), dt),
        "ck": dense_init(r[7], (L, d, cfg.d_ff), d, dt),
        "cv": dense_init(r[8], (L, cfg.d_ff, d), cfg.d_ff, dt),
        "cr": dense_init(r[9], (L, d, d), d, dt),
    }
    return {
        "embed": dense_init(r[10], (cfg.vocab_padded, d), d, dt),
        "blocks": blocks,
        "ln_f": jnp.ones((d,), dt),
        "lm_head": dense_init(r[11], (d, cfg.vocab_padded), d, dt),
    }


def param_rules(cfg: RWKVConfig) -> ShardingRules:
    return ShardingRules(rules=(
        (r"embed", P(MODEL_AXIS, None)),
        (r"lm_head", P(None, MODEL_AXIS)),
        (r"/w[rkvg]$", P(None, None, MODEL_AXIS)),
        (r"/wo$", P(None, MODEL_AXIS, None)),
        (r"/ck$", P(None, None, MODEL_AXIS)),
        (r"/cv$", P(None, MODEL_AXIS, None)),
        (r"/cr$", P(None, None, MODEL_AXIS)),
        # per-channel vectors sharded with the head shard
        (r"/(w0|u|ln_x)$", P(None, MODEL_AXIS)),
    ))


def in_scan_param_names(params) -> frozenset[str]:
    from repro.utils.trees import named_leaves
    return frozenset(n for n, _ in named_leaves(params)
                     if n.startswith("blocks/"))


def _token_shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """xx_t = x_{t-1}; first position uses ``last`` (decode) or zeros."""
    if x.shape[1] == 1 and last is not None:
        return last[:, None, :]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if last is not None:
        shifted = shifted.at[:, 0].set(last)
    return shifted


def _ddlerp(p, x, xx):
    """Data-dependent interpolation → 5 mixed inputs (r, k, v, w, g)."""
    dx = xx - x
    base = x + dx * p["mu_x"]
    lo = jnp.tanh(base @ p["lora_mix_a"])          # (B,S,5*lm)
    lo = lo.reshape(*lo.shape[:2], 5, -1)
    mod = jnp.einsum("bstl,tld->bstd", lo, p["lora_mix_b"])
    mix = p["mu_rkvwg"][None, None] + mod          # (B,S,5,d)
    return x[:, :, None, :] + dx[:, :, None, :] * mix


def _decay(p, xw, d_local_slice):
    """w_t in (0,1): exp(-exp(w0 + lora_w(xw))), sliced to local channels."""
    lo = jnp.tanh(xw @ p["lora_w_a"]) @ p["lora_w_b"]   # (B,S,d) full
    lo = d_local_slice(lo)
    w0 = p["w0"]                                        # already local (sharded)
    logw = -jnp.exp(jnp.clip(w0[None, None].astype(jnp.float32)
                             + lo.astype(jnp.float32), -10.0, 8.0))
    return logw                                          # (B,S,d_local) <= 0


def wkv_chunked(r, k, v, logw, u, state, chunk: int, unroll_all=False):
    """Chunked WKV.  r,k,v: (B,S,H,N); logw: (B,S,H,N) (<=0); u: (H,N);
    state: (B,H,N,N) [indexed state[b,h,i,j] ~ k-dim i, v-dim j].
    Returns (y (B,S,H,N), final state)."""
    B, S, H, N = r.shape
    C = min(chunk, S)
    pad = (-S) % C
    S_out = S
    if pad:
        # zero-pad: k=0 adds nothing to the state; logw=0 (w=1) leaves
        # the decay product unchanged — exact for the valid positions
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zp)
        k = jnp.pad(k, zp)
        v = jnp.pad(v, zp)
        logw = jnp.pad(logw, zp)
        S = S + pad
    T = S // C
    rc = r.reshape(B, T, C, H, N).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kc = k.reshape(B, T, C, H, N).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vc = v.reshape(B, T, C, H, N).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    lw = logw.reshape(B, T, C, H, N).transpose(1, 0, 3, 2, 4).astype(jnp.float32)

    uf = u.astype(jnp.float32)

    def body(S0, xs):
        rr, kk, vv, ww = xs                      # (B,H,C,N)
        L = jnp.cumsum(ww, axis=2)               # log ∏_{s<=t} w_s
        Lprev = L - ww                           # log ∏_{s<t}
        # inter-chunk contribution: y_inter[t] = (r_t * W_{t-1}) @ S0
        r_dec = rr * jnp.exp(Lprev)
        y = jnp.einsum("bhcn,bhnm->bhcm", r_dec, S0)
        # intra-chunk: A[t,s] = sum_n r_tn k_sn exp(Lprev_t - L_s)_n , s<t
        att = jnp.einsum("bhcn,bhsn->bhcs",
                         rr * jnp.exp(Lprev), kk * jnp.exp(-L))
        # guard: exp(Lprev_t - L_s) for s<t is <=... computed stably via
        # factored exps; strictly-lower mask keeps only s<t terms
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        # diagonal (bonus) term: (r_t · u k_t) v_t
        diag = jnp.einsum("bhcn,bhcn->bhc", rr, uf[None, :, None, :] * kk)
        y = y + jnp.einsum("bhcs,bhsn->bhcn", att, vv) + diag[..., None] * vv
        # state update: S_C = D(W_C) S0 + Σ_s D(W_C/W_s) k_s v_s^T
        WC = L[:, :, -1:, :]                      # (B,H,1,N)
        k_dec = kk * jnp.exp(WC - L)
        S1 = S0 * jnp.exp(WC.squeeze(2))[..., None] + \
            jnp.einsum("bhsn,bhsm->bhnm", k_dec, vv)
        return S1, y

    state, ys = jax.lax.scan(
        body, state.astype(jnp.float32), (rc, kc, vc, lw),
        unroll=T if unroll_all else 1)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, N)
    if pad:
        y = y[:, :S_out]
    return y.astype(r.dtype), state


def _time_mix(p, x, cfg: RWKVConfig, state, last_x):
    """Returns (out, new_state, new_last_x)."""
    B, S, _ = x.shape
    H, N = cfg.heads_local, cfg.head_size
    xx = _token_shift(x, last_x)
    mixed = _ddlerp(p, x, xx)                     # (B,S,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    if cfg.tp > 1:
        off = jax.lax.axis_index(MODEL_AXIS) * cfg.d_local
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, off, cfg.d_local, 2)
    else:
        sl = lambda t: t

    r = (xr @ p["wr"]).reshape(B, S, H, N)
    k = (xk @ p["wk"]).reshape(B, S, H, N)
    v = (xv @ p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    logw = _decay(p, xw, sl).reshape(B, S, H, N)
    u = p["u"].reshape(H, N)

    y, new_state = wkv_chunked(r, k, v, logw, u, state, cfg.chunk,
                               unroll_all=cfg.chunk_unroll)
    # per-head groupnorm
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + 64e-5)
    yn = (yn.reshape(B, S, -1) * p["ln_x"].astype(jnp.float32)).astype(x.dtype)
    out = (yn * g) @ p["wo"]
    out = jax.lax.psum(out, MODEL_AXIS) if cfg.tp > 1 else out
    return out, new_state, x[:, -1]


def _channel_mix(p, x, cfg: RWKVConfig, last_x):
    xx = _token_shift(x, last_x)
    xk = x + (xx - x) * p["mu_ck"]
    xr = x + (xx - x) * p["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))        # (B,S,ff_local) col-par
    out = k @ p["cv"]                                 # row-parallel
    r_local = jax.nn.sigmoid(xr @ p["cr"])            # (B,S,d_local) col-par
    if cfg.tp > 1:
        out = jax.lax.psum(out, MODEL_AXIS)
        r = jax.lax.all_gather(r_local, MODEL_AXIS, axis=-1, tiled=True)
    else:
        r = r_local
    return r * out, x[:, -1]


def block(p, x, cfg: RWKVConfig, state=None, lasts=None):
    """One RWKV block.  state: (B,H,N,N) or zeros; lasts: decode shifts."""
    B = x.shape[0]
    H, N = cfg.heads_local, cfg.head_size
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)
    l_tm = lasts["tm"] if lasts else None
    l_cm = lasts["cm"] if lasts else None
    a, new_state, new_ltm = _time_mix(p, rms_norm(x, p["ln1"]), cfg, state, l_tm)
    x = x + a
    m, new_lcm = _channel_mix(p, rms_norm(x, p["ln2"]), cfg, l_cm)
    x = x + m
    return x, new_state, {"tm": new_ltm, "cm": new_lcm}


# ------------------------------------------------------------------ train
def train_forward(params, batch, cfg: RWKVConfig) -> jax.Array:
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens, cfg.tp).astype(cfg.dtype)

    def body(p, x):
        out, _, _ = block(p, x, cfg)
        return out

    if cfg.depcha_in_scan:
        from repro.parallel.sharding import reduce_axes_tree
        mesh_axes = tuple(cfg.dp_axes) + (("model",) if cfg.tp > 1 else ())
        depcha = reduce_axes_tree(
            param_rules(cfg), params["blocks"], "blocks/", mesh_axes)
    else:
        depcha = ()
    x = scan_layers(
        body, params["blocks"], x,
        depcha_axes=depcha,
        unroll=cfg.scan_unroll, remat=cfg.remat,
        depcha_reducer=cfg.depcha_reducer, intra_size=cfg.intra_size,
    )
    h = rms_norm(x, params["ln_f"])
    logits = h @ params["lm_head"]
    per_tok = sharded_softmax_xent(logits, batch["labels"], cfg.tp)
    return jnp.sum(per_tok) / batch["global_tokens"]


# ------------------------------------------------------------------ serve
def make_state(cfg: RWKVConfig, batch: int):
    H, N = cfg.heads_local, cfg.head_size
    return {
        "wkv": jnp.zeros((cfg.n_layers, batch, H, N, N), jnp.float32),
        "tm": jnp.zeros((cfg.n_layers, batch, cfg.d_model), cfg.dtype),
        "cm": jnp.zeros((cfg.n_layers, batch, cfg.d_model), cfg.dtype),
    }


def decode_state_specs(cfg: RWKVConfig, batch_entry):
    return {
        "wkv": P(None, batch_entry, MODEL_AXIS, None, None),
        "tm": P(None, batch_entry, None),   # residual stream: replicated
        "cm": P(None, batch_entry, None),
    }


def prefill(params, tokens, cfg: RWKVConfig):
    x = embed_lookup(params["embed"], tokens, cfg.tp).astype(cfg.dtype)

    def body(x, xs):
        p, st = xs
        out, new_st, lasts = block(p, x, cfg, state=st)
        return out, (new_st, lasts["tm"], lasts["cm"])

    B = tokens.shape[0]
    H, N = cfg.heads_local, cfg.head_size
    st0 = jnp.zeros((cfg.n_layers, B, H, N, N), jnp.float32)
    x, (wkv, tm, cm) = jax.lax.scan(body, x, (params["blocks"], st0),
                                    unroll=cfg.scan_unroll)
    h = rms_norm(x[:, -1:], params["ln_f"])
    logits = (h @ params["lm_head"])[:, 0]
    return logits, {"wkv": wkv, "tm": tm, "cm": cm}


def decode_step(params, state, token, pos, cfg: RWKVConfig):
    x = embed_lookup(params["embed"], token[:, None], cfg.tp).astype(cfg.dtype)

    def body(x, xs):
        p, st, tm, cm = xs
        out, new_st, lasts = block(
            p, x, cfg, state=st, lasts={"tm": tm, "cm": cm})
        return out, (new_st, lasts["tm"], lasts["cm"])

    x, (wkv, tm, cm) = jax.lax.scan(
        body, x, (params["blocks"], state["wkv"], state["tm"], state["cm"]),
        unroll=cfg.scan_unroll)
    h = rms_norm(x, params["ln_f"])
    logits = (h @ params["lm_head"])[:, 0]
    return logits, {"wkv": wkv, "tm": tm, "cm": cm}
