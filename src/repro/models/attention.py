"""Attention: chunked online-softmax (memory-safe reference path).

The pure-jnp chunked implementation is the portable path (and the AD path);
``repro.kernels.flash_attention`` is the Pallas TPU kernel with the same
math, validated against this reference.  Supports causal, sliding-window
(h2o-danube), cross-attention (llama-vision) and single-token decode
against a KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(
    q_pos: jax.Array,       # (Sq,) int32 — absolute positions of queries
    k_pos: jax.Array,       # (Ck,) int32 — absolute positions of keys
    causal: bool,
    window: Optional[int],
    kv_len: Optional[jax.Array],   # dynamic valid-length of the kv cache
) -> jax.Array:
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def attention(
    q: jax.Array,                 # (B, Sq, Hq, D)
    k: jax.Array,                 # (B, Sk, Hkv, D)
    v: jax.Array,                 # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,
    chunk: int = 1024,
    use_flash: bool = False,
    unroll_all: bool = False,
) -> jax.Array:
    """Grouped-query attention with online softmax over KV chunks.

    ``q_offset``: absolute position of q[0] (decode: the cache length).
    ``kv_len``: dynamic number of valid kv positions (decode with a
    fixed-size cache).  Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    if use_flash and causal and window is None and kv_len is None and Sq == Sk:
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(q, k, v, causal=True)

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, rep, D)
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)

    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = jnp.minimum(
            kv_len if kv_len is not None else jnp.int32(Sk), Sk
        )
    else:
        kv_valid = kv_len

    kc = k.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        acc, m_run, l_run = carry
        kci, vci, ci = xs
        k_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        # scores: (B, Sq, Hkv, rep, chunk)
        s = jnp.einsum(
            "bqhrd,bchd->bqhrc", qf, kci.astype(jnp.float32)
        )
        msk = _mask(q_pos, k_pos, causal, window,
                    kv_valid if (kv_valid is not None or pad) else None)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhrc,bchd->bqhrd", p, vci.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, Hkv, rep, D), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, rep), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)),
        unroll=n_chunks if unroll_all else 1,
    )
    l_safe = jnp.where(l_run > 0, l_run, 1.0)
    out = acc / l_safe[..., None]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_attention(
    q: jax.Array,            # (B, 1, Hq, D)
    k_cache: jax.Array,      # (B, Smax, Hkv, D)
    v_cache: jax.Array,
    kv_len: jax.Array,       # int32 valid cache length (incl. new token):
    #                          scalar (shared) or (B,) per-row (the paged
    #                          continuous-batching path, where every slot
    #                          sits at its own position)
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token decode: dense masked attention over the cache (the
    score row is (B, Hq, Smax) — tiny; no chunking needed)."""
    B, _, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, rep, D)
    s = jnp.einsum("bhrd,bshd->bhrs", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(Smax, dtype=jnp.int32)
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        valid = pos < kv_len
        if window is not None:
            valid &= pos > (kv_len - 1) - window
        valid = valid[None, None, None, :]
    else:
        valid = pos[None, :] < kv_len[:, None]          # (B, Smax)
        if window is not None:
            valid &= pos[None, :] > (kv_len[:, None] - 1) - window
        valid = valid[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)
