from repro.models.registry import FAMILIES, ModelAPI, family_of

__all__ = ["FAMILIES", "ModelAPI", "family_of"]
