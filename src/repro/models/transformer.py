"""Unified decoder-only transformer LM covering the LM-family archs.

Config flags cover: GQA (any kv_heads vs tp), RoPE, sliding-window attention
(h2o-danube), qk-norm (qwen3), MoE FFN (granite, kimi-k2), cross-attention
image layers (llama-3.2-vision), stub modality embeddings (musicgen frame
embeds / vision patch embeds), padded heads (starcoder2 24H → 32 on tp=16),
padded vocab (granite 49155 → 49168).

All forward code runs inside shard_map on local shards with explicit TP
collectives (DESIGN.md §5).  Layers are scanned (HLO size O(1) in depth —
kimi-k2 at 61L compiles like 1L).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.overlap import scan_layers, sync_in_backward
from repro.models import attention as attn_lib
from repro.models.common import (
    ACTIVATIONS,
    HeadLayout,
    MODEL_AXIS,
    apply_rope,
    dense_init,
    embed_lookup,
    pad_heads,
    rms_norm,
    rope_angles,
    sharded_softmax_xent,
    split_rngs,
    swiglu,
)
from repro.models.moe import MoECfg, moe_ffn
from repro.parallel.sharding import ShardingRules


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    act: str = "silu"
    gated: bool = True
    qk_norm: bool = False
    swa_window: Optional[int] = None
    rope_theta: float = 500_000.0
    moe: Optional[MoECfg] = None
    cross_attn_every: Optional[int] = None   # 1 cross layer per N self layers
    n_img_tokens: int = 0
    frame_embeds: bool = False        # musicgen stub conditioning input
    dtype: Any = jnp.bfloat16
    tp: int = 1
    attn_chunk: int = 1024
    remat: str = "dots"
    scan_unroll: int = 1
    depcha_in_scan: bool = False      # emit DP psums inside backward scan
    dp_axes: tuple[str, ...] = ("data",)
    use_flash: bool = False
    chunk_unroll: bool = False        # unroll chunk scans (exact HLO cost)
    depcha_reducer: str = "flat"      # flat | hierarchical (in-scan sync)
    intra_size: int = 16              # intra-pod "data" size (hierarchical)
    fsdp: bool = False                # ZeRO-3: block weights stored sharded
                                      # over "data" too; all-gathered per
                                      # layer inside the scan (bwd transpose
                                      # = reduce-scatter of the grads)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def heads_padded(self) -> int:
        return pad_heads(self.n_heads, self.tp)

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, self.tp)

    @property
    def layout(self) -> HeadLayout:
        return HeadLayout(self.heads_padded, self.kv_heads, self.hd, self.tp)

    @property
    def n_cross(self) -> int:
        if not self.cross_attn_every:
            return 0
        return self.n_layers // (self.cross_attn_every + 1)

    @property
    def n_self(self) -> int:
        return self.n_layers - self.n_cross


# ------------------------------------------------------------------ params
def init_params(rng, cfg: TransformerConfig) -> dict:
    """Global (unsharded) parameter pytree.  Use under jax.eval_shape for
    full-size configs (dry-run); materialize only for reduced configs."""
    d, hd, L = cfg.d_model, cfg.hd, cfg.n_self
    Hq, Hkv = cfg.heads_padded, cfg.kv_heads
    ff = cfg.d_ff
    dt = cfg.dtype
    rngs = split_rngs(rng, 24)

    def blk(L_: int, r, cross: bool) -> dict:
        p = {
            "ln1": jnp.ones((L_, d), dt),
            "wq": dense_init(r[0], (L_, d, Hq * hd), d, dt),
            "wk": dense_init(r[1], (L_, d, Hkv * hd), d, dt),
            "wv": dense_init(r[2], (L_, d, Hkv * hd), d, dt),
            "wo": dense_init(r[3], (L_, Hq * hd, d), Hq * hd, dt),
            "ln2": jnp.ones((L_, d), dt),
        }
        if cfg.qk_norm:
            p["qnorm"] = jnp.ones((L_, hd), dt)
            p["knorm"] = jnp.ones((L_, hd), dt)
        if cross:
            p["lnkv"] = jnp.ones((L_, d), dt)
            p["gate_attn"] = jnp.zeros((L_,), dt)
        if cfg.moe is not None and not cross:
            m = cfg.moe
            p["router"] = dense_init(r[4], (L_, d, m.num_experts), d, jnp.float32)
            p["w_gate"] = dense_init(r[5], (L_, m.num_experts, d, m.d_expert), d, dt)
            p["w_up"] = dense_init(r[6], (L_, m.num_experts, d, m.d_expert), d, dt)
            p["w_down"] = dense_init(
                r[7], (L_, m.num_experts, m.d_expert, d), m.d_expert, dt
            )
            if m.shared_experts:
                ds = m.d_expert * m.shared_experts
                p["ws_g"] = dense_init(r[8], (L_, d, ds), d, dt)
                p["ws_u"] = dense_init(r[10], (L_, d, ds), d, dt)
                p["ws_down"] = dense_init(r[9], (L_, ds, d), ds, dt)
        else:
            if cfg.gated:
                # separate gate/up (a fused [gate|up] matrix would shard the
                # concatenated dim — wrong halves per device)
                p["wg"] = dense_init(r[4], (L_, d, ff), d, dt)
                p["wu"] = dense_init(r[6], (L_, d, ff), d, dt)
            else:
                p["wi"] = dense_init(r[4], (L_, d, ff), d, dt)
            p["wdown"] = dense_init(r[5], (L_, ff, d), ff, dt)
        return p

    params = {
        "embed": dense_init(rngs[0], (cfg.vocab_padded, d), d, dt),
        "blocks": blk(L, rngs[1:12], cross=False),
        "ln_f": jnp.ones((d,), dt),
        "lm_head": dense_init(rngs[11], (d, cfg.vocab_padded), d, dt),
    }
    if cfg.n_cross:
        params["cross_blocks"] = blk(cfg.n_cross, rngs[12:23], cross=True)
    return params


# FSDP storage: the big per-layer matrices get "data" on a second dim;
# the scan body all-gathers them before use (fsdp_gather).  dim chosen so
# the head/expert structure stays intact (the non-model matrix dim).
_FSDP_DIM = {
    "wq": 1, "wo": 2, "wi": 1, "wg": 1, "wu": 1, "wdown": 2,
    "w_gate": 3, "w_up": 3, "w_down": 2, "ws_g": 1, "ws_u": 1,
    "ws_down": 2,
}


def param_rules(cfg: TransformerConfig) -> ShardingRules:
    kv_sharded = cfg.layout.kv_sharded
    fsdp = getattr(cfg, "fsdp", False)

    dp = tuple(cfg.dp_axes)      # fsdp shards over EVERY dp axis (pods too)
    dp_entry = dp if len(dp) > 1 else dp[0]

    def spec3(model_dim: int, name: str) -> P:
        entries = [None, None, None]
        entries[model_dim] = MODEL_AXIS
        if fsdp and name in _FSDP_DIM:
            entries[_FSDP_DIM[name]] = dp_entry
        return P(*entries)

    def spec4(model_dim: int, name: str) -> P:
        entries = [None, None, None, None]
        entries[model_dim] = MODEL_AXIS
        if fsdp and name in _FSDP_DIM:
            entries[_FSDP_DIM[name]] = dp_entry
        return P(*entries)

    rules = [
        (r"embed", P(MODEL_AXIS, None)),
        (r"lm_head", P(None, MODEL_AXIS)),
        (r"/wq$", spec3(2, "wq")),
        (r"/wo$", spec3(1, "wo")),
        (r"/wi$", spec3(2, "wi")),
        (r"/wg$", spec3(2, "wg")),
        (r"/wu$", spec3(2, "wu")),
        (r"/wdown$", spec3(1, "wdown")),
        (r"/w_gate$", spec4(1, "w_gate")),
        (r"/w_up$", spec4(1, "w_up")),
        (r"/w_down$", spec4(1, "w_down")),
        (r"/ws_g$", spec3(2, "ws_g")),
        (r"/ws_u$", spec3(2, "ws_u")),
        (r"/ws_down$", spec3(1, "ws_down")),
    ]
    if kv_sharded:
        rules += [
            (r"/wk$", P(None, None, MODEL_AXIS)),
            (r"/wv$", P(None, None, MODEL_AXIS)),
        ]
    # else: wk/wv replicated, sliced per-device (HeadLayout) — default P()
    return ShardingRules(rules=tuple(rules))


def fsdp_gather(p: dict, cfg: TransformerConfig) -> dict:
    """All-gather the FSDP-sharded weights of ONE layer (inside the scan
    body: weights live gathered only for this layer's compute; the AD
    transpose reduce-scatters the gradients over "data" automatically)."""
    if not getattr(cfg, "fsdp", False):
        return p
    out = dict(p)
    dp = tuple(cfg.dp_axes)
    ax = dp if len(dp) > 1 else dp[0]
    for name, dim in _FSDP_DIM.items():
        if name in out:
            # per-layer tensors have the stacking dim stripped → dim-1
            out[name] = jax.lax.all_gather(
                out[name], ax, axis=dim - 1, tiled=True)
    return out


def in_scan_param_names(params: dict) -> frozenset[str]:
    """Leaves whose gradient is psum'd inside the backward scan (depcha)."""
    from repro.utils.trees import named_leaves

    return frozenset(
        n for n, _ in named_leaves(params)
        if n.startswith("blocks/") or n.startswith("cross_blocks/")
    )


# ----------------------------------------------------------------- blocks
def _attn_qkv(p, h, cfg: TransformerConfig, li=None):
    """Project to q, k, v local heads.  Returns (B,S,q_local,hd) × kv."""
    lay = cfg.layout
    hd = cfg.hd
    q = (h @ p["wq"]).reshape(*h.shape[:2], lay.q_local, hd)
    if lay.kv_sharded:
        k = (h @ p["wk"]).reshape(*h.shape[:2], lay.kv_local, hd)
        v = (h @ p["wv"]).reshape(*h.shape[:2], lay.kv_local, hd)
    else:
        # kv projection replicated; slice the kv head(s) this device reads
        start = lay.kv_slice_start() * hd if cfg.tp > 1 else 0
        wk = jax.lax.dynamic_slice_in_dim(p["wk"], start, lay.kv_local * hd, 1)
        wv = jax.lax.dynamic_slice_in_dim(p["wv"], start, lay.kv_local * hd, 1)
        k = (h @ wk).reshape(*h.shape[:2], lay.kv_local, hd)
        v = (h @ wv).reshape(*h.shape[:2], lay.kv_local, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    return q, k, v


def _ffn(p, h, cfg: TransformerConfig):
    if cfg.moe is not None:
        B, S, d = h.shape
        out, aux = moe_ffn(p, h.reshape(B * S, d), cfg.moe, cfg.tp)
        return out.reshape(B, S, d), aux
    if cfg.gated:
        a = swiglu(h @ p["wg"], h @ p["wu"])
    else:
        a = ACTIVATIONS[cfg.act](h @ p["wi"])
    out = a @ p["wdown"]
    out = jax.lax.psum(out, MODEL_AXIS) if cfg.tp > 1 else out
    return out, jnp.zeros((), jnp.float32)


def self_block(p, carry, cfg: TransformerConfig, rope, *, q_offset=0):
    """One decoder block; carry = (x, aux). rope = (cos, sin)."""
    x, aux = carry
    p = fsdp_gather(p, cfg)
    h = rms_norm(x, p["ln1"])
    q, k, v = _attn_qkv(p, h, cfg)
    cos, sin = rope
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = attn_lib.attention(
        q, k, v,
        causal=True,
        window=cfg.swa_window,
        q_offset=q_offset,
        chunk=cfg.attn_chunk,
        use_flash=cfg.use_flash,
        unroll_all=cfg.chunk_unroll,
    )
    o = o.reshape(*x.shape[:2], -1) @ p["wo"]
    o = jax.lax.psum(o, MODEL_AXIS) if cfg.tp > 1 else o
    x = x + o
    h = rms_norm(x, p["ln2"])
    f, aux_i = _ffn(p, h, cfg)
    return (x + f, aux + aux_i)


def cross_block(p, carry, cfg: TransformerConfig, img_embeds):
    """Gated cross-attention block (llama-3.2-vision style)."""
    x, aux = carry
    p = fsdp_gather(p, cfg)
    h = rms_norm(x, p["ln1"])
    hkv = rms_norm(img_embeds, p["lnkv"])
    lay = cfg.layout
    hd = cfg.hd
    q = (h @ p["wq"]).reshape(*h.shape[:2], lay.q_local, hd)
    if lay.kv_sharded:
        k = (hkv @ p["wk"]).reshape(*hkv.shape[:2], lay.kv_local, hd)
        v = (hkv @ p["wv"]).reshape(*hkv.shape[:2], lay.kv_local, hd)
    else:
        start = lay.kv_slice_start() * hd if cfg.tp > 1 else 0
        wk = jax.lax.dynamic_slice_in_dim(p["wk"], start, lay.kv_local * hd, 1)
        wv = jax.lax.dynamic_slice_in_dim(p["wv"], start, lay.kv_local * hd, 1)
        k = (hkv @ wk).reshape(*hkv.shape[:2], lay.kv_local, hd)
        v = (hkv @ wv).reshape(*hkv.shape[:2], lay.kv_local, hd)
    o = attn_lib.attention(
        q, k, v, causal=False, chunk=cfg.attn_chunk,
        unroll_all=cfg.chunk_unroll,
    )
    o = o.reshape(*x.shape[:2], -1) @ p["wo"]
    o = jax.lax.psum(o, MODEL_AXIS) if cfg.tp > 1 else o
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * o
    h = rms_norm(x, p["ln2"])
    f, aux_i = _ffn(p, h, cfg)
    return (x + f, aux + aux_i)


def _depcha_axes(cfg: TransformerConfig, params_subtree, prefix: str):
    """Per-leaf grad-reduction groups for in-scan sync (DP axes, plus
    "model" for leaves replicated over the model axis)."""
    if not cfg.depcha_in_scan:
        return ()
    from repro.parallel.sharding import reduce_axes_tree

    mesh_axes = tuple(cfg.dp_axes) + (("model",) if cfg.tp > 1 else ())
    return reduce_axes_tree(param_rules(cfg), params_subtree, prefix, mesh_axes)


def _stack_scan(cfg: TransformerConfig, body, stacked, carry):
    return scan_layers(
        body,
        stacked,
        carry,
        depcha_axes=_depcha_axes(cfg, stacked, "blocks/"),
        unroll=cfg.scan_unroll,
        remat=cfg.remat,
        depcha_reducer=cfg.depcha_reducer,
        intra_size=cfg.intra_size,
    )


def backbone(params, x, cfg: TransformerConfig, rope, img_embeds=None,
             q_offset=0):
    """Run all blocks. x: (B, S, d) → (B, S, d), aux."""
    carry = (x, jnp.zeros((), jnp.float32))
    body = lambda p, c: self_block(p, c, cfg, rope, q_offset=q_offset)
    if cfg.n_cross == 0:
        carry = _stack_scan(cfg, body, params["blocks"], carry)
    else:
        per = cfg.cross_attn_every
        cb = params["cross_blocks"]
        for g in range(cfg.n_cross):
            grp = jax.tree.map(lambda a: a[g * per:(g + 1) * per],
                               params["blocks"])
            carry = _stack_scan(cfg, body, grp, carry)
            cp = jax.tree.map(lambda a: a[g], cb)
            cfn = lambda p, c: cross_block(p, c, cfg, img_embeds)
            if cfg.depcha_in_scan:
                cfn = sync_in_backward(
                    cfn, _depcha_axes(cfg, cp, "cross_blocks/"))
            carry = cfn(cp, carry)
        rem = cfg.n_self - cfg.n_cross * per
        if rem:
            grp = jax.tree.map(lambda a: a[-rem:], params["blocks"])
            carry = _stack_scan(cfg, body, grp, carry)
    return carry


# ------------------------------------------------------------------ train
def train_forward(params, batch, cfg: TransformerConfig) -> jax.Array:
    """Local-shard loss: sum of token xent / global token count.

    psum over DP axes (done by the train step) yields the exact global mean
    — the paper's rescale=1/mini_batch_size folded into the loss.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.tp).astype(cfg.dtype)
    if cfg.frame_embeds and "frame_embeds" in batch:
        x = x + batch["frame_embeds"].astype(cfg.dtype)
    cos, sin = rope_angles(jnp.arange(S), cfg.hd, cfg.rope_theta)
    img = batch.get("img_embeds")
    if img is not None:
        img = img.astype(cfg.dtype)
    (h, aux) = backbone(params, x, cfg, (cos, sin), img_embeds=img)
    h = rms_norm(h, params["ln_f"])
    logits = h @ params["lm_head"]                       # (B, S, V/tp)
    per_tok = sharded_softmax_xent(logits, batch["labels"], cfg.tp)
    local_sum = jnp.sum(per_tok)
    # aux is a per-shard estimate; scale so the DP psum averages it
    dp_scale = (B * S) / batch["global_tokens"]
    return local_sum / batch["global_tokens"] + aux * dp_scale / cfg.n_layers


def pipeline_train_forward(params, mbs, cfg: TransformerConfig, *,
                           n_stages: int, stage_axis: str = "stage"):
    """Staged wave-pipeline loss (DESIGN.md §15): sum over microbatches
    of the local-shard loss, nonzero ONLY on the last stage.

    ``mbs`` is the microbatch-split batch tree (leading dim M, the same
    split the grad-accumulation path uses — ``global_tokens`` already
    divided by M).  Each device holds ONE stage's slice of the stacked
    block params (dim 0 sharded over ``stage_axis``); stage 0 embeds the
    injected microbatch, every stage runs its layer slice, activations
    hop to the next stage via ppermute, and the last stage runs the head
    + xent.  The aux (MoE) accumulator rides the activation carry so the
    last stage folds it into the loss exactly like ``train_forward``.

    The caller psums the result over ``stage_axis`` OUTSIDE the grad —
    adding the other stages' masked exact zeros, so the staged loss and
    gradients are bit-identical to a stage=1 run of this same code.
    """
    if cfg.n_cross:
        raise ValueError(
            "pipeline stages do not support cross-attention layers")
    tokens = mbs["tokens"]
    M = tokens.shape[0]
    B, S = tokens.shape[1:]
    cos, sin = rope_angles(jnp.arange(S), cfg.hd, cfg.rope_theta)
    gtok = mbs["global_tokens"]                       # (M,) split scalars

    def inject(m):
        x = embed_lookup(params["embed"], tokens[m], cfg.tp)
        x = x.astype(cfg.dtype)
        if cfg.frame_embeds and "frame_embeds" in mbs:
            x = x + mbs["frame_embeds"][m].astype(cfg.dtype)
        return (x, jnp.zeros((), jnp.float32))

    def stage(carry):
        body = lambda p, c: self_block(p, c, cfg, (cos, sin))
        return _stack_scan(cfg, body, params["blocks"], carry)

    def head_loss(carry, m):
        h, aux = carry
        hn = rms_norm(h, params["ln_f"])
        logits = hn @ params["lm_head"]
        per_tok = sharded_softmax_xent(logits, mbs["labels"][m], cfg.tp)
        dp_scale = (B * S) / gtok[m]
        return (jnp.sum(per_tok) / gtok[m]
                + aux * dp_scale / cfg.n_layers)

    from repro.parallel.pipeline import pipeline_wave_loss

    losses = pipeline_wave_loss(inject, stage, head_loss, M,
                                n_stages=n_stages, axis=stage_axis)
    return jnp.sum(losses)


# ------------------------------------------------------------------ serve
def prefill(params, tokens, cfg: TransformerConfig, img_embeds=None,
            frame_embeds=None, last_pos=None):
    """Full-sequence forward; returns (next_token_logits_local, kv_cache).

    Cache layout: dict of (n_self, B, S, kv_local, hd) stacked arrays.

    ``last_pos`` (scalar int32) selects which position's logits to
    return — the continuous-batching engine right-pads prompts to a
    length bucket and reads the logits at the true last prompt token
    (causality makes every position < last_pos+1 independent of the
    padding, so the bucketed prefill is bit-exact with an exact-length
    one).  None keeps the static behavior (last position).
    """
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.tp).astype(cfg.dtype)
    if cfg.frame_embeds and frame_embeds is not None:
        x = x + frame_embeds.astype(cfg.dtype)
    cos, sin = rope_angles(jnp.arange(S), cfg.hd, cfg.rope_theta)
    rope = (cos, sin)

    def body(carry, p):
        x = carry
        p = fsdp_gather(p, cfg)
        h = rms_norm(x, p["ln1"])
        q, k, v = _attn_qkv(p, h, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        o = attn_lib.attention(q, k, v, causal=True, window=cfg.swa_window,
                               chunk=cfg.attn_chunk, use_flash=cfg.use_flash,
                               unroll_all=cfg.chunk_unroll)
        o = o.reshape(B, S, -1) @ p["wo"]
        o = jax.lax.psum(o, MODEL_AXIS) if cfg.tp > 1 else o
        x = x + o
        h = rms_norm(x, p["ln2"])
        f, _ = _ffn(p, h, cfg)
        return x + f, {"k": k, "v": v}

    if cfg.n_cross == 0:
        x, cache = jax.lax.scan(body, x, params["blocks"],
                                unroll=cfg.scan_unroll)
    else:
        caches = []
        per = cfg.cross_attn_every
        for g in range(cfg.n_cross):
            grp = jax.tree.map(lambda a: a[g * per:(g + 1) * per],
                               params["blocks"])
            x, c = jax.lax.scan(body, x, grp, unroll=cfg.scan_unroll)
            caches.append(c)
            cp = jax.tree.map(lambda a: a[g], params["cross_blocks"])
            (x, _) = cross_block(
                cp, (x, jnp.zeros((), jnp.float32)), cfg,
                img_embeds.astype(cfg.dtype))
        rem = cfg.n_self - cfg.n_cross * per
        if rem:
            grp = jax.tree.map(lambda a: a[-rem:], params["blocks"])
            x, c = jax.lax.scan(body, x, grp, unroll=cfg.scan_unroll)
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *caches)

    if last_pos is None:
        sel = x[:, -1:]
    else:
        sel = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, 1)
    h = rms_norm(sel, params["ln_f"])
    logits = h @ params["lm_head"]
    return logits[:, 0], cache


def decode_step(params, cache, token, pos, cfg: TransformerConfig,
                img_embeds=None):
    """One decode step. token: (B,) int32; pos: absolute position (scalar).

    cache: dict k/v of (n_self, B, Smax, kv_local, hd).  When Smax <
    pos+1 the cache is treated as a ring buffer (sliding-window archs:
    Smax == window, the ring IS the window).  Returns
    (next_logits_local (B, V/tp), new cache).
    """
    B = token.shape[0]
    smax = cache["k"].shape[2]
    slot = pos % smax
    kv_len = jnp.minimum(pos + 1, smax)
    win = cfg.swa_window if (cfg.swa_window and smax > cfg.swa_window) else None
    x = embed_lookup(params["embed"], token[:, None], cfg.tp).astype(cfg.dtype)
    cos, sin = rope_angles(jnp.array([pos]), cfg.hd, cfg.rope_theta)

    def body(x, layer):
        p, kc, vc = layer
        p = fsdp_gather(p, cfg)
        h = rms_norm(x, p["ln1"])
        q, k, v = _attn_qkv(p, h, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = attn_lib.decode_attention(q, kc, vc, kv_len, window=win)
        o = o.reshape(B, 1, -1) @ p["wo"]
        o = jax.lax.psum(o, MODEL_AXIS) if cfg.tp > 1 else o
        x = x + o
        h = rms_norm(x, p["ln2"])
        f, _ = _ffn(p, h, cfg)
        return x + f, {"k": kc, "v": vc}

    def scan_body(x, xs):
        p, kc, vc = xs
        x, c = body(x, (p, kc, vc))
        return x, c

    if cfg.n_cross == 0:
        x, new_cache = jax.lax.scan(
            scan_body, x, (params["blocks"], cache["k"], cache["v"]),
            unroll=cfg.scan_unroll,
        )
    else:
        per = cfg.cross_attn_every
        new_k, new_v = [], []
        off = 0
        for g in range(cfg.n_cross):
            grp = jax.tree.map(lambda a: a[g * per:(g + 1) * per],
                               params["blocks"])
            kc = cache["k"][off:off + per]
            vc = cache["v"][off:off + per]
            x, c = jax.lax.scan(scan_body, x, (grp, kc, vc),
                                unroll=cfg.scan_unroll)
            new_k.append(c["k"]); new_v.append(c["v"])
            off += per
            cp = jax.tree.map(lambda a: a[g], params["cross_blocks"])
            (x, _) = cross_block(cp, (x, jnp.zeros((), jnp.float32)), cfg,
                                 img_embeds.astype(cfg.dtype))
        rem = cfg.n_self - cfg.n_cross * per
        if rem:
            grp = jax.tree.map(lambda a: a[-rem:], params["blocks"])
            x, c = jax.lax.scan(
                scan_body, x, (grp, cache["k"][off:], cache["v"][off:]),
                unroll=cfg.scan_unroll)
            new_k.append(c["k"]); new_v.append(c["v"])
        new_cache = {"k": jnp.concatenate(new_k, 0),
                     "v": jnp.concatenate(new_v, 0)}

    h = rms_norm(x, params["ln_f"])
    logits = (h @ params["lm_head"])[:, 0]               # (B, V/tp)
    return logits, new_cache


def decode_step_paged(params, pool_k, pool_v, block_tables, tokens,
                      positions, cfg: TransformerConfig):
    """One decode step over a paged KV pool with per-slot positions.

    pool_k/pool_v: (n_self, num_blocks, block_size, kv_local, hd) — the
    rank-local physical block pool.  block_tables: (W, max_blocks) int32
    local block ids per slot; tokens: (W,) the token each slot consumes;
    positions: (W,) its absolute position.  Logical position ``p`` of
    slot ``w`` lives at flat pool row ``table[w, p // bs] * bs + p %
    bs``.  The per-position math is identical to ``decode_step`` (same
    qkv/rope/attention/psum sequence, per-slot kv_len instead of a
    shared scalar), so greedy decoding through the pool is bit-exact
    with the static cache when the gathered extent matches ``max_len``.
    Returns (logits_local (W, V/tp), new_pool_k, new_pool_v).
    """
    assert cfg.n_cross == 0, "paged decode serves decoder-only archs"
    W = tokens.shape[0]
    bs = pool_k.shape[2]
    MB = block_tables.shape[1]
    x = embed_lookup(params["embed"], tokens[:, None], cfg.tp).astype(cfg.dtype)
    cos, sin = rope_angles(positions[:, None], cfg.hd, cfg.rope_theta)
    # per-slot write row + gather map into the flat (num_blocks*bs) pool
    wr = (jnp.take_along_axis(block_tables, (positions // bs)[:, None],
                              axis=1)[:, 0] * bs + positions % bs)
    gat = ((block_tables * bs)[:, :, None]
           + jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(
               W, MB * bs)
    kv_len = positions + 1
    win = (cfg.swa_window
           if (cfg.swa_window and MB * bs > cfg.swa_window) else None)

    def body(x, xs):
        p, kc, vc = xs
        p = fsdp_gather(p, cfg)
        h = rms_norm(x, p["ln1"])
        q, k, v = _attn_qkv(p, h, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        kf = kc.reshape(-1, *kc.shape[2:])
        vf = vc.reshape(-1, *vc.shape[2:])
        kf = kf.at[wr].set(k[:, 0])
        vf = vf.at[wr].set(v[:, 0])
        kw = jnp.take(kf, gat, axis=0)
        vw = jnp.take(vf, gat, axis=0)
        o = attn_lib.decode_attention(q, kw, vw, kv_len, window=win)
        o = o.reshape(W, 1, -1) @ p["wo"]
        o = jax.lax.psum(o, MODEL_AXIS) if cfg.tp > 1 else o
        x = x + o
        h = rms_norm(x, p["ln2"])
        f, _ = _ffn(p, h, cfg)
        return x + f, {"k": kf.reshape(kc.shape), "v": vf.reshape(vc.shape)}

    x, new_pool = jax.lax.scan(
        body, x, (params["blocks"], pool_k, pool_v),
        unroll=cfg.scan_unroll)
    h = rms_norm(x, params["ln_f"])
    logits = (h @ params["lm_head"])[:, 0]
    return logits, new_pool["k"], new_pool["v"]


def make_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Empty KV cache shapes (local shard view)."""
    lay = cfg.layout
    shape = (cfg.n_self, batch, max_len, lay.kv_local, cfg.hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
    }


def decode_state_specs(cfg: TransformerConfig, batch_entry):
    """PartitionSpecs for the decode cache (global view).

    kv-head dim sharded over "model": when kv_sharded that is the natural
    layout; when kv_heads < tp each rank's slice differs (sliced-KV GQA),
    so the global dim is tp × kv_local with per-rank content — correct
    round-trip either way."""
    s = P(None, batch_entry, None, MODEL_AXIS, None)
    return {"k": s, "v": s}
