"""Mamba-2 (SSD, arXiv:2405.21060) block and the Zamba2 hybrid
(arXiv:2411.15242): a Mamba-2 backbone with a *shared* transformer block
applied every ``attn_every`` layers (weights reused at each application).

SSD recurrence per head (P = head dim, N = ssm state):
  h_t = a_t h_{t-1} + dt_t * x_t B_t^T        h: (P, N), a_t scalar/head
  y_t = h_t C_t + D x_t
evaluated chunk-parallel: intra-chunk attention  M[t,s] = C_t·B_s ·
exp(cumlog a (t..s]) · dt_s  (strictly causal + diagonal), inter-chunk
state carried by lax.scan.  TP: heads sharded over "model".
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.overlap import scan_layers
from repro.models import attention as attn_lib
from repro.models.common import (
    MODEL_AXIS,
    HeadLayout,
    apply_rope,
    dense_init,
    embed_lookup,
    rms_norm,
    rope_angles,
    sharded_softmax_xent,
    split_rngs,
    swiglu,
)
from repro.parallel.sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int                      # shared-attn MLP width (zamba2)
    vocab: int
    ssm_state: int = 64
    head_p: int = 64               # channels per ssm head
    expand: int = 2
    d_conv: int = 4
    attn_every: int = 0            # 0 → pure mamba; zamba2: 6
    n_heads: int = 32              # shared attention block heads
    kv_heads: int = 32
    dtype: Any = jnp.bfloat16
    tp: int = 1
    chunk: int = 64
    rope_theta: float = 10_000.0
    remat: str = "dots"
    scan_unroll: int = 1
    depcha_in_scan: bool = False
    dp_axes: tuple[str, ...] = ("data",)
    chunk_unroll: bool = False
    depcha_reducer: str = "flat"
    intra_size: int = 16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.head_p

    @property
    def heads_local(self) -> int:
        return self.ssm_heads // self.tp if self.tp > 1 else self.ssm_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // self.tp) * self.tp

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads


def init_params(rng, cfg: SSMConfig) -> dict:
    d, L, dt = cfg.d_model, cfg.n_layers, cfg.dtype
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    r = split_rngs(rng, 16)
    # fused in-proj: z (di) | x (di) | B (N) | C (N) | dt (H)
    proj_out = 2 * di + 2 * N + H
    blocks = {
        "ln": jnp.ones((L, d), dt),
        "w_in": dense_init(r[0], (L, d, proj_out), d, dt),
        "conv_w": dense_init(r[1], (L, cfg.d_conv, di + 2 * N), cfg.d_conv, dt),
        "A_log": jnp.zeros((L, H), jnp.float32),
        "D": jnp.ones((L, H), jnp.float32),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "ln_y": jnp.ones((L, di), dt),
        "w_out": dense_init(r[2], (L, di, d), di, dt),
    }
    params = {
        "embed": dense_init(r[3], (cfg.vocab_padded, d), d, dt),
        "blocks": blocks,
        "ln_f": jnp.ones((d,), dt),
        "lm_head": dense_init(r[4], (d, cfg.vocab_padded), d, dt),
    }
    if cfg.attn_every:
        lay = HeadLayout(cfg.n_heads, cfg.kv_heads, cfg.hd, cfg.tp)
        params["shared_attn"] = {
            "ln1": jnp.ones((d,), dt),
            "wq": dense_init(r[5], (d, cfg.n_heads * cfg.hd), d, dt),
            "wk": dense_init(r[6], (d, cfg.kv_heads * cfg.hd), d, dt),
            "wv": dense_init(r[7], (d, cfg.kv_heads * cfg.hd), d, dt),
            "wo": dense_init(r[8], (cfg.n_heads * cfg.hd, d), d, dt),
            "ln2": jnp.ones((d,), dt),
            "wg": dense_init(r[9], (d, cfg.d_ff), d, dt),
            "wu": dense_init(r[11], (d, cfg.d_ff), d, dt),
            "wdown": dense_init(r[10], (cfg.d_ff, d), cfg.d_ff, dt),
        }
    return params


def param_rules(cfg: SSMConfig) -> ShardingRules:
    # NOTE: w_in fuses z|x|B|C|dt: B/C/dt parts are replicated reads, so the
    # fused weight stays replicated; z|x sub-blocks are sliced per device.
    rules = [
        (r"embed", P(MODEL_AXIS, None)),
        (r"lm_head", P(None, MODEL_AXIS)),
        (r"/w_out$", P(None, MODEL_AXIS, None)),
        (r"shared_attn/wq$", P(None, MODEL_AXIS)),
        (r"shared_attn/wo$", P(MODEL_AXIS, None)),
        (r"shared_attn/w[gu]$", P(None, MODEL_AXIS)),
        (r"shared_attn/wdown$", P(MODEL_AXIS, None)),
        (r"/(A_log|D|dt_bias)$", P(None, MODEL_AXIS)),
        (r"/ln_y$", P(None, MODEL_AXIS)),
    ]
    if cfg.attn_every and cfg.kv_heads >= cfg.tp:
        rules += [
            (r"shared_attn/wk$", P(None, MODEL_AXIS)),
            (r"shared_attn/wv$", P(None, MODEL_AXIS)),
        ]
    return ShardingRules(rules=tuple(rules))


def in_scan_param_names(params) -> frozenset[str]:
    from repro.utils.trees import named_leaves
    return frozenset(n for n, _ in named_leaves(params)
                     if n.startswith("blocks/"))


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C); state: (B,K-1,C)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(xh, B_in, C_in, loga, dt, state, chunk: int, unroll_all=False):
    """Chunked SSD. xh: (B,S,H,P); B_in/C_in: (B,S,N); loga: (B,S,H) (<=0);
    dt: (B,S,H); state: (B,H,P,N).  Returns (y, new_state)."""
    Bb, S, H, Pd = xh.shape
    N = B_in.shape[-1]
    C = min(chunk, S)
    pad = (-S) % C
    S_out = S
    if pad:
        # zero-pad: x=0 and dt=0 (loga=0, a=1) leave the state unchanged
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_in = jnp.pad(B_in, ((0, 0), (0, pad), (0, 0)))
        C_in = jnp.pad(C_in, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    T = S // C
    f32 = jnp.float32
    xc = xh.reshape(Bb, T, C, H, Pd).transpose(1, 0, 3, 2, 4).astype(f32)
    bc = B_in.reshape(Bb, T, C, N).transpose(1, 0, 2, 3).astype(f32)
    cc = C_in.reshape(Bb, T, C, N).transpose(1, 0, 2, 3).astype(f32)
    lg = loga.reshape(Bb, T, C, H).transpose(1, 0, 3, 2).astype(f32)
    dc = dt.reshape(Bb, T, C, H).transpose(1, 0, 3, 2).astype(f32)

    def body(S0, xs):
        xx, bb, ccc, ll, dd = xs      # (B,H,C,P), (B,C,N), (B,C,N), (B,H,C), (B,H,C)
        Lc = jnp.cumsum(ll, axis=2)   # (B,H,C)
        # inter-chunk: y_inter[t] = exp(Lc_t) * C_t @ S0^T
        y = jnp.einsum("bcn,bhpn->bhcp", ccc, S0) * jnp.exp(Lc)[..., None]
        # intra-chunk causal attention (incl. diagonal):
        # M[t,s] = (C_t·B_s) exp(Lc_t - Lc_s) dt_s   for s <= t
        scores = jnp.einsum("bcn,bsn->bcs", ccc, bb)
        dec = jnp.exp(Lc[:, :, :, None] - Lc[:, :, None, :])   # (B,H,C,C)
        mask = jnp.tril(jnp.ones((C, C), bool))
        M = scores[:, None] * jnp.where(mask[None, None], dec, 0.0) \
            * dd[:, :, None, :]
        y = y + jnp.einsum("bhts,bhsp->bhtp", M, xx)
        # state: S1 = exp(Lc_C) S0 + Σ_s exp(Lc_C - Lc_s) dt_s x_s B_s^T
        WC = Lc[:, :, -1]
        w_s = jnp.exp(WC[:, :, None] - Lc) * dd                # (B,H,C)
        S1 = S0 * jnp.exp(WC)[..., None, None] + \
            jnp.einsum("bhc,bhcp,bcn->bhpn", w_s, xx, bb)
        return S1, y

    state, ys = jax.lax.scan(body, state.astype(f32), (xc, bc, cc, lg, dc),
                             unroll=T if unroll_all else 1)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(Bb, S, H, Pd)
    if pad:
        y = y[:, :S_out]
    return y, state


def mamba_block(p, x, cfg: SSMConfig, state=None, conv_state=None):
    """One Mamba-2 block on the residual stream.  Returns
    (out, new_ssm_state, new_conv_state)."""
    Bb, S, d = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    Hl, Pd = cfg.heads_local, cfg.head_p
    h = rms_norm(x, p["ln"])
    zxbcdt = h @ p["w_in"]                       # replicated (small N,H tails)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    xs, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)

    # local head shard
    if cfg.tp > 1:
        off_d = jax.lax.axis_index(MODEL_AXIS) * (di // cfg.tp)
        off_h = jax.lax.axis_index(MODEL_AXIS) * Hl
        xs = jax.lax.dynamic_slice_in_dim(xs, off_d, di // cfg.tp, 2)
        z = jax.lax.dynamic_slice_in_dim(z, off_d, di // cfg.tp, 2)
        dt = jax.lax.dynamic_slice_in_dim(dt, off_h, Hl, 2)
    xh = xs.reshape(Bb, S, Hl, Pd)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    loga = -jnp.exp(p["A_log"])[None, None] * dt            # (B,S,Hl) <= 0
    if state is None:
        state = jnp.zeros((Bb, Hl, Pd, N), jnp.float32)
    y, new_state = ssd_chunked(xh, Bc, Cc, loga, dt, state, cfg.chunk,
                               unroll_all=cfg.chunk_unroll)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bb, S, -1)
    # gated rms groupnorm, one group per ssm head (TP-invariant: heads are
    # never split across devices — matches Mamba-2 ngroups usage)
    yz = y * jax.nn.silu(z.astype(jnp.float32))
    ln_y = p["ln_y"]
    yg = yz.reshape(Bb, S, Hl, Pd)
    var = jnp.mean(jnp.square(yg), axis=-1, keepdims=True)
    yz = (yg * jax.lax.rsqrt(var + 1e-6)).reshape(Bb, S, -1) \
        * ln_y.astype(jnp.float32)
    out = yz.astype(x.dtype) @ p["w_out"]
    out = jax.lax.psum(out, MODEL_AXIS) if cfg.tp > 1 else out
    return x + out, new_state, new_conv


def shared_attn_block(p, x, cfg: SSMConfig, rope, kv_cache=None, pos=None):
    """Zamba2's shared transformer block (GQA + SwiGLU MLP).

    Train/prefill: kv_cache None → full causal self-attention.
    Decode: kv_cache (B,Smax,kv_local,hd) pair + absolute pos."""
    Bb, S, d = x.shape
    lay = HeadLayout(cfg.n_heads, cfg.kv_heads, cfg.hd, cfg.tp)
    h = rms_norm(x, p["ln1"])
    q = (h @ p["wq"]).reshape(Bb, S, lay.q_local, cfg.hd)
    if lay.kv_sharded:
        wk, wv = p["wk"], p["wv"]
    else:
        start = lay.kv_slice_start() * cfg.hd if cfg.tp > 1 else 0
        wk = jax.lax.dynamic_slice_in_dim(p["wk"], start,
                                          lay.kv_local * cfg.hd, 1)
        wv = jax.lax.dynamic_slice_in_dim(p["wv"], start,
                                          lay.kv_local * cfg.hd, 1)
    k = (h @ wk).reshape(Bb, S, lay.kv_local, cfg.hd)
    v = (h @ wv).reshape(Bb, S, lay.kv_local, cfg.hd)
    cos, sin = rope
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if kv_cache is None:
        o = attn_lib.attention(q, k, v, causal=True,
                               unroll_all=cfg.chunk_unroll)
        new_cache = (k, v)          # prefill: caller slices its window
    else:
        kc, vc = kv_cache
        smax = kc.shape[1]
        slot = pos % smax
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = attn_lib.decode_attention(q, kc, vc, jnp.minimum(pos + 1, smax))
        new_cache = (kc, vc)
    o = o.reshape(Bb, S, -1) @ p["wo"]
    o = jax.lax.psum(o, MODEL_AXIS) if cfg.tp > 1 else o
    x = x + o
    h = rms_norm(x, p["ln2"])
    f = swiglu(h @ p["wg"], h @ p["wu"]) @ p["wdown"]
    f = jax.lax.psum(f, MODEL_AXIS) if cfg.tp > 1 else f
    return x + f, new_cache


# ------------------------------------------------------------------ train
def _groups(cfg: SSMConfig) -> list[int]:
    """Mamba-layer group sizes between shared-attn applications."""
    if not cfg.attn_every:
        return [cfg.n_layers]
    out = []
    rem = cfg.n_layers
    while rem > 0:
        g = min(cfg.attn_every, rem)
        out.append(g)
        rem -= g
    return out


def train_forward(params, batch, cfg: SSMConfig) -> jax.Array:
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = embed_lookup(params["embed"], tokens, cfg.tp).astype(cfg.dtype)
    rope = rope_angles(jnp.arange(S), cfg.hd, cfg.rope_theta) \
        if cfg.attn_every else None

    def body(p, x):
        out, _, _ = mamba_block(p, x, cfg)
        return out

    if cfg.depcha_in_scan:
        from repro.parallel.sharding import reduce_axes_tree
        mesh_axes = tuple(cfg.dp_axes) + (("model",) if cfg.tp > 1 else ())
        depcha = reduce_axes_tree(
            param_rules(cfg), params["blocks"], "blocks/", mesh_axes)
    else:
        depcha = ()

    off = 0
    for gi, g in enumerate(_groups(cfg)):
        grp = jax.tree.map(lambda a: a[off:off + g], params["blocks"])
        x = scan_layers(
            body, grp, x,
            depcha_axes=depcha,
            unroll=cfg.scan_unroll, remat=cfg.remat,
            depcha_reducer=cfg.depcha_reducer, intra_size=cfg.intra_size,
        )
        off += g
        if cfg.attn_every and off < cfg.n_layers:
            fn = lambda p, xx: shared_attn_block(p, xx, cfg, rope)[0]
            if cfg.depcha_in_scan:
                # shared weights are reused: sync once, outside (tail bucket)
                pass
            x = fn(params["shared_attn"], x)

    h = rms_norm(x, params["ln_f"])
    logits = h @ params["lm_head"]
    per_tok = sharded_softmax_xent(logits, batch["labels"], cfg.tp)
    return jnp.sum(per_tok) / batch["global_tokens"]


# ------------------------------------------------------------------ serve
def n_attn_sites(cfg: SSMConfig) -> int:
    if not cfg.attn_every:
        return 0
    return max(len(_groups(cfg)) - 1, 0)


def make_state(cfg: SSMConfig, batch: int, attn_window: int):
    Hl, Pd, N = cfg.heads_local, cfg.head_p, cfg.ssm_state
    lay = HeadLayout(cfg.n_heads, cfg.kv_heads, cfg.hd, cfg.tp)
    di, Nc = cfg.d_inner, cfg.ssm_state
    st = {
        "ssm": jnp.zeros((cfg.n_layers, batch, Hl, Pd, N), jnp.float32),
        "conv": jnp.zeros(
            (cfg.n_layers, batch, cfg.d_conv - 1, di + 2 * Nc), cfg.dtype),
    }
    na = n_attn_sites(cfg)
    if na:
        st["attn_k"] = jnp.zeros(
            (na, batch, attn_window, lay.kv_local, cfg.hd), cfg.dtype)
        st["attn_v"] = jnp.zeros_like(st["attn_k"])
    return st


def decode_state_specs(cfg: SSMConfig, batch_entry):
    specs = {
        "ssm": P(None, batch_entry, MODEL_AXIS, None, None),
        "conv": P(None, batch_entry, None, None),   # replicated channels
    }
    if n_attn_sites(cfg):
        specs["attn_k"] = P(None, batch_entry, None, MODEL_AXIS, None)
        specs["attn_v"] = P(None, batch_entry, None, MODEL_AXIS, None)
    return specs


def prefill(params, tokens, cfg: SSMConfig, attn_window: int = 0):
    Bb, S = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.tp).astype(cfg.dtype)
    rope = rope_angles(jnp.arange(S), cfg.hd, cfg.rope_theta) \
        if cfg.attn_every else None
    st = make_state(cfg, Bb, attn_window or S)

    ssm_out, conv_out, k_out, v_out = [], [], [], []
    off = 0
    for gi, g in enumerate(_groups(cfg)):
        grp = jax.tree.map(lambda a: a[off:off + g], params["blocks"])

        def body(x, xs):
            p, st_i, cv_i = xs
            out, ns, nc = mamba_block(p, x, cfg, state=st_i, conv_state=cv_i)
            return out, (ns, nc)

        x, (ns, nc) = jax.lax.scan(
            body, x, (grp, st["ssm"][off:off + g], st["conv"][off:off + g]),
            unroll=cfg.scan_unroll)
        ssm_out.append(ns); conv_out.append(nc)
        off += g
        if cfg.attn_every and off < cfg.n_layers:
            x, (k, v) = shared_attn_block(params["shared_attn"], x, cfg, rope)
            w = attn_window or S
            keep = min(w, S)
            pad = w - keep
            k_w = jnp.pad(k[:, S - keep:], ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_w = jnp.pad(v[:, S - keep:], ((0, 0), (0, pad), (0, 0), (0, 0)))
            if keep == w and S % w:   # ring-align: token p lives at slot p%w
                k_w = jnp.roll(k_w, S % w, axis=1)
                v_w = jnp.roll(v_w, S % w, axis=1)
            k_out.append(k_w); v_out.append(v_w)

    h = rms_norm(x[:, -1:], params["ln_f"])
    logits = (h @ params["lm_head"])[:, 0]
    state = {
        "ssm": jnp.concatenate(ssm_out, 0),
        "conv": jnp.concatenate(conv_out, 0),
    }
    if k_out:
        state["attn_k"] = jnp.stack(k_out, 0)
        state["attn_v"] = jnp.stack(v_out, 0)
    return logits, state


def decode_step(params, state, token, pos, cfg: SSMConfig):
    x = embed_lookup(params["embed"], token[:, None], cfg.tp).astype(cfg.dtype)
    rope = rope_angles(jnp.array([pos]), cfg.hd, cfg.rope_theta) \
        if cfg.attn_every else None

    def body(x, xs):
        p, st_i, cv_i = xs
        out, ns, nc = mamba_block(p, x, cfg, state=st_i, conv_state=cv_i)
        return out, (ns, nc)

    off = 0
    site = 0
    ssm_out, conv_out = [], []
    new_k, new_v = [], []
    for gi, g in enumerate(_groups(cfg)):
        grp = jax.tree.map(lambda a: a[off:off + g], params["blocks"])
        x, (ns, nc) = jax.lax.scan(
            body, x, (grp, state["ssm"][off:off + g],
                      state["conv"][off:off + g]),
            unroll=cfg.scan_unroll)
        ssm_out.append(ns); conv_out.append(nc)
        off += g
        if cfg.attn_every and off < cfg.n_layers:
            kv = (state["attn_k"][site], state["attn_v"][site])
            x, new_kv = shared_attn_block(
                params["shared_attn"], x, cfg, rope, kv_cache=kv, pos=pos)
            new_k.append(new_kv[0]); new_v.append(new_kv[1])
            site += 1

    h = rms_norm(x, params["ln_f"])
    logits = (h @ params["lm_head"])[:, 0]
    new_state = {
        "ssm": jnp.concatenate(ssm_out, 0),
        "conv": jnp.concatenate(conv_out, 0),
    }
    if new_k:
        new_state["attn_k"] = jnp.stack(new_k, 0)
        new_state["attn_v"] = jnp.stack(new_v, 0)
    return logits, new_state
