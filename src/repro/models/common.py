"""Shared model building blocks, written for execution INSIDE shard_map.

All layers operate on *local shards* and emit their tensor-parallel
collectives explicitly (DESIGN.md §5) — the framework, not XLA's sharding
propagation, owns the collective schedule (that is the paper's subject).

Conventions:
  - "model" mesh axis = tensor parallel (TP); size available via cfg.tp.
  - Activations between blocks are replicated across "model".
  - Column-parallel weights: stored P(..., "model") — local matmul.
  - Row-parallel weights: stored P("model", ...) — local matmul + psum.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

MODEL_AXIS = "model"


# ---------------------------------------------------------------- numerics
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


# ------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int → cos/sin (..., head_dim/2) f32."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# -------------------------------------------------------------- init utils
def dense_init(rng, shape, in_dim: int, dtype) -> jax.Array:
    std = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def split_rngs(rng, n: int):
    return list(jax.random.split(rng, n))


# -------------------------------------------------- TP matmuls (explicit)
def col_parallel(x: jax.Array, w: jax.Array) -> jax.Array:
    """x replicated, w column-sharded → output sharded (no collective)."""
    return x @ w


def row_parallel(x_sharded: jax.Array, w: jax.Array) -> jax.Array:
    """x sharded on contraction dim, w row-sharded → psum over model."""
    return jax.lax.psum(x_sharded @ w, MODEL_AXIS)


# -------------------------------------------- vocab-sharded embedding/loss
def embed_lookup(emb_local: jax.Array, ids: jax.Array, tp: int) -> jax.Array:
    """emb_local: (V/tp, d); ids: (B, S) global vocab ids → (B, S, d).

    Each device looks up ids inside its vocab shard (others → 0), psum
    rebuilds the full embedding.  No gather of a global table — matters at
    vocab 256k (minitron) / 164k (kimi).
    """
    v_local = emb_local.shape[0]
    start = jax.lax.axis_index(MODEL_AXIS) * v_local
    local_ids = ids - start
    in_shard = (local_ids >= 0) & (local_ids < v_local)
    safe = jnp.where(in_shard, local_ids, 0)
    out = jnp.take(emb_local, safe, axis=0)
    out = jnp.where(in_shard[..., None], out, 0)
    return jax.lax.psum(out, MODEL_AXIS) if tp > 1 else out


def sharded_softmax_xent(
    logits_local: jax.Array, labels: jax.Array, tp: int
) -> jax.Array:
    """Cross-entropy over vocab sharded on the model axis.

    logits_local: (B, S, V/tp) f32; labels: (B, S) global ids.
    Returns per-token loss (B, S) — never materializes the full vocab.
    """
    logits_local = logits_local.astype(jnp.float32)
    v_local = logits_local.shape[-1]
    local_max = jnp.max(logits_local, axis=-1)
    # shift is only for numerical stability — exact to stop-grad (xent is
    # shift-invariant), and pmax has no AD rule anyway
    local_max = jax.lax.stop_gradient(local_max)
    gmax = jax.lax.pmax(local_max, MODEL_AXIS) if tp > 1 else local_max
    shifted = logits_local - gmax[..., None]
    local_sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    sumexp = jax.lax.psum(local_sumexp, MODEL_AXIS) if tp > 1 else local_sumexp
    start = jax.lax.axis_index(MODEL_AXIS) * v_local
    local_ids = labels - start
    in_shard = (local_ids >= 0) & (local_ids < v_local)
    safe = jnp.where(in_shard, local_ids, 0)
    true_logit = jnp.take_along_axis(shifted, safe[..., None], axis=-1)[..., 0]
    true_logit = jnp.where(in_shard, true_logit, 0.0)
    if tp > 1:
        true_logit = jax.lax.psum(true_logit, MODEL_AXIS)
    return jnp.log(sumexp) - true_logit


# ------------------------------------------------------------ GQA helpers
@dataclasses.dataclass(frozen=True)
class HeadLayout:
    """How q and kv heads distribute over the TP axis (DESIGN.md §5).

    When kv_heads < tp, each device *slices* the replicated kv projection to
    the kv head(s) its local q heads read (grad correctness falls out of the
    slice transpose + model-axis psum of replicated-param grads).
    """

    n_heads: int          # possibly padded up to a multiple of tp
    kv_heads: int
    head_dim: int
    tp: int

    @property
    def q_local(self) -> int:
        return self.n_heads // self.tp

    @property
    def group(self) -> int:          # q heads per kv head
        return self.n_heads // self.kv_heads

    @property
    def kv_sharded(self) -> bool:
        return self.kv_heads >= self.tp

    @property
    def kv_local(self) -> int:
        if self.kv_sharded:
            return self.kv_heads // self.tp
        return max(self.q_local // self.group, 1)

    def kv_slice_start(self) -> jax.Array:
        """First kv head this device needs (only when not kv_sharded)."""
        idx = jax.lax.axis_index(MODEL_AXIS)
        return (idx * self.q_local) // self.group


def pad_heads(n_heads: int, tp: int) -> int:
    """Round up so heads shard evenly (starcoder2: 24 → 32 on tp=16)."""
    return int(-(-n_heads // tp) * tp)
