"""The paper's own evaluation models: ResNet-50 (He et al. 2015) and an
Inception-BN-style net, for the paper-figure reproductions (Figs 13-16).

Pure data-parallel (conv nets; no TP) — params replicated, gradients
reduced over the DP axes by the strategy under test, exactly the paper's
setting (one GPU per MPI process).  BatchNorm statistics are local to the
worker, as in the paper's MXNET runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_rngs
from repro.parallel.sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    stages: tuple[int, ...] = (3, 4, 6, 3)      # ResNet-50
    widths: tuple[int, ...] = (256, 512, 1024, 2048)
    stem_width: int = 64
    num_classes: int = 10
    img_size: int = 32
    dtype: Any = jnp.float32
    tp: int = 1                                  # unused (DP-only); kept for API
    dp_axes: tuple[str, ...] = ("data",)
    depcha_in_scan: bool = False                 # convnets: no layer scan


def _conv(rng, k, cin, cout, dtype):
    return dense_init(rng, (k, k, cin, cout), k * k * cin, dtype)


def init_params(rng, cfg: ResNetConfig) -> dict:
    rngs = split_rngs(rng, 4 + sum(cfg.stages) * 8)
    it = iter(rngs)
    dt = cfg.dtype
    params: dict[str, Any] = {
        "stem": {
            "conv": _conv(next(it), 3, 3, cfg.stem_width, dt),
            "bn_s": jnp.ones((cfg.stem_width,), dt),
            "bn_b": jnp.zeros((cfg.stem_width,), dt),
        }
    }
    cin = cfg.stem_width
    for si, (n, w) in enumerate(zip(cfg.stages, cfg.widths)):
        blocks = []
        for bi in range(n):
            mid = w // 4
            blk = {
                "c1": _conv(next(it), 1, cin, mid, dt),
                "bn1s": jnp.ones((mid,), dt), "bn1b": jnp.zeros((mid,), dt),
                "c2": _conv(next(it), 3, mid, mid, dt),
                "bn2s": jnp.ones((mid,), dt), "bn2b": jnp.zeros((mid,), dt),
                "c3": _conv(next(it), 1, mid, w, dt),
                "bn3s": jnp.ones((w,), dt), "bn3b": jnp.zeros((w,), dt),
            }
            if cin != w:
                blk["proj"] = _conv(next(it), 1, cin, w, dt)
            blocks.append(blk)
            cin = w
        params[f"stage{si}"] = blocks
    params["head"] = dense_init(next(it), (cin, cfg.num_classes), cin, dt)
    return params


def param_rules(cfg: ResNetConfig) -> ShardingRules:
    return ShardingRules(rules=())   # everything replicated (DP only)


def in_scan_param_names(params) -> frozenset[str]:
    return frozenset()


def _bn(x, s, b):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-5) * s + b


def _conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bottleneck(p, x, stride):
    h = jax.nn.relu(_bn(_conv2d(x, p["c1"]), p["bn1s"], p["bn1b"]))
    h = jax.nn.relu(_bn(_conv2d(h, p["c2"], stride), p["bn2s"], p["bn2b"]))
    h = _bn(_conv2d(h, p["c3"]), p["bn3s"], p["bn3b"])
    sc = x
    if "proj" in p:
        sc = _conv2d(x, p["proj"], stride)
    elif stride != 1:
        sc = x[:, ::stride, ::stride]
    return jax.nn.relu(h + sc)


def forward(params, images, cfg: ResNetConfig):
    """images: (B, H, W, 3) → logits (B, classes)."""
    x = jax.nn.relu(_bn(_conv2d(images, params["stem"]["conv"]),
                        params["stem"]["bn_s"], params["stem"]["bn_b"]))
    for si, n in enumerate(cfg.stages):
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = _bottleneck(params[f"stage{si}"][bi], x, stride)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]


def train_forward(params, batch, cfg: ResNetConfig) -> jax.Array:
    logits = forward(params, batch["images"], cfg).astype(jnp.float32)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll) / batch["global_tokens"]


# ------------------------------------------------------- Inception-BN-ish
@dataclasses.dataclass(frozen=True)
class InceptionConfig:
    name: str
    num_classes: int = 1000
    img_size: int = 224
    width_mult: float = 1.0
    dtype: Any = jnp.float32
    tp: int = 1
    dp_axes: tuple[str, ...] = ("data",)
    depcha_in_scan: bool = False


def init_inception(rng, cfg: InceptionConfig) -> dict:
    """A compact Inception-BN-style net: stem + 6 mixed blocks."""
    rngs = split_rngs(rng, 64)
    it = iter(rngs)
    dt = cfg.dtype
    w = lambda c: int(c * cfg.width_mult)
    params: dict[str, Any] = {
        "stem": _conv(next(it), 3, 3, w(64), dt),
        "stem_bn_s": jnp.ones((w(64),), dt),
        "stem_bn_b": jnp.zeros((w(64),), dt),
    }
    cin = w(64)
    for bi, cout in enumerate([64, 128, 128, 256, 256, 512]):
        c = w(cout)
        params[f"mix{bi}"] = {
            "b1": _conv(next(it), 1, cin, c // 4, dt),
            "b3a": _conv(next(it), 1, cin, c // 4, dt),
            "b3b": _conv(next(it), 3, c // 4, c // 2, dt),
            "b5a": _conv(next(it), 1, cin, c // 8, dt),
            "b5b": _conv(next(it), 3, c // 8, c // 8, dt),
            "b5c": _conv(next(it), 3, c // 8, c // 8, dt),
            "bp": _conv(next(it), 1, cin, c // 8, dt),
            "bn_s": jnp.ones((c // 4 + c // 2 + c // 8 + c // 8,), dt),
            "bn_b": jnp.zeros((c // 4 + c // 2 + c // 8 + c // 8,), dt),
        }
        cin = c // 4 + c // 2 + c // 8 + c // 8
    params["head"] = dense_init(next(it), (cin, cfg.num_classes), cin, dt)
    return params


def inception_forward(params, images, cfg: InceptionConfig):
    x = jax.nn.relu(_bn(_conv2d(images, params["stem"], 2),
                        params["stem_bn_s"], params["stem_bn_b"]))
    for bi in range(6):
        p = params[f"mix{bi}"]
        stride = 2 if bi % 2 == 0 else 1
        b1 = _conv2d(x, p["b1"], stride)
        b3 = _conv2d(jax.nn.relu(_conv2d(x, p["b3a"])), p["b3b"], stride)
        b5 = jax.nn.relu(_conv2d(x, p["b5a"]))
        b5 = jax.nn.relu(_conv2d(b5, p["b5b"]))
        b5 = _conv2d(b5, p["b5c"], stride)
        bp = _conv2d(x, p["bp"], stride)
        x = jax.nn.relu(_bn(jnp.concatenate([b1, b3, b5, bp], -1),
                            p["bn_s"], p["bn_b"]))
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]


def inception_train_forward(params, batch, cfg: InceptionConfig) -> jax.Array:
    logits = inception_forward(params, batch["images"], cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    return jnp.sum(nll) / batch["global_tokens"]
