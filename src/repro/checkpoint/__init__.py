from repro.checkpoint.manager import CheckpointManager, restore, save
from repro.checkpoint.reshard import reshard

__all__ = ["CheckpointManager", "reshard", "restore", "save"]
