"""Elastic re-scale: load a checkpoint onto a DIFFERENT mesh.

Checkpoints store *global* arrays (manager.py), so resharding is just
placement under the new mesh's NamedShardings — the mechanism behind
elastic scaling (node loss → smaller mesh; capacity gain → bigger mesh).
Divisibility is validated per leaf so a bad target mesh fails loudly
before any training step runs.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import ShardingRules
from repro.utils.trees import flatten_with_names


def validate_divisibility(tree: Any, specs: Any, mesh: Mesh) -> None:
    named, _ = flatten_with_names(tree)
    spec_named, _ = flatten_with_names(specs)
    for (name, leaf), (_, spec) in zip(named, spec_named):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            if leaf.shape[dim] % n:
                raise ValueError(
                    f"{name}: dim {dim} ({leaf.shape[dim]}) not divisible "
                    f"by mesh axes {axes} (={n})")


def reshard(tree: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    """Place a host (or differently-sharded) tree onto ``mesh``."""
    specs = rules.tree_specs(tree)
    validate_divisibility(tree, specs, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    return jax.device_put(tree, shardings)
