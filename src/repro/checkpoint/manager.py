"""Fault-tolerant checkpointing: atomic, async, sharded-aware.

Layout (one directory per step):
    <root>/step_000100.tmp/   → written, fsync'd, then renamed to
    <root>/step_000100/
        manifest.json         (step, leaf names/shapes/dtypes, mesh info)
        <leaf-name>.npy       (full/global array value per leaf)

Atomicity = tmp-dir + rename: a crash mid-write never corrupts the latest
complete checkpoint; ``latest_step`` only considers renamed dirs.  The
async writer snapshots arrays to host first (jax.device_get), so training
continues while the write proceeds.  Restore can target a DIFFERENT mesh
(elastic re-scale) — see ``repro.checkpoint.reshard``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.utils.trees import flatten_with_names, unflatten_from_names

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _fname(name: str) -> str:
    return _SAFE.sub("__", name) + ".npy"


def _to_savable(v: np.ndarray) -> np.ndarray:
    """numpy can't persist ml_dtypes (bfloat16, fp8) — widen to float32;
    restore() casts back per the target tree's dtypes."""
    if v.dtype.kind == "V" or v.dtype.name in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return v.astype(np.float32)
    return v


def save(root: str, step: int, tree: Any, *, blocking: bool = True) -> str:
    """Write checkpoint atomically; returns the final directory path."""
    named, _ = flatten_with_names(tree)
    host = [(n, _to_savable(np.asarray(jax.device_get(v))))
            for n, v in named]

    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"

    def write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for n, v in host:
            np.save(os.path.join(tmp, _fname(n)), v)
            manifest["leaves"].append(
                {"name": n, "shape": list(v.shape), "dtype": str(v.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
        return final
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(root, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(root: str, step: int, like: Any) -> Any:
    """Load a checkpoint into the structure of ``like`` (host numpy)."""
    path = os.path.join(root, f"step_{step:08d}")
    named, treedef = flatten_with_names(like)
    out = []
    for n, leaf in named:
        v = np.load(os.path.join(path, _fname(n)))
        want = tuple(leaf.shape)
        if tuple(v.shape) != want:
            raise ValueError(f"{n}: checkpoint {v.shape} != expected {want}")
        out.append(np.asarray(jax.numpy.asarray(v, dtype=leaf.dtype)))
    return unflatten_from_names(treedef, out)


class CheckpointManager:
    """Periodic async checkpointing with retention (keep last k).

    ``retries``/``backoff_s`` wrap every save/restore attempt in
    retry-with-exponential-backoff against transient I/O faults (flaky
    network filesystems, the elastic supervisor's injected faults).
    Atomicity is untouched: each attempt goes through the tmp-dir +
    rename protocol, so an attempt that dies mid-write never becomes
    ``latest()``.  ``fault_injector(op)`` — op in {"save", "restore"} —
    is called at the START of each attempt; raising ``OSError`` from it
    simulates the transient fault (tests, supervisor fault plans).
    """

    def __init__(self, root: str, *, every: int = 100, keep: int = 3,
                 blocking: bool = False, retries: int = 0,
                 backoff_s: float = 0.05,
                 fault_injector: Callable[[str], None] | None = None):
        self.root = root
        self.every = every
        self.keep = keep
        self.blocking = blocking
        self.retries = retries
        self.backoff_s = backoff_s
        self.fault_injector = fault_injector
        self._last_thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def _with_retries(self, op: str, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` with up to ``retries`` retried attempts; sleeps
        ``backoff_s * 2**i`` between attempts."""
        attempts = self.retries + 1
        for i in range(attempts):
            try:
                if self.fault_injector is not None:
                    self.fault_injector(op)
                return fn()
            except OSError:
                if i == attempts - 1:
                    raise
                time.sleep(self.backoff_s * (2 ** i))

    def maybe_save(self, step: int, tree: Any) -> bool:
        if step % self.every:
            return False
        self.wait()
        if self.blocking:
            self._with_retries(
                "save", lambda: save(self.root, step, tree, blocking=True))
        else:
            self._last_thread = threading.Thread(
                target=self._with_retries, args=(
                    "save",
                    lambda: save(self.root, step, tree, blocking=True)),
                daemon=True)
            # snapshot to host BEFORE returning control (cheap on CPU;
            # on TPU this is the D2H copy that must precede async write)
            jax.block_until_ready(jax.tree.leaves(tree))
            self._last_thread.start()
        self._gc()
        return True

    def save_now(self, step: int, tree: Any) -> None:
        """Blocking save with the retry policy — the supervisor's
        post-transition anchor checkpoint."""
        self.wait()
        self._with_retries(
            "save", lambda: save(self.root, step, tree, blocking=True))
        self._gc()

    def wait(self):
        if self._last_thread is not None:
            self._last_thread.join()
            self._last_thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.root)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.root)

    def restore(self, like: Any, step: Optional[int] = None) -> tuple[int, Any]:
        s = self.latest() if step is None else step
        if s is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        return s, self._with_retries(
            "restore", lambda: restore(self.root, s, like))

    def manifest(self, step: int) -> list[str]:
        """Leaf names recorded in a checkpoint's manifest — lets a
        restorer validate the target structure (e.g. that a deferred
        step's ``opt_state["pending"]`` carry is actually present)
        BEFORE loading arrays."""
        path = os.path.join(self.root, f"step_{step:08d}",
                            "manifest.json")
        with open(path) as f:
            return [l["name"] for l in json.load(f)["leaves"]]
