"""Fault-tolerant checkpointing: atomic, async, sharded-aware.

Layout (one directory per step):
    <root>/step_000100.tmp/   → written, fsync'd, then renamed to
    <root>/step_000100/
        manifest.json         (step, leaf names/shapes/dtypes, mesh info)
        <leaf-name>.npy       (full/global array value per leaf)

Atomicity = tmp-dir + rename: a crash mid-write never corrupts the latest
complete checkpoint; ``latest_step`` only considers renamed dirs.  The
async writer snapshots arrays to host first (jax.device_get), so training
continues while the write proceeds.  Restore can target a DIFFERENT mesh
(elastic re-scale) — see ``repro.checkpoint.reshard``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.trees import flatten_with_names, unflatten_from_names

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _fname(name: str) -> str:
    return _SAFE.sub("__", name) + ".npy"


def _to_savable(v: np.ndarray) -> np.ndarray:
    """numpy can't persist ml_dtypes (bfloat16, fp8) — widen to float32;
    restore() casts back per the target tree's dtypes."""
    if v.dtype.kind == "V" or v.dtype.name in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return v.astype(np.float32)
    return v


def save(root: str, step: int, tree: Any, *, blocking: bool = True) -> str:
    """Write checkpoint atomically; returns the final directory path."""
    named, _ = flatten_with_names(tree)
    host = [(n, _to_savable(np.asarray(jax.device_get(v))))
            for n, v in named]

    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"

    def write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for n, v in host:
            np.save(os.path.join(tmp, _fname(n)), v)
            manifest["leaves"].append(
                {"name": n, "shape": list(v.shape), "dtype": str(v.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
        return final
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(root, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(root: str, step: int, like: Any) -> Any:
    """Load a checkpoint into the structure of ``like`` (host numpy)."""
    path = os.path.join(root, f"step_{step:08d}")
    named, treedef = flatten_with_names(like)
    out = []
    for n, leaf in named:
        v = np.load(os.path.join(path, _fname(n)))
        want = tuple(leaf.shape)
        if tuple(v.shape) != want:
            raise ValueError(f"{n}: checkpoint {v.shape} != expected {want}")
        out.append(np.asarray(jax.numpy.asarray(v, dtype=leaf.dtype)))
    return unflatten_from_names(treedef, out)


class CheckpointManager:
    """Periodic async checkpointing with retention (keep last k)."""

    def __init__(self, root: str, *, every: int = 100, keep: int = 3,
                 blocking: bool = False):
        self.root = root
        self.every = every
        self.keep = keep
        self.blocking = blocking
        self._last_thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def maybe_save(self, step: int, tree: Any) -> bool:
        if step % self.every:
            return False
        self.wait()
        if self.blocking:
            save(self.root, step, tree, blocking=True)
        else:
            named, _ = flatten_with_names(tree)
            host_tree = tree  # device_get happens inside save()
            self._last_thread = threading.Thread(
                target=save, args=(self.root, step, host_tree),
                kwargs={"blocking": True}, daemon=True)
            # snapshot to host BEFORE returning control (cheap on CPU;
            # on TPU this is the D2H copy that must precede async write)
            jax.block_until_ready(jax.tree.leaves(tree))
            self._last_thread.start()
        self._gc()
        return True

    def wait(self):
        if self._last_thread is not None:
            self._last_thread.join()
            self._last_thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.root)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.root)

    def restore(self, like: Any, step: Optional[int] = None) -> tuple[int, Any]:
        s = self.latest() if step is None else step
        if s is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        return s, restore(self.root, s, like)
