"""GradSync facade + a paper-faithful KVStore API, both on the
CommSchedule IR (DESIGN.md §4).

``GradSync`` is the production entry point: built once per train setup from
the gradient pytree structure and param PartitionSpecs, it plans the
configured strategy's ``CommSchedule`` ONCE (inspectable as ``.schedule``)
and emits it inside the (shard_map'd, jitted) train step via
``repro.core.schedule.execute``.

``KVStore`` reproduces the paper's python API (Figs 3, 5, 8, 10) so the
paper's training loops port nearly line-for-line — used by the
paper-figure benchmarks and tests.  It is traced code: "push" records the
staged collective, "pull" materializes it with the strategy's dependency
structure.  Both paths flow through the same ``emit_gated`` emitter, and
KVStore records the ops it emits as the same ``CollectiveOp`` IR
(``.schedule()``), so paper-API and production paths cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dependency as dep
from repro.core.buckets import Bucket, BucketPlan, LeafInfo, make_bucket_plan
from repro.core.registry import StrategyInfo, get_strategy
from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    REDUCE_SCATTER,
    REGROUP,
    CollectiveOp,
    CommSchedule,
    emit_gated,
    execute,
    group_size,
)
from repro.core.strategies import make_reducer


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    strategy: str = "depcha"         # any registered strategy name
    reducer: str = "flat"            # any registered reducer name
    bucket_bytes: int = 4 * 1024 * 1024
    num_channels: int = 4            # ConCom communicator count
    comm_dtype: Any = jnp.float32
    mean_axes: tuple[str, ...] = ()  # axes whose psum becomes a mean
    exclude_axes: tuple[str, ...] = ()  # reduced elsewhere (ZeRO-1 RS)
    use_fused_staging: bool = True   # fused pack/unpack kernels (§8)
    loss_scale: float = 1.0          # folded into pack; unpack divides
    # StepProgram (§9): non-empty → plan the ZeRO-1 step as per-bucket
    # RS→UPDATE→AG ops over these axes, appended to the sync schedule
    # (set exclude_axes to the same axes — the RS *is* their reduction)
    zero1_dp_axes: tuple[str, ...] = ()
    zero1_clip: bool = False         # plan the NORM op (grad clipping)
    # pipelined StepProgram (§10): tag the zero1 all-gathers PRE so they
    # detach into the NEXT step's top (executed via ``apply_pending``
    # with the carried update shards) instead of serializing the tail
    zero1_defer_ag: bool = False
    # grad-accumulation factor of the consuming train step — meta
    # strategies (auto) fold the M-microbatch scan into their ranking
    # (with the peeled-tail release shape unless accum_overlap is off)
    zero1_accum: int = 1
    zero1_accum_overlap: bool = True
    # PER-MICROBATCH ComputeModel for meta-strategy ranking: without it
    # auto ranks schedules on comm alone (ComputeModel(0, 0)) and the
    # deferred family has no forward window to hide its gathers under
    sim_compute: Any = None
    # pipeline context (DESIGN.md §15): stages > 1 → meta strategies
    # rank pipeline × zero1 × accum jointly (``pp:<sched>:<strategy>``
    # rows); the train step fills these from its resolved pipeline plan
    pp_stages: int = 1
    pp_schedule: str = "auto"        # "auto" | "gpipe" | "1f1b"
    pp_microbatches: int = 0         # 0 → derived (accum, else 2·stages)
    pp_activation_bytes: int = 0     # stage-boundary payload per hop
    # static analysis (DESIGN.md §11): run the five repro.analysis
    # passes over the planned schedule and raise ScheduleError (with a
    # printable witness) instead of deadlocking at run time / failing
    # at trace time with a cryptic XLA error
    verify: bool = True


class GradSync:
    """Configured gradient synchronizer (the KVStore.create analogue)."""

    def __init__(
        self,
        cfg: GradSyncConfig,
        mesh,
        param_specs: Any,
        grads_like: Any,
        *,
        in_scan_names: frozenset[str] = frozenset(),
    ):
        self.cfg = cfg
        # kept for the measured per-op replay (repro.obs.measure), which
        # re-dispatches the planned schedule op-by-op over this mesh with
        # these specs
        self.mesh = mesh
        self.param_specs = param_specs
        self.info: StrategyInfo = get_strategy(cfg.strategy)  # fail fast
        if self.info.two_phase and cfg.reducer not in ("flat", "ring"):
            # "flat" → psum_scatter/all_gather; "ring" → the chunked ring
            # kernels carry the RS/AG ops themselves (DESIGN.md §8)
            raise ValueError(
                f"strategy {cfg.strategy!r} emits raw reduce-scatter/"
                f"all-gather ops and would silently ignore "
                f"reducer={cfg.reducer!r}; use reducer='flat'/'ring' or "
                f"a non-two-phase strategy")
        self.mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) \
            if hasattr(mesh, "devices") else dict(mesh.shape)
        self.plan: BucketPlan = make_bucket_plan(
            grads_like,
            param_specs,
            mesh,
            bucket_bytes=cfg.bucket_bytes,
            num_channels=1 if self.info.single_chain else cfg.num_channels,
            comm_dtype=cfg.comm_dtype,
            exclude_axes=cfg.exclude_axes,
        )
        self.reducer = make_reducer(
            cfg.reducer, self.mesh_shape, mean_axes=cfg.mean_axes
        )
        # leaves whose psum already happened inside the backward scan
        self.skip_names = (
            in_scan_names if self.info.uses_in_scan else frozenset())
        # meta strategies (auto) plan by simulating candidates — hand them
        # the real topology so the cost model is calibrated
        plan_kw = {}
        if self.info.meta:
            plan_kw["context"] = {
                "mesh_shape": self.mesh_shape,
                "reducer": cfg.reducer,
                "itemsize": np.dtype(cfg.comm_dtype).itemsize,
                "fused_staging": cfg.use_fused_staging,
                "compute": cfg.sim_compute,
            }
            if cfg.pp_stages > 1:
                plan_kw["context"]["pp"] = {
                    "stages": cfg.pp_stages,
                    "schedule": cfg.pp_schedule,
                    "microbatches": cfg.pp_microbatches,
                    "activation_bytes": cfg.pp_activation_bytes,
                }
        # the strategy's dependency structure, planned once, inspectable
        self.schedule: CommSchedule = self.info.plan(
            self.plan, skip_names=self.skip_names, **plan_kw)

        # StepProgram (§9): append the ZeRO-1 RS→UPDATE→AG triples,
        # planned by the SAME strategy over the dp-axes bucket plan
        self.program = None
        self.dp_plan = None
        if cfg.zero1_dp_axes:
            from repro.core.stepprogram import (
                build_step_program,
                zero1_bucket_plan,
            )

            id_offset = (max(b.bucket_id for b in self.plan.buckets) + 1
                         if self.plan.buckets else 0)
            self.dp_plan = zero1_bucket_plan(
                grads_like, param_specs, mesh,
                dp_axes=cfg.zero1_dp_axes,
                bucket_bytes=cfg.bucket_bytes,
                num_channels=1 if self.info.single_chain
                else cfg.num_channels,
                id_offset=id_offset)
            dp_size = group_size(cfg.zero1_dp_axes, self.mesh_shape)
            plan_kw2 = {}
            if self.info.meta:
                plan_kw2["context"] = {
                    **plan_kw["context"],
                    "zero1": {"dp_axes": tuple(cfg.zero1_dp_axes),
                              "dp_size": dp_size,
                              "clip": cfg.zero1_clip,
                              "defer": cfg.zero1_defer_ag,
                              "accum": cfg.zero1_accum,
                              "accum_overlap": cfg.zero1_accum_overlap},
                }
            base = self.info.plan(
                self.dp_plan, skip_names=frozenset(), **plan_kw2)
            self.program = build_step_program(
                self.schedule, self.plan, base, self.dp_plan,
                dp_axes=tuple(cfg.zero1_dp_axes), dp_size=dp_size,
                clip=cfg.zero1_clip, defer_ag=cfg.zero1_defer_ag)
            self.schedule = self.program.schedule

        if cfg.verify:
            from repro.analysis import verify_schedule

            verify_schedule(
                self.schedule,
                mesh_shape=self.mesh_shape,
                default_reducer=cfg.reducer,
                plan_comm_dtype=cfg.comm_dtype,
                expect_defer=(self.program.defer_ag
                              if self.program is not None else False),
            )

    def _two_phase_impl(self) -> str:
        # ring-family reducers route the RS/AG ops through the chunked
        # ring kernels (§8); the zero1 triples ride the same transport.
        # (Two-phase strategies only ever reach here with flat/ring —
        # the constructor guard rejects the rest.)
        ring_family = (self.cfg.reducer == "ring"
                       or self.cfg.reducer.endswith("_ring"))
        emits_rs_ag = self.info.two_phase or self.program is not None
        return "ring" if (ring_family and emits_rs_ag) else "psum"

    def __call__(self, grads: Any, *, update_fn=None,
                 clip_norm: float = 0.0, aux: dict | None = None,
                 schedule: CommSchedule | None = None) -> Any:
        """Emit the planned schedule over ``grads``.

        For pure sync schedules this returns the reduced gradients.  A
        StepProgram schedule (``zero1_dp_axes``) additionally needs
        ``update_fn`` (see ``repro.optim.zero.scheduled_update``); the
        returned tree then holds the all-gathered *updates*.

        ``schedule`` overrides the planned schedule for phase-split
        execution — the deferred train step passes
        ``program.post_schedule()`` here (the update shards then land
        in ``aux["update_shards"]``) and gathers last step's shards via
        ``apply_pending``.
        """
        return execute(
            self.schedule if schedule is None else schedule,
            grads,
            self.plan,
            reducer=self.reducer,
            mesh_shape=self.mesh_shape,
            mean_axes=self.cfg.mean_axes,
            use_fused_staging=self.cfg.use_fused_staging,
            loss_scale=self.cfg.loss_scale,
            two_phase_impl=self._two_phase_impl(),
            update_fn=update_fn,
            clip_norm=clip_norm,
            aux=aux,
        )

    def apply_pending(self, updates_like: Any,
                      pending: dict[int, jax.Array]) -> Any:
        """Materialize the deferred PRE program: all-gather the update
        shards carried from the previous step (``pending``: bucket_id →
        local shard) into ``updates_like`` (a zeros tree shaped like the
        params).  Every leaf covered by the dp plan is overwritten; the
        gathers free-fly, so bucket 0's result is available while later
        buckets are still on the wire.
        """
        if self.program is None or not self.program.defer_ag:
            raise ValueError(
                "apply_pending requires a StepProgram planned with "
                "zero1_defer_ag=True")
        return execute(
            self.program.pre_schedule(),
            updates_like,
            self.plan,
            reducer=self.reducer,
            mesh_shape=self.mesh_shape,
            mean_axes=self.cfg.mean_axes,
            use_fused_staging=self.cfg.use_fused_staging,
            two_phase_impl=self._two_phase_impl(),
            pending=pending,
        )


class KVStore:
    """Paper API: create / init / push / pull / barrier  (Figs 3, 5, 8, 10).

    Use inside a shard_map'd function.  Any registered strategy name is a
    valid ``kind``; semantics derive from the strategy's registry
    metadata, not name strings:
      funnel   (single_chain)  — pushes reduce immediately on ONE chain.
      concom / priority        — key hashed to ``num_channels`` chains.
      depcha   (deferred_pull) — push only stages the buffer; pull
               performs the chained allreduce (decoupled push/pull
               batches, paper Fig 10).
      rsag     (two_phase)     — push emits the reduce-scatter, pull the
               all-gather (needs ``mesh_shape`` for group sizes).

    Every op emitted is recorded as CommSchedule IR — ``.schedule()``
    returns the trace for inspection, built from the same CollectiveOp
    nodes GradSync plans ahead of time.
    """

    def __init__(self, kind: str, *, reduce_axes: tuple[str, ...],
                 num_channels: int = 4,
                 mesh_shape: dict[str, int] | None = None):
        self.info = get_strategy(kind)
        self.kind = kind
        self.reduce_axes = tuple(reduce_axes)
        self.num_channels = 1 if self.info.single_chain else num_channels
        self.mesh_shape = mesh_shape
        if self.info.two_phase:
            self._group = self._group_size()
        self._tokens = [dep.new_token() for _ in range(self.num_channels)]
        self._staged: dict[int, jax.Array] = {}
        self._reduced: dict[int, jax.Array] = {}
        self._shards: dict[int, tuple[jax.Array, int]] = {}
        self._shapes: dict[int, tuple[int, ...]] = {}
        self._ops: list[CollectiveOp] = []
        self._last_op: dict[int, int] = {}   # channel -> last op_id
        self._rs_ops: dict[int, int] = {}    # key -> its RS op_id
        self._barrier_join: tuple[int, ...] = ()  # chain tails at barrier()
        self._regroups = 0                   # regroup() count (bucket ids)

    @classmethod
    def create(cls, kind: str, **kw) -> "KVStore":
        return cls(kind, **kw)

    def _group_size(self) -> int:
        if self.mesh_shape is None:
            raise ValueError(
                f"kind={self.kind!r} emits reduce-scatter/all-gather and "
                f"needs mesh_shape= for group sizes")
        return group_size(self.reduce_axes, self.mesh_shape)

    def init(self, key: int, value: jax.Array) -> jax.Array:
        """Paper Fig 4: broadcast initial value from rank 0.

        Real broadcast semantics: non-root ranks contribute zeros to a
        psum, so every rank receives rank 0's value BIT-EXACTLY (adding
        zeros is exact in floating point — no psum/size rounding) and
        ranks that somehow diverged are repaired.  Under SPMD all ranks
        already hold identical values, making this the identity.  The
        collective rides the key's channel chain and is recorded in the
        IR like any other op.
        """
        if not self.reduce_axes:
            return value
        root = jnp.bool_(True)
        for a in self.reduce_axes:
            root = jnp.logical_and(root, jax.lax.axis_index(a) == 0)
        self._shapes[key] = value.shape
        masked = jnp.where(root, jnp.ravel(value), 0)
        bcast = self._emit(key, masked, ALLREDUCE)
        return bcast.reshape(value.shape)

    def _chan(self, key: int) -> int:
        return key % self.num_channels

    def _bucket(self, key: int, buf: jax.Array) -> Bucket:
        leaf = LeafInfo(name=str(key), index=key,
                        shape=self._shapes[key], dtype=buf.dtype,
                        size=buf.shape[0])
        return Bucket(leaves=(leaf,), reduce_axes=self.reduce_axes,
                      channel=self._chan(key), bucket_id=key)

    def _record(self, key: int, buf: jax.Array, kind: str,
                extra_deps: tuple[int, ...] = ()) -> CollectiveOp:
        c = self._chan(key)
        deps = tuple(extra_deps)
        if c in self._last_op:
            if self._last_op[c] not in deps:
                deps = (self._last_op[c],) + deps
        elif self._barrier_join:
            # first op on this channel after a barrier(): really gated on
            # every pre-barrier chain tail (the joined token)
            deps = tuple(d for d in self._barrier_join
                         if d not in deps) + deps
        op = CollectiveOp(op_id=len(self._ops), bucket=self._bucket(key, buf),
                          chain=c, depends_on=deps, kind=kind)
        self._ops.append(op)
        self._last_op[c] = op.op_id
        return op

    def _emit(self, key: int, buf: jax.Array, kind: str,
              extra_deps: tuple[int, ...] = ()) -> jax.Array:
        """Record the op in the IR and emit it through THE emitter."""
        op = self._record(key, buf, kind, extra_deps)
        if kind == REDUCE_SCATTER:
            self._rs_ops[key] = op.op_id
        c = self._chan(key)
        if kind == ALLREDUCE:
            fn = lambda b: jax.lax.psum(b, self.reduce_axes)  # MPI_Allreduce
        elif kind == REDUCE_SCATTER:
            fn = (lambda b: b) if self._group == 1 else (
                lambda b: jax.lax.psum_scatter(
                    b, self.reduce_axes, scatter_dimension=0, tiled=True))
        elif kind == ALL_GATHER:
            fn = (lambda b: b) if self._group == 1 else (
                lambda b: jax.lax.all_gather(
                    b, self.reduce_axes, axis=0, tiled=True))
        else:
            raise ValueError(kind)
        out, self._tokens[c] = emit_gated(buf, self._tokens[c], fn)
        return out

    def push(self, key: int, grad: jax.Array) -> None:
        self._shapes[key] = grad.shape
        send_buf = jnp.ravel(grad)                   # CopyFromTo → comm_buf
        if self.info.deferred_pull:
            self._staged[key] = send_buf             # decoupled: reduce at pull
            return
        if self.info.two_phase:
            n = send_buf.shape[0]
            if (-n) % self._group:
                send_buf = jnp.pad(send_buf, (0, (-n) % self._group))
            shard = self._emit(key, send_buf, REDUCE_SCATTER)
            self._shards[key] = (shard, n)
            return
        self._reduced[key] = self._emit(key, send_buf, ALLREDUCE)

    def pull(self, key: int, like: jax.Array | None = None) -> jax.Array:
        if self.info.deferred_pull and key in self._staged:
            self._reduced[key] = self._emit(
                key, self._staged.pop(key), ALLREDUCE)
        if self.info.two_phase and key in self._shards:
            shard, n = self._shards.pop(key)
            full = self._emit(key, shard, ALL_GATHER,
                              extra_deps=(self._rs_ops[key],))
            self._reduced[key] = full[:n] if full.shape[0] != n else full
        out = self._reduced[key]
        return out.reshape(self._shapes[key])        # CopyFromTo(recv_buf, g)

    def barrier(self) -> None:
        """Paper Fig 8 line 13: join all outstanding chains.  Recorded in
        the IR by making every subsequent op's first emission on a channel
        depend on all pre-barrier chain tails."""
        joined = dep.new_token()
        joined = dep.update(joined, *self._tokens)
        self._tokens = [joined for _ in self._tokens]
        self._barrier_join = tuple(sorted(self._last_op.values()))
        self._last_op = {}

    def regroup(self, *, reduce_axes: tuple[str, ...] | None = None,
                mesh_shape: dict[str, int] | None = None) -> jax.Array:
        """MXNET-MPI group rebuild (DESIGN.md §13): dissolve the current
        communicator and re-form it over ``reduce_axes``/``mesh_shape``.

        Stronger than ``barrier()``: besides joining every outstanding
        chain, the OLD group runs one scalar psum — a real collective
        every member must reach, the analogue of ``MPI_Group_free`` +
        ``MPI_Comm_create`` — recorded in the IR as a REGROUP op that
        depends on every chain tail, so the reshard analysis pass can
        prove no old-group op is still in flight when membership
        changes.  Returns the barrier's scalar (== old group size).
        """
        tails = tuple(sorted(self._last_op.values())) or self._barrier_join
        bucket = Bucket(
            leaves=(LeafInfo(name=f"__regroup{self._regroups}", index=0,
                             shape=(), dtype=jnp.float32, size=1),),
            reduce_axes=self.reduce_axes, channel=0,
            bucket_id=1_000_000 + self._regroups)
        op = CollectiveOp(op_id=len(self._ops), bucket=bucket, chain=0,
                          depends_on=tails, kind=REGROUP)
        self._ops.append(op)
        self._regroups += 1
        joined = dep.update(dep.new_token(), *self._tokens)
        done, tok = emit_gated(
            jnp.float32(1.0), joined,
            lambda v: jax.lax.psum(v, self.reduce_axes))
        self._tokens = [tok for _ in self._tokens]
        self._last_op = {}
        self._barrier_join = (op.op_id,)
        if reduce_axes is not None:
            self.reduce_axes = tuple(reduce_axes)
        if mesh_shape is not None:
            self.mesh_shape = mesh_shape
        if self.info.two_phase:
            self._group = self._group_size()
        return done

    def schedule(self, verify: bool = True) -> CommSchedule:
        """The IR of every collective this store has emitted so far.

        ``verify`` runs the repro.analysis passes over the trace —
        pure-Python metadata checks, safe inside a jit/shard_map trace
        (rank simulation is skipped when ``mesh_shape`` was not given).
        """
        s = CommSchedule(tuple(self._ops)).validate()
        if verify:
            from repro.analysis import verify_schedule

            verify_schedule(s, mesh_shape=self.mesh_shape,
                            expect_defer=False)
        return s
