"""GradSync facade + a paper-faithful KVStore API.

``GradSync`` is the production entry point: built once per train setup from
the gradient pytree structure and param PartitionSpecs, it applies the
configured embedding strategy inside the (shard_map'd, jitted) train step.

``KVStore`` reproduces the paper's python API (Figs 3, 5, 8, 10) so the
paper's training loops port nearly line-for-line — used by
``examples/paper_api.py`` and the paper-figure benchmarks.  It is traced
code: "push" records the staged collective, "pull" materializes it with the
strategy's dependency structure.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import dependency as dep
from repro.core.buckets import BucketPlan, make_bucket_plan
from repro.core.strategies import make_reducer, sync_grads


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    strategy: str = "depcha"         # funnel | concom | depcha
    reducer: str = "flat"            # flat | hierarchical | compressed
    bucket_bytes: int = 4 * 1024 * 1024
    num_channels: int = 4            # ConCom communicator count
    comm_dtype: Any = jnp.float32
    mean_axes: tuple[str, ...] = ()  # axes whose psum becomes a mean
    exclude_axes: tuple[str, ...] = ()  # reduced elsewhere (ZeRO-1 RS)


class GradSync:
    """Configured gradient synchronizer (the KVStore.create analogue)."""

    def __init__(
        self,
        cfg: GradSyncConfig,
        mesh,
        param_specs: Any,
        grads_like: Any,
        *,
        in_scan_names: frozenset[str] = frozenset(),
    ):
        self.cfg = cfg
        self.mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) \
            if hasattr(mesh, "devices") else dict(mesh.shape)
        self.plan: BucketPlan = make_bucket_plan(
            grads_like,
            param_specs,
            mesh,
            bucket_bytes=cfg.bucket_bytes,
            num_channels=cfg.num_channels if cfg.strategy != "funnel" else 1,
            comm_dtype=cfg.comm_dtype,
            exclude_axes=cfg.exclude_axes,
        )
        self.reducer = make_reducer(
            cfg.reducer, self.mesh_shape, mean_axes=cfg.mean_axes
        )
        # depcha: leaves whose psum already happened inside the backward scan
        self.skip_names = in_scan_names if cfg.strategy == "depcha" else frozenset()

    def __call__(self, grads: Any) -> Any:
        return sync_grads(
            grads,
            self.plan,
            strategy=self.cfg.strategy,
            reducer=self.reducer,
            skip_names=self.skip_names,
        )


class KVStore:
    """Paper API: create / init / push / pull / barrier  (Figs 3, 5, 8, 10).

    Use inside a shard_map'd function.  Ordering semantics per strategy:
      funnel: pushes reduce immediately on ONE token chain (main thread).
      concom: key hashed to ``num_channels`` chains (communicators).
      depcha: push only stages the buffer; pull performs the chained
              allreduce — the paper's decoupled push/pull batches.
    """

    def __init__(self, kind: str, *, reduce_axes: tuple[str, ...],
                 num_channels: int = 4, mesh_shape: dict[str, int] | None = None):
        assert kind in ("funnel", "concom", "depcha"), kind
        self.kind = kind
        self.reduce_axes = reduce_axes
        self.num_channels = num_channels if kind != "funnel" else 1
        self._tokens = [dep.new_token() for _ in range(self.num_channels)]
        self._staged: dict[int, jax.Array] = {}
        self._reduced: dict[int, jax.Array] = {}
        self._shapes: dict[int, tuple[int, ...]] = {}

    @classmethod
    def create(cls, kind: str, **kw) -> "KVStore":
        return cls(kind, **kw)

    def init(self, key: int, value: jax.Array) -> jax.Array:
        """Paper Fig 4: broadcast initial value from rank 0.  Under SPMD all
        ranks hold identical initial values by construction; we emit a
        psum/size for bit-identical semantics when values could diverge."""
        n = 1
        # keep semantics: average across the group (== bcast of identical vals)
        for _ in self.reduce_axes:
            pass
        return value  # SPMD: already replicated; kept for API fidelity

    def _chan(self, key: int) -> int:
        return key % self.num_channels

    def push(self, key: int, grad: jax.Array) -> None:
        self._shapes[key] = grad.shape
        send_buf = jnp.ravel(grad)                       # CopyFromTo → comm_buf
        if self.kind == "depcha":
            self._staged[key] = send_buf                 # decoupled: reduce at pull
            return
        c = self._chan(key)
        send_buf = dep.gate(send_buf, self._tokens[c])   # WaitToRead / read-dep
        red = jax.lax.psum(send_buf, self.reduce_axes)   # MPI_Allreduce
        self._tokens[c] = dep.update(self._tokens[c], red)
        self._reduced[key] = red

    def pull(self, key: int, like: jax.Array | None = None) -> jax.Array:
        if self.kind == "depcha" and key in self._staged:
            c = self._chan(key)
            buf = dep.gate(self._staged.pop(key), self._tokens[c])
            red = jax.lax.psum(buf, self.reduce_axes)    # stage 2: network reduce
            self._tokens[c] = dep.update(self._tokens[c], red)  # dummy mutate
            self._reduced[key] = red
        out = self._reduced[key]
        return out.reshape(self._shapes[key])            # CopyFromTo(recv_buf, g)

    def barrier(self) -> None:
        """Paper Fig 8 line 13: join all outstanding chains."""
        joined = dep.new_token()
        joined = dep.update(joined, *self._tokens)
        self._tokens = [joined for _ in self._tokens]
