"""StepProgram: the WHOLE training step as one scheduled program
(DESIGN.md §9).

The paper embeds collectives into the training DAG; Shi et al.
(1805.03812) and MXNET-MPI (1801.03855) model the *parameter update* as
a schedulable task of the same iteration DAG.  This module extends the
CommSchedule IR accordingly: the ZeRO-1 optimizer step stops being a
monolithic post-script and becomes per-bucket

    reduce_scatter(grad bucket k)  →  UPDATE(shard k)  →  all_gather(k)

op triples whose REDUCE_SCATTER dependency structure is planned by the
SAME registered strategies (funnel / concom / depcha / priority / rsag /
auto) that plan the gradient sync — so bucket k's shard update overlaps
bucket k+1's reduce-scatter and earlier buckets' all-gathers, the MXNET
push/pull overlap extended through the update.

Construction:
  ``zero1_bucket_plan``   — dp-axes bucket plan over ALL gradient leaves
      (f32 wire, ids offset past the sync plan's buckets).
  ``zero1_schedule``      — transform a strategy's base schedule on that
      plan (allreduce chains, or rsag's RS/AG pairs) into RS→UPDATE→AG
      triples, with an optional NORM op (scalar psum of local squared
      norms) gating every UPDATE for global-norm clipping on shards.
  ``build_step_program``  — splice the sync schedule and the zero1 ops
      into ONE CommSchedule: each zero1 RS additionally depends on the
      sync op that produced its leaves (the model-axis psum must land
      before the dp reduce-scatter consumes it).

Executed by ``repro.core.schedule.execute`` (UPDATE ops call the
supplied ``update_fn``); costed by ``repro.sim`` (UPDATE = shard-update
HBM time, NORM = scalar latency-bound allreduce).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.core.buckets import Bucket, BucketPlan, make_bucket_plan
from repro.core.schedule import (
    ALL_GATHER,
    NORM,
    POST,
    PRE,
    REDUCE_SCATTER,
    UPDATE,
    CollectiveOp,
    CommSchedule,
)


@dataclasses.dataclass(frozen=True)
class StepProgram:
    """One schedule for the full step: sync + per-bucket ZeRO-1 ops.

    ``plan`` is the leaf-indexed sync BucketPlan (treedef / num_leaves /
    base comm dtype — what ``execute`` needs); ``dp_plan`` holds the
    zero1 dp-axes buckets whose RS/UPDATE/AG triples follow the sync ops
    in ``schedule``.
    """

    schedule: CommSchedule
    plan: BucketPlan
    dp_plan: BucketPlan
    dp_axes: tuple[str, ...]
    dp_size: int
    clip: bool
    num_sync_ops: int
    defer_ag: bool = False

    def stats(self) -> dict[str, Any]:
        return self.schedule.stats()

    def post_schedule(self) -> CommSchedule:
        """The ops that run in the step that produced the gradients
        (sync + RS + NORM + UPDATE; plus the AGs unless deferred)."""
        return self.schedule.split_phases()[0]

    def pre_schedule(self) -> CommSchedule:
        """The deferred all-gathers, re-rooted for the NEXT step's top:
        their update-shard inputs arrive as carried state
        (``execute(pending=...)``), so every op free-flies — bucket 0's
        gather overlaps the rest and the input pipeline."""
        return self.schedule.split_phases()[1]


def zero1_bucket_plan(
    grads_like: Any,
    param_specs: Any,
    mesh,
    *,
    dp_axes: tuple[str, ...],
    bucket_bytes: int = 4 * 1024 * 1024,
    num_channels: int = 1,
    id_offset: int = 0,
) -> BucketPlan:
    """Bucket ALL gradient leaves by their data-parallel reduce axes.

    The wire dtype is pinned to f32 (per-bucket ``comm_dtype`` override)
    so the shard-update math matches the monolithic zero1 optimizer
    bit-for-bit regardless of the sync schedule's comm dtype; bucket ids
    are offset past the sync plan's so the two coexist in one schedule.
    """
    axis_names = tuple(mesh.axis_names)
    exclude = tuple(a for a in axis_names if a not in dp_axes)
    raw = make_bucket_plan(
        grads_like, param_specs, mesh,
        bucket_bytes=bucket_bytes, num_channels=num_channels,
        comm_dtype=jnp.float32, exclude_axes=exclude)
    covered = {l.index for b in raw.buckets for l in b.leaves}
    if len(covered) != raw.num_leaves:
        raise ValueError(
            f"ZeRO-1 StepProgram requires every parameter replicated "
            f"over the dp axes {dp_axes} (got {len(covered)} of "
            f"{raw.num_leaves} leaves dp-reducible — params already "
            f"sharded over {dp_axes}, e.g. FSDP, keep their own storage)")
    buckets = tuple(
        dataclasses.replace(
            b,
            bucket_id=b.bucket_id + id_offset,
            comm_dtype=jnp.float32,
            leaves=tuple(dataclasses.replace(l, dtype=jnp.float32)
                         for l in b.leaves))
        for b in raw.buckets)
    return BucketPlan(buckets=buckets, treedef=raw.treedef,
                      num_leaves=raw.num_leaves, comm_dtype=jnp.float32)


def _zero1_ops(
    base: CommSchedule,
    *,
    dp_axes: tuple[str, ...],
    clip: bool,
    start_op_id: int,
    chain_offset: int,
    leaf_deps,
    defer_ag: bool = False,
) -> list[CollectiveOp]:
    """Rewrite a base strategy schedule into RS→UPDATE→AG triples.

    The base schedule was planned on the dp bucket plan by any
    registered strategy: allreduce chains (funnel/concom/depcha/
    priority) or RS/AG pairs (rsag).  Chain-ordering edges land on the
    REDUCE_SCATTER ops only — updates and all-gathers free-fly behind
    their own data deps, which is exactly the pipelining the paper's
    dependency-chain design buys the sync half of the step.

    With ``defer_ag`` the all-gathers are tagged PRE (DESIGN.md §10):
    they detach from this step's tail and execute at the top of the
    NEXT step, the update shards crossing the boundary as carried
    state.  The in-step dependency edges are kept so the un-split
    schedule still validates (and still describes the scheduled,
    same-step execution bit-exactly).
    """
    heads = [op for op in base.ops if op.kind != ALL_GATHER]
    rs_of: dict[int, int] = {}          # base op_id -> new RS op_id
    ops: list[CollectiveOp] = []
    oid = start_op_id

    for bop in heads:                   # RS block (chains preserved)
        deps = tuple(rs_of[d] for d in bop.depends_on if d in rs_of)
        extra = leaf_deps(bop.bucket)
        deps = tuple(dict.fromkeys(extra + deps))
        ops.append(CollectiveOp(
            op_id=oid, bucket=bop.bucket, chain=bop.chain + chain_offset,
            depends_on=deps, kind=REDUCE_SCATTER))
        rs_of[bop.op_id] = oid
        oid += 1

    norm_id: int | None = None
    if clip and ops:
        # the global grad norm needs every reduced shard: one scalar
        # psum op gating all updates (the schedulable form of
        # clip_by_global_norm under ZeRO sharding)
        norm_bucket = Bucket(
            leaves=(), reduce_axes=tuple(dp_axes),
            channel=max((op.chain for op in ops), default=chain_offset) + 1,
            bucket_id=max(op.bucket.bucket_id for op in ops) + 1,
            comm_dtype=jnp.float32)
        norm_id = oid
        ops.append(CollectiveOp(
            op_id=oid, bucket=norm_bucket, chain=norm_bucket.channel,
            depends_on=tuple(rs_of.values()), kind=NORM))
        oid += 1

    for bop in heads:                   # UPDATE + AG per bucket
        rs_id = rs_of[bop.op_id]
        upd_deps = (rs_id,) + ((norm_id,) if norm_id is not None else ())
        ops.append(CollectiveOp(
            op_id=oid, bucket=bop.bucket, chain=bop.chain + chain_offset,
            depends_on=upd_deps, kind=UPDATE))
        ops.append(CollectiveOp(
            op_id=oid + 1, bucket=bop.bucket,
            chain=bop.chain + chain_offset,
            depends_on=(oid,), kind=ALL_GATHER,
            phase=PRE if defer_ag else POST))
        oid += 2
    return ops


def zero1_schedule(
    base: CommSchedule,
    *,
    dp_axes: tuple[str, ...],
    clip: bool = False,
    defer_ag: bool = False,
) -> CommSchedule:
    """The zero1 RS→UPDATE→AG program alone (no sync ops) — what the
    simulator and autotuner rank.  ``defer_ag`` tags the all-gathers
    PRE (split with ``CommSchedule.split_phases`` for the pipelined
    two-step timeline)."""
    ops = _zero1_ops(base, dp_axes=dp_axes, clip=clip, start_op_id=0,
                     chain_offset=0, leaf_deps=lambda bucket: (),
                     defer_ag=defer_ag)
    return CommSchedule(tuple(ops)).validate()


def build_step_program(
    sync_schedule: CommSchedule,
    sync_plan: BucketPlan,
    base: CommSchedule,
    dp_plan: BucketPlan,
    *,
    dp_axes: tuple[str, ...],
    dp_size: int,
    clip: bool = False,
    defer_ag: bool = False,
) -> StepProgram:
    """Splice sync ops and zero1 RS→UPDATE→AG ops into one schedule.

    Each zero1 reduce-scatter depends on the LAST sync op touching any
    of its leaves (the model-axis psum result is what the dp RS
    consumes); leaves with no sync op (TP-sharded params whose only
    reduction IS the dp one) start as soon as their chain allows.

    ``defer_ag`` builds the PIPELINED program: the all-gathers are
    tagged PRE, to be executed at the top of the next step via
    ``StepProgram.pre_schedule()`` while ``post_schedule()`` carries
    everything else (DESIGN.md §10).
    """
    sync_ops = sync_schedule.ops
    n_sync = len(sync_ops)
    chain_offset = (max(op.chain for op in sync_ops) + 1) if sync_ops else 0

    last_touch: dict[str, int] = {}
    for op in sync_ops:
        for leaf in op.bucket.leaves:
            last_touch[leaf.name] = op.op_id

    def leaf_deps(bucket: Bucket) -> tuple[int, ...]:
        return tuple(sorted({last_touch[l.name] for l in bucket.leaves
                             if l.name in last_touch}))

    zops = _zero1_ops(base, dp_axes=dp_axes, clip=clip,
                      start_op_id=n_sync, chain_offset=chain_offset,
                      leaf_deps=leaf_deps, defer_ag=defer_ag)
    schedule = CommSchedule(tuple(sync_ops) + tuple(zops)).validate()
    return StepProgram(
        schedule=schedule, plan=sync_plan, dp_plan=dp_plan,
        dp_axes=tuple(dp_axes), dp_size=dp_size, clip=clip,
        num_sync_ops=n_sync, defer_ag=defer_ag)
