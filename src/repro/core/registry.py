"""Strategy / reducer registry: names → plan functions & reducer factories.

The paper's design variable — WHICH dependency structure the scheduler
sees — used to live as ``if/elif`` control flow inside ``sync_grads``.
Here it becomes data: a strategy is a pure

    plan(bucket_plan: BucketPlan, *, skip_names=frozenset()) -> CommSchedule

function registered under a name, and a reducer is a factory

    factory(mesh_shape: dict[str, int], *, mean_axes=()) -> Reducer

returning the per-bucket collective.  Everything that used to hardcode
``("funnel", "concom", "depcha")`` — CLI ``choices=``, benchmark sweeps,
``GradSync`` dispatch — now derives from this registry, so adding a
strategy is one decorated function (see ``priority``/``rsag`` in
``repro.core.strategies``), not an edit to core control flow.

Per-strategy behavior that used to be name-string special cases is
declared as metadata on registration:

  uses_in_scan  — leaves already reduced inside the backward scan
                  (``repro.core.overlap``) are dropped from the schedule
                  (depcha).
  deferred_pull — KVStore semantics: ``push`` only stages the buffer,
                  ``pull`` performs the reduction (depcha's decoupled
                  batches, paper Fig 10).
  two_phase     — KVStore semantics: ``push`` emits the reduce-scatter,
                  ``pull`` the all-gather (rsag).
  single_chain  — all keys share ONE dependency chain (funnel).
  meta          — the plan delegates to other registered strategies
                  (``auto``: picks by simulation).  Meta plans accept an
                  extra ``context`` mapping (mesh_shape / reducer / …)
                  that GradSync supplies, and are excluded from candidate
                  enumeration (``fixed_strategy_names``) so they can
                  never delegate to themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class StrategyInfo:
    name: str
    plan: Callable[..., Any]     # (BucketPlan, *, skip_names) -> CommSchedule
    uses_in_scan: bool = False
    deferred_pull: bool = False
    two_phase: bool = False
    single_chain: bool = False
    meta: bool = False
    doc: str = ""


_STRATEGIES: dict[str, StrategyInfo] = {}
_REDUCERS: dict[str, Callable[..., Any]] = {}


def register_strategy(
    name: str,
    *,
    uses_in_scan: bool = False,
    deferred_pull: bool = False,
    two_phase: bool = False,
    single_chain: bool = False,
    meta: bool = False,
    doc: str = "",
    override: bool = False,
) -> Callable:
    """Decorator: register ``plan`` under ``name`` with its metadata."""

    def deco(plan: Callable) -> Callable:
        if name in _STRATEGIES and not override:
            raise ValueError(f"strategy {name!r} already registered")
        _STRATEGIES[name] = StrategyInfo(
            name=name, plan=plan, uses_in_scan=uses_in_scan,
            deferred_pull=deferred_pull, two_phase=two_phase,
            single_chain=single_chain, meta=meta,
            doc=doc or (plan.__doc__ or "").strip().split("\n")[0])
        return plan

    return deco


def register_reducer(name: str, *, override: bool = False) -> Callable:
    """Decorator: register a reducer factory under ``name``."""

    def deco(factory: Callable) -> Callable:
        if name in _REDUCERS and not override:
            raise ValueError(f"reducer {name!r} already registered")
        _REDUCERS[name] = factory
        return factory

    return deco


def get_strategy(name: str) -> StrategyInfo:
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}, want one of {strategy_names()}"
        ) from None


def get_reducer(name: str) -> Callable[..., Any]:
    try:
        return _REDUCERS[name]
    except KeyError:
        raise ValueError(
            f"unknown reducer {name!r}, want one of {reducer_names()}"
        ) from None


def strategy_names() -> tuple[str, ...]:
    """Registered strategy names, in registration order (builtins first)."""
    return tuple(_STRATEGIES)


def fixed_strategy_names() -> tuple[str, ...]:
    """Strategies that plan a concrete schedule themselves — the candidate
    set meta strategies (``auto``) choose from."""
    return tuple(n for n, s in _STRATEGIES.items() if not s.meta)


def reducer_names() -> tuple[str, ...]:
    return tuple(_REDUCERS)
