"""Compressed gradient allreduce (beyond-paper lever, DESIGN.md §3).

int8 block-quantized allreduce with error feedback:

  q = round(g / scale)  per block of 256 elements, scale = max|g|/127
  allreduce in int16/int32 accumulate (we model as: all_gather int8 shards
  is wasteful; instead reduce-scatter fp-decoded partials) — on TPU the
  practical scheme is quantize → reduce-scatter(int8 decoded on the fly is
  not expressible in one HLO op) — so we use the standard 2-phase scheme:

    (1) reduce_scatter the fp32 buffer shard-wise is what we *replace*;
        instead each device quantizes its full buffer, all-to-alls int8
        shards, locally dequantizes+sums, requantizes its reduced shard,
        and all-gathers int8.

  Wire bytes: 2 × size × 1 byte (vs 2 × size × 4 bytes fp32) → ~4× less
  collective traffic at the cost of two quantize kernels (Pallas:
  ``repro/kernels/quantize``).  Error feedback keeps the residual locally
  and adds it to the next step's gradient (Karimireddy et al., 2019-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, pad


def quantize_blockwise(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (n,) f32 → (q int8 (n,), scales f32 (n/BLOCK,))."""
    xb = x.reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_blockwise(q: jax.Array, scale: jax.Array) -> jax.Array:
    qb = q.reshape(-1, BLOCK).astype(jnp.float32)
    return (qb * scale[:, None]).reshape(-1)


def compressed_allreduce(
    buf: jax.Array, axes: tuple[str, ...], *, group_size: int,
    inter_axes: tuple[str, ...] = (), use_ring: bool = False
) -> jax.Array:
    """Quantized allreduce over ``axes`` (total group size ``group_size``).

    Scheme (ring-equivalent two-phase):
      quantize → all-to-all int8 shards → local dequant+reduce →
      requantize shard → all-gather int8 → dequant.
    Falls back to fp psum when the buffer is too small to shard.

    ``use_ring`` routes the phase-3 int8 gather (the bulk wire bytes)
    through the chunked ring all-gather in ``repro.kernels.collectives``
    (single-axis groups; multi-axis groups keep ``lax.all_gather``).
    """
    n = buf.shape[0]
    axis = axes if len(axes) > 1 else axes[0]
    buf_p, pad = _pad_to(buf, BLOCK * group_size)
    m = buf_p.shape[0]
    if m < BLOCK * group_size:
        return jax.lax.psum(buf, axis)

    # phase 1: everyone quantizes its local gradient, shards go to owners
    q, s = quantize_blockwise(buf_p)
    q_sh = q.reshape(group_size, m // group_size)
    s_sh = s.reshape(group_size, (m // BLOCK) // group_size)
    # all_to_all over a single flattened group axis
    q_recv = _all_to_all_grouped(q_sh, axes)      # (group, m/group) int8
    s_recv = _all_to_all_grouped(s_sh, axes)
    # phase 2: dequantize each peer's shard and reduce locally
    deq = jax.vmap(dequantize_blockwise)(
        q_recv.reshape(group_size, -1), s_recv.reshape(group_size, -1)
    )
    red = jnp.sum(deq, axis=0)                     # (m/group,) f32
    if inter_axes:
        # hierarchical-compressed: fp psum of the 1/group shard across
        # the slow axes (pods) — 1/group of the bytes on that tier
        red = jax.lax.psum(red, inter_axes)
    # phase 3: requantize the reduced shard, all-gather
    q2, s2 = quantize_blockwise(red)
    if use_ring and len(axes) == 1:
        from repro.kernels.collectives.ops import ring_all_gather

        ring_shape = {axes[0]: group_size}
        q_all = ring_all_gather(q2, axes, ring_shape)   # (m,) int8
        s_all = ring_all_gather(s2, axes, ring_shape)
    else:
        q_all = _all_gather_grouped(q2, axes)          # (m,) int8
        s_all = _all_gather_grouped(s2, axes)
    out = dequantize_blockwise(q_all, s_all)
    return out[:n] if pad else out


def _all_to_all_grouped(x: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """all_to_all over possibly-multiple mesh axes, splitting dim 0."""
    if len(axes) == 1:
        return jax.lax.all_to_all(
            x, axes[0], split_axis=0, concat_axis=0, tiled=False
        ).reshape(x.shape)
    # multi-axis: treat (a0, a1) as one group by chaining
    out = x
    # reshape shard dim into (len(a0), len(a1)) blocks handled sequentially
    out = jax.lax.all_to_all(out, axes, split_axis=0, concat_axis=0, tiled=False)
    return out.reshape(x.shape)


def _all_gather_grouped(x: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    axis = axes if len(axes) > 1 else axes[0]
    return jax.lax.all_gather(x, axis, axis=0, tiled=True)


def error_feedback_step(
    grad: jax.Array, residual: jax.Array, sync_fn
) -> tuple[jax.Array, jax.Array]:
    """g' = sync(g + r); r' = (g + r) - dequant-roundtrip(g + r)."""
    corrected = grad + residual
    synced = sync_fn(corrected)
    q, s = quantize_blockwise(_pad_to(corrected, BLOCK)[0])
    approx = dequantize_blockwise(q, s)[: corrected.shape[0]]
    new_residual = corrected - approx
    return synced, new_residual
