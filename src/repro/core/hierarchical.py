"""Hierarchical (pod-aware) allreduce — the TPU analogue of DepCha's 3 stages.

The paper decomposes MPI_Allreduce into (1) intra-node reduce, (2) inter-node
allreduce, (3) intra-node broadcast (§4.3) so the stages can be pipelined
independently.  On a multi-pod TPU mesh the natural decomposition is:

    (1) reduce-scatter over the fast intra-pod ICI axis ("data"),
    (2) allreduce of the 1/N shard over the slow inter-pod DCN axis ("pod"),
    (3) all-gather over the intra-pod axis.

This moves only 1/N of the gradient bytes over the slow axis (vs all bytes
for a flat allreduce over ("pod","data")) and each stage is a separately
schedulable HLO collective, exactly mirroring the paper's sub-task split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.collectives.ops import ring_all_gather, ring_reduce_scatter


def hierarchical_allreduce(
    buf: jax.Array,
    *,
    intra_axis: str = "data",
    inter_axis: str = "pod",
    intra_size: int,
    use_ring: bool = False,
) -> jax.Array:
    """3-stage allreduce of a 1-D comm buffer over (inter_axis, intra_axis).

    ``use_ring`` routes the fast-tier bulk bytes (stages 1 and 3) through
    the chunked bidirectional ring kernels in
    ``repro.kernels.collectives`` instead of the opaque
    ``psum_scatter``/``all_gather``; the small inter-pod shard stays a
    plain psum.
    """
    n = buf.shape[0]
    pad = (-n) % intra_size
    if pad:
        buf = jnp.pad(buf, (0, pad))
    mesh_shape = {intra_axis: intra_size}
    # (1) intra-pod reduce-scatter: each device owns 1/intra_size of the sum
    if use_ring:
        shard = ring_reduce_scatter(buf, (intra_axis,), mesh_shape)
    else:
        shard = jax.lax.psum_scatter(
            buf, intra_axis, scatter_dimension=0, tiled=True)
    # (2) inter-pod allreduce of the shard only (1/intra_size of the bytes on DCN)
    shard = jax.lax.psum(shard, inter_axis)
    # (3) intra-pod all-gather to rebuild the full reduced buffer
    if use_ring:
        full = ring_all_gather(shard, (intra_axis,), mesh_shape)
    else:
        full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
    return full[:n] if pad else full


def flat_allreduce(buf: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """Single-stage allreduce over all axes (the paper-faithful primitive)."""
    if not axes:
        return buf
    return jax.lax.psum(buf, axes)
