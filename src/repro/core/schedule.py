"""CommSchedule IR: the dependency structure handed to the scheduler,
as inspectable data (DESIGN.md §4).

The paper's three designs (Funneled / Concurrent / Dependency-chained)
differ ONLY in which collective waits on which — previously that
structure existed implicitly as Python control flow inside ``sync_grads``.
Here it is a first-class value:

  ``CollectiveOp``  — one collective: a bucket, the chain it rides, the
                      ops it depends on, its kind (allreduce or one half
                      of a reduce-scatter→all-gather pair) and an
                      optional reducer tag.
  ``CommSchedule``  — a topologically-ordered tuple of ops, with chain /
                      ordering accessors so schedule properties (chain
                      count, chain length, bucket order) are assertable
                      in microseconds without compiling HLO.
  ``execute``       — the ONE emitter: walks the ops and turns each into
                      a gated collective via ``emit_gated``.  All token
                      gating / psum emission in the repo flows through
                      here — strategies are pure planners and never
                      touch tokens.

The MXNET analogy (DESIGN.md §2): an op's ``depends_on`` edges are the
engine's read-tags, the token update after each collective is the write
to the dummy variable, and a *chain* is the paper's per-communicator
serialization.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import dependency as dep
from repro.core.buckets import Bucket, BucketPlan, pack, unpack
from repro.kernels.collectives import ops as coll_ops

Reducer = Callable[[jax.Array, Bucket], jax.Array]

# op kinds
ALLREDUCE = "allreduce"
REDUCE_SCATTER = "reduce_scatter"
ALL_GATHER = "all_gather"


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One staged collective in the schedule."""

    op_id: int
    bucket: Bucket
    chain: int                          # which dependency chain it rides
    depends_on: tuple[int, ...] = ()    # op_ids that must complete first
    kind: str = ALLREDUCE
    reducer: str = ""                   # registered reducer tag; "" = default


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Topologically ordered collective ops (op i may only depend on j<i)."""

    ops: tuple[CollectiveOp, ...]

    def chains(self) -> dict[int, list[CollectiveOp]]:
        out: dict[int, list[CollectiveOp]] = {}
        for op in self.ops:
            out.setdefault(op.chain, []).append(op)
        return out

    @property
    def num_chains(self) -> int:
        return len({op.chain for op in self.ops})

    def chain_lengths(self) -> dict[int, int]:
        return {ch: len(ops) for ch, ops in self.chains().items()}

    def bucket_order(self, chain: int | None = None) -> tuple[int, ...]:
        """bucket_ids in emission order (optionally for one chain),
        counting each reduce-scatter/all-gather pair once (at the RS)."""
        return tuple(
            op.bucket.bucket_id for op in self.ops
            if op.kind != ALL_GATHER
            and (chain is None or op.chain == chain))

    def leaf_names(self) -> frozenset[str]:
        return frozenset(
            l.name for op in self.ops for l in op.bucket.leaves)

    def comm_bytes(self, itemsize: int = 4) -> int:
        """Total payload bytes moved (RS/AG pairs counted once — they move
        one bucket between them)."""
        return sum(op.bucket.size * itemsize for op in self.ops
                   if op.kind != ALL_GATHER)

    def chain_bytes(self, itemsize: int = 4) -> dict[int, int]:
        """Payload bytes per dependency chain (the simulator's unit of
        serialization; also what a per-channel bandwidth budget sees)."""
        out: dict[int, int] = {}
        for op in self.ops:
            if op.kind == ALL_GATHER:
                continue
            out[op.chain] = out.get(op.chain, 0) + op.bucket.size * itemsize
        return out

    def axes_used(self) -> frozenset[tuple[str, ...]]:
        """Distinct reduction-axis groups (the communicators involved)."""
        return frozenset(op.bucket.reduce_axes for op in self.ops)

    def stats(self) -> dict[str, Any]:
        lengths = self.chain_lengths()
        kinds: dict[str, int] = {}
        for op in self.ops:
            kinds[op.kind] = kinds.get(op.kind, 0) + 1
        return {
            "num_ops": len(self.ops),
            "num_chains": self.num_chains,
            "max_chain_len": max(lengths.values()) if lengths else 0,
            "kinds": kinds,
        }

    def validate(self) -> "CommSchedule":
        """Check op_id uniqueness and topological order; returns self so
        planners can end with ``return CommSchedule(ops).validate()``."""
        seen: set[int] = set()
        for op in self.ops:
            if op.op_id in seen:
                raise ValueError(f"duplicate op_id {op.op_id}")
            for d in op.depends_on:
                if d not in seen:
                    raise ValueError(
                        f"op {op.op_id} depends on {d}, which does not "
                        f"precede it (schedule must be topologically "
                        f"ordered)")
            if op.kind not in (ALLREDUCE, REDUCE_SCATTER, ALL_GATHER):
                raise ValueError(f"op {op.op_id}: unknown kind {op.kind!r}")
            seen.add(op.op_id)
        return self


def group_size(axes: tuple[str, ...], mesh_shape: Mapping[str, int]) -> int:
    """Devices participating in a collective over ``axes`` (the MPI
    communicator size).  Shared by every emitter path — GradSync's
    executor and KVStore alike — so group semantics cannot drift."""
    g = 1
    for a in axes:
        g *= mesh_shape[a]
    return g


def mean_scale(axes: tuple[str, ...], mesh_shape: Mapping[str, int],
               mean_axes: tuple[str, ...]) -> float:
    """1/size over the ``mean_axes`` subset of ``axes`` (data-parallel
    mean; the paper's rescale=1/mini_batch_size lives in the loss when
    ``mean_axes`` is empty)."""
    n = 1
    for a in axes:
        if a in mean_axes:
            n *= mesh_shape[a]
    return 1.0 / n


def live_buckets(
    plan: BucketPlan, skip_names: frozenset[str] = frozenset()
) -> list[Bucket]:
    """Buckets in creation order with ``skip_names`` leaves dropped
    (depcha's in-scan leaves were already reduced inside the backward);
    buckets left empty disappear entirely."""
    out: list[Bucket] = []
    for bucket in plan.buckets:
        keep = [l for l in bucket.leaves if l.name not in skip_names]
        if not keep:
            continue
        if len(keep) != len(bucket.leaves):
            bucket = dataclasses.replace(bucket, leaves=tuple(keep))
        out.append(bucket)
    return out


def live_channels(
    plan: BucketPlan, skip_names: frozenset[str] = frozenset()
) -> dict[int, list[Bucket]]:
    """``live_buckets`` grouped by channel (the ConCom communicator)."""
    out: dict[int, list[Bucket]] = {}
    for bucket in live_buckets(plan, skip_names):
        out.setdefault(bucket.channel, []).append(bucket)
    return out


def emit_gated(
    buf: jax.Array, token: jax.Array, reduce_fn: Callable[[jax.Array], Any]
) -> tuple[Any, jax.Array]:
    """THE collective emitter (MXNET engine-thread analogue, DESIGN.md §2).

    Gate ``buf`` on ``token`` (read-dep), run the collective, and return
    (result, next_token) where next_token waits on the result (the write
    to the dummy variable).  Every collective emitted by this repo — the
    strategy executor below and ``KVStore.push/pull`` alike — goes
    through this one function, so the token discipline cannot drift
    between the paper-API and production paths.
    """
    buf = dep.gate(buf, token)
    red = reduce_fn(buf)
    return red, dep.update(token, red)


def _join(tokens: list[jax.Array]) -> jax.Array:
    if not tokens:
        return dep.new_token()
    if len(tokens) == 1:
        return tokens[0]
    return dep.update(dep.new_token(), *tokens)


def execute(
    schedule: CommSchedule,
    grads: Any,
    plan: BucketPlan,
    *,
    reducer: Reducer,
    reducers: Mapping[str, Reducer] | None = None,
    mesh_shape: Mapping[str, int] | None = None,
    mean_axes: tuple[str, ...] = (),
    use_fused_staging: bool = True,
    loss_scale: float = 1.0,
    two_phase_impl: str = "psum",
) -> Any:
    """Materialize a CommSchedule over a gradient pytree.

    ``reducer`` handles untagged allreduce ops; ``reducers`` maps reducer
    tags to alternates.  ``mesh_shape`` is required only when the
    schedule contains reduce-scatter/all-gather ops (group sizes);
    ``mean_axes`` applies the data-parallel mean on that path (allreduce
    reducers carry their own scaling).

    ``use_fused_staging`` stages each bucket through the fused pack /
    unpack kernels (``repro.kernels.collectives``): one pass over HBM
    with the comm-dtype cast and the optional ``loss_scale`` folded in,
    instead of per-leaf ravel+cast+concatenate.  Buckets with non-float
    dtypes fall back to the leafwise ref path automatically.

    ``two_phase_impl`` selects the reduce-scatter/all-gather transport:
    XLA's ``psum_scatter``/``all_gather`` ("psum") or the chunked
    bidirectional ring collectives ("ring").
    """
    if two_phase_impl not in ("psum", "ring"):
        raise ValueError(f"unknown two_phase_impl {two_phase_impl!r}")
    flat_grads = jax.tree_util.tree_leaves(grads)
    assert len(flat_grads) == plan.num_leaves, (
        f"plan built for {plan.num_leaves} leaves, got {len(flat_grads)}")
    flat_out: list[jax.Array | None] = list(flat_grads)
    reducers = dict(reducers or {})
    by_id = {op.op_id: op for op in schedule.ops}

    def fused_ok(bucket: Bucket) -> bool:
        return use_fused_staging and coll_ops.staging_supported(
            (l.dtype for l in bucket.leaves), plan.comm_dtype)

    def stage_in(bucket: Bucket) -> jax.Array:
        """CopyFromTo(g, comm_buf): pack + cast (+ loss-scale), fused."""
        if fused_ok(bucket):
            return coll_ops.fused_pack(
                bucket, flat_grads, plan.comm_dtype, scale=loss_scale)
        if loss_scale != 1.0:
            # the ref impl scales in f32 BEFORE the comm-dtype cast —
            # scaling after would defeat the underflow protection the
            # loss scale exists for (and diverge from the fused path)
            return coll_ops.fused_pack(
                bucket, flat_grads, plan.comm_dtype, scale=loss_scale,
                impl="leafwise")
        return pack(bucket, flat_grads, plan.comm_dtype)

    def stage_out(bucket: Bucket, buf: jax.Array) -> None:
        """CopyFromTo(recv_buf, g): unscale + cast back + scatter, fused."""
        inv = 1.0 / loss_scale
        if fused_ok(bucket):
            coll_ops.fused_unpack(bucket, buf, flat_out, scale=inv)
            return
        if loss_scale != 1.0:
            coll_ops.fused_unpack(bucket, buf, flat_out, scale=inv,
                                  impl="leafwise")
            return
        unpack(bucket, buf, flat_out)

    def group_of(bucket: Bucket) -> int:
        if mesh_shape is None:
            raise ValueError(
                "mesh_shape is required to execute reduce_scatter/"
                "all_gather ops (group size)")
        return group_size(bucket.reduce_axes, mesh_shape)

    def scale_of(bucket: Bucket) -> float:
        if mesh_shape is None:
            return 1.0
        return mean_scale(bucket.reduce_axes, mesh_shape, mean_axes)

    tokens: dict[int, jax.Array] = {}       # op_id -> token after that op
    shards: dict[int, tuple[jax.Array, int]] = {}   # RS op -> (shard, size)

    for op in schedule.ops:
        token = _join([tokens[d] for d in op.depends_on])
        bucket = op.bucket

        if op.kind == ALLREDUCE:
            red = reducers.get(op.reducer, reducer) if op.reducer else reducer
            send_buf = stage_in(bucket)
            recv_buf, tokens[op.op_id] = emit_gated(
                send_buf, token, lambda b, _r=red, _bk=bucket: _r(b, _bk))
            stage_out(bucket, recv_buf)

        elif op.kind == REDUCE_SCATTER:
            group = group_of(bucket)
            send_buf = stage_in(bucket)
            n = send_buf.shape[0]
            if (-n) % group:
                send_buf = jnp.pad(send_buf, (0, (-n) % group))

            def rs(b, _bk=bucket, _g=group):
                if _g == 1:
                    return b
                if two_phase_impl == "ring":
                    return coll_ops.ring_reduce_scatter(
                        b, _bk.reduce_axes, mesh_shape)
                return jax.lax.psum_scatter(
                    b, _bk.reduce_axes, scatter_dimension=0, tiled=True)

            shard, tokens[op.op_id] = emit_gated(send_buf, token, rs)
            shards[op.op_id] = (shard, n)

        elif op.kind == ALL_GATHER:
            # the producing RS is the dep with the SAME bucket — deps may
            # also carry chain-ordering edges to other buckets' ops
            srcs = [d for d in op.depends_on if d in shards
                    and by_id[d].bucket.bucket_id == op.bucket.bucket_id]
            if not srcs:
                raise ValueError(
                    f"all_gather op {op.op_id} has no reduce_scatter dep "
                    f"for bucket {op.bucket.bucket_id}")
            shard, n = shards[srcs[0]]
            group = group_of(bucket)

            def ag(b, _bk=bucket, _g=group):
                if _g == 1:
                    return b
                if two_phase_impl == "ring":
                    return coll_ops.ring_all_gather(
                        b, _bk.reduce_axes, mesh_shape)
                return jax.lax.all_gather(
                    b, _bk.reduce_axes, axis=0, tiled=True)

            full, tokens[op.op_id] = emit_gated(shard, token, ag)
            if full.shape[0] != n:
                full = full[:n]
            s = scale_of(bucket)
            if s != 1.0:
                full = full * s
            stage_out(bucket, full)

        else:
            raise ValueError(f"unknown op kind {op.kind!r}")

    return jax.tree_util.tree_unflatten(plan.treedef, flat_out)
