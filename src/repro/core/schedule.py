"""CommSchedule IR: the dependency structure handed to the scheduler,
as inspectable data (DESIGN.md §4).

The paper's three designs (Funneled / Concurrent / Dependency-chained)
differ ONLY in which collective waits on which — previously that
structure existed implicitly as Python control flow inside ``sync_grads``.
Here it is a first-class value:

  ``CollectiveOp``  — one collective: a bucket, the chain it rides, the
                      ops it depends on, its kind (allreduce or one half
                      of a reduce-scatter→all-gather pair) and an
                      optional reducer tag.
  ``CommSchedule``  — a topologically-ordered tuple of ops, with chain /
                      ordering accessors so schedule properties (chain
                      count, chain length, bucket order) are assertable
                      in microseconds without compiling HLO.
  ``execute``       — the ONE emitter: walks the ops and turns each into
                      a gated collective via ``emit_gated``.  All token
                      gating / psum emission in the repo flows through
                      here — strategies are pure planners and never
                      touch tokens.

The MXNET analogy (DESIGN.md §2): an op's ``depends_on`` edges are the
engine's read-tags, the token update after each collective is the write
to the dummy variable, and a *chain* is the paper's per-communicator
serialization.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dependency as dep
from repro.core.buckets import Bucket, BucketPlan, pack, unpack
from repro.kernels.collectives import ops as coll_ops

Reducer = Callable[[jax.Array, Bucket], jax.Array]

# op kinds
ALLREDUCE = "allreduce"
REDUCE_SCATTER = "reduce_scatter"
ALL_GATHER = "all_gather"
# full-step (StepProgram) kinds — the training step beyond the gradient
# sync, as schedulable nodes (DESIGN.md §9):
UPDATE = "update"    # sharded optimizer update of one bucket's RS shard
NORM = "norm"        # scalar psum of local squared grad norms (clipping)
# elastic (repro.elastic, DESIGN.md §13) kinds — live mesh transitions as
# schedulable nodes:
RESHARD = "reshard"  # move one state bucket across a mesh transition:
#                      gather side (old mesh, shard arrives via ``pending``)
#                      materializes the global view; scatter side (new
#                      mesh) re-slices it into the new dp shards
REGROUP = "regroup"  # the MXNET-MPI group-rebuild barrier: a scalar psum
#                      joining every old-mesh chain before new-mesh ops
# serving (repro.runtime, DESIGN.md §14) kind — decode-time compute as a
# schedulable node, so decode plans (per-layer DECODE → tp psum ALLREDUCE,
# sampler ALL_GATHER) rank through the same sim as training plans:
DECODE = "decode"    # local decode math for one layer group / the lm_head:
#                      no wire payload (its tp collectives are explicit
#                      ALLREDUCE/ALL_GATHER ops downstream); the sim costs
#                      it as an HBM pass over the node's local param bytes
# pipeline-parallel (DESIGN.md §15) kinds — one stage boundary crossing is
# a matched SEND/RECV pair over the "stage" axis.  Under SPMD the wire
# move is a single ppermute every stage rank issues; the pair is two
# schedule nodes so each side carries its own deps (sender's compute,
# receiver's readiness) and its own token.  Pairing is by bucket_id: a
# SEND and the RECV with the same bucket_id are the two halves of one
# transfer, and the RECV must list its SEND in ``depends_on`` (the data
# edge the payload rides).  ``CollectiveOp.shift`` is the ppermute hop:
# +1 moves payload to the next stage (forward activations), -1 to the
# previous stage (backward cotangents).
SEND = "send"        # pack the boundary payload, park it for the pair
RECV = "recv"        # execute the ppermute hop, deliver into the leaves

KINDS = (ALLREDUCE, REDUCE_SCATTER, ALL_GATHER, UPDATE, NORM,
         RESHARD, REGROUP, DECODE, SEND, RECV)
# kinds that move a bucket's payload over the wire exactly once (RS/AG
# pairs are counted at the RS; SEND/RECV pairs at the SEND; UPDATE is
# local math, NORM a scalar)
_WIRE_KINDS = (ALLREDUCE, REDUCE_SCATTER)
_PAYLOAD_KINDS = _WIRE_KINDS + (SEND,)

# execution phases (pipelined StepProgram, DESIGN.md §10): POST ops run
# after this step's backward produced their inputs; PRE ops are DEFERRED
# — they consume state carried from the previous step and execute at the
# top of the NEXT step, overlapping its forward (the ZeRO-1 all-gathers
# of already-computed update shards are the canonical case)
POST = "post"
PRE = "pre"
PHASES = (POST, PRE)


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One staged collective in the schedule."""

    op_id: int
    bucket: Bucket
    chain: int                          # which dependency chain it rides
    depends_on: tuple[int, ...] = ()    # op_ids that must complete first
    kind: str = ALLREDUCE
    reducer: str = ""                   # registered reducer tag; "" = default
    phase: str = POST                   # POST (same step) | PRE (next step)
    shift: int = 1                      # SEND/RECV only: ppermute hop along
    #                                     the stage axis (+1 next, -1 prev)


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Topologically ordered collective ops (op i may only depend on j<i)."""

    ops: tuple[CollectiveOp, ...]

    def chains(self) -> dict[int, list[CollectiveOp]]:
        out: dict[int, list[CollectiveOp]] = {}
        for op in self.ops:
            out.setdefault(op.chain, []).append(op)
        return out

    @property
    def num_chains(self) -> int:
        return len({op.chain for op in self.ops})

    def chain_lengths(self) -> dict[int, int]:
        return {ch: len(ops) for ch, ops in self.chains().items()}

    def bucket_order(self, chain: int | None = None) -> tuple[int, ...]:
        """bucket_ids in emission order (optionally for one chain),
        counting each reduce-scatter/all-gather pair once (at the RS)."""
        return tuple(
            op.bucket.bucket_id for op in self.ops
            if op.kind in _WIRE_KINDS
            and (chain is None or op.chain == chain))

    def leaf_names(self) -> frozenset[str]:
        return frozenset(
            l.name for op in self.ops for l in op.bucket.leaves)

    def comm_bytes(self, itemsize: int = 4) -> int:
        """Total payload bytes moved (RS/AG pairs counted once — they move
        one bucket between them; SEND/RECV pairs once at the SEND;
        UPDATE/NORM ops move no payload)."""
        return sum(op.bucket.size * itemsize for op in self.ops
                   if op.kind in _PAYLOAD_KINDS)

    def chain_bytes(self, itemsize: int = 4) -> dict[int, int]:
        """Payload bytes per dependency chain (the simulator's unit of
        serialization; also what a per-channel bandwidth budget sees)."""
        out: dict[int, int] = {}
        for op in self.ops:
            if op.kind not in _PAYLOAD_KINDS:
                continue
            out[op.chain] = out.get(op.chain, 0) + op.bucket.size * itemsize
        return out

    def axes_used(self) -> frozenset[tuple[str, ...]]:
        """Distinct reduction-axis groups (the communicators involved)."""
        return frozenset(op.bucket.reduce_axes for op in self.ops)

    def phase_counts(self) -> dict[str, int]:
        """Op count per execution phase ({"post": n} for plain schedules,
        {"post": n, "pre": m} once all-gathers were deferred)."""
        out: dict[str, int] = {}
        for op in self.ops:
            out[op.phase] = out.get(op.phase, 0) + 1
        return out

    def deferred_bytes(self, itemsize: int = 4) -> int:
        """Payload bytes whose materialization crosses the step boundary
        (the PRE ops' buckets — what the next step's forward must gather
        before it can read those params)."""
        return sum(
            op.bucket.size * (np_itemsize(op.bucket.comm_dtype, itemsize))
            for op in self.ops if op.phase == PRE)

    def split_phases(self) -> tuple["CommSchedule", "CommSchedule"]:
        """(post, pre) sub-schedules for pipelined execution.

        POST ops keep their ids and deps — nothing may depend on a PRE
        op inside one step (a deferred op's result only exists NEXT
        step), checked here at planning time.  PRE ops drop every dep
        on a POST op: those producers ran in the PREVIOUS step, and
        their results arrive as carried state (``execute(pending=...)``),
        not as in-schedule edges.
        """
        pre_ids = {op.op_id for op in self.ops if op.phase == PRE}
        for op in self.ops:
            if op.phase != PRE and pre_ids.intersection(op.depends_on):
                raise ValueError(
                    f"post op {op.op_id} depends on deferred (PRE) op(s) "
                    f"{sorted(pre_ids.intersection(op.depends_on))} — a "
                    f"deferred result does not exist until the next step")
        post = tuple(op for op in self.ops if op.phase != PRE)
        pre = tuple(
            dataclasses.replace(
                op, depends_on=tuple(d for d in op.depends_on
                                     if d in pre_ids))
            for op in self.ops if op.phase == PRE)
        return (CommSchedule(post).validate(),
                CommSchedule(pre).validate())

    def split_regroup(self) -> tuple["CommSchedule", "CommSchedule"]:
        """(old, new) sub-schedules of an elastic transition, split at the
        first REGROUP op (which stays on the old side — the barrier runs
        on the mesh being dissolved).

        The two sides execute as SEPARATE programs on DIFFERENT meshes,
        so new-side deps on old-side ops are dropped: those producers'
        results cross the transition as carried host state (the encoded
        global view), not as in-schedule edges — the same rule
        ``split_phases`` applies at the step boundary.
        """
        cut = next((i for i, op in enumerate(self.ops)
                    if op.kind == REGROUP), None)
        if cut is None:
            raise ValueError("split_regroup: schedule has no REGROUP op")
        old = self.ops[:cut + 1]
        old_ids = {op.op_id for op in old}
        new_ids = {op.op_id for op in self.ops[cut + 1:]}
        new = tuple(
            dataclasses.replace(
                op, depends_on=tuple(d for d in op.depends_on
                                     if d in new_ids))
            for op in self.ops[cut + 1:])
        for op in old:
            if not old_ids.issuperset(op.depends_on):
                raise ValueError(
                    f"old-side op {op.op_id} depends on post-regroup "
                    f"op(s) {sorted(set(op.depends_on) - old_ids)}")
        return (CommSchedule(old).validate(),
                CommSchedule(new).validate())

    def stats(self) -> dict[str, Any]:
        lengths = self.chain_lengths()
        kinds: dict[str, int] = {}
        for op in self.ops:
            kinds[op.kind] = kinds.get(op.kind, 0) + 1
        return {
            "num_ops": len(self.ops),
            "num_chains": self.num_chains,
            "max_chain_len": max(lengths.values()) if lengths else 0,
            "kinds": kinds,
            "phases": self.phase_counts(),
        }

    def validate(self) -> "CommSchedule":
        """Structural soundness: op_id uniqueness, no dangling / forward
        chain-dep references, known kinds/phases/bucket indices.

        One implementation shared with the static analyzer — this is
        ``repro.analysis.passes.structural_findings`` (the deadlock
        pass's first stage), so the shallow planner-exit check and the
        full verifier cannot drift.  Returns self so planners can end
        with ``return CommSchedule(ops).validate()``.
        """
        from repro.analysis.passes import structural_findings

        findings = structural_findings(self)
        if findings:
            raise ValueError(findings[0].message)
        return self

    def update_ops(self) -> tuple[CollectiveOp, ...]:
        """The StepProgram's optimizer-update nodes (empty for pure-sync
        schedules)."""
        return tuple(op for op in self.ops if op.kind == UPDATE)


def np_itemsize(dtype: Any, fallback: int) -> int:
    """Wire bytes per element for a bucket's pinned comm dtype (falling
    back to the schedule-level itemsize when the bucket has no pin)."""
    return fallback if dtype is None else np.dtype(dtype).itemsize


def group_size(axes: tuple[str, ...], mesh_shape: Mapping[str, int]) -> int:
    """Devices participating in a collective over ``axes`` (the MPI
    communicator size).  Shared by every emitter path — GradSync's
    executor and KVStore alike — so group semantics cannot drift."""
    g = 1
    for a in axes:
        g *= mesh_shape[a]
    return g


def mean_scale(axes: tuple[str, ...], mesh_shape: Mapping[str, int],
               mean_axes: tuple[str, ...]) -> float:
    """1/size over the ``mean_axes`` subset of ``axes`` (data-parallel
    mean; the paper's rescale=1/mini_batch_size lives in the loss when
    ``mean_axes`` is empty)."""
    n = 1
    for a in axes:
        if a in mean_axes:
            n *= mesh_shape[a]
    return 1.0 / n


def live_buckets(
    plan: BucketPlan, skip_names: frozenset[str] = frozenset()
) -> list[Bucket]:
    """Buckets in creation order with ``skip_names`` leaves dropped
    (depcha's in-scan leaves were already reduced inside the backward);
    buckets left empty disappear entirely."""
    out: list[Bucket] = []
    for bucket in plan.buckets:
        keep = [l for l in bucket.leaves if l.name not in skip_names]
        if not keep:
            continue
        if len(keep) != len(bucket.leaves):
            bucket = dataclasses.replace(bucket, leaves=tuple(keep))
        out.append(bucket)
    return out


def live_channels(
    plan: BucketPlan, skip_names: frozenset[str] = frozenset()
) -> dict[int, list[Bucket]]:
    """``live_buckets`` grouped by channel (the ConCom communicator)."""
    out: dict[int, list[Bucket]] = {}
    for bucket in live_buckets(plan, skip_names):
        out.setdefault(bucket.channel, []).append(bucket)
    return out


def emit_gated(
    buf: jax.Array, token: jax.Array, reduce_fn: Callable[[jax.Array], Any]
) -> tuple[Any, jax.Array]:
    """THE collective emitter (MXNET engine-thread analogue, DESIGN.md §2).

    Gate ``buf`` on ``token`` (read-dep), run the collective, and return
    (result, next_token) where next_token waits on the result (the write
    to the dummy variable).  Every collective emitted by this repo — the
    strategy executor below and ``KVStore.push/pull`` alike — goes
    through this one function, so the token discipline cannot drift
    between the paper-API and production paths.
    """
    buf = dep.gate(buf, token)
    red = reduce_fn(buf)
    return red, dep.update(token, red)


def _join(tokens: list[jax.Array]) -> jax.Array:
    if not tokens:
        return dep.new_token()
    if len(tokens) == 1:
        return tokens[0]
    return dep.update(dep.new_token(), *tokens)


def op_scope_name(op: CollectiveOp) -> str:
    """XLA named-scope / profiler annotation label for one op.  Shared by
    the full emitter and the per-op measured replay (``repro.obs``) so a
    device profile and a measured Timeline name ops identically."""
    return (f"comm.{op.kind}.b{op.bucket.bucket_id}"
            f".op{op.op_id}.{op.phase}")


class _OpEmitter:
    """Per-op emission engine behind ``execute``.

    Holds the cross-op state a schedule threads between ops — completion
    tokens, reduce-scatter/update shards, NORM clip multipliers — and
    emits ONE op at a time into a flat leaf list.  ``execute`` drives it
    over a whole schedule inside one traced program; the measured replay
    (``repro.obs.measure``) drives the same emitter one op per jitted
    dispatch, injecting the carried state explicitly, so the profiled
    path cannot drift from the production path.
    """

    def __init__(
        self,
        schedule: CommSchedule,
        plan: BucketPlan,
        *,
        reducer: Reducer,
        reducers: Mapping[str, Reducer] | None = None,
        mesh_shape: Mapping[str, int] | None = None,
        mean_axes: tuple[str, ...] = (),
        use_fused_staging: bool = True,
        loss_scale: float = 1.0,
        two_phase_impl: str = "psum",
        update_fn: Callable[[CollectiveOp, jax.Array], jax.Array] | None = None,
        clip_norm: float = 0.0,
        aux: dict | None = None,
        pending: Mapping[int, jax.Array] | None = None,
    ):
        if two_phase_impl not in ("psum", "ring"):
            raise ValueError(f"unknown two_phase_impl {two_phase_impl!r}")
        self.plan = plan
        self.reducer = reducer
        self.reducers = dict(reducers or {})
        self.mesh_shape = mesh_shape
        self.mean_axes = mean_axes
        self.use_fused_staging = use_fused_staging
        self.loss_scale = loss_scale
        self.two_phase_impl = two_phase_impl
        self.update_fn = update_fn
        self.clip_norm = clip_norm
        self.aux = aux
        self.pending = pending
        self.by_id = {op.op_id: op for op in schedule.ops}
        # carried state (op_id-keyed); the replay swaps these between
        # per-op dispatches
        self.tokens: dict[int, jax.Array] = {}
        self.shards: dict[int, tuple[jax.Array, int]] = {}
        self.clip_scales: dict[int, jax.Array] = {}

    # -- staging helpers ---------------------------------------------

    def _dtype_of(self, bucket: Bucket):
        return (bucket.comm_dtype if bucket.comm_dtype is not None
                else self.plan.comm_dtype)

    def _fused_ok(self, bucket: Bucket) -> bool:
        return self.use_fused_staging and coll_ops.staging_supported(
            (l.dtype for l in bucket.leaves), self._dtype_of(bucket))

    def _stage_in(self, bucket: Bucket, flat_out: list) -> jax.Array:
        """CopyFromTo(g, comm_buf): pack + cast (+ loss-scale), fused."""
        if self._fused_ok(bucket):
            return coll_ops.fused_pack(
                bucket, flat_out, self._dtype_of(bucket),
                scale=self.loss_scale)
        if self.loss_scale != 1.0:
            # the ref impl scales in f32 BEFORE the comm-dtype cast —
            # scaling after would defeat the underflow protection the
            # loss scale exists for (and diverge from the fused path)
            return coll_ops.fused_pack(
                bucket, flat_out, self._dtype_of(bucket),
                scale=self.loss_scale, impl="leafwise")
        return pack(bucket, flat_out, self._dtype_of(bucket))

    def _stage_out(self, bucket: Bucket, buf: jax.Array,
                   inv_scale: float, flat_out: list) -> None:
        """CopyFromTo(recv_buf, g): unscale + cast back + scatter, fused."""
        if self._fused_ok(bucket):
            coll_ops.fused_unpack(bucket, buf, flat_out, scale=inv_scale)
            return
        if inv_scale != 1.0:
            coll_ops.fused_unpack(bucket, buf, flat_out, scale=inv_scale,
                                  impl="leafwise")
            return
        unpack(bucket, buf, flat_out)

    def _group_of(self, bucket: Bucket) -> int:
        if self.mesh_shape is None:
            raise ValueError(
                "mesh_shape is required to execute reduce_scatter/"
                "all_gather ops (group size)")
        return group_size(bucket.reduce_axes, self.mesh_shape)

    def _scale_of(self, bucket: Bucket) -> float:
        if self.mesh_shape is None:
            return 1.0
        return mean_scale(bucket.reduce_axes, self.mesh_shape,
                          self.mean_axes)

    def _shard_src(self, op: CollectiveOp, want: str,
                   optional: bool = False) -> int | None:
        """The dep producing this op's same-bucket shard — deps may also
        carry chain-ordering edges to other buckets' ops.  ``optional``
        returns None instead of raising (a deferred gather whose shard
        arrives via ``pending`` has no in-schedule producer)."""
        srcs = [d for d in op.depends_on if d in self.shards
                and self.by_id[d].bucket.bucket_id == op.bucket.bucket_id]
        if not srcs:
            if optional:
                return None
            raise ValueError(
                f"{op.kind} op {op.op_id} has no {want} dep for "
                f"bucket {op.bucket.bucket_id}")
        return srcs[0]

    # -- the per-op body ---------------------------------------------

    def emit(self, op: CollectiveOp, flat_out: list) -> None:
        """Emit one op, reading/writing leaves in ``flat_out`` and the
        carried token/shard/clip state on self.  Deps whose tokens are
        absent gate on a fresh token: in full execution every dep's
        token exists (topological order); in the per-op replay the dep
        already completed in an earlier dispatch, so its data edge is
        the real array handed in and no token is needed."""
        token = _join([self.tokens[d] for d in op.depends_on
                       if d in self.tokens])
        bucket = op.bucket
        mesh_shape = self.mesh_shape
        two_phase_impl = self.two_phase_impl

        if op.kind == ALLREDUCE:
            red = (self.reducers.get(op.reducer, self.reducer)
                   if op.reducer else self.reducer)
            send_buf = self._stage_in(bucket, flat_out)
            recv_buf, self.tokens[op.op_id] = emit_gated(
                send_buf, token, lambda b, _r=red, _bk=bucket: _r(b, _bk))
            self._stage_out(bucket, recv_buf, 1.0 / self.loss_scale,
                            flat_out)

        elif op.kind == REDUCE_SCATTER:
            group = self._group_of(bucket)
            send_buf = self._stage_in(bucket, flat_out)
            n = send_buf.shape[0]
            if (-n) % group:
                send_buf = jnp.pad(send_buf, (0, (-n) % group))

            def rs(b, _bk=bucket, _g=group):
                if _g == 1:
                    return b
                if two_phase_impl == "ring":
                    return coll_ops.ring_reduce_scatter(
                        b, _bk.reduce_axes, mesh_shape)
                return jax.lax.psum_scatter(
                    b, _bk.reduce_axes, scatter_dimension=0, tiled=True)

            shard, self.tokens[op.op_id] = emit_gated(send_buf, token, rs)
            self.shards[op.op_id] = (shard, n)

        elif op.kind == NORM:
            # local sum of squares over every producing RS shard (each
            # gradient element lives in exactly one shard across the
            # reduce group, so the psum is the true global squared norm).
            # The shards are still loss-scaled and pre-mean (UPDATE folds
            # scale_of/loss_scale in later) — undo both here so the norm
            # and the clip threshold see the TRUE gradients.
            sq = jnp.float32(0.0)
            for d in op.depends_on:
                if d in self.shards and self.by_id[d].kind == REDUCE_SCATTER:
                    s, _ = self.shards[d]
                    g_scale = (self._scale_of(self.by_id[d].bucket)
                               / self.loss_scale)
                    sq = sq + g_scale * g_scale * jnp.sum(
                        jnp.square(s.astype(jnp.float32)))
            red, self.tokens[op.op_id] = emit_gated(
                sq, token,
                lambda v, _ax=bucket.reduce_axes: jax.lax.psum(v, _ax))
            norm = jnp.sqrt(red)
            if self.clip_norm > 0:
                self.clip_scales[op.op_id] = jnp.minimum(
                    1.0, self.clip_norm / (norm + 1e-9))
            if self.aux is not None:
                self.aux["grad_norm"] = norm

        elif op.kind == UPDATE:
            if self.update_fn is None:
                raise ValueError(
                    f"schedule contains UPDATE op {op.op_id} but no "
                    f"update_fn was supplied")
            src = self._shard_src(op, "reduce_scatter")
            g_shard, n = self.shards[src]
            g_shard = g_shard.astype(jnp.float32)
            # dp mean + loss unscale
            s = self._scale_of(bucket) / self.loss_scale
            if s != 1.0:
                g_shard = g_shard * s
            for d in op.depends_on:             # clip on shards, pre-update
                if d in self.clip_scales:
                    g_shard = g_shard * self.clip_scales[d]
            upd, self.tokens[op.op_id] = emit_gated(
                g_shard, token, lambda v, _op=op: self.update_fn(_op, v))
            self.shards[op.op_id] = (upd, n)
            if self.aux is not None:
                self.aux.setdefault(
                    "update_shards", {})[bucket.bucket_id] = upd

        elif op.kind == ALL_GATHER:
            has_pending = (self.pending is not None
                           and bucket.bucket_id in self.pending)
            src = self._shard_src(op, "reduce_scatter", optional=has_pending)
            if src is not None:
                shard, n = self.shards[src]
                gathers_updates = self.by_id[src].kind == UPDATE
            else:
                # PRE program: the shard was produced by LAST step's
                # UPDATE op and carried across the boundary — always an
                # update shard (dp mean + loss unscale already applied)
                shard, n = self.pending[bucket.bucket_id], bucket.size
                gathers_updates = True
            group = self._group_of(bucket)

            def ag(b, _bk=bucket, _g=group):
                if _g == 1:
                    return b
                if two_phase_impl == "ring":
                    return coll_ops.ring_all_gather(
                        b, _bk.reduce_axes, mesh_shape)
                return jax.lax.all_gather(
                    b, _bk.reduce_axes, axis=0, tiled=True)

            full, self.tokens[op.op_id] = emit_gated(shard, token, ag)
            if full.shape[0] != n:
                full = full[:n]
            if gathers_updates:
                # gathering optimizer updates: the dp mean and loss
                # unscale were already applied to the grad shard
                self._stage_out(bucket, full, 1.0, flat_out)
            else:
                s = self._scale_of(bucket)
                if s != 1.0:
                    full = full * s
                self._stage_out(bucket, full, 1.0 / self.loss_scale,
                                flat_out)

        elif op.kind == RESHARD:
            # Elastic state movement (DESIGN.md §13).  One op kind, two
            # sides, disambiguated exactly like deferred gathers: a shard
            # in ``pending`` marks the GATHER side (old mesh — rebuild the
            # bucket's global view from this rank's dp shard); no pending
            # entry marks the SCATTER side (new mesh — pack the global
            # leaves and slice this rank's new dp shard).
            group = self._group_of(bucket)
            if self.pending is not None and bucket.bucket_id in self.pending:
                shard, n = self.pending[bucket.bucket_id], bucket.size

                def rag(b, _bk=bucket, _g=group):
                    if _g == 1:
                        return b
                    if two_phase_impl == "ring":
                        return coll_ops.ring_all_gather(
                            b, _bk.reduce_axes, mesh_shape)
                    return jax.lax.all_gather(
                        b, _bk.reduce_axes, axis=0, tiled=True)

                full, self.tokens[op.op_id] = emit_gated(shard, token, rag)
                if full.shape[0] != n:
                    full = full[:n]
                # state values, never gradients: no dp mean, no loss scale
                self._stage_out(bucket, full, 1.0, flat_out)
            else:
                buf = self._stage_in(bucket, flat_out)
                n = buf.shape[0]
                if (-n) % group:
                    buf = jnp.pad(buf, (0, (-n) % group))
                n_shard = buf.shape[0] // group
                axes = bucket.reduce_axes
                idx = jax.lax.axis_index(
                    axes if len(axes) > 1 else axes[0])
                shard = jax.lax.dynamic_slice_in_dim(
                    buf, idx * n_shard, n_shard, 0)
                self.tokens[op.op_id] = dep.update(token, shard)
                self.shards[op.op_id] = (shard, n)
                if self.aux is not None:
                    self.aux.setdefault(
                        "reshard_shards", {})[bucket.bucket_id] = shard

        elif op.kind == REGROUP:
            # the group-rebuild barrier: a scalar psum every member of the
            # dissolving communicator joins — the MXNET-MPI regroup moment
            done, self.tokens[op.op_id] = emit_gated(
                jnp.float32(1.0), token,
                lambda v, _ax=bucket.reduce_axes: jax.lax.psum(v, _ax))
            if self.aux is not None:
                self.aux["regroup_done"] = done

        elif op.kind == DECODE:
            # local decode compute placeholder: the serving engine runs the
            # real math (repro.runtime.serve_loop); in a traced program the
            # node is a pure scheduling point — gate on deps, advance the
            # token — so decode plans execute/replay without special-casing
            done, self.tokens[op.op_id] = emit_gated(
                jnp.float32(1.0), token, lambda v: v)
            if self.aux is not None:
                self.aux.setdefault("decode_nodes", []).append(
                    op.bucket.bucket_id)

        elif op.kind == SEND:
            # Pipeline boundary, sender half (DESIGN.md §15): pack the
            # payload and park it for the matched RECV.  The wire move is
            # the RECV's ppermute — under SPMD that single collective IS
            # both halves, so the SEND node contributes the sender-side
            # deps (the producing stage compute) and the staging pass.
            buf = self._stage_in(bucket, flat_out)
            self.tokens[op.op_id] = dep.update(token, buf)
            self.shards[op.op_id] = (buf, buf.shape[0])

        elif op.kind == RECV:
            # receiver half: gate on the matched SEND (the same-bucket
            # dep) plus the receiver-side readiness deps, execute the
            # ppermute hop, and deliver the payload into the leaves.
            if len(bucket.reduce_axes) != 1:
                raise ValueError(
                    f"recv op {op.op_id}: SEND/RECV ride exactly one "
                    f"stage axis, got {bucket.reduce_axes!r}")
            src = self._shard_src(op, "send")
            buf, _n = self.shards[src]
            axis = bucket.reduce_axes[0]
            group = self._group_of(bucket)
            perm = [(i, (i + op.shift) % group) for i in range(group)]

            def hop(b, _ax=axis, _perm=perm, _g=group):
                if _g == 1:
                    return b
                return jax.lax.ppermute(b, _ax, _perm)

            shifted, self.tokens[op.op_id] = emit_gated(buf, token, hop)
            self._stage_out(bucket, shifted, 1.0 / self.loss_scale,
                            flat_out)

        else:
            raise ValueError(f"unknown op kind {op.kind!r}")


def execute(
    schedule: CommSchedule,
    grads: Any,
    plan: BucketPlan,
    *,
    reducer: Reducer,
    reducers: Mapping[str, Reducer] | None = None,
    mesh_shape: Mapping[str, int] | None = None,
    mean_axes: tuple[str, ...] = (),
    use_fused_staging: bool = True,
    loss_scale: float = 1.0,
    two_phase_impl: str = "psum",
    update_fn: Callable[[CollectiveOp, jax.Array], jax.Array] | None = None,
    clip_norm: float = 0.0,
    aux: dict | None = None,
    pending: Mapping[int, jax.Array] | None = None,
) -> Any:
    """Materialize a CommSchedule over a gradient pytree.

    ``reducer`` handles untagged allreduce ops; ``reducers`` maps reducer
    tags to alternates.  ``mesh_shape`` is required only when the
    schedule contains reduce-scatter/all-gather ops (group sizes);
    ``mean_axes`` applies the data-parallel mean on that path (allreduce
    reducers carry their own scaling).

    ``use_fused_staging`` stages each bucket through the fused pack /
    unpack kernels (``repro.kernels.collectives``): one pass over HBM
    with the comm-dtype cast and the optional ``loss_scale`` folded in,
    instead of per-leaf ravel+cast+concatenate.  Buckets with non-float
    dtypes fall back to the leafwise ref path automatically.

    ``two_phase_impl`` selects the reduce-scatter/all-gather transport:
    XLA's ``psum_scatter``/``all_gather`` ("psum") or the chunked
    bidirectional ring collectives ("ring").

    Full-step (StepProgram, DESIGN.md §9) ops:
      UPDATE — ``update_fn(op, g_shard) -> upd_shard`` runs the sharded
        optimizer math on the producing reduce-scatter's shard (the
        data-parallel mean from ``mean_axes`` and the inverse loss scale
        are applied to the shard first); the following ALL_GATHER then
        carries *updates*, not gradients.
      NORM — psums the squared norm of every producing RS shard over the
        op's reduce axes; with ``clip_norm > 0`` dependent UPDATE ops
        see their grad shards clipped by the global norm.  The norm
        lands in ``aux["grad_norm"]`` when ``aux`` is given.

    Ops read leaves from the CURRENT flat output list, so an op whose
    bucket shares leaves with an earlier op (ZeRO-1's dp reduce-scatter
    after the model-axis sync) consumes the earlier op's result —
    provided the schedule carries the dependency edge.

    Pipelined (phase-split) execution (DESIGN.md §10):
      ``pending`` maps bucket_id → the update shard CARRIED from the
        previous step.  An ALL_GATHER with no in-schedule shard producer
        reads its shard from there (and, being an update shard, skips
        the dp-mean/loss-unscale that gradient gathers apply) — this is
        how a PRE program materializes last step's deferred gathers.
      UPDATE ops record their output shard in ``aux["update_shards"]``
        (bucket_id-keyed) when ``aux`` is given, so a POST program with
        deferred all-gathers can hand the shards to the next step.

    Each op is emitted under a ``jax.named_scope`` (``op_scope_name``) so
    device profiles attribute time to IR ops; the opt-in per-op measured
    replay lives in ``repro.obs.measure`` and drives the same emitter.
    """
    flat_out: list[jax.Array] = list(jax.tree_util.tree_leaves(grads))
    assert len(flat_out) == plan.num_leaves, (
        f"plan built for {plan.num_leaves} leaves, got {len(flat_out)}")
    em = _OpEmitter(
        schedule, plan, reducer=reducer, reducers=reducers,
        mesh_shape=mesh_shape, mean_axes=mean_axes,
        use_fused_staging=use_fused_staging, loss_scale=loss_scale,
        two_phase_impl=two_phase_impl, update_fn=update_fn,
        clip_norm=clip_norm, aux=aux, pending=pending)
    for op in schedule.ops:
        with jax.named_scope(op_scope_name(op)):
            em.emit(op, flat_out)
    return jax.tree_util.tree_unflatten(plan.treedef, flat_out)
