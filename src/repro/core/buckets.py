"""Gradient bucketing: KVStore keys → communication buffers.

The paper allreduces one tensor per KVStore key from a dedicated
``comm_buf`` (Figs 6, 9, 11).  We generalize the comm buffer to a *bucket*:
a contiguous 1-D staging buffer holding one or more gradient leaves of the
same reduction signature.  Bucket size is a schedule parameter (paper's
per-key granularity == ``bucket_bytes=0``); hashing buckets to channels
reproduces ConCom's key→communicator hash.

Leaves are grouped by their *reduction signature* — the tuple of mesh axes
their gradient must be psum'd over (``missing_axes`` of the param spec) —
because a single collective can only serve leaves that reduce over the same
axis group (the MPI analogue: one communicator per process group).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import missing_axes
from repro.utils.trees import flatten_with_names


@dataclasses.dataclass(frozen=True)
class LeafInfo:
    name: str
    index: int          # position in the flat gradient list
    shape: tuple[int, ...]
    dtype: Any
    size: int           # elements


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One communication buffer: a set of leaves reduced by one collective."""

    leaves: tuple[LeafInfo, ...]
    reduce_axes: tuple[str, ...]   # mesh axes of the psum (the "communicator")
    channel: int                   # ConCom: which communicator chain
    bucket_id: int
    # per-bucket wire dtype override (None = the plan's comm_dtype).  The
    # ZeRO-1 StepProgram buckets pin f32 so the shard-update math matches
    # the monolithic optimizer bit-for-bit even under a bf16 sync wire.
    comm_dtype: Any = None

    @property
    def size(self) -> int:
        return sum(l.size for l in self.leaves)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(l.name for l in self.leaves)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: tuple[Bucket, ...]
    treedef: Any
    num_leaves: int
    comm_dtype: Any

    @property
    def total_bytes(self) -> int:
        return sum(b.size for b in self.buckets) * np.dtype(self.comm_dtype).itemsize

    def channels(self) -> dict[int, list[Bucket]]:
        out: dict[int, list[Bucket]] = {}
        for b in self.buckets:
            out.setdefault(b.channel, []).append(b)
        return out


# plans are pure functions of shapes/specs/mesh-topology + knobs, and
# launchers/benchmarks/sim rebuild identical plans every call — memoize.
# FIFO-bounded so long sweeps (grid_search over bucket sizes) can't grow
# the cache without bound.
_PLAN_CACHE: dict[tuple, BucketPlan] = {}
_PLAN_CACHE_MAX = 256


def clear_bucket_plan_cache() -> None:
    _PLAN_CACHE.clear()


def _plan_cache_key(grads_like, param_specs, mesh, bucket_bytes,
                    num_channels, comm_dtype, reverse, exclude_axes):
    """Everything the plan depends on: leaf shapes/dtypes + tree
    structure, the param specs, the mesh topology, and the knobs.
    Returns None (uncacheable) for leaves without shape/dtype."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_like)
    sig = []
    for leaf in leaves:
        if not (hasattr(leaf, "shape") and hasattr(leaf, "dtype")):
            return None
        # .name, not .str: custom ml_dtypes (float8 variants, bfloat16)
        # all stringify to '<V1'/'<V2' under .str and would collide
        sig.append((tuple(leaf.shape), np.dtype(leaf.dtype).name))
    spec_leaves = tuple(jax.tree_util.tree_leaves(
        param_specs, is_leaf=lambda x: x is None))
    mesh_key = (tuple(mesh.axis_names), tuple(mesh.devices.shape)) \
        if hasattr(mesh, "devices") else tuple(sorted(dict(mesh.shape).items()))
    return (treedef, tuple(sig), spec_leaves, mesh_key, int(bucket_bytes),
            int(num_channels), np.dtype(comm_dtype).name, bool(reverse),
            tuple(exclude_axes))


def make_bucket_plan(
    grads_like: Any,
    param_specs: Any,
    mesh,
    *,
    bucket_bytes: int = 4 * 1024 * 1024,
    num_channels: int = 1,
    comm_dtype=jnp.float32,
    reverse: bool = True,
    exclude_axes: tuple[str, ...] = (),
) -> BucketPlan:
    """Build a bucket plan for a gradient pytree.

    Args:
      grads_like: pytree of arrays or ShapeDtypeStructs (gradient shapes).
      param_specs: matching pytree of PartitionSpecs for the *params*.
      mesh: the device mesh (for axis names/sizes).
      bucket_bytes: max staging-buffer size; 0 → one bucket per leaf
        (the paper's per-key granularity).
      num_channels: ConCom communicator count; buckets are round-robin
        hashed to channels (paper: ``key % num_comms``).
      reverse: bucket in reverse key order — gradients become ready
        back-to-front during backprop, so reverse order lets early buckets
        fill first (overlap-friendly; the paper iterates keys in order
        because MXNET orders keys input→output, ready order is reversed).
      exclude_axes: mesh axes some other mechanism reduces (e.g. ZeRO-1's
        reduce-scatter covers the DP axes) — dropped from reduce sets.

    Memoized on (treedef, leaf shapes/dtypes, specs, mesh topology,
    bucket_bytes, num_channels, comm_dtype, reverse, exclude_axes):
    repeated calls return the SAME BucketPlan object.
    """
    key = _plan_cache_key(grads_like, param_specs, mesh, bucket_bytes,
                          num_channels, comm_dtype, reverse, exclude_axes)
    if key is not None:
        try:
            return _PLAN_CACHE[key]
        except (KeyError, TypeError):
            pass

    named, treedef = flatten_with_names(grads_like)
    specs_named, _ = flatten_with_names(param_specs)
    itemsize = np.dtype(comm_dtype).itemsize

    infos: list[tuple[LeafInfo, tuple[str, ...]]] = []
    for i, ((name, leaf), (_, spec)) in enumerate(zip(named, specs_named)):
        axes = missing_axes(spec, mesh)
        if exclude_axes:
            axes = tuple(a for a in axes if a not in exclude_axes)
        if not axes:
            continue   # nothing to reduce — leaf passes through sync
        info = LeafInfo(
            name=name,
            index=i,
            shape=tuple(leaf.shape),
            dtype=leaf.dtype,
            size=int(np.prod(leaf.shape)) if leaf.shape else 1,
        )
        infos.append((info, axes))

    if reverse:
        infos = infos[::-1]

    # group by reduction signature, then fill size-capped buckets in order
    buckets: list[Bucket] = []
    by_axes: dict[tuple[str, ...], list[LeafInfo]] = {}
    order: list[tuple[str, ...]] = []
    for info, axes in infos:
        if axes not in by_axes:
            by_axes[axes] = []
            order.append(axes)
        by_axes[axes].append(info)

    bid = 0
    for axes in order:
        cur: list[LeafInfo] = []
        cur_bytes = 0
        for info in by_axes[axes]:
            leaf_bytes = info.size * itemsize
            if cur and bucket_bytes and cur_bytes + leaf_bytes > bucket_bytes:
                buckets.append(
                    Bucket(tuple(cur), axes, bid % num_channels, bid)
                )
                bid += 1
                cur, cur_bytes = [], 0
            cur.append(info)
            cur_bytes += leaf_bytes
            if bucket_bytes == 0 and cur:
                buckets.append(
                    Bucket(tuple(cur), axes, bid % num_channels, bid)
                )
                bid += 1
                cur, cur_bytes = [], 0
        if cur:
            buckets.append(Bucket(tuple(cur), axes, bid % num_channels, bid))
            bid += 1

    plan = BucketPlan(
        buckets=tuple(buckets),
        treedef=treedef,
        num_leaves=len(named),
        comm_dtype=comm_dtype,
    )
    if key is not None:
        try:
            if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
                _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
            _PLAN_CACHE[key] = plan
        except TypeError:   # unhashable spec leaf — just don't cache
            pass
    return plan


def pack(bucket: Bucket, flat_leaves: Sequence[jax.Array], comm_dtype) -> jax.Array:
    """CopyFromTo(g, send_buf): stage bucket leaves into one 1-D comm buffer."""
    parts = [
        jnp.ravel(flat_leaves[l.index]).astype(comm_dtype) for l in bucket.leaves
    ]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def unpack(
    bucket: Bucket, buf: jax.Array, flat_out: list[jax.Array | None]
) -> None:
    """CopyFromTo(recv_buf, g): split the reduced buffer back into leaves.

    Offsets are compile-time constants, so these are static ``lax.slice``
    ops (dynamic slices block XLA fusion of the cast-back into the
    consumer).  This is the ref path of the fused unpack kernel
    (``repro.kernels.collectives``).
    """
    off = 0
    for l in bucket.leaves:
        piece = jax.lax.slice(buf, (off,), (off + l.size,))
        flat_out[l.index] = piece.reshape(l.shape).astype(l.dtype)
        off += l.size
