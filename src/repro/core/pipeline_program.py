"""Pipeline-parallel schedules as CommSchedule programs (DESIGN.md §15).

The "stage" mesh axis becomes IR territory: one stage-boundary crossing
is a matched SEND/RECV pair (``schedule.SEND``/``schedule.RECV``), and a
whole pipeline schedule — which microbatch each stage forwards or
backwards, in what order — is a CommSchedule whose per-device dependency
chains encode the slot order and whose cross-chain SEND→RECV edges carry
the activations (shift +1) and cotangents (shift -1).

Three schedule kinds:

  gpipe        all forwards, flush, all backwards.  Matches the executed
               wave pipeline in ``repro.parallel.pipeline`` — every wave
               is a lockstep ppermute barrier across all stages, which
               the simulator costs as wave-synchronized.
  1f1b         warmup of ``S-1-stage`` forwards, then one-forward/
               one-backward steady state: in-flight microbatches per
               stage never exceed the stage count, and each stage's
               gradients release as soon as ITS last backward retires —
               bucket reduce-scatters overlap the drain bubble.
  interleaved  1F1B over ``n_stages × virtual`` stages: device ``d``
               hosts global (virtual) stages ``{d, d+S, d+2S, ...}``, so
               consecutive global stages sit on consecutive devices and
               every boundary is still a single +1/-1 ppermute hop.
               The bubble shrinks by ~1/virtual.

The 1F1B and interleaved slot orders come from one deterministic list
scheduler over unit-cost slots (prefer-drain: a runnable backward beats
a runnable forward; forwards fill lowest-virtual-chunk first under the
per-stage in-flight cap).  The simulator replays the SAME committed
order with real per-stage times (``repro.sim.compute.pipeline_timeline``),
so the plan and its costing cannot drift.

Composition with the ZeRO-1 StepProgram (§9/§10): ``compose_step``
splices a sync/step schedule after the pipeline ops, wiring each
bucket's first sync op to the final backward of its owning stage
(buckets are reverse key order == output-first == latest global stage
first, so early buckets release earliest under 1F1B).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from repro.core.buckets import Bucket, LeafInfo
from repro.core.schedule import (
    ALL_GATHER,
    ALLREDUCE,
    RECV,
    REDUCE_SCATTER,
    SEND,
    CollectiveOp,
    CommSchedule,
)

SCHEDULES = ("gpipe", "1f1b", "interleaved")
STAGE_AXIS = "stage"


@dataclasses.dataclass(frozen=True)
class Slot:
    """One unit of per-microbatch stage compute (schedulable work)."""

    phase: str    # "F" | "B"
    stage: int    # GLOBAL (virtual) stage in [0, n_stages * virtual)
    mb: int


def _schedule_slots(kind: str, n_stages: int, n_microbatches: int,
                    virtual: int) -> list[tuple[int, Slot]]:
    """Global commit order of (device, slot) pairs.

    GPipe: round-robin wave order (the lockstep executed schedule).
    1F1B / interleaved: deterministic unit-cost list scheduling with the
    prefer-drain rule and per-global-stage in-flight cap ``S_tot - g``
    (stage g may hold at most that many live microbatches — the classic
    1F1B warmup bound; max over stages is the stage count).
    """
    S, M, v = n_stages, n_microbatches, virtual
    S_tot = S * v
    if kind == "gpipe":
        if v != 1:
            raise ValueError("gpipe has no interleaved variant (use "
                             "kind='interleaved')")
        commits: list[tuple[int, Slot]] = []
        for w in range(M + S - 1):              # forward waves
            for g in range(S):
                m = w - g
                if 0 <= m < M:
                    commits.append((g, Slot("F", g, m)))
        for w in range(M + S - 1):              # backward waves (reversed)
            for g in range(S - 1, -1, -1):
                m = w - (S - 1 - g)
                if 0 <= m < M:
                    commits.append((g, Slot("B", g, m)))
        return commits

    if kind not in ("1f1b", "interleaved"):
        raise ValueError(f"unknown pipeline schedule {kind!r}")
    if kind == "1f1b" and v != 1:
        raise ValueError("plain 1f1b has virtual=1 (use 'interleaved')")

    dev_of = lambda g: g % S
    # unit-cost event state
    dev_clock = [0.0] * S
    f_arrive: dict[tuple[int, int], float] = {}   # (g, m) -> input ready
    b_arrive: dict[tuple[int, int], float] = {}   # (g, m) -> cotangent ready
    f_done: dict[tuple[int, int], float] = {}
    next_f = [0] * S_tot                          # per global stage
    next_b = [0] * S_tot
    in_flight = [0] * S_tot
    commits = []
    total = 2 * M * S_tot
    while len(commits) < total:
        best = None   # (start, prefer_fwd, g, phase)
        for g in range(S_tot):
            d = dev_of(g)
            if next_b[g] < M and next_b[g] < next_f[g]:
                m = next_b[g]
                if g == S_tot - 1:
                    ready = f_done.get((g, m))
                else:
                    ready = b_arrive.get((g, m))
                if ready is not None:
                    start = max(dev_clock[d], ready)
                    cand = (start, 0, g, "B")
                    if best is None or cand < best:
                        best = cand
            if next_f[g] < M and in_flight[g] < S_tot - g:
                m = next_f[g]
                ready = 0.0 if g == 0 else f_arrive.get((g, m))
                if ready is not None:
                    start = max(dev_clock[d], ready)
                    cand = (start, 1, g, "F")
                    if best is None or cand < best:
                        best = cand
        if best is None:   # pragma: no cover — generator invariant
            raise RuntimeError("pipeline slot scheduler stalled")
        start, _, g, phase = best
        d = dev_of(g)
        end = start + 1.0
        dev_clock[d] = end
        if phase == "F":
            m = next_f[g]
            next_f[g] += 1
            in_flight[g] += 1
            f_done[(g, m)] = end
            if g + 1 < S_tot:
                f_arrive[(g + 1, m)] = end
            commits.append((d, Slot("F", g, m)))
        else:
            m = next_b[g]
            next_b[g] += 1
            in_flight[g] -= 1
            if g > 0:
                b_arrive[(g - 1, m)] = end
            commits.append((d, Slot("B", g, m)))
    return commits


def max_in_flight(plan: "PipelinePlan") -> int:
    """Peak live microbatches on any global stage (issued forwards minus
    retired backwards) — 1F1B's memory bound: ≤ total stage count."""
    live = {}
    peak = 0
    for _, slot in plan.commits:
        live[slot.stage] = live.get(slot.stage, 0) + (
            1 if slot.phase == "F" else -1)
        peak = max(peak, live[slot.stage])
    return peak


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A pipeline schedule lowered to the CommSchedule IR."""

    schedule: CommSchedule
    kind: str
    n_stages: int            # physical stages (mesh axis extent)
    n_microbatches: int
    virtual: int             # virtual stages per device (interleaved)
    stage_axis: str
    activation_bytes: int    # payload per boundary crossing, per rank
    commits: tuple[tuple[int, Slot], ...]      # global commit order
    # op_id -> (role "send"|"recv", slot that produced/consumes it)
    op_slot: Mapping[int, tuple[str, Slot]]

    @property
    def total_stages(self) -> int:
        return self.n_stages * self.virtual

    def final_backward_op(self, stage: int) -> int | None:
        """The last IR op of global ``stage``'s final backward slot (the
        stage's gradient-release point; None for a 1-stage plan)."""
        last = None
        for op_id, (_, slot) in self.op_slot.items():
            if (slot.stage == stage and slot.phase == "B"
                    and slot.mb == self.n_microbatches - 1):
                last = op_id if last is None else max(last, op_id)
        return last


def plan_pipeline(
    n_stages: int,
    n_microbatches: int,
    *,
    kind: str = "1f1b",
    virtual: int = 1,
    activation_bytes: int,
    stage_axis: str = STAGE_AXIS,
    itemsize: int = 4,
    id_offset: int = 0,
    chain_offset: int = 0,
    channel: int = 0,
) -> PipelinePlan:
    """Plan one pipeline schedule as a CommSchedule.

    Per boundary crossing: a SEND on the producing device's chain and a
    RECV on the consuming device's chain (chain = device index — the
    per-stage serialization), the RECV depending on its SEND (the data
    edge the payload rides) and both serialized after the device's
    previous op.  ``activation_bytes`` is the per-rank payload of one
    microbatch's boundary tensor.
    """
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    if n_microbatches < 1:
        raise ValueError(
            f"n_microbatches must be >= 1, got {n_microbatches}")
    commits = _schedule_slots(kind, n_stages, n_microbatches, virtual)
    S_tot = n_stages * virtual
    elems = max(1, int(activation_bytes) // max(1, itemsize))

    ops: list[CollectiveOp] = []
    op_slot: dict[int, tuple[str, Slot]] = {}
    last_on_dev: dict[int, int] = {}
    # (phase, boundary-src stage, mb) -> SEND op_id, for RECV pairing
    sends: dict[tuple[str, int, int], int] = {}
    next_id = id_offset
    next_bucket = 0

    def mk_bucket(name: str) -> Bucket:
        nonlocal next_bucket
        b = Bucket(
            leaves=(LeafInfo(name=name, index=0, shape=(elems,),
                             dtype=np.float32, size=elems),),
            reduce_axes=(stage_axis,), channel=channel,
            bucket_id=next_bucket)
        next_bucket += 1
        return b

    def emit(dev: int, role: str, slot: Slot, *, shift: int,
             bucket: Bucket, extra_deps: tuple[int, ...] = ()) -> int:
        nonlocal next_id
        deps = tuple(extra_deps)
        if dev in last_on_dev:
            deps = (last_on_dev[dev],) + deps
        op = CollectiveOp(
            op_id=next_id, bucket=bucket, chain=chain_offset + dev,
            depends_on=deps, kind=SEND if role == "send" else RECV,
            shift=shift)
        ops.append(op)
        op_slot[next_id] = (role, slot)
        last_on_dev[dev] = next_id
        next_id += 1
        return op.op_id

    for dev, slot in commits:
        g, m = slot.stage, slot.mb
        if slot.phase == "F":
            if g > 0:
                # receive this microbatch's activation before computing
                send_id = sends[("F", g - 1, m)]
                bucket = ops[send_id - id_offset].bucket
                emit(dev, "recv", slot, shift=1, bucket=bucket,
                     extra_deps=(send_id,))
            if g + 1 < S_tot:
                bucket = mk_bucket(f"pp/act/g{g}/m{m}")
                sends[("F", g, m)] = emit(dev, "send", slot, shift=1,
                                          bucket=bucket)
        else:
            if g + 1 < S_tot:
                send_id = sends[("B", g + 1, m)]
                bucket = ops[send_id - id_offset].bucket
                emit(dev, "recv", slot, shift=-1, bucket=bucket,
                     extra_deps=(send_id,))
            if g > 0:
                bucket = mk_bucket(f"pp/grad/g{g}/m{m}")
                sends[("B", g, m)] = emit(dev, "send", slot, shift=-1,
                                          bucket=bucket)

    schedule = CommSchedule(tuple(ops))
    if ops:
        schedule = schedule.validate()
    return PipelinePlan(
        schedule=schedule, kind=kind, n_stages=n_stages,
        n_microbatches=n_microbatches, virtual=virtual,
        stage_axis=stage_axis, activation_bytes=int(activation_bytes),
        commits=tuple(commits), op_slot=op_slot)


def bucket_stage_map(pp: PipelinePlan, sync: CommSchedule) -> dict[int, int]:
    """sync bucket_id -> owning global stage, reverse-linear: buckets are
    reverse key order (output layers first), so bucket 0 belongs to the
    LAST global stage — the first to retire its backwards under 1F1B."""
    bids = sorted({op.bucket.bucket_id for op in sync.ops
                   if op.kind in (ALLREDUCE, REDUCE_SCATTER, ALL_GATHER)})
    S_tot = pp.total_stages
    n = max(1, len(bids))
    return {bid: S_tot - 1 - min(S_tot - 1, (i * S_tot) // n)
            for i, bid in enumerate(bids)}


def compose_step(
    pp: PipelinePlan, sync: CommSchedule
) -> tuple[CommSchedule, dict[int, int]]:
    """Splice a sync/step schedule after the pipeline program.

    Sync op ids shift past the pipeline ops (internal deps preserved);
    each bucket's FIRST wire op additionally depends on the final
    backward op of the stage owning that bucket, so reduce-scatters
    begin the moment their stage's gradients exist — inside the drain
    bubble under 1F1B.  Returns (joint schedule, old→new sync id map).
    """
    off = len(pp.schedule.ops)
    stage_of = bucket_stage_map(pp, sync)
    id_map = {op.op_id: op.op_id + off for op in sync.ops}
    seen_bucket: set[int] = set()
    out = list(pp.schedule.ops)
    for op in sync.ops:
        deps = tuple(id_map[d] for d in op.depends_on)
        if (op.kind in (ALLREDUCE, REDUCE_SCATTER)
                and op.bucket.bucket_id not in seen_bucket):
            seen_bucket.add(op.bucket.bucket_id)
            rel = pp.final_backward_op(
                stage_of.get(op.bucket.bucket_id, pp.total_stages - 1))
            if rel is not None:
                deps = deps + (rel,)
        out.append(dataclasses.replace(
            op, op_id=id_map[op.op_id], depends_on=deps))
    return CommSchedule(tuple(out)).validate(), id_map
