"""DepCha's compute/comm overlap: emit the collective INSIDE the backward.

Paper §4.3: push (copy to comm_buf) is scheduled the moment a gradient is
produced, and the allreduce+pull are engine tasks that overlap the rest of
back-propagation.  In XLA the equivalent is to place each layer's gradient
psum *inside the backward scan body*: its consumer (the optimizer update)
lives outside the loop, so the async collective (``all-reduce-start`` /
``-done``) can be hoisted across the remaining per-layer backward compute
by XLA's latency-hiding scheduler and collective pipeliner — the exact
engine-thread overlap of the paper, one level down.

``sync_in_backward(fn, axes)`` wraps a layer function so that its parameter
cotangents are reduced over ``axes`` immediately in the backward pass.
Apply it to the body of a ``jax.lax.scan`` over stacked layer params and
every scan iteration of the backward emits one in-flight collective.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def sync_in_backward(
    fn: Callable[..., Any],
    axes: Any,
    *,
    scale: float = 1.0,
    reducer: str = "flat",
    intra_size: int = 0,
) -> Callable[..., Any]:
    """Wrap ``fn(params, *args)`` so d/d(params) is psum'd in the backward.

    ``axes``: either one tuple of mesh axis names applied to every param
    leaf, or a flat list of tuples aligned with ``tree_flatten(params)``
    (per-leaf reduction groups — replicated-over-model leaves include
    "model", TP-sharded leaves only the DP axes; built by
    ``repro.parallel.sharding.reduce_axes_tree``).

    The wrapped function is mathematically identical under the convention
    that un-wrapped training psums gradients after backward; with the
    wrapper those psums happen eagerly, per call site (per scan iteration).
    """
    if not axes:
        return fn
    per_leaf = isinstance(axes, list)

    @jax.custom_vjp
    def wrapped(params, *args):
        return fn(params, *args)

    def fwd(params, *args):
        out, vjp = jax.vjp(lambda p, *a: fn(p, *a), params, *args)
        return out, vjp

    def _reduce(t, ax):
        if not ax:
            return t * scale if scale != 1.0 else t
        ax = tuple(ax)
        if reducer == "hierarchical" and "pod" in ax and "data" in ax:
            # 3-stage RS(data) → AR(pod) → AG(data): only 1/intra of the
            # bytes cross the slow inter-pod links (DESIGN.md §3)
            from repro.core.hierarchical import hierarchical_allreduce

            flat = hierarchical_allreduce(
                jnp.ravel(t), intra_axis="data", inter_axis="pod",
                intra_size=intra_size)
            out = flat.reshape(t.shape)
            rest = tuple(a for a in ax if a not in ("pod", "data"))
            if rest:
                out = jax.lax.psum(out, rest)
        elif reducer == "compressed" and intra_size > 1 and "data" in ax:
            # int8 wire format for the in-scan DP sync (~4× fewer bytes;
            # lossy — no per-leaf error feedback inside the scan, so pair
            # with small LR or reserve for the large expert grads).
            # Multi-pod: int8 all-to-all INTRA pod + fp psum of the 1/16
            # shard across pods (hierarchical-compressed).
            from repro.core.compression import compressed_allreduce

            inter = ("pod",) if "pod" in ax else ()
            rest = tuple(a for a in ax if a not in ("pod", "data"))
            flat = compressed_allreduce(
                jnp.ravel(t).astype(jnp.float32), ("data",),
                group_size=intra_size, inter_axes=inter)
            out = flat.reshape(t.shape).astype(t.dtype)
            if rest:
                out = jax.lax.psum(out, rest)
        else:
            out = jax.lax.psum(t, ax)
        return out * scale if scale != 1.0 else out

    def bwd(vjp, g):
        grads = vjp(g)
        dparams, dargs = grads[0], grads[1:]
        # the paper's push+allreduce, emitted inside the backward scan body
        if per_leaf:
            flat, td = jax.tree_util.tree_flatten(dparams)
            assert len(flat) == len(axes), (len(flat), len(axes))
            flat = [_reduce(t, ax) for t, ax in zip(flat, axes)]
            dparams = jax.tree_util.tree_unflatten(td, flat)
        else:
            dparams = jax.tree.map(lambda t: _reduce(t, axes), dparams)
        return (dparams, *dargs)

    wrapped.defvjp(fwd, bwd)
    return wrapped


def scan_layers(
    layer_fn: Callable[[Any, Any], Any],
    stacked_params: Any,
    x: Any,
    *,
    depcha_axes: Any = (),
    unroll: int = 1,
    remat: str = "none",
    depcha_reducer: str = "flat",
    intra_size: int = 0,
):
    """scan over stacked layer params with optional in-backward grad sync.

    layer_fn(params_i, carry) -> carry.  Returns final carry.

    remat: "none" | "dots" | "full" — activation checkpointing policy for
    the layer body (a §Perf lever; "dots" keeps matmul outputs).
    """
    f = layer_fn
    if remat == "full":
        f = jax.checkpoint(f)
    elif remat == "dots":
        f = jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_saveable
        )
    if depcha_axes:
        f = sync_in_backward(f, depcha_axes, reducer=depcha_reducer,
                             intra_size=intra_size)

    def body(carry, params_i):
        return f(params_i, carry), None

    out, _ = jax.lax.scan(body, x, stacked_params, unroll=unroll)
    return out
