"""Dependency-token engine: MXNET read/write tags → XLA scheduling edges.

MXNET's engine (paper §3.1) orders tasks with explicit read/mutate tags on
objects; DepCha (paper §4.3) serializes collectives by making each one
*write* a shared dummy variable — successive writes to one object execute
in queue order on every worker.

The XLA analogue of "a write to the dummy variable" is an artificial
dataflow edge, injected with ``jax.lax.optimization_barrier``:  every
consumer of any barrier output is scheduled after every producer of any
barrier input.  A tiny scalar *token* threaded through barriers therefore
reproduces the dummy-variable chain:

  - ``gate(x, token)``        = read-dependency:  x's consumers wait for token
  - ``update(token, x)``      = mutate-dependency: new token waits for x

Both are free at runtime (the token is a scalar; barriers emit no code) —
they only constrain the scheduler, exactly like MXNET's tags.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def new_token() -> jax.Array:
    """A fresh dependency token (the paper's 'dummy' variable)."""
    return jnp.zeros((), dtype=jnp.float32)


def gate(x: Any, token: jax.Array) -> Any:
    """Return ``x`` such that its consumers are scheduled after ``token``.

    MXNET analogue: push(op, read_deps=[dummy.tag]).
    """
    flat, treedef = jax.tree_util.tree_flatten(x)
    out = jax.lax.optimization_barrier(tuple(flat) + (token,))
    return jax.tree_util.tree_unflatten(treedef, list(out[:-1]))


def update(token: jax.Array, *completed: Any) -> jax.Array:
    """Return a new token scheduled after all of ``completed``.

    MXNET analogue: push(op, mutate=[dummy.tag]) — the op 'writes' the dummy.
    """
    flat: list[Any] = [token]
    for c in completed:
        flat.extend(jax.tree_util.tree_leaves(c))
    out = jax.lax.optimization_barrier(tuple(flat))
    return out[0]


def chain(token: jax.Array, x: Any) -> tuple[Any, jax.Array]:
    """gate + update in one step: x waits on token; next token waits on x."""
    gated = gate(x, token)
    return gated, update(token, gated)
