"""Core: the paper's contribution — collective embedding in training DAGs.

See DESIGN.md §2-3 for the MXNET/MPI → JAX/XLA mapping.
"""
from repro.core.buckets import Bucket, BucketPlan, make_bucket_plan
from repro.core.dependency import chain, gate, new_token, update
from repro.core.kvstore import GradSync, GradSyncConfig, KVStore
from repro.core.overlap import scan_layers, sync_in_backward
from repro.core.strategies import REDUCERS, STRATEGIES, make_reducer, sync_grads

__all__ = [
    "Bucket",
    "BucketPlan",
    "GradSync",
    "GradSyncConfig",
    "KVStore",
    "REDUCERS",
    "STRATEGIES",
    "chain",
    "gate",
    "make_bucket_plan",
    "make_reducer",
    "new_token",
    "scan_layers",
    "sync_grads",
    "sync_in_backward",
    "update",
]
