"""Core: the paper's contribution — collective embedding in training DAGs.

See DESIGN.md §2-3 for the MXNET/MPI → JAX/XLA mapping and §4 for the
CommSchedule IR + strategy/reducer registry.
"""
from repro.core.buckets import Bucket, BucketPlan, make_bucket_plan
from repro.core.dependency import chain, gate, new_token, update
from repro.core.kvstore import GradSync, GradSyncConfig, KVStore
from repro.core.overlap import scan_layers, sync_in_backward
from repro.core.pipeline_program import (
    PipelinePlan,
    Slot,
    bucket_stage_map,
    compose_step,
    max_in_flight,
    plan_pipeline,
)
from repro.core.registry import (
    StrategyInfo,
    fixed_strategy_names,
    get_reducer,
    get_strategy,
    reducer_names,
    register_reducer,
    register_strategy,
    strategy_names,
)
from repro.core.schedule import (
    CollectiveOp,
    CommSchedule,
    emit_gated,
    execute,
)
from repro.core.stepprogram import (
    StepProgram,
    build_step_program,
    zero1_bucket_plan,
    zero1_schedule,
)
from repro.core.strategies import make_reducer, sync_grads


# simulator entry points (repro.sim) re-exported lazily: repro.sim imports
# repro.core submodules, so an eager import here would be circular.  Going
# through this package also registers the "auto" strategy as a side effect.
_SIM_EXPORTS = (
    "ComputeModel",
    "NetworkModel",
    "SimConfig",
    "Timeline",
    "compute_model_for",
    "default_network",
    "grid_search",
    "rank_strategies",
    "simulate",
    "simulate_pipelined",
    "simulate_strategy",
)


def __getattr__(name: str):
    # live registry views — a strategy registered after this package was
    # imported still shows up (a plain `from ... import STRATEGIES` here
    # would freeze the tuple at import time)
    if name == "STRATEGIES":
        return strategy_names()
    if name == "REDUCERS":
        return reducer_names()
    if name in _SIM_EXPORTS:
        import repro.sim as _sim

        return getattr(_sim, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Bucket",
    "BucketPlan",
    "CollectiveOp",
    "CommSchedule",
    "ComputeModel",
    "GradSync",
    "GradSyncConfig",
    "KVStore",
    "NetworkModel",
    "PipelinePlan",
    "REDUCERS",
    "STRATEGIES",
    "SimConfig",
    "Slot",
    "StepProgram",
    "StrategyInfo",
    "Timeline",
    "bucket_stage_map",
    "build_step_program",
    "chain",
    "compose_step",
    "compute_model_for",
    "default_network",
    "emit_gated",
    "execute",
    "fixed_strategy_names",
    "gate",
    "get_reducer",
    "get_strategy",
    "grid_search",
    "make_bucket_plan",
    "make_reducer",
    "max_in_flight",
    "new_token",
    "plan_pipeline",
    "rank_strategies",
    "reducer_names",
    "register_reducer",
    "register_strategy",
    "scan_layers",
    "simulate",
    "simulate_pipelined",
    "simulate_strategy",
    "strategy_names",
    "sync_grads",
    "sync_in_backward",
    "update",
    "zero1_bucket_plan",
    "zero1_schedule",
]
