"""Collective-embedding strategies as pure CommSchedule planners.

Every strategy computes the identical reduction (psum of each bucket over
its reduction axes); they differ ONLY in the dependency structure handed to
the XLA scheduler — the direct analogue of which MXNET thread issues the
MPI call (DESIGN.md §2, §3).  A strategy is a pure

    plan(bucket_plan, *, skip_names=frozenset()) -> CommSchedule

function registered in ``repro.core.registry``; token gating and psum
emission live exclusively in ``repro.core.schedule.execute``.

Paper strategies (§4):
  funnel  — ONE token chain through every collective: collective i+1 cannot
            start before collective i's result exists.  At most one in
            flight; zero comm/comm overlap.  Paper §4.1.
  concom  — buckets hashed to ``num_channels`` chains; chains are mutually
            independent, so up to ``num_channels`` collectives fly at once
            (the OUTSTANDING window of paper Fig 8).  Paper §4.2.
  depcha  — no post-backward chain at all for scan-resident params (their
            psums were already emitted inside the backward scan by
            ``repro.core.overlap``); the leftover (non-scan) buckets are
            reduced on independent chains like concom.  Paper §4.3.

Beyond-paper strategies the IR makes nearly free (DESIGN.md §4):
  priority — concom's chains, but each chain reduces its buckets in
             REVERSE creation order.  Buckets are created in gradient-
             ready order (back-to-front of the model), so reversing a
             chain reduces the *front* layers first — the gradients the
             next forward pass needs earliest (ByteScheduler-style
             priority ordering).
  rsag     — each bucket's allreduce is split into reduce-scatter →
             all-gather ops pipelined per channel: RS ops chain serially,
             each AG waits only on its own RS, so bucket i's AG overlaps
             bucket i+1's RS (half the bytes in flight per step).

Reducers (selected via ``reducer=``):
  flat          — plain psum over all reduction axes (paper's primitive).
  hierarchical  — 3-stage RS→pod-AR→AG (DESIGN.md §3: TPU analogue of the
                  paper's intra-node/inter-node/broadcast split).
  compressed    — int8 block-quantized wire format (~4x fewer bytes).
  ring          — chunked bidirectional ring RS→AG owned at the kernel
                  level (``repro.kernels.collectives``, DESIGN.md §8)
                  instead of the opaque ``lax.psum``; with two-phase
                  strategies (rsag) the rings carry the RS/AG ops
                  themselves.
  hierarchical_ring / compressed_ring — the same reducers with their
                  bulk-byte stages routed through the ring kernels.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core import registry
from repro.core.buckets import Bucket, BucketPlan
from repro.core.compression import compressed_allreduce
from repro.core.hierarchical import flat_allreduce, hierarchical_allreduce
from repro.kernels.collectives import ops as coll_ops
from repro.core.registry import (
    get_strategy,
    register_reducer,
    register_strategy,
)
from repro.core.schedule import (
    ALL_GATHER,
    REDUCE_SCATTER,
    CollectiveOp,
    CommSchedule,
    execute,
    group_size,
    live_buckets,
    live_channels,
    mean_scale,
)

Reducer = Callable[[jax.Array, Bucket], jax.Array]


# ---------------------------------------------------------------- reducers

def _scale_of(bucket: Bucket, mesh_shape, mean_axes) -> float:
    return mean_scale(bucket.reduce_axes, mesh_shape, mean_axes)


@register_reducer("flat")
def _flat_factory(mesh_shape: dict[str, int], *,
                  mean_axes: tuple[str, ...] = ()) -> Reducer:
    """Plain psum over all reduction axes (the paper's primitive)."""

    def reduce_flat(buf: jax.Array, bucket: Bucket) -> jax.Array:
        out = flat_allreduce(buf, bucket.reduce_axes)
        s = _scale_of(bucket, mesh_shape, mean_axes)
        return out * s if s != 1.0 else out

    return reduce_flat


def _hier_impl(mesh_shape: dict[str, int], *,
               mean_axes: tuple[str, ...] = (),
               use_ring: bool = False) -> Reducer:
    def reduce_hier(buf: jax.Array, bucket: Bucket) -> jax.Array:
        axes = bucket.reduce_axes
        if "pod" in axes and "data" in axes:
            out = hierarchical_allreduce(
                buf,
                intra_axis="data",
                inter_axis="pod",
                intra_size=mesh_shape["data"],
                use_ring=use_ring,
            )
            rest = tuple(a for a in axes if a not in ("pod", "data"))
            if rest:
                out = jax.lax.psum(out, rest)
        else:
            out = flat_allreduce(buf, axes)
        s = _scale_of(bucket, mesh_shape, mean_axes)
        return out * s if s != 1.0 else out

    return reduce_hier


@register_reducer("hierarchical")
def _hier_factory(mesh_shape: dict[str, int], *,
                  mean_axes: tuple[str, ...] = ()) -> Reducer:
    """3-stage RS(data) → AR(pod) → AG(data) when both axes are present."""
    return _hier_impl(mesh_shape, mean_axes=mean_axes)


@register_reducer("hierarchical_ring")
def _hier_ring_factory(mesh_shape: dict[str, int], *,
                       mean_axes: tuple[str, ...] = ()) -> Reducer:
    """hierarchical with the fast-tier bulk bytes (stages 1 and 3) on the
    chunked ring kernels instead of psum_scatter/all_gather (§8)."""
    return _hier_impl(mesh_shape, mean_axes=mean_axes, use_ring=True)


def _comp_impl(mesh_shape: dict[str, int], *,
               mean_axes: tuple[str, ...] = (),
               use_ring: bool = False) -> Reducer:
    def reduce_comp(buf: jax.Array, bucket: Bucket) -> jax.Array:
        group = group_size(bucket.reduce_axes, mesh_shape)
        if group == 1 or buf.shape[0] < 256 * group:
            out = flat_allreduce(buf, bucket.reduce_axes)
        else:
            out = compressed_allreduce(
                buf, bucket.reduce_axes, group_size=group,
                use_ring=use_ring,
            )
        s = _scale_of(bucket, mesh_shape, mean_axes)
        return out * s if s != 1.0 else out

    return reduce_comp


@register_reducer("compressed")
def _comp_factory(mesh_shape: dict[str, int], *,
                  mean_axes: tuple[str, ...] = ()) -> Reducer:
    """int8 block-quantized wire format for large buffers."""
    return _comp_impl(mesh_shape, mean_axes=mean_axes)


@register_reducer("compressed_ring")
def _comp_ring_factory(mesh_shape: dict[str, int], *,
                       mean_axes: tuple[str, ...] = ()) -> Reducer:
    """compressed with the int8 gather phase on the ring all-gather (§8;
    single-axis groups — multi-axis groups keep lax.all_gather)."""
    return _comp_impl(mesh_shape, mean_axes=mean_axes, use_ring=True)


@register_reducer("ring")
def _ring_factory(mesh_shape: dict[str, int], *,
                  mean_axes: tuple[str, ...] = ()) -> Reducer:
    """Chunked bidirectional ring allreduce (kernel-owned RS→AG path)."""

    def reduce_ring(buf: jax.Array, bucket: Bucket) -> jax.Array:
        out = coll_ops.ring_allreduce(buf, bucket.reduce_axes, mesh_shape)
        s = _scale_of(bucket, mesh_shape, mean_axes)
        return out * s if s != 1.0 else out

    return reduce_ring


def make_reducer(
    name: str, mesh_shape: dict[str, int], *, mean_axes: tuple[str, ...] = ()
) -> Reducer:
    """Build the per-bucket collective from the registered factory."""
    return registry.get_reducer(name)(mesh_shape, mean_axes=mean_axes)


# --------------------------------------------------------------- planners

def _chain(buckets: list[Bucket], chain_id: int, start_id: int,
           ops: list[CollectiveOp]) -> int:
    """Append one serialized chain (op i+1 waits on op i); returns next id."""
    prev: int | None = None
    oid = start_id
    for bucket in buckets:
        ops.append(CollectiveOp(
            op_id=oid, bucket=bucket, chain=chain_id,
            depends_on=(prev,) if prev is not None else ()))
        prev = oid
        oid += 1
    return oid


@register_strategy("funnel", single_chain=True)
def plan_funnel(plan: BucketPlan, *,
                skip_names: frozenset[str] = frozenset()) -> CommSchedule:
    """One chain through ALL buckets in creation order (paper §4.1)."""
    ops: list[CollectiveOp] = []
    _chain(live_buckets(plan, skip_names), 0, 0, ops)
    return CommSchedule(tuple(ops)).validate()


@register_strategy("concom")
def plan_concom(plan: BucketPlan, *,
                skip_names: frozenset[str] = frozenset()) -> CommSchedule:
    """Independent chain per channel → up to num_channels in flight (§4.2)."""
    ops: list[CollectiveOp] = []
    oid = 0
    for ch, buckets in sorted(live_channels(plan, skip_names).items()):
        oid = _chain(buckets, ch, oid, ops)
    return CommSchedule(tuple(ops)).validate()


@register_strategy("depcha", uses_in_scan=True, deferred_pull=True)
def plan_depcha(plan: BucketPlan, *,
                skip_names: frozenset[str] = frozenset()) -> CommSchedule:
    """In-scan leaves (``skip_names``) were reduced inside the backward
    scan; leftover buckets ride independent chains like concom (§4.3)."""
    return plan_concom(plan, skip_names=skip_names)


@register_strategy("priority")
def plan_priority(plan: BucketPlan, *,
                  skip_names: frozenset[str] = frozenset()) -> CommSchedule:
    """concom chains with each chain's buckets in REVERSE creation order:
    front-of-model gradients (needed first next step) finish first."""
    ops: list[CollectiveOp] = []
    oid = 0
    for ch, buckets in sorted(live_channels(plan, skip_names).items()):
        oid = _chain(list(reversed(buckets)), ch, oid, ops)
    return CommSchedule(tuple(ops)).validate()


@register_strategy("rsag", two_phase=True)
def plan_rsag(plan: BucketPlan, *,
              skip_names: frozenset[str] = frozenset()) -> CommSchedule:
    """Per-bucket reduce-scatter→all-gather pipelined over channels: RS
    ops chain serially per channel; each AG depends only on its own RS,
    so bucket i's AG overlaps bucket i+1's RS."""
    ops: list[CollectiveOp] = []
    oid = 0
    for ch, buckets in sorted(live_channels(plan, skip_names).items()):
        prev_rs: int | None = None
        for bucket in buckets:
            rs_id, ag_id = oid, oid + 1
            ops.append(CollectiveOp(
                op_id=rs_id, bucket=bucket, chain=ch, kind=REDUCE_SCATTER,
                depends_on=(prev_rs,) if prev_rs is not None else ()))
            ops.append(CollectiveOp(
                op_id=ag_id, bucket=bucket, chain=ch, kind=ALL_GATHER,
                depends_on=(rs_id,)))
            prev_rs = rs_id
            oid += 2
    return CommSchedule(tuple(ops)).validate()


# --------------------------------------------------------------- executor

def sync_grads(
    grads: Any,
    plan: BucketPlan,
    *,
    strategy: str,
    reducer: Reducer,
    skip_names: frozenset[str] = frozenset(),
    mesh_shape: dict[str, int] | None = None,
    mean_axes: tuple[str, ...] = (),
) -> Any:
    """Apply a registered collective-embedding strategy to a gradient
    pytree: plan the CommSchedule, then emit it.

    ``skip_names``: leaves already reduced inside the backward (depcha's
    in-scan psums) — they pass through untouched.  ``mesh_shape`` is
    needed only for strategies emitting reduce-scatter/all-gather ops
    (rsag) or when ``mean_axes`` scaling applies on that path.
    """
    info = get_strategy(strategy)
    plan_kw = {}
    if info.meta and mesh_shape is not None:
        plan_kw["context"] = {"mesh_shape": mesh_shape}
    schedule = info.plan(plan, skip_names=skip_names, **plan_kw)
    return execute(schedule, grads, plan, reducer=reducer,
                   mesh_shape=mesh_shape, mean_axes=mean_axes)


def __getattr__(name: str):
    # STRATEGIES/REDUCERS are derived from the registry (live views), so
    # late-registered strategies appear without editing this module.
    if name == "STRATEGIES":
        return registry.strategy_names()
    if name == "REDUCERS":
        return registry.reducer_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
