"""The paper's three collective-embedding designs, as HLO schedules.

Every strategy computes the identical reduction (psum of each bucket over
its reduction axes); they differ ONLY in the dependency structure handed to
the XLA scheduler — the direct analogue of which MXNET thread issues the
MPI call (DESIGN.md §2, §3):

  funnel  — ONE token chain through every collective: collective i+1 cannot
            start before collective i's result exists.  At most one in
            flight; zero comm/comm overlap.  Paper §4.1.
  concom  — buckets hashed to `num_channels` chains; chains are mutually
            independent, so up to `num_channels` collectives fly at once
            (the OUTSTANDING window of paper Fig 8).  Paper §4.2.
  depcha  — no post-backward chain at all for scan-resident params (their
            psums were already emitted inside the backward scan by
            ``repro.core.overlap``); the leftover (non-scan) buckets are
            reduced on independent chains like concom.  A dummy-token write
            chain orders the in-scan collectives.  Paper §4.3.

Beyond-paper reducers (selected via ``reducer=``):
  flat          — plain psum over all reduction axes (paper's primitive).
  hierarchical  — 3-stage RS→pod-AR→AG (DESIGN.md: TPU analogue of the
                  paper's intra-node/inter-node/broadcast split).
  compressed    — int8 block-quantized wire format (~4x fewer bytes).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import dependency as dep
from repro.core.buckets import Bucket, BucketPlan, pack, unpack
from repro.core.compression import compressed_allreduce
from repro.core.hierarchical import flat_allreduce, hierarchical_allreduce

Reducer = Callable[[jax.Array, Bucket], jax.Array]

STRATEGIES = ("funnel", "concom", "depcha")
REDUCERS = ("flat", "hierarchical", "compressed")


def make_reducer(
    name: str, mesh_shape: dict[str, int], *, mean_axes: tuple[str, ...] = ()
) -> Reducer:
    """Build the per-bucket collective. ``mean_axes``: divide by their size
    (data-parallel mean; the paper's rescale=1/mini_batch_size is applied in
    the loss instead when ``mean_axes`` is empty)."""

    def scale_of(bucket: Bucket) -> float:
        n = 1
        for a in bucket.reduce_axes:
            if a in mean_axes:
                n *= mesh_shape[a]
        return 1.0 / n

    if name == "flat":

        def reduce_flat(buf: jax.Array, bucket: Bucket) -> jax.Array:
            out = flat_allreduce(buf, bucket.reduce_axes)
            s = scale_of(bucket)
            return out * s if s != 1.0 else out

        return reduce_flat

    if name == "hierarchical":

        def reduce_hier(buf: jax.Array, bucket: Bucket) -> jax.Array:
            axes = bucket.reduce_axes
            if "pod" in axes and "data" in axes:
                out = hierarchical_allreduce(
                    buf,
                    intra_axis="data",
                    inter_axis="pod",
                    intra_size=mesh_shape["data"],
                )
                rest = tuple(a for a in axes if a not in ("pod", "data"))
                if rest:
                    out = jax.lax.psum(out, rest)
            else:
                out = flat_allreduce(buf, axes)
            s = scale_of(bucket)
            return out * s if s != 1.0 else out

        return reduce_hier

    if name == "compressed":

        def reduce_comp(buf: jax.Array, bucket: Bucket) -> jax.Array:
            group = 1
            for a in bucket.reduce_axes:
                group *= mesh_shape[a]
            if group == 1 or buf.shape[0] < 256 * group:
                out = flat_allreduce(buf, bucket.reduce_axes)
            else:
                out = compressed_allreduce(
                    buf, bucket.reduce_axes, group_size=group
                )
            s = scale_of(bucket)
            return out * s if s != 1.0 else out

        return reduce_comp

    raise ValueError(f"unknown reducer {name!r}, want one of {REDUCERS}")


def _sync_chain(
    buckets: list[Bucket],
    flat_grads: list[jax.Array],
    flat_out: list[jax.Array | None],
    reducer: Reducer,
    comm_dtype,
    token: jax.Array,
) -> jax.Array:
    """One serialized chain: bucket i+1's collective waits on bucket i's."""
    for bucket in buckets:
        send_buf = pack(bucket, flat_grads, comm_dtype)     # CopyFromTo(g, send_buf)
        send_buf = dep.gate(send_buf, token)                # WaitToRead / read-dep
        recv_buf = reducer(send_buf, bucket)                # MPI_Allreduce
        token = dep.update(token, recv_buf)                 # write the dummy var
        unpack(bucket, recv_buf, flat_out)                  # CopyFromTo(recv, g)
    return token


def sync_grads(
    grads: Any,
    plan: BucketPlan,
    *,
    strategy: str,
    reducer: Reducer,
    skip_names: frozenset[str] = frozenset(),
) -> Any:
    """Apply a collective-embedding strategy to a gradient pytree.

    ``skip_names``: leaves already reduced inside the backward (depcha's
    in-scan psums) — they pass through untouched.
    """
    flat_grads = jax.tree_util.tree_leaves(grads)
    assert len(flat_grads) == plan.num_leaves, (
        f"plan built for {plan.num_leaves} leaves, got {len(flat_grads)}"
    )
    flat_out: list[jax.Array | None] = list(flat_grads)

    live: dict[int, list[Bucket]] = {}
    for bucket in plan.buckets:
        keep = [l for l in bucket.leaves if l.name not in skip_names]
        if not keep:
            continue
        b = dataclasses.replace(bucket, leaves=tuple(keep))
        live.setdefault(bucket.channel, []).append(b)

    if strategy == "funnel":
        # single chain through ALL buckets regardless of channel
        token = dep.new_token()
        all_buckets = [b for ch in sorted(live) for b in live[ch]]
        _sync_chain(all_buckets, flat_grads, flat_out, reducer,
                    plan.comm_dtype, token)
    elif strategy in ("concom", "depcha"):
        # independent chain per channel → up to num_channels in flight
        for ch in sorted(live):
            token = dep.new_token()
            _sync_chain(live[ch], flat_grads, flat_out, reducer,
                        plan.comm_dtype, token)
    else:
        raise ValueError(f"unknown strategy {strategy!r}, want {STRATEGIES}")

    return jax.tree_util.tree_unflatten(plan.treedef, flat_out)
