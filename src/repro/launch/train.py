"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 100 --strategy depcha [--smoke]

``--smoke`` runs the arch's reduced config on the local device mesh (the
CPU-runnable path); without it the full config targets the production
mesh (requires a real 256-chip slice — on this container use
``repro.launch.dryrun`` instead, which AOT-compiles the same program).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.core import (
    GradSyncConfig,
    get_strategy,
    reducer_names,
    strategy_names,
)
import repro.sim  # noqa: F401  (registers "auto" → --strategy auto)
from repro.data import ImagePipeline, TokenPipeline
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models.registry import family_of
from repro.optim import adamw, cosine_warmup, sgd, zero1
from repro.parallel.sharding import dp_axes_of
from repro.runtime import Trainer, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--strategy", default="depcha",
                    choices=strategy_names())
    ap.add_argument("--reducer", default="flat",
                    choices=reducer_names())
    ap.add_argument("--channels", type=int, default=4)
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--zero1-plan", default="scheduled",
                    choices=["scheduled", "deferred", "monolithic"],
                    help="scheduled = StepProgram (per-bucket RS→UPDATE→"
                         "AG planned by the strategy, clipped via the "
                         "NORM op); deferred = pipelined StepProgram "
                         "(AGs detach into the next step's top, update "
                         "shards carried in opt_state); monolithic = "
                         "opaque optimizer.update")
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--pp-stages", type=int, default=1,
                    help="pipeline stages over a 'stage' mesh axis "
                         "(smoke mesh only; --microbatch doubles as the "
                         "pipeline microbatch count M)")
    ap.add_argument("--pp-schedule", default="auto",
                    choices=["auto", "gpipe", "1f1b"],
                    help="pipeline schedule; auto = argmin of the "
                         "analytic pipeline wall (repro.sim."
                         "choose_pp_schedule)")
    ap.add_argument("--no-accum-overlap", action="store_true",
                    help="keep the final microbatch inside the "
                         "accumulation scan (sync waits for the whole "
                         "scan) instead of peeling it for overlap")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--events-jsonl", default="",
                    help="append per-step JSONL telemetry (repro.obs "
                         "EventLog) to this path")
    ap.add_argument("--metrics-json", default="",
                    help="write the final metrics snapshot here")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        mesh = make_smoke_mesh(1, 1, stage=args.pp_stages
                               if args.pp_stages > 1 else 0)
        cfg = arch.make_smoke()
        seq, batch = args.seq, args.batch
    else:
        if args.pp_stages > 1:
            raise SystemExit(
                "--pp-stages needs the smoke mesh (--smoke); the "
                "production mesh has no 'stage' axis")
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = arch.make_config(
            tp=mesh.shape["model"], dp_axes=dp_axes_of(mesh),
            depcha_in_scan=get_strategy(args.strategy).uses_in_scan)
        shape = arch.shapes[0]
        seq, batch = shape.seq_len, shape.global_batch

    api = family_of(cfg)
    if arch.family in ("resnet", "inception"):
        pipe = ImagePipeline(cfg.img_size, cfg.num_classes, batch,
                             mesh=mesh)
        opt = sgd(cosine_warmup(args.lr, 10, args.steps), momentum=0.9)
    else:
        extras = {
            name: (tuple(shape_fn(cfg, seq)), jnp.float32)
            for name, shape_fn, _ in arch.extra_inputs}
        pipe = TokenPipeline(cfg.vocab, seq, batch, mesh=mesh,
                             extra_specs=extras)
        opt = adamw(cosine_warmup(args.lr, 10, args.steps))
    if args.zero1:
        import numpy as np

        dp = dp_axes_of(mesh)
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        opt = zero1(opt, dp, dp_size)

    sync = GradSyncConfig(
        strategy=args.strategy, reducer=args.reducer,
        bucket_bytes=int(args.bucket_mb * 1024 * 1024),
        num_channels=args.channels,
        exclude_axes=dp_axes_of(mesh) if args.zero1 else ())
    params = api.init(jax.random.PRNGKey(0), cfg)
    # donate params/opt_state on the production path: the optimizer
    # update reuses their buffers in place (halves peak state memory).
    # Smoke runs keep donation off so the host copies stay comparable.
    ts = make_train_step(cfg, mesh, sync, opt,
                         batch_like=pipe.batch_at(0), params_like=params,
                         clip_norm=args.clip_norm,
                         zero1_mode=args.zero1,
                         zero1_plan=args.zero1_plan,
                         microbatch=args.microbatch,
                         accum_overlap=not args.no_accum_overlap,
                         donate=not args.smoke,
                         pp_stages=args.pp_stages,
                         pp_schedule=args.pp_schedule)
    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every) \
        if args.ckpt_dir else None
    trainer = Trainer(ts, pipe, ckpt, log_every=10,
                      events_path=args.events_jsonl or None)
    # init_opt derives zero1 shard sizes from the step's LOCAL shapes
    # (opt.init on global TP-sharded params would size them wrong)
    opt_state = ts.init_opt() if args.zero1 else opt.init(params)
    # (deferred plan: checkpoints keep params + opt_state["pending"]
    # consistent, so resume is exact as-is; a consumer exporting params
    # must flush the carried shards with ts.finalize(params, opt_state))
    _, _, hist = trainer.run(params, opt_state, args.steps)
    print(f"[train] {args.arch} {args.strategy}: "
          f"loss {hist['losses'][0]:.3f} -> {hist['losses'][-1]:.3f}")
    snap = hist.get("metrics", {})
    compile_s = hist.get("compile_time")
    tps = snap.get("tokens_per_s")
    if compile_s is not None:
        print(f"[train] compile {compile_s:.2f}s (excluded from "
              f"throughput)"
              + (f", {tps:,.0f} tokens/s" if tps else ""))
    if args.metrics_json:
        import json

        with open(args.metrics_json, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[train] metrics snapshot -> {args.metrics_json}")


if __name__ == "__main__":
    main()
