"""Production serving launcher: batched greedy decoding for any arch with
a serve path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models.registry import family_of
from repro.parallel.sharding import dp_axes_of
from repro.runtime import Server
from repro.runtime.serve_loop import RequestQueue


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        mesh = make_smoke_mesh(1, 1)
        cfg = arch.make_smoke()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = arch.make_config(tp=mesh.shape["model"],
                               dp_axes=dp_axes_of(mesh))
    api = family_of(cfg)
    if api.prefill is None:
        raise SystemExit(f"{args.arch} has no serve path")
    params = api.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, mesh, params, max_len=64)
    queue = RequestQueue(server, batch=args.batch)

    rng = np.random.default_rng(0)
    handles = [queue.submit(
        rng.integers(1, min(cfg.vocab, 512), size=rng.integers(4, 12),
                     dtype=np.int32), args.max_new)
        for _ in range(args.requests)]
    t0 = time.perf_counter()
    done = 0
    while done < args.requests:
        done += queue.serve_once()
    dt = time.perf_counter() - t0
    for i, h in enumerate(handles):
        print(f"req {i}: {h.get(timeout=30).tolist()}")
    print(f"[serve] {args.requests} requests in {dt:.2f}s "
          f"({args.requests * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
