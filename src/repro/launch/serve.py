"""Production serving launcher: continuous-batching (default) or static
batched decoding for any arch with a serve path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --temperature 0.8 --top-k 16 --seed 7

Defaults keep greedy decoding (temperature 0) and the continuous engine
for families with a paged decode hook; ``--engine static`` forces the
original ``RequestQueue`` batcher.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models.registry import family_of
from repro.parallel.sharding import dp_axes_of
from repro.runtime import ContinuousScheduler, SamplingParams, Server
from repro.runtime.serve_loop import RequestQueue


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="static batcher width / continuous in-flight slots")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous",
                    help="continuous falls back to static for families "
                         "without a paged decode hook")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV-cache block size (must divide max-len)")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per device launch (one host sync)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (the default)")
    ap.add_argument("--top-k", type=int, default=0, help="0 = no cap")
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed base")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        mesh = make_smoke_mesh(1, 1)
        cfg = arch.make_smoke()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = arch.make_config(tp=mesh.shape["model"],
                               dp_axes=dp_axes_of(mesh))
    api = family_of(cfg)
    if api.prefill is None:
        raise SystemExit(f"{args.arch} has no serve path")
    params = api.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, mesh, params, max_len=args.max_len)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, min(cfg.vocab, 512),
                            size=rng.integers(4, 12), dtype=np.int32)
               for _ in range(args.requests)]

    use_continuous = (args.engine == "continuous"
                      and api.decode_paged is not None)
    if args.engine == "continuous" and not use_continuous:
        print(f"[serve] {cfg.name}'s family has no paged decode hook; "
              f"falling back to the static batcher")

    t0 = time.perf_counter()
    if use_continuous:
        eng = ContinuousScheduler(
            server, slots=args.batch, block_size=args.block_size,
            chunk=args.chunk)
        handles = [eng.submit(p, args.max_new, SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, seed=args.seed + i))
            for i, p in enumerate(prompts)]
        eng.run_until_idle()
    else:
        queue = RequestQueue(server, batch=args.batch)
        handles = [queue.submit(p, args.max_new) for p in prompts]
        done = 0
        while done < args.requests:
            done += queue.serve_once()
    dt = time.perf_counter() - t0
    for i, h in enumerate(handles):
        out = h.get(timeout=30)
        if isinstance(out, Exception):
            raise out
        print(f"req {i}: {out.tolist()}")
    print(f"[serve] engine={'continuous' if use_continuous else 'static'} "
          f"{args.requests} requests in {dt:.2f}s "
          f"({args.requests * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
