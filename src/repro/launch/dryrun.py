import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  - the sharding config is coherent (SPMD partitioning succeeds),
  - the program compiles (no unsupported collective / shape mismatch),
  - memory_analysis() shows the per-device footprint,
  - cost_analysis() + the HLO collective schedule feed §Roofline.

Results append incrementally to a JSON file (compiles are minutes each on
one CPU core; a crash loses nothing).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k --mesh single --out results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import (
    decode_state_structs,
    get_arch,
    image_input_specs,
    param_structs,
    train_input_specs,
    ARCHS,
)
from repro.core import (
    GradSyncConfig,
    get_strategy,
    reducer_names,
    strategy_names,
)
import repro.sim  # noqa: F401  (registers "auto" → --strategy auto)
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models.registry import family_of
from repro.optim import adamw, sgd
from repro.parallel.sharding import batch_spec, dp_axes_of
from repro.runtime.train_loop import make_train_step

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*([a-z0-9\[\],{} ]+)", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_stats(hlo_text: str) -> list[dict]:
    """Parse per-op collective operand bytes + group size from HLO text."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"%?[\w.-]+\s*=\s*(\([^)]*\)|[a-z0-9\[\],{} ]+)\s*"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?", line)
        if not m or (m.group(3) == "-done"):
            continue
        kind = m.group(2)
        tys = m.group(1)
        bytes_total = 0
        for dt, dims in _SHAPE_RE.findall(tys):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d.strip():
                    n *= int(d)
            bytes_total += n * _DTYPE_BYTES[dt]
        g = _GROUPS_RE.search(line)
        group = len(g.group(1).split(",")) if g else 0
        out.append({"kind": kind, "result_bytes": bytes_total,
                    "group_size": group})
    return out


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _delta_unroll_chunks(arch) -> bool:
    """Unroll chunk scans in the delta compiles?  Transformer kv-chunk
    loops are short (<=32 trips) → unroll for exact attention accounting.
    rwkv/ssm recurrence loops are long (T up to 128) → keep rolled; their
    bodies hold no collectives, and the flop contribution is added
    analytically in benchmarks/roofline.py (§Roofline methodology)."""
    return arch.family not in ("rwkv", "ssm")


def _lower_for(arch, cfg, shape, mesh, sync, api, rules, step_kw=None):
    """Build + lower the cell's step function for a given config."""
    dp = dp_axes_of(mesh)
    params_sds = param_structs(cfg)
    pspecs = rules.tree_specs(params_sds)
    step_kw = dict(step_kw or {})
    if shape.kind == "train":
        if arch.family in ("resnet", "inception"):
            batch_sds = image_input_specs(cfg, shape)
            opt = sgd(0.1, momentum=0.9)
        else:
            batch_sds = train_input_specs(arch, cfg, shape)
            opt = adamw(3e-4)
        if step_kw.pop("zero1", False):
            # ZeRO-1 dry-run: the compiled program carries the
            # StepProgram's RS→UPDATE→AG ops (or the monolithic pair)
            from repro.optim import zero1 as _zero1

            dp_size = int(np.prod([mesh.shape[a] for a in dp])) or 1
            opt = _zero1(opt, tuple(dp), dp_size)
            sync = dataclasses.replace(sync, exclude_axes=tuple(dp))
            step_kw["zero1_mode"] = True
        # donate=True matches production: the AOT memory_analysis then
        # reports the aliased (in-place params/opt_state) footprint
        ts = make_train_step(cfg, mesh, sync, opt,
                             batch_like=batch_sds, params_like=params_sds,
                             donate=True, **step_kw)
        args = (params_sds, ts.opt_state_like, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        lowered = ts.fn.lower(*args)
    elif shape.kind == "prefill":
        GB, S = shape.global_batch, shape.seq_len
        bspec = batch_spec(mesh)
        extras = {
            name: jax.ShapeDtypeStruct((GB, *shape_fn(cfg, S)), dt)
            for name, shape_fn, dt in arch.extra_inputs}

        def prefill_fn(params, tokens, extras):
            kw = {}
            if "img_embeds" in extras:
                kw["img_embeds"] = extras["img_embeds"]
            if "frame_embeds" in extras:
                kw["frame_embeds"] = extras["frame_embeds"]
            logits, cache = api.prefill(params, tokens, cfg, **kw) \
                if kw else api.prefill(params, tokens, cfg)
            return logits, cache

        batch_entry = tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)
        cspecs = api.decode_state_specs(cfg, batch_entry)
        espec = {k: bspec for k in extras}
        lspec = P(batch_entry, "model")   # logits: (B, V/tp) vocab-sharded
        fn = jax.jit(jax.shard_map(
            prefill_fn, mesh=mesh,
            in_specs=(pspecs, bspec, espec),
            out_specs=(lspec, cspecs),
            check_vma=False))
        lowered = fn.lower(
            params_sds, jax.ShapeDtypeStruct((GB, S), jnp.int32), extras)
    elif shape.kind == "decode":
        GB, S = shape.global_batch, shape.seq_len
        dp_size = int(np.prod([mesh.shape[a] for a in dp])) or 1
        if GB % dp_size:
            # batch=1 long-context decode: no DP to exploit — replicate
            # over the data axes (honest idle-chip finding; SP is the
            # §Perf lever), shard only over "model".
            bspec = P(None)
        else:
            bspec = batch_spec(mesh)
        state_sds, cspecs = decode_state_structs(
            arch, cfg, shape, mesh, replicate_batch=bool(GB % dp_size))
        extras = {
            name: jax.ShapeDtypeStruct((GB, *shape_fn(cfg, S)), dt)
            for name, shape_fn, dt in arch.extra_inputs
            if name == "img_embeds"}   # decode conditions on images only

        def decode_fn(params, state, tok, pos, extras):
            kw = {"img_embeds": extras["img_embeds"]} if extras else {}
            logits, new_state = api.decode_step(
                params, state, tok, pos, cfg, **kw) \
                if kw else api.decode_step(params, state, tok, pos, cfg)
            return logits, new_state

        espec = {k: bspec for k in extras}
        lspec = P(bspec[0] if len(bspec) else None, "model")
        fn = jax.jit(jax.shard_map(
            decode_fn, mesh=mesh,
            in_specs=(pspecs, cspecs, bspec, P(), espec),
            out_specs=(lspec, cspecs),
            check_vma=False))
        lowered = fn.lower(
            params_sds, state_sds,
            jax.ShapeDtypeStruct((GB,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32), extras)
    else:
        raise ValueError(shape.kind)
    return lowered


def _cost(compiled):
    cost = compiled.cost_analysis()
    # jax<0.5 returns a per-device list of dicts; newer jax a single dict
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost


def _cost_record(compiled) -> dict:
    cost = _cost(compiled)
    hlo = compiled.as_text()
    colls = collective_stats(hlo)
    return {
        "flops": float(cost.get("flops", -1)) if cost else None,
        "bytes_accessed": float(cost.get("bytes accessed", -1))
        if cost else None,
        "collectives": _summarize(colls),
    }


def lower_cell(arch_id: str, shape_name: str, mesh, *,
               sync: GradSyncConfig | None = None,
               overrides: dict | None = None) -> dict[str, Any]:
    """Lower+compile one cell; returns the §Dry-run/§Roofline record.

    Two extra reduced-depth compiles (layer_pair, chunk scans unrolled)
    give exact HLO cost accounting: XLA's cost_analysis counts a scan
    body ONCE, so totals are reconstructed as
        f(L_small) + m · (f(L_large) − f(L_small)).
    """
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    if not shape.applicable:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "note": shape.note}
    dp = dp_axes_of(mesh)
    tp = mesh_shape_dict(mesh).get("model", 1)
    sync = sync or GradSyncConfig(strategy="depcha", num_channels=4)
    over = dict(overrides or {})
    step_kw = {}
    for k in ("microbatch", "zero1", "zero1_plan", "clip_norm"):
        if k in over:
            step_kw[k] = over.pop(k)
    base_cfg_probe = arch.make_config(tp=tp, dp_axes=dp)
    if shape.kind == "train" and get_strategy(sync.strategy).uses_in_scan \
            and hasattr(base_cfg_probe, "depcha_in_scan"):
        over.setdefault("depcha_in_scan", True)
    cfg = arch.make_config(tp=tp, dp_axes=dp, **over)
    api = family_of(cfg)
    rules = api.param_rules(cfg)
    t0 = time.time()

    lowered = _lower_for(arch, cfg, shape, mesh, sync, api, rules, step_kw)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = _cost(compiled)
    hlo = compiled.as_text()
    colls = collective_stats(hlo)

    # ---- exact-cost delta compiles (reduced depth, chunk scans unrolled)
    scaling = None
    if arch.layer_pair is not None:
        l_small, l_large, unit = arch.layer_pair
        mult = (cfg.n_layers - l_small) / unit
        recs = {}
        for L in (l_small, l_large):
            cfg_l = arch.make_config(
                tp=tp, dp_axes=dp,
                **{**over, "n_layers": L,
                   "chunk_unroll": _delta_unroll_chunks(arch),
                   "scan_unroll": max(L, 2)})
            low = _lower_for(arch, cfg_l, shape, mesh, sync,
                             family_of(cfg_l), api.param_rules(cfg_l),
                             step_kw)
            recs[L] = _cost_record(low.compile())
        scaling = {"l_small": l_small, "l_large": l_large,
                   "multiplier": mult,
                   "chunks_unrolled": _delta_unroll_chunks(arch),
                   "small": recs[l_small], "large": recs[l_large]}

    def _mem_field(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    record = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "status": "ok",
        "kind": shape.kind,
        "strategy": sync.strategy,
        "reducer": sync.reducer,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": _mem_field("argument_size_in_bytes"),
            "output_bytes": _mem_field("output_size_in_bytes"),
            "temp_bytes": _mem_field("temp_size_in_bytes"),
            "generated_code_bytes": _mem_field(
                "generated_code_size_in_bytes"),
        },
        "cost": {
            "flops": float(cost.get("flops", -1)) if cost else None,
            "bytes_accessed": float(cost.get("bytes accessed", -1))
            if cost else None,
        },
        "collectives": _summarize(colls),
        "scaling": scaling,
    }
    return record


def _prefill_cache_specs(api, cfg, batch_entry):
    """Prefill returns full-seq caches; same specs as decode state but the
    rwkv/ssm prefill returns layer-stacked state dicts of the same form."""
    return api.decode_state_specs(cfg, batch_entry)


def _summarize(colls: list[dict]) -> dict:
    agg: dict[str, dict] = {}
    for c in colls:
        a = agg.setdefault(c["kind"], {"count": 0, "result_bytes": 0})
        a["count"] += 1
        a["result_bytes"] += c["result_bytes"]
    agg["ops"] = colls[:400]
    return agg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    help="single | multi | both | DxM (e.g. 64x4)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--strategy", default="depcha",
                    choices=strategy_names())
    ap.add_argument("--reducer", default="flat",
                    choices=reducer_names())
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--comm-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--channels", type=int, default=4)
    ap.add_argument("--zero1", action="store_true",
                    help="compile train cells with the ZeRO-1 optimizer")
    ap.add_argument("--zero1-plan", default="scheduled",
                    choices=["scheduled", "deferred", "monolithic"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides k=v (e.g. remat=full)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    if args.zero1:
        overrides["zero1"] = True
        overrides["zero1_plan"] = args.zero1_plan

    sync = GradSyncConfig(
        strategy=args.strategy, reducer=args.reducer,
        bucket_bytes=int(args.bucket_mb * 1024 * 1024),
        num_channels=args.channels,
        comm_dtype=jnp.bfloat16 if args.comm_dtype == "bf16"
        else jnp.float32)

    cells = []
    arch_ids = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    for aid in arch_ids:
        arch = ARCHS[aid]
        names = [s.name for s in arch.shapes] \
            if (args.all or not args.shape) else [args.shape]
        for sn in names:
            cells.append((aid, sn))

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))
    if args.mesh.count("x") == 1:   # e.g. --mesh 64x4: same 256 chips,
        import jax                   # different (data, model) factorization
        from jax.sharding import AxisType
        d_, m_ = (int(v) for v in args.mesh.split("x"))
        alt = jax.make_mesh((d_, m_), ("data", "model"),
                            axis_types=(AxisType.Auto,) * 2)
        meshes.append((args.mesh, alt))
    if args.mesh.count("x") == 2:   # e.g. --mesh 4x16x16: N-pod mesh
        import jax                   # (needs XLA_FLAGS device count >= P*D*M)
        from jax.sharding import AxisType
        p_, d_, m_ = (int(v) for v in args.mesh.split("x"))
        alt = jax.make_mesh((p_, d_, m_), ("pod", "data", "model"),
                            axis_types=(AxisType.Auto,) * 3)
        meshes.append((args.mesh, alt))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r.get("mesh_name"), r.get("tag", ""),
             r.get("strategy"), r.get("reducer"))
            for r in results}

    for mesh_name, mesh in meshes:
        for aid, sn in cells:
            key = (aid, sn, mesh_name, args.tag, args.strategy, args.reducer)
            if key in done:
                print(f"[dryrun] SKIP (cached) {aid} {sn} {mesh_name}")
                continue
            print(f"[dryrun] {aid} × {sn} × {mesh_name} ...", flush=True)
            try:
                rec = lower_cell(aid, sn, mesh, sync=sync,
                                 overrides=overrides)
            except Exception as e:
                rec = {"arch": aid, "shape": sn, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            rec["mesh_name"] = mesh_name
            rec["tag"] = args.tag
            rec.setdefault("strategy", args.strategy)
            rec.setdefault("reducer", args.reducer)
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
            status = rec["status"]
            extra = (f" compile={rec.get('compile_s')}s"
                     if status == "ok" else
                     f" {rec.get('error', rec.get('note', ''))[:120]}")
            print(f"[dryrun]   -> {status}{extra}", flush=True)

    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    er = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] total: {ok} ok, {sk} skipped, {er} error")
    return 1 if er else 0


if __name__ == "__main__":
    raise SystemExit(main())
