"""Production meshes.  Functions, not module-level constants — importing
this module never touches jax device state."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh(data: int = 1, model: int = 1, stage: int = 0):
    """Tiny mesh for CPU tests; axes always present so all collective code
    paths run (psum over size-1 axes is the identity).  ``stage >= 1``
    inserts a "stage" axis between "data" and "model" — dp×stage×tp,
    the §15 pipeline smoke topology (extent 1 keeps the staged code path
    with a trivial pipeline: the bit-exact stage=1 reference).  The
    default 0 keeps the legacy two-axis mesh."""
    if stage >= 1:
        n = data * stage * model
        return jax.make_mesh(
            (data, stage, model), ("data", "stage", "model"),
            axis_types=(AxisType.Auto,) * 3,
            devices=jax.devices()[:n])
    n = data * model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto,) * 2,
        devices=jax.devices()[:n])


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
