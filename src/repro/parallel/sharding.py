"""Sharding rules: map parameter/activation logical axes to mesh axes.

Conventions (see DESIGN.md §5):
  - data-parallel axes: ("pod", "data") when present (multi-pod) else ("data",)
  - tensor-parallel axis: "model"

Parameter PartitionSpecs are derived from a per-param annotation attached by
the model code (each module names which of its weight dims is sharded over
"model").  Everything not mentioned is replicated.

``missing_axes(spec, mesh_axes)`` gives the mesh axes a gradient for that
param must still be reduced over after ``jax.grad`` inside ``shard_map``:
the complement of the axes appearing in its spec.  This is the general
correctness rule used by every grad-sync strategy in ``repro.core``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"
DP_AXES = ("pod", "data")  # subset actually present in the mesh is used


def dp_axes_of(mesh: Mesh | jax.sharding.AbstractMesh) -> tuple[str, ...]:
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def flat_spec_axes(spec: P) -> set[str]:
    out: set[str] = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            out.update(entry)
        else:
            out.add(entry)
    return out


def missing_axes(spec: P, mesh: Mesh | jax.sharding.AbstractMesh) -> tuple[str, ...]:
    """Mesh axes NOT appearing in ``spec`` — grads must be psum'd over these."""
    have = flat_spec_axes(spec)
    return tuple(a for a in mesh.axis_names if a not in have)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Regex → PartitionSpec table, first match wins.

    Rules map parameter *names* (the stable KVStore keys from
    ``repro.utils.trees``) to PartitionSpecs.  Model definitions register
    their rules via ``param_rules()``; configs may override (a §Perf lever).
    """

    rules: tuple[tuple[str, P], ...]
    default: P = P()

    def spec(self, name: str) -> P:
        for pat, spec in self.rules:
            if re.search(pat, name):
                return spec
        return self.default

    def tree_specs(self, params: Any) -> Any:
        from repro.utils.trees import flatten_with_names, unflatten_from_names

        named, treedef = flatten_with_names(params)
        return unflatten_from_names(treedef, [self.spec(n) for n, _ in named])

    def shardings(self, params: Any, mesh: Mesh) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.tree_specs(params)
        )


def spec_for_param(rules: ShardingRules, name: str) -> P:
    return rules.spec(name)


def reduce_axes_tree(
    rules: ShardingRules, params: Any, prefix: str, mesh_axes: tuple[str, ...]
) -> Any:
    """Per-leaf gradient-reduction axis groups (for depcha in-scan sync).

    For each param leaf (named ``prefix + path``): the mesh axes NOT in its
    PartitionSpec — DP axes for TP-sharded leaves, DP + "model" for
    replicated leaves (see DESIGN.md §grad-reduction rule).
    """
    from repro.utils.trees import flatten_with_names, unflatten_from_names

    named, _ = flatten_with_names(params)
    axes = []
    for n, _ in named:
        have = flat_spec_axes(rules.spec(prefix + n))
        axes.append(tuple(a for a in mesh_axes if a not in have))
    return axes  # flat list, aligned with tree_flatten order of ``params``


def localize_structs(tree: Any, specs: Any, mesh) -> Any:
    """Global ShapeDtypeStructs → per-device local shard structs."""
    def one(leaf, spec):
        shape = list(leaf.shape)
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            for a in axes:
                shape[dim] //= mesh.shape[a]
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)

    return jax.tree.map(one, tree, specs,
                        is_leaf=lambda x: hasattr(x, "shape"))


def stage_shard_specs(
    specs: Any,
    *,
    axis: str = "stage",
    prefixes: tuple[str, ...] = ("blocks/",),
) -> Any:
    """Overlay pipeline-stage sharding on a param-spec tree (DESIGN.md
    §15): the layer-stack dim (dim 0) of every block param is sharded
    over ``axis``, so each pipeline stage holds a contiguous slice of
    the stacked layers.  Every other leaf keeps its spec — replicated
    over the stage axis, which is exactly what ``missing_axes`` needs to
    route their gradients through a psum over ``axis`` (the off-stage
    contributions are where-masked exact zeros, so that psum is a
    bit-exact broadcast of the owning stage's gradient)."""
    from repro.utils.trees import flatten_with_names, unflatten_from_names

    named, treedef = flatten_with_names(specs)
    out = []
    for n, s in named:
        if any(n.startswith(p) for p in prefixes):
            entries = list(s) if len(s) else [None]
            if entries[0] is not None:
                raise ValueError(
                    f"stage overlay: {n} already shards its stack dim "
                    f"over {entries[0]!r}")
            entries[0] = axis
            s = P(*entries)
        out.append(s)
    return unflatten_from_names(treedef, out)


def batch_spec(mesh: Mesh | jax.sharding.AbstractMesh) -> P:
    """Batch dim sharded over every data-parallel axis present."""
    dp = dp_axes_of(mesh)
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))


def local_batch(global_batch: int, mesh: Mesh | jax.sharding.AbstractMesh) -> int:
    n = 1
    for a in dp_axes_of(mesh):
        n *= mesh.shape[a]
    if global_batch % n:
        raise ValueError(f"global_batch {global_batch} not divisible by DP={n}")
    return global_batch // n
