from repro.parallel.sharding import (
    DP_AXES,
    MODEL_AXIS,
    ShardingRules,
    missing_axes,
    spec_for_param,
)

__all__ = [
    "DP_AXES",
    "MODEL_AXIS",
    "ShardingRules",
    "missing_axes",
    "spec_for_param",
]
