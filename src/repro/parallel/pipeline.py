"""Pipeline parallelism skeleton: GPipe-style microbatch schedule over a
"stage" mesh axis, collective-permute for activations between stages.

Not used by the assigned shapes (TP×DP covers them — DESIGN.md §5), but
the mechanism ships tested: stages are a shard_map'd scan over microbatch
waves where each device holds one stage's params and passes activations
to its +1 neighbour via ``jax.lax.ppermute``.  Bubble fraction =
(S−1)/(M+S−1) for S stages, M microbatches.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,          # this device's stage params (stacked dim 0
                                # removed by shard_map over axis "stage")
    microbatches: jax.Array,    # (M, mb, ...) input microbatches
    *,
    axis: str = "stage",
    n_stages: int,
) -> jax.Array:
    """Run M microbatches through S pipeline stages; returns outputs in
    microbatch order.  Must run inside shard_map with ``axis`` in the
    mesh.  Each device applies its stage to whatever wave it holds, then
    ppermutes the activation ring one step."""
    M = microbatches.shape[0]
    sid = jax.lax.axis_index(axis)
    n_waves = M + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    mb_shape = microbatches.shape[1:]

    def wave(carry, t):
        in_flight, outputs = carry
        # stage 0 injects microbatch t (if any remain)
        inject = jnp.where(t < M, t, 0)
        fresh = microbatches[inject]
        x = jnp.where(sid == 0, fresh, in_flight)
        y = stage_fn(stage_params, x)
        # last stage emits a finished microbatch (wave t → mb t-S+1)
        done_idx = t - (n_stages - 1)
        emit = jnp.logical_and(sid == n_stages - 1, done_idx >= 0)
        outputs = jax.lax.cond(
            jnp.any(emit),
            lambda o: o.at[jnp.maximum(done_idx, 0)].set(
                jnp.where(emit, y, o[jnp.maximum(done_idx, 0)])),
            lambda o: o,
            outputs)
        # rotate activations forward one stage
        nxt = jax.lax.ppermute(y, axis, perm)
        return (nxt, outputs), None

    init = (jnp.zeros_like(microbatches[0]),
            jnp.zeros((M, *mb_shape), microbatches.dtype))
    (_, outputs), _ = jax.lax.scan(
        wave, init, jnp.arange(n_waves, dtype=jnp.int32))
    # outputs live on the last stage; broadcast so every stage returns them
    outputs = jax.lax.psum(
        jnp.where(sid == n_stages - 1, outputs, 0.0), axis)
    return outputs


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
