"""Pipeline parallelism over a "stage" mesh axis (DESIGN.md §15).

Two layers:

- ``pipeline_forward`` — the original GPipe-style inference skeleton:
  stages are a shard_map'd scan over microbatch waves where each device
  holds one stage's params and passes activations to its +1 neighbour
  via ``jax.lax.ppermute``.  Bubble fraction = (S−1)/(M+S−1) for S
  stages, M microbatches.

- ``pipeline_wave_loss`` — the differentiable training counterpart used
  by ``runtime.train_loop`` (``pp_stages > 1``): the same wave structure
  but carrying an arbitrary pytree (activations + aux) and emitting a
  per-microbatch loss on the last stage.  Warmup/drain garbage is killed
  by ``jnp.where`` masks, whose VJP is an exact zero on the discarded
  branch — so off-wave compute contributes bit-exact zeros to every
  gradient and a staged run matches its stage=1 reference exactly.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,          # this device's stage params (stacked dim 0
                                # removed by shard_map over axis "stage")
    microbatches: jax.Array,    # (M, mb, ...) input microbatches
    *,
    axis: str = "stage",
    n_stages: int,
    broadcast: str = "psum",    # "psum" | "hop"
) -> jax.Array:
    """Run M microbatches through S pipeline stages; returns outputs in
    microbatch order.  Must run inside shard_map with ``axis`` in the
    mesh.  Each device applies its stage to whatever wave it holds, then
    ppermutes the activation ring one step.

    ``broadcast`` picks how the finished outputs (which live on the last
    stage) reach the caller: "psum" masks every other stage to
    ``zeros_like`` and sums — the result is replicated on all stages and
    the mask keeps integer outputs integer (a ``0.0`` fill would upcast
    them, and an unmasked psum would sum S stale buffers); "hop" is the
    cheaper one-hop alternative — a single ppermute moves the buffer
    last→first instead of all-reducing it, so only stage 0 holds valid
    outputs (other stages see zeros).
    """
    M = microbatches.shape[0]
    sid = jax.lax.axis_index(axis)
    n_waves = M + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    mb_shape = microbatches.shape[1:]

    def wave(carry, t):
        in_flight, outputs = carry
        # stage 0 injects microbatch t (if any remain)
        inject = jnp.where(t < M, t, 0)
        fresh = microbatches[inject]
        x = jnp.where(sid == 0, fresh, in_flight)
        y = stage_fn(stage_params, x)
        # last stage emits a finished microbatch (wave t → mb t-S+1)
        done_idx = t - (n_stages - 1)
        emit = jnp.logical_and(sid == n_stages - 1, done_idx >= 0)
        outputs = jax.lax.cond(
            jnp.any(emit),
            lambda o: o.at[jnp.maximum(done_idx, 0)].set(
                jnp.where(emit, y, o[jnp.maximum(done_idx, 0)])),
            lambda o: o,
            outputs)
        # rotate activations forward one stage
        nxt = jax.lax.ppermute(y, axis, perm)
        return (nxt, outputs), None

    init = (jnp.zeros_like(microbatches[0]),
            jnp.zeros((M, *mb_shape), microbatches.dtype))
    (_, outputs), _ = jax.lax.scan(
        wave, init, jnp.arange(n_waves, dtype=jnp.int32))
    # outputs live on the last stage; mask with zeros_like (NOT 0.0 — that
    # would upcast integer outputs) so the psum adds exact zeros
    masked = jnp.where(sid == n_stages - 1, outputs,
                       jnp.zeros_like(outputs))
    if broadcast == "hop":
        return jax.lax.ppermute(masked, axis, [(n_stages - 1, 0)])
    if broadcast != "psum":
        raise ValueError(f"broadcast must be 'psum' or 'hop', "
                         f"got {broadcast!r}")
    return jax.lax.psum(masked, axis)


def pipeline_wave_loss(
    inject_fn: Callable[[jax.Array], Any],
    stage_fn: Callable[[Any], Any],
    loss_fn: Callable[[Any, jax.Array], jax.Array],
    n_microbatches: int,
    *,
    n_stages: int,
    axis: str = "stage",
) -> jax.Array:
    """Differentiable wave pipeline for TRAINING (inside shard_map over
    ``axis``).  Each device runs one stage; every wave it applies its
    stage to whatever carry it holds, then the carry ring-rotates one
    stage forward.  Returns the (M,) per-microbatch scalar losses —
    nonzero ONLY on the last stage (psum over ``axis`` OUTSIDE the grad
    makes the value global; adding the other stages' exact zeros keeps
    it bit-identical to the last stage's local value).

    - ``inject_fn(m)`` → the carry for microbatch ``m`` entering stage 0
      (e.g. the embedded tokens plus a zero aux accumulator).
    - ``stage_fn(carry)`` → carry after this device's layer slice.
    - ``loss_fn(carry, m)`` → scalar local loss for microbatch ``m``
      (the head + xent; only the last stage's value survives the mask).

    Exactness: warmup/drain waves process garbage, but every garbage
    path dies in a ``jnp.where`` (stage-0 inject overwrites the wrapped
    ring carry; the (M,) mask drops off-wave losses) or in the discarded
    final carry — all of which backpropagate exact-zero cotangents, so
    gradients accumulate the same per-microbatch terms in the same order
    as a stage=1 run of the identical code (plus exact ``+0.0`` terms).
    """
    M, S = n_microbatches, n_stages
    sid = jax.lax.axis_index(axis)
    last = S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]
    mb_ix = jnp.arange(M)

    def rotate(carry):
        return jax.tree.map(
            lambda a: jax.lax.ppermute(a, axis, perm), carry)

    def wave(carry, t):
        in_flight, losses = carry
        fresh = inject_fn(jnp.clip(t, 0, M - 1))
        x = jax.tree.map(lambda f, c: jnp.where(sid == 0, f, c),
                         fresh, in_flight)
        y = stage_fn(x)
        md = jnp.clip(t - last, 0, M - 1)
        val = loss_fn(y, md)
        mask = jnp.logical_and(
            jnp.logical_and(sid == last, t - last >= 0), mb_ix == md)
        losses = jnp.where(mask, val, losses)
        return (rotate(y), losses), None

    # zeros_like keeps only shapes — XLA drops the inject compute
    init = (jax.tree.map(jnp.zeros_like, inject_fn(jnp.int32(0))),
            jnp.zeros((M,), jnp.float32))
    (_, losses), _ = jax.lax.scan(
        wave, init, jnp.arange(M + S - 1, dtype=jnp.int32))
    return losses


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
