"""Elastic scaling demo — online-first (DESIGN.md §13).

Part 1 (online): a fault-injecting `repro.elastic.Supervisor` drives
ZeRO-1 training through a live 8→4→8 device cycle: a transient step
fault is retried in place, checkpoint-I/O faults are absorbed by the
manager's backoff, and a simulated rank loss at step 5 shrinks the mesh
tp4→tp2 by resharding params AND optimizer shards as *scheduled*
RESHARD/REGROUP collectives — then grows back. A clean scripted replay
of the same mesh trajectory reproduces the faulty run bit-for-bit.

Part 2 (offline fallback): the original checkpoint-round-trip resize —
restore an 8-device checkpoint onto a 4-device mesh via
`checkpoint.reshard` — kept for the cold-restart path where no live
group survives.

This script forces 8 fake CPU devices, so run it standalone:

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import repro  # noqa: F401  (applies the jaxcompat shim before jax imports)
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.checkpoint import CheckpointManager, reshard
from repro.core import GradSyncConfig
from repro.data import TokenPipeline
from repro.elastic import FaultPlan, Supervisor
from repro.models import transformer as tf
from repro.models.registry import family_of
from repro.optim import adamw, zero1
from repro.runtime import Trainer, make_train_step
from repro.utils.trees import named_leaves


def mk_mesh(data, model, n):
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2,
                         devices=jax.devices()[:n])


def maxdiff(a, b):
    return max((float(np.max(np.abs(np.asarray(x, np.float32)
                                    - np.asarray(y, np.float32))))
                for (_, x), (_, y) in zip(named_leaves(a),
                                          named_leaves(b))
                if np.asarray(x).size), default=0.0)


# ---------------------------------------------------------------- online

def mk_cfg(tp):
    return tf.TransformerConfig(
        name="elastic", n_layers=2, d_model=64, n_heads=8, kv_heads=2,
        d_ff=128, vocab=96, tp=tp, attn_chunk=16, dtype=jnp.float32)


MESHES = {"tp4": ((2, 4), 8, 4), "tp2": ((2, 2), 4, 2)}
_BUILT = {}


def build_for(key):
    """Builder for the Supervisor's ladder: one (train_step, pipeline,
    placed_params) per mesh rung. The batch schedule is mesh-independent
    (same seed, same dp extent), so a replay sees identical data."""
    if key not in _BUILT:
        dims, ndev, tp = MESHES[key]
        mesh = mk_mesh(*dims, ndev)
        cfg = mk_cfg(tp)
        pipe = TokenPipeline(96, 32, 8, seed=5, mesh=mesh)
        params = family_of(cfg).init(jax.random.PRNGKey(2), mk_cfg(1))
        sync = GradSyncConfig(strategy="concom", bucket_bytes=1 << 12,
                              exclude_axes=("data",))
        ts = make_train_step(
            cfg, mesh, sync, zero1(adamw(1e-3), ("data",), 2),
            batch_like=pipe.batch_at(0), params_like=params,
            zero1_mode=True, clip_norm=0.0)
        ps = jax.device_put(params, ts.shardings(ts.param_specs))
        _BUILT[key] = (ts, pipe, ps)
    return _BUILT[key]


def online():
    plan = FaultPlan(rank_loss=frozenset({5}), transient=frozenset({2}),
                     step_retries=1, ckpt_io_faults=2, ckpt_retries=3)
    with tempfile.TemporaryDirectory() as root:
        sup = Supervisor(build_for, ("tp4", "tp2"), root, plan=plan,
                         every=4, grow_back_after=5)
        params, opt, rep = sup.run(12)
    for t in rep["transitions"]:
        print(f"[elastic] {t['reason']}: {t['from_key']}->{t['to_key']} "
              f"@ step {t['resume_step']}, "
              f"{t['reshard_bytes'] / 1e6:.2f} MB resharded in "
              f"{t['latency_s'] * 1e3:.0f} ms")

    # replay the realized mesh trajectory with zero faults: bit-exact
    with tempfile.TemporaryDirectory() as root:
        clean = Supervisor(build_for, ("tp4", "tp2"), root,
                           script=rep["script"], every=4,
                           printer=lambda s: None)
        p2, o2, _ = clean.run(12)
    d = max(maxdiff(params, p2), maxdiff(opt, o2))
    print(f"[elastic] faulty vs clean scripted replay: maxdiff {d:g}")
    assert d == 0.0
    print("[elastic] online 8->4->8 cycle under faults: OK")


# --------------------------------------------------------------- offline

def build_plain(cfg, mesh, pipe, params_like):
    opt = adamw(1e-3)
    ts = make_train_step(
        cfg, mesh, GradSyncConfig(strategy="depcha", num_channels=2),
        opt, batch_like=pipe.batch_at(0), params_like=params_like)
    return opt, ts


def offline():
    """Cold-restart fallback: no live group survives, so resize goes
    through a checkpoint round-trip (`checkpoint.reshard`)."""
    mesh8 = mk_mesh(2, 4, 8)
    cfg8 = tf.TransformerConfig(
        name="elastic", n_layers=2, d_model=64, n_heads=8, kv_heads=4,
        d_ff=128, vocab=128, tp=4, attn_chunk=32, dtype=jnp.float32,
        depcha_in_scan=True)
    pipe8 = TokenPipeline(cfg8.vocab, 32, 8, seed=5, mesh=mesh8)
    params = tf.init_params(jax.random.PRNGKey(0), cfg8)
    rules8 = family_of(cfg8).param_rules(cfg8)
    params = reshard(params, rules8, mesh8)

    with tempfile.TemporaryDirectory() as ckdir:
        ckpt = CheckpointManager(ckdir, every=10, keep=2, blocking=True)
        opt, ts = build_plain(cfg8, mesh8, pipe8, params)
        trainer = Trainer(ts, pipe8, ckpt, log_every=10)
        params, opt_state, _ = trainer.run(params, opt.init(params), 20)
        print("[elastic] trained 20 steps on 8 devices (2 DP x 4 TP)")

        # ---- the whole fleet restarted: only 4 devices come back ----
        mesh4 = mk_mesh(2, 2, 4)
        cfg4 = tf.TransformerConfig(
            name="elastic", n_layers=2, d_model=64, n_heads=8, kv_heads=4,
            d_ff=128, vocab=128, tp=2, attn_chunk=32, dtype=jnp.float32,
            depcha_in_scan=True)
        rules4 = family_of(cfg4).param_rules(cfg4)
        pipe4 = TokenPipeline(cfg4.vocab, 32, 8, seed=5, mesh=mesh4)

        step, state = ckpt.restore(
            {"params": jax.tree.map(np.asarray, params),
             "opt": jax.tree.map(np.asarray, opt_state)})
        params4 = reshard(state["params"], rules4, mesh4)
        opt4, ts4 = build_plain(cfg4, mesh4, pipe4, params4)
        # optimizer state is param-shaped: reshard each sub-tree
        opt_state4 = {
            k: reshard(v, rules4, mesh4) for k, v in state["opt"].items()}
        trainer4 = Trainer(ts4, pipe4, None, log_every=10)
        params4, _, hist = trainer4.run(params4, opt_state4, 40,
                                        start_step=step)
        print(f"[elastic] resumed at step {step} on 4 devices (2 DP x "
              f"2 TP); final loss {hist['losses'][-1]:.3f}")
        print("[elastic] offline checkpoint-reshard fallback: OK")


def main():
    online()
    offline()


if __name__ == "__main__":
    main()
