"""Elastic scaling demo: train on an 8-device mesh, lose half the fleet,
restore the checkpoint onto a 4-device mesh (re-sharded), and continue —
the node-failure recovery path at mesh granularity.

This script forces 8 fake CPU devices, so run it standalone:

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.checkpoint import CheckpointManager, reshard
from repro.core import GradSyncConfig
from repro.data import TokenPipeline
from repro.models import transformer as tf
from repro.models.registry import family_of
from repro.optim import adamw
from repro.runtime import Trainer, make_train_step


def mk_mesh(data, model, n):
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2,
                         devices=jax.devices()[:n])


def build(cfg, mesh, pipe, params_like):
    opt = adamw(1e-3)
    ts = make_train_step(
        cfg, mesh, GradSyncConfig(strategy="depcha", num_channels=2),
        opt, batch_like=pipe.batch_at(0), params_like=params_like)
    return opt, ts


def main():
    mesh8 = mk_mesh(2, 4, 8)
    cfg8 = tf.TransformerConfig(
        name="elastic", n_layers=2, d_model=64, n_heads=8, kv_heads=4,
        d_ff=128, vocab=128, tp=4, attn_chunk=32, dtype=jnp.float32,
        depcha_in_scan=True)
    pipe8 = TokenPipeline(cfg8.vocab, 32, 8, seed=5, mesh=mesh8)
    params = tf.init_params(jax.random.PRNGKey(0), cfg8)
    api = family_of(cfg8)
    rules8 = api.param_rules(cfg8)
    params = reshard(params, rules8, mesh8)

    with tempfile.TemporaryDirectory() as ckdir:
        ckpt = CheckpointManager(ckdir, every=10, keep=2, blocking=True)
        opt, ts = build(cfg8, mesh8, pipe8, params)
        trainer = Trainer(ts, pipe8, ckpt, log_every=10)
        params, opt_state, _ = trainer.run(params, opt.init(params), 20)
        print("[elastic] trained 20 steps on 8 devices (2 DP x 4 TP)")

        # ---- simulate losing a pod: only 4 devices remain ----
        mesh4 = mk_mesh(2, 2, 4)
        cfg4 = tf.TransformerConfig(
            name="elastic", n_layers=2, d_model=64, n_heads=8, kv_heads=4,
            d_ff=128, vocab=128, tp=2, attn_chunk=32, dtype=jnp.float32,
            depcha_in_scan=True)
        rules4 = family_of(cfg4).param_rules(cfg4)
        pipe4 = TokenPipeline(cfg4.vocab, 32, 8, seed=5, mesh=mesh4)

        step, state = ckpt.restore(
            {"params": jax.tree.map(np.asarray, params),
             "opt": jax.tree.map(np.asarray, opt_state)})
        params4 = reshard(state["params"], rules4, mesh4)
        opt4, ts4 = build(cfg4, mesh4, pipe4, params4)
        # optimizer state is param-shaped: reshard each sub-tree
        opt_state4 = {
            k: reshard(v, rules4, mesh4) for k, v in state["opt"].items()}
        trainer4 = Trainer(ts4, pipe4, None, log_every=10)
        params4, _, hist = trainer4.run(params4, opt_state4, 40,
                                        start_step=step)
        print(f"[elastic] resumed at step {step} on 4 devices (2 DP x "
              f"2 TP); final loss {hist['losses'][-1]:.3f}")
        print("[elastic] checkpoint-reshard elastic scaling: OK")


if __name__ == "__main__":
    main()
