"""Batched serving demo: a small LM behind the RequestQueue front-end
(batched greedy decode with a sharded KV cache).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as tf
from repro.runtime import Server
from repro.runtime.serve_loop import RequestQueue


def main():
    mesh = make_smoke_mesh(1, 1)
    cfg = tf.TransformerConfig(
        name="serve-lm", n_layers=4, d_model=128, n_heads=8, kv_heads=2,
        d_ff=256, vocab=512, tp=1, attn_chunk=64, dtype=jnp.float32)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, mesh, params, max_len=64)
    queue = RequestQueue(server, batch=4, timeout_s=0.1)

    rng = np.random.default_rng(0)
    handles = []
    t0 = time.perf_counter()
    for i in range(10):
        prompt = rng.integers(1, cfg.vocab, size=rng.integers(4, 12),
                              dtype=np.int32)
        handles.append((i, prompt, queue.submit(prompt, max_new=8)))

    served = 0
    while served < 10:
        served += queue.serve_once()
    dt = time.perf_counter() - t0

    for i, prompt, h in handles:
        out = h.get(timeout=10)
        print(f"req {i}: prompt[{len(prompt)}] -> {out.tolist()}")
    print(f"served 10 requests in {dt:.2f}s (batch=4, greedy, KV cache)")


if __name__ == "__main__":
    main()
