"""Quickstart: train a small LM with the paper's collective-embedding
strategies, checkpoint it, and serve greedy continuations.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import GradSyncConfig
from repro.data import TokenPipeline
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as tf
from repro.optim import adamw, cosine_warmup
from repro.runtime import Server, Trainer, make_train_step


def main():
    mesh = make_smoke_mesh(1, 1)   # axes (data, model) — same code path
    cfg = tf.TransformerConfig(    # as the 256-chip production mesh
        name="quickstart-lm", n_layers=4, d_model=128, n_heads=8,
        kv_heads=4, d_ff=256, vocab=512, tp=1, attn_chunk=64,
        dtype=jnp.float32)
    pipe = TokenPipeline(cfg.vocab, 64, 8, seed=0, mesh=mesh)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(cosine_warmup(1e-3, 20, 200))

    # the paper's DepCha design: per-layer gradient collectives emitted
    # inside the backward scan, overlapping the remaining backprop
    step = make_train_step(
        cfg, mesh,
        GradSyncConfig(strategy="depcha", num_channels=4,
                       bucket_bytes=1 << 16),
        opt, batch_like=pipe.batch_at(0), params_like=params)

    with tempfile.TemporaryDirectory() as ckdir:
        ckpt = CheckpointManager(ckdir, every=50, keep=2)
        trainer = Trainer(step, pipe, ckpt, log_every=25)
        params, opt_state, hist = trainer.run(
            params, opt.init(params), num_steps=200)
        print(f"loss: {hist['losses'][0]:.3f} -> {hist['losses'][-1]:.3f}")

    server = Server(cfg, mesh, params, max_len=96)
    prompts = np.array([[1, 2, 3, 4, 5, 6, 7, 8]] * 4, np.int32)
    out = server.generate(prompts, max_new=16)
    print("generated:", out[0].tolist())


if __name__ == "__main__":
    main()
