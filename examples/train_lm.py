"""End-to-end training driver: a scaled-down qwen3-family model trained
for a few hundred steps on CPU, with checkpointing and failure recovery.
``--scale 100m --steps 300`` reproduces the deliverable-size run on real
hardware (on this CPU container it defaults to ~10M × 120 steps).

    PYTHONPATH=src python examples/train_lm.py [--scale 10m] [--steps 120]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import GradSyncConfig, get_strategy, strategy_names
from repro.data import TokenPipeline
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as tf
from repro.optim import adamw, cosine_warmup
from repro.runtime import Trainer, make_train_step

SCALES = {
    # name: (layers, d_model, heads, kv, ff, vocab) ≈ params
    "1m": (2, 128, 4, 2, 256, 2048),
    "10m": (4, 256, 8, 4, 1024, 8192),
    "100m": (12, 768, 12, 4, 2048, 32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="10m", choices=sorted(SCALES))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--strategy", default="depcha",
                    choices=strategy_names())
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    L, d, h, kv, ff, vocab = SCALES[args.scale]
    mesh = make_smoke_mesh(1, 1)
    cfg = tf.TransformerConfig(
        name=f"lm-{args.scale}", n_layers=L, d_model=d, n_heads=h,
        kv_heads=kv, d_ff=ff, vocab=vocab, qk_norm=True, tp=1,
        attn_chunk=min(args.seq, 512), dtype=jnp.float32,
        depcha_in_scan=get_strategy(args.strategy).uses_in_scan)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"strategy={args.strategy}")

    pipe = TokenPipeline(vocab, args.seq, args.batch, seed=0, mesh=mesh)
    opt = adamw(cosine_warmup(3e-4, args.steps // 10, args.steps))
    ts = make_train_step(
        cfg, mesh,
        GradSyncConfig(strategy=args.strategy, num_channels=4),
        opt, batch_like=pipe.batch_at(0), params_like=params)

    with tempfile.TemporaryDirectory() as ckdir:
        ckpt = CheckpointManager(ckdir, every=max(args.steps // 4, 10),
                                 keep=2)
        trainer = Trainer(ts, pipe, ckpt, log_every=10)
        params, _, hist = trainer.run(params, opt.init(params), args.steps)
    print(f"[train] done: loss {hist['losses'][0]:.3f} -> "
          f"{hist['losses'][-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
