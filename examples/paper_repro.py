"""Paper reproduction driver: (1) the paper's python API (Figs 5, 8, 10)
ported line-for-line onto our KVStore; (2) ResNet-50/CIFAR training with
each strategy (paper §5.1 setting, reduced scale for CPU); (3) the
calibrated Fig 13–16 tables with the paper's claims checked.

    PYTHONPATH=src python examples/paper_repro.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.paper_figures import fig13, fig14, fig16, validate
from repro.configs import get_arch
from repro.core import GradSyncConfig, KVStore
from repro.data import ImagePipeline
from repro.launch.mesh import make_smoke_mesh
from repro.models.registry import family_of
from repro.optim import sgd, linear_scaling_rule
from repro.runtime import Trainer, make_train_step


def paper_api_demo(mesh):
    """Paper Fig 10 (DepCha python): push all keys, then pull + update."""
    grads = {k: jnp.ones((8, 8)) * (k + 1) for k in range(4)}

    def train_iter(grads):
        kv = KVStore.create("depCha".lower(),
                            reduce_axes=("data",), num_channels=2)
        for key in range(4):                    # Fig 10 line 6-7
            kv.push(key, grads[key])
        outs = {}
        for key in range(4):                    # Fig 10 line 8-11
            outs[key] = kv.pull(key)
            # SGD.Update(params[key], outs[key], rescale=1/mb) happens in
            # repro.runtime via the optimizer
        return outs

    gspecs = {k: P() for k in grads}
    outs = jax.jit(lambda g: jax.shard_map(
        train_iter, mesh=mesh, in_specs=(gspecs,), out_specs=gspecs,
        check_vma=False)(g))(grads)
    ok = all(bool(jnp.allclose(outs[k], grads[k])) for k in range(4))
    print(f"[paper-api] KVStore DepCha push/pull roundtrip: "
          f"{'OK' if ok else 'MISMATCH'}")


def cifar_strategies(mesh, steps=8):
    """Paper §5.1: ResNet-50 on CIFAR, one strategy per run (reduced)."""
    arch = get_arch("resnet50-cifar")
    cfg = arch.make_smoke()
    api = family_of(cfg)
    pipe = ImagePipeline(cfg.img_size, cfg.num_classes, 8, mesh=mesh)
    params = api.init(jax.random.PRNGKey(0), cfg)
    # paper §5.2: linear LR scaling with worker count
    lr = linear_scaling_rule(0.1, 256, 256)
    opt = sgd(lr, momentum=0.9)
    for strat in ("funnel", "concom", "depcha"):
        ts = make_train_step(
            cfg, mesh, GradSyncConfig(strategy=strat, num_channels=4),
            opt, batch_like=pipe.batch_at(0), params_like=params)
        tr = Trainer(ts, pipe, None, log_every=1000)
        _, _, hist = tr.run(params, opt.init(params), steps)
        print(f"[cifar] {strat:7s} loss {hist['losses'][0]:.3f} -> "
              f"{hist['losses'][-1]:.3f} "
              f"(identical math, schedule differs)")


def figures():
    print("\n[fig13] CIFAR ResNet-50 epoch seconds (funnel/concom/depcha)")
    for n, f, c, d in fig13():
        print(f"   {n:3d} GPUs: {f:7.1f} {c:7.1f} {d:7.1f}")
    print("[fig14] ImageNet Inception-BN epoch seconds")
    for n, f, c, d in fig14():
        print(f"   {n:3d} GPUs: {f:7.1f} {c:7.1f} {d:7.1f}")
    print("[fig16] ImageNet ResNet-50 DepCha scaling")
    for n, t in fig16():
        print(f"   {n:3d} GPUs: {t:7.1f}s/epoch")
    v = validate()
    print("[claims]",
          f"DepCha/Funnel(Inception) ≥1.6×: {v['claim_1.6x']} "
          f"(min {v['inception_depcha_speedup_min']:.2f});",
          f"CIFAR gap shrinks by 32 GPUs: {v['claim_gap_shrinks']};",
          f"~50s epoch @256: {v['claim_50s']} "
          f"({v['imagenet_epoch_256']:.0f}s)")


def main():
    mesh = make_smoke_mesh(1, 1)
    paper_api_demo(mesh)
    cifar_strategies(mesh)
    figures()


if __name__ == "__main__":
    main()
