"""HLO schedule evidence: how each strategy's dependency structure lands
in the compiled program (EXPERIMENTS §Paper-validation point 3).

Compiles one small train step per REGISTERED strategy (8 fake devices —
run standalone), plain AND as the ZeRO-1 StepProgram (`<name>+zero1`
rows), and reports, per row:
  - the CommSchedule IR statistics (op count, chain count, longest
    chain, UPDATE-op count) — the planned dependency structure,
    asserted in microseconds.  StepProgram rows carry the per-bucket
    RS→UPDATE→AG triples + the NORM clip op in the same IR,
  - number of HLO collective ops (all-reduce + reduce-scatter +
    all-gather) and how many sit inside the while-loop body (depcha:
    per-layer in-scan psums → pipelinable by XLA),
  - the repro.sim discrete-event prediction for the SAME planned
    schedule on the same 2×4 mesh (step time, exposed comm, overlap;
    UPDATE ops costed as shard-update HBM time) — the simulated
    timeline printed next to the chain stats it explains.

Expected IR shapes: funnel = 1 chain through every bucket; concom and
priority ≈ num_channels chains; rsag = 2 ops (RS+AG) per bucket; auto
delegates to the simulator's predicted winner; `+zero1` rows add
3 ops per dp bucket + 1 NORM.

    PYTHONPATH=src python -m benchmarks.schedule_analysis
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re
import warnings

warnings.filterwarnings("ignore")

_COLL = r"(?:all-reduce|reduce-scatter|all-gather)"


def analyze(strategy: str, zero1: str = "") -> dict:
    """One row: ``zero1`` is "" (plain sync), "scheduled" (StepProgram)
    or "deferred" (phase-split StepProgram — the AGs tagged PRE)."""
    import repro  # noqa: F401  (jaxcompat before jax.sharding imports)
    import jax
    import jax.numpy as jnp
    from jax.sharding import AxisType

    from repro.core import GradSyncConfig, get_strategy
    from repro.data import TokenPipeline
    from repro.models import transformer as tf
    from repro.optim import adamw, zero1 as make_zero1
    from repro.runtime import make_train_step
    from repro.sim import compute_model_for, sim_config_for, simulate

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    cfg = tf.TransformerConfig(
        name="sched", n_layers=4, d_model=64, n_heads=8, kv_heads=4,
        d_ff=128, vocab=128, tp=4, attn_chunk=32, dtype=jnp.float32,
        depcha_in_scan=get_strategy(strategy).uses_in_scan)
    pipe = TokenPipeline(cfg.vocab, 32, 8, mesh=mesh)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = pipe.batch_at(0)
    opt = make_zero1(adamw(1e-3), ("data",), 2) if zero1 else adamw(1e-3)
    ts = make_train_step(
        cfg, mesh,
        GradSyncConfig(strategy=strategy, num_channels=4, bucket_bytes=0,
                       exclude_axes=("data",) if zero1 else ()),
        opt, batch_like=batch, params_like=params, zero1_mode=bool(zero1),
        zero1_plan=zero1 or "scheduled")
    ir = ts.gradsync.schedule.stats()
    phases = ir["phases"]
    # static analyzer verdict for the planned schedule (DESIGN.md §11):
    # "OK" or the distinct pass:code error classes
    from repro.analysis import run_passes

    report = run_passes(
        ts.gradsync.schedule,
        mesh_shape=ts.gradsync.mesh_shape,
        default_reducer=ts.gradsync.cfg.reducer,
        plan_comm_dtype=ts.gradsync.cfg.comm_dtype,
        expect_defer=zero1 == "deferred")
    verdict = "OK" if report.ok else ";".join(report.error_classes)
    # simulated timeline of the SAME planned schedule on this 2×4 mesh
    # (UPDATE/NORM ops of the StepProgram rows costed by the engine;
    # deferred rows in pipelined steady state — PRE gathers at the top)
    mesh_shape = {"data": 2, "model": 4}
    compute = compute_model_for(cfg, global_batch=8, seq_len=32,
                                n_devices=8)
    if zero1 == "deferred":
        from repro.sim import simulate_pipelined

        post, pre = ts.gradsync.schedule.split_phases()
        tl = simulate_pipelined(post, pre, mesh_shape, compute=compute,
                                sim=sim_config_for(strategy))
    else:
        tl = simulate(ts.gradsync.schedule, mesh_shape, compute=compute,
                      sim=sim_config_for(strategy))
    opt_state = ts.init_opt()
    lowered = ts.fn.lower(params, opt_state, batch, jnp.int32(0))
    compiled = lowered.compile()
    hlo = compiled.as_text()
    # measured wall time of the compiled step (8 fake CPU devices —
    # orders overhead, not network) next to the sim prediction
    from repro.obs import host_time_us

    step0 = jnp.int32(0)
    measured_us = host_time_us(
        lambda: compiled(params, opt_state, batch, step0), reps=3)

    total = len(re.findall(rf"= [^=\n]*{_COLL}\(", hlo))
    # collectives inside while-loop bodies (depcha: per-layer in-scan psums)
    body_names = set(re.findall(r"body=%([\w.-]+)", hlo))
    in_loop = 0
    for name in body_names:
        idx = hlo.find("\n%" + name)
        if idx < 0:
            continue
        end = hlo.find("\n}", idx)
        seg = hlo[idx:end if end > 0 else idx + 200000]
        in_loop += len(re.findall(rf"= [^=\n]*{_COLL}\(", seg))
    tag = {"": "", "scheduled": "+zero1", "deferred": "+zero1d"}[zero1]
    return {"strategy": strategy + tag,
            "analyzer": verdict,
            "ir_ops": ir["num_ops"],
            "ir_chains": ir["num_chains"],
            "ir_max_chain": ir["max_chain_len"],
            "ir_update_ops": ir["kinds"].get("update", 0),
            "ir_pre_ops": phases.get("pre", 0),
            "ir_post_ops": phases.get("post", 0),
            "deferred_kb": ts.gradsync.schedule.deferred_bytes() / 1024,
            "collective_ops": total,
            "in_loop_body": in_loop,
            "loop_trip_multiplied": in_loop * 4,   # n_layers=4
            "sim_step_us": tl.step_time * 1e6,
            "sim_exposed_us": tl.exposed_comm * 1e6,
            "sim_overlap": tl.overlap_fraction,
            "measured_us": measured_us,
            "measured_vs_sim": measured_us / (tl.step_time * 1e6)}


def main():
    import repro.sim  # noqa: F401  (registers the "auto" strategy)

    from repro.core import strategy_names

    print("strategy,analyzer,ir_ops,ir_chains,ir_max_chain,ir_update_ops,"
          "ir_pre_ops,ir_post_ops,deferred_kb,"
          "collective_ops_static,in_loop_body,runtime_collectives(~),"
          "sim_step_us,sim_exposed_us,sim_overlap,"
          "measured_us,measured_vs_sim")
    for s in strategy_names():
        for zero1 in ("", "scheduled", "deferred"):
            r = analyze(s, zero1=zero1)
            runtime = (r["collective_ops"] - r["in_loop_body"]
                       + r["loop_trip_multiplied"])
            print(f"{r['strategy']},{r['analyzer']},"
                  f"{r['ir_ops']},{r['ir_chains']},"
                  f"{r['ir_max_chain']},{r['ir_update_ops']},"
                  f"{r['ir_pre_ops']},{r['ir_post_ops']},"
                  f"{r['deferred_kb']:.0f},"
                  f"{r['collective_ops']},"
                  f"{r['in_loop_body']},{runtime},"
                  f"{r['sim_step_us']:.1f},{r['sim_exposed_us']:.1f},"
                  f"{r['sim_overlap']:.2f},"
                  f"{r['measured_us']:.1f},{r['measured_vs_sim']:.2f}")


if __name__ == "__main__":
    main()
