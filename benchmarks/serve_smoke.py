"""Serving smoke (CI gate + BENCH_serve.json artifact, DESIGN.md §14).

Open-loop load benchmark of the two serving paths on 8 fake CPU devices
(dp=2 × tp=4): requests with mixed prompt lengths and budgets arrive on
a fixed schedule regardless of completion (open loop), and each engine
drains them —

  static      — ``RequestQueue`` + ``Server.generate``: batches pad to
                the widest member and decode to the batch-max budget;
  continuous  — ``ContinuousScheduler``: in-flight batching over the
                paged KV pool, per-slot budgets, immediate retire.

Gates: the paged engine must be BIT-exact with the static path under
greedy, and continuous must beat static on BOTH tokens/s and p99 latency
under the mixed open-loop load.  Also reported (non-gating): the
host-sync delta row (device-side token accumulation vs the old
np.asarray-per-token loop) and the decode-plan simulated-vs-measured row
(``repro.sim.serve`` prices a v5e; the measured column is this CPU —
the row records both clocks and their ratio, like the obs trace diff).
Writes BENCH_serve.json with the provenance header (`obs.bench_metadata`).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import warnings

warnings.filterwarnings("ignore")
import json
import queue as queue_mod
import sys
import time

import repro  # noqa: F401  (applies the jaxcompat shim before jax imports)
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.models import transformer as tf
from repro.models.registry import family_of
from repro.runtime import ContinuousScheduler, Server
from repro.runtime.serve_loop import RequestQueue

FAILURES: list[str] = []


def check(name, cond):
    print(("PASS " if cond else "FAIL ") + name, flush=True)
    if not cond:
        FAILURES.append(name)


def mk_cfg():
    return tf.TransformerConfig(
        name="dense", n_layers=2, d_model=64, n_heads=8, kv_heads=4,
        d_ff=128, vocab=96, tp=4, attn_chunk=16, dtype=jnp.float32)


# ------------------------------------------------ open-loop load drivers
def mixed_workload(n, seed=0):
    """(prompt, max_new, arrival_s) triples: few shapes (bounds static
    recompiles), mixed budgets, fixed-rate arrivals."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([8, 16, 32], size=n)
    budgets = rng.choice([4, 8, 16], size=n)
    prompts = [rng.integers(1, 96, size=int(L)).astype(np.int32)
               for L in lens]
    arrivals = np.arange(n) * 0.02
    return prompts, [int(b) for b in budgets], arrivals


def run_static(server, batch, prompts, budgets, arrivals):
    q = RequestQueue(server, batch=batch, timeout_s=0.01)
    return _drive(prompts, budgets, arrivals,
                  submit=lambda p, mn: q.submit(p, mn),
                  pump=lambda: q.serve_once())


def run_continuous(eng, prompts, budgets, arrivals):
    return _drive(prompts, budgets, arrivals,
                  submit=lambda p, mn: eng.submit(p, mn),
                  pump=lambda: eng.step())


def _drive(prompts, budgets, arrivals, *, submit, pump):
    n = len(prompts)
    handles: dict[int, tuple] = {}
    lat, toks = [], 0
    t0 = time.perf_counter()
    i = 0
    while len(lat) < n:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            handles[i] = (submit(prompts[i], budgets[i]), arrivals[i])
            i += 1
        if not handles and i < n:
            time.sleep(max(arrivals[i] - now, 0.0))
            continue
        pump()
        for j, (h, ta) in list(handles.items()):
            try:
                r = h.get_nowait()
            except queue_mod.Empty:
                continue
            if isinstance(r, Exception):
                raise r
            lat.append(time.perf_counter() - t0 - ta)
            toks += int(r.shape[0])
            del handles[j]
    wall = time.perf_counter() - t0
    return {
        "wall_s": round(wall, 3),
        "tokens": toks,
        "tokens_per_s": round(toks / wall, 2),
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
    }


def main():
    t_start = time.time()
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    cfg = mk_cfg()
    params = family_of(cfg).init(jax.random.PRNGKey(7), cfg)
    srv = Server(cfg, mesh, params, max_len=64)
    eng = ContinuousScheduler(srv, slots=8, block_size=16, chunk=4)

    # 1. bit-exactness gate: paged continuous ≡ static under greedy
    rng = np.random.default_rng(11)
    bx_prompts = [rng.integers(1, 96, size=int(L)).astype(np.int32)
                  for L in (5, 12, 17, 3, 30, 9)]
    outs = eng.generate_batch(bx_prompts, 10)
    exact = all(
        np.array_equal(srv.generate(np.tile(p[None], (2, 1)), 10)[0], o)
        for p, o in zip(bx_prompts, outs))
    check("serve-paged-greedy-bitexact", exact)

    # 2. host-sync delta (satellite: device-side token accumulation):
    #    the same static batch with and without a per-token np.asarray
    sync_prompt = np.tile(
        rng.integers(1, 96, size=16, dtype=np.int32)[None], (8, 1))
    srv.generate(sync_prompt, 32)                       # warm the shape
    t0 = time.perf_counter()
    srv.generate(sync_prompt, 32)
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    srv.generate(sync_prompt, 32, sync_per_token=True)
    t_persync = time.perf_counter() - t0
    host_sync_row = {
        "batched_s": round(t_batched, 3),
        "per_token_sync_s": round(t_persync, 3),
        "speedup": round(t_persync / max(t_batched, 1e-9), 3),
    }
    print(f"[serve] host-sync delta: batched {t_batched:.3f}s vs "
          f"per-token {t_persync:.3f}s "
          f"({host_sync_row['speedup']:.2f}x)")

    # 3. decode-plan simulated vs measured: steady-state full-batch
    #    decode throughput vs the IR plan's simulated per-token latency
    from repro.sim import DecodeModel, rank_decode_plans

    warm = [rng.integers(1, 96, size=8, dtype=np.int32) for _ in range(8)]
    eng.generate_batch(warm, 31)                        # warm decode path
    for p in warm:
        eng.submit(p, 31)
    eng._admit()
    t0 = time.perf_counter()
    steady = 0
    while not eng.idle:
        steady += eng.step()
    t_steady = time.perf_counter() - t0
    measured_tok = t_steady / max(steady, 1)
    dm = DecodeModel.for_config(cfg, dict(mesh.shape), batch=8)
    # k_cand=4: at this toy vocab (96) the default 16-candidate gather
    # would exceed the full-vocab payload and invert the ranking
    ranked = rank_decode_plans(dm, dict(mesh.shape), k_cand=4)
    sim_rows = {r["sampler"]: r["token_time"] for r in ranked}
    # batch-steps, not tokens: one decode step advances every slot
    sim_step = sim_rows["topk"] * 1e0
    decode_plan_row = {
        "simulated": {k: round(v, 9) for k, v in sim_rows.items()},
        "simulated_topk_step_s": sim_step,
        "measured_tokens": steady,
        "measured_per_token_s": round(measured_tok, 6),
        "measured_per_step_s": round(measured_tok * 8, 6),
        "measured_over_simulated": round(
            (measured_tok * 8) / max(sim_step, 1e-12), 1),
        "note": "simulated prices a v5e mesh; measured is CPU fake "
                "devices — the ratio is the clock gap, not an error",
    }
    check("serve-decode-plans-verify-and-rank",
          len(ranked) == 3 and sim_rows["topk"] < sim_rows["full"])

    # 4. the open-loop shootout (the headline rows)
    prompts, budgets, arrivals = mixed_workload(24, seed=3)
    run_static(srv, 8, prompts, budgets, arrivals)      # warm static shapes
    eng.generate_batch([p for p in prompts[:3]], 4)     # warm prefill buckets
    static_row = run_static(srv, 8, prompts, budgets, arrivals)
    cont_row = run_continuous(eng, prompts, budgets, arrivals)
    print(f"[serve] static:     {static_row}")
    print(f"[serve] continuous: {cont_row}")
    check("serve-continuous-beats-static-tokens-per-s",
          cont_row["tokens_per_s"] > static_row["tokens_per_s"])
    check("serve-continuous-beats-static-p99",
          cont_row["p99_latency_s"] < static_row["p99_latency_s"])

    from repro.obs import bench_metadata

    out = {
        "bench": "serve",
        "meta": bench_metadata(mesh_shape=dict(mesh.shape)),
        "workload": {"requests": len(prompts),
                     "prompt_lens": [8, 16, 32],
                     "budgets": [4, 8, 16],
                     "inter_arrival_s": 0.02,
                     "slots": 8, "block_size": 16, "chunk": 4},
        "rows": {
            "bitexact_greedy_vs_static": bool(exact),
            "host_sync_delta": host_sync_row,
            "decode_plan_sim_vs_measured": decode_plan_row,
            "open_loop": {"static": static_row, "continuous": cont_row},
        },
        "checks": {"failed": FAILURES,
                   "wall_s": round(time.time() - t_start, 2)},
    }
    with open("BENCH_serve.json", "w") as f:
        json.dump(out, f, indent=1)
    print("[bench] wrote BENCH_serve.json")
    if FAILURES:
        print(f"FAILED: {len(FAILURES)} check(s): {FAILURES}")
        return 1
    print("DONE")
    return 0


if __name__ == "__main__":
    sys.exit(main())
