"""Subprocess worker for the ring-vs-psum microbenchmark rows.

Runs under 8 fake CPU devices (jax fixes the device count at first init,
so the parent benchmark process — which must see the real single device —
spawns this).  Prints ``name,us`` CSV lines parsed by benchmarks/run.py.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings

warnings.filterwarnings("ignore")
import time

import repro  # noqa: F401  (jaxcompat shim before jax.sharding imports)
import jax
import jax.numpy as jnp
from jax.sharding import AxisType, PartitionSpec as P

from repro.kernels.collectives.ops import ring_allreduce


def _t(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def main():
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    shape = {"data": 8}
    for n in (1 << 16, 1 << 20):
        x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)

        def run(body):
            return jax.jit(lambda v: jax.shard_map(
                body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                check_vma=False)(v))

        psum = run(lambda v: jax.lax.psum(v, ("data",)))
        ring = run(lambda v: ring_allreduce(v, ("data",), shape))
        ring_uni = run(lambda v: ring_allreduce(
            v, ("data",), shape, bidirectional=False))
        kb = n * 4 >> 10
        print(f"allreduce_psum_{kb}kb,{_t(psum, x):.1f}")
        print(f"allreduce_ring_{kb}kb,{_t(ring, x):.1f}")
        print(f"allreduce_ring_uni_{kb}kb,{_t(ring_uni, x):.1f}")


if __name__ == "__main__":
    main()
