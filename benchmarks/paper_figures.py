"""Paper-figure reproductions (Figs 13–16): strategy comparison under a
calibrated cost model.

This container has one CPU and no network, so epoch times are produced by
an execution model of the three embedding strategies, driven by (a) the
paper's hardware (Minsky: P100 GPUs ~9.3 TF fp32, EDR InfiniBand ~12 GB/s
per node of 4 GPUs) and (b) per-model compute/param counts measured from
our implementations.  The model is the DAG semantics of §4:

  funnel : T = T_fwd + T_bwd + T_comm          (one collective at a time,
           issued by the main thread after each grad is ready — comm is
           fully exposed)
  concom : T = T_fwd + T_bwd + max(0, T_comm − overlap·T_bwd·(k−1)/k)
           (k communicators fly concurrently; overlap bounded by the
           backward compute available after the first bucket)
  depcha : T = T_fwd + max(T_bwd, T_comm) + t_bucket
           (per-layer push/offload: comm pipelines against the whole
           backward; exposed time is only the last bucket's tail)

Validation targets from the paper: DepCha ≥1.6× faster than Funnel on
ImageNet/Inception up to 128 GPUs (Fig 14); all strategies converge at
32 GPUs on CIFAR (Fig 13, comm-dominated); ~50 s/epoch at 256 GPUs on
ImageNet/ResNet-50 (Fig 16).
"""
from __future__ import annotations

import dataclasses

# paper-era hardware (per GPU / per node of 4 GPUs)
GPU_FLOPS = 9.3e12 * 0.45        # P100 fp32 at realistic efficiency
NODE_NIC_BW = 12.0e9             # EDR IB per node
GPUS_PER_NODE = 4
ALLREDUCE_EFF = 0.35        # 2017-era MPI (pre-NCCL inter-node)
FUNNEL_KEY_LATENCY = 15e-3   # main-thread WaitToRead+issue serialization


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    images: int                 # images / epoch
    flops_fwd: float            # per image, forward
    params: int                 # gradient elements (fp32)
    batch_per_gpu: int


# flops/params measured from our model implementations (fwd, per image)
RESNET50_CIFAR = Workload("resnet50-cifar", 50_000, 1.0e8, 25.6e6, 128)
RESNET50_IMAGENET = Workload("resnet50-imagenet", 1_281_167, 4.1e9,
                             25.6e6, 32)
INCEPTION_IMAGENET = Workload("inception-bn", 1_281_167, 2.0e9,
                              11.3e6, 64)


def step_times(w: Workload, n_gpus: int):
    t_fwd = w.flops_fwd * w.batch_per_gpu / GPU_FLOPS
    t_bwd = 2.0 * t_fwd
    # ring allreduce across nodes; 4 GPUs share a NIC
    nodes = max(n_gpus // GPUS_PER_NODE, 1)
    bw = NODE_NIC_BW / GPUS_PER_NODE * ALLREDUCE_EFF
    grad_bytes = w.params * 4
    t_comm = 2 * (n_gpus - 1) / max(n_gpus, 1) * grad_bytes / bw \
        if n_gpus > 1 else 0.0
    return t_fwd, t_bwd, t_comm


def epoch_time(w: Workload, n_gpus: int, strategy: str,
               channels: int = 4, n_buckets: int = 25) -> float:
    t_fwd, t_bwd, t_comm = step_times(w, n_gpus)
    if strategy == "funnel":
        t_step = t_fwd + t_bwd + t_comm + FUNNEL_KEY_LATENCY
    elif strategy == "concom":
        overlapped = min(t_comm, t_bwd * (channels - 1) / channels)
        t_step = t_fwd + t_bwd + (t_comm - overlapped) \
            + 0.1 * t_comm            # window barriers (Fig 8)
    elif strategy == "depcha":
        tail = t_comm / n_buckets
        t_step = t_fwd + max(t_bwd, t_comm) + tail
    else:
        raise ValueError(strategy)
    steps = w.images / (w.batch_per_gpu * n_gpus)
    return t_step * steps


def fig13():
    """CIFAR ResNet-50, 4..32 GPUs (paper Fig 13)."""
    rows = []
    for n in (4, 8, 16, 32):
        rows.append((n, *(epoch_time(RESNET50_CIFAR, n, s)
                          for s in ("funnel", "concom", "depcha"))))
    return rows


def fig14():
    """ImageNet Inception-BN, 16..128 GPUs (paper Fig 14)."""
    rows = []
    for n in (16, 32, 64, 128):
        rows.append((n, *(epoch_time(INCEPTION_IMAGENET, n, s)
                          for s in ("funnel", "concom", "depcha"))))
    return rows


def fig15():
    """ImageNet ResNet-50, 16..128 GPUs (paper Fig 15)."""
    rows = []
    for n in (16, 32, 64, 128):
        rows.append((n, *(epoch_time(RESNET50_IMAGENET, n, s)
                          for s in ("funnel", "concom", "depcha"))))
    return rows


def fig16():
    """Scaling ImageNet ResNet-50 to 256 GPUs, DepCha (paper Fig 16)."""
    return [(n, epoch_time(RESNET50_IMAGENET, n, "depcha"))
            for n in (32, 64, 128, 256)]


def validate() -> dict:
    """Check the paper's claims hold in our reproduction."""
    out = {}
    # claim 1: DepCha >= 1.6x over Funnel on Inception up to 128 GPUs
    speedups = [f / d for _, f, _, d in fig14()]
    out["inception_depcha_speedup_min"] = min(speedups)
    out["claim_1.6x"] = min(speedups) >= 1.6
    # claim 2: strategies converge on CIFAR at 32 GPUs (gap < @8 gap)
    r13 = {n: (f, c, d) for n, f, c, d in fig13()}
    gap8 = r13[8][0] / r13[8][2]
    gap32 = r13[32][0] / r13[32][2]
    out["cifar_gap_8"] = gap8
    out["cifar_gap_32"] = gap32
    out["claim_gap_shrinks"] = True if gap32 <= gap8 * 1.05 else False
    # claim 3: ~50 s/epoch at 256 GPUs
    t256 = fig16()[-1][1]
    out["imagenet_epoch_256"] = t256
    out["claim_50s"] = 30.0 <= t256 <= 90.0
    return out
